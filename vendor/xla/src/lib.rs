//! Compile-time stub of the `xla` (PJRT) Rust bindings.
//!
//! The real bindings wrap a native PJRT plugin and cannot be built in this
//! offline environment, so this crate reproduces exactly the API surface
//! `brgemm_dl::runtime` uses and fails *at runtime* on any operation that
//! would need the native library. The failure mode is deliberate:
//! * client construction **succeeds** (so manifest handling, caching and
//!   error-path tests run against the real `Runtime` type), and
//! * `HloModuleProto::from_text_file` / `compile` / `execute` return
//!   errors mentioning the stub, which the callers surface as ordinary
//!   artifact-loading failures.
//!
//! Swapping in the real bindings is a Cargo.toml change only.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `{e:?}` formatting and
/// `?`-conversion into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{}: XLA PJRT bindings are stubbed in this build (no native XLA available)",
        what
    ))
}

/// Element types crossing the runtime boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    /// Anything the stub does not model.
    Unsupported,
}

/// Marker for element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn from_f64(v: f64) -> i32 {
        v as i32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// Host-side literal: flat f64 storage + shape, enough to round-trip the
/// typed views the runtime uses.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
    ty: ElementType,
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl Literal {
    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: v.iter().map(|x| x.to_f64()).collect(),
            dims: vec![v.len() as i64],
            ty: T::element_type(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), ty: self.ty })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::element_type() != self.ty {
            return Err(Error(format!(
                "to_vec: literal is {:?}, asked for {:?}",
                self.ty,
                T::element_type()
            )));
        }
        Ok(self.data.iter().map(|&v| T::from_f64(v)).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(stub_err("Literal::to_tuple"))
    }
}

/// Parsed HLO module. The stub validates that the file exists and is
/// readable, then refuses to parse (parsing needs the native library).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Err(e) => Err(Error(format!("reading {}: {}", path, e))),
            Ok(_) => Err(stub_err("HloModuleProto::from_text_file")),
        }
    }
}

/// An XLA computation (opaque).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (never actually produced by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (opaque).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client. Construction succeeds so the surrounding runtime (manifest
/// loading, executable cache, error paths) stays exercisable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (stub — native XLA unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_typed_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = l.reshape(&[2, 3]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err(), "type mismatch must error");
        assert!(l.reshape(&[7]).is_err(), "bad element count must error");
    }

    #[test]
    fn stubbed_operations_error_not_panic() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
