//! In-tree subset of the `anyhow` crate API (the execution environment has
//! no crates.io access, so the few surfaces this repo uses are reproduced
//! here): [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros and the [`Context`] extension trait.
//!
//! Semantics follow upstream where it matters to callers:
//! * `Error` is built from any `std::error::Error + Send + Sync + 'static`
//!   via `From` (so `?` converts automatically) and records the source
//!   chain as strings.
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole chain joined with `": "` — what `main.rs` relies on
//!   for one-line error reports.
//! * `.context(..)` / `.with_context(..)` push an outer message.

use std::fmt;

/// A string-chained error: `chain[0]` is the outermost (most recent)
/// message, later entries are the causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Push an outer context message (used by the [`Context`] trait).
    pub fn wrap<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The `cause` chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {}: {}", i, c)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{}", e), "missing thing");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{}", e), "reading config");
        assert_eq!(format!("{:#}", e), "reading config: missing thing");
        let dbg = format!("{:?}", e);
        assert!(dbg.contains("Caused by"), "{}", dbg);
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(format!("{}", e), "bad value 42");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {}", x);
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{}", e), "nothing here");
    }
}
