"""Pure-jnp correctness oracles for the L1 Pallas kernel and L2 models.

No Pallas, no blocking — straight dense algebra. Every kernel/model test
asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    "identity": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


def brgemm_ref(a, b, c=None, *, alpha=1.0, beta=0.0, bias=None, activation="identity"):
    """Oracle for kernels.brgemm: beta*C + alpha*sum_i a[i]@b[i] (+epilogue)."""
    acc = jnp.einsum("imk,ikn->mn", a, b)
    out = alpha * acc
    if c is not None and beta != 0.0:
        out = out + beta * c
    if bias is not None:
        out = out + bias
    return ACTIVATIONS[activation](out)


def fc_ref(x, w, bias=None, activation="identity"):
    """Oracle for blocked_matmul: act(x @ w + bias)."""
    y = x @ w
    if bias is not None:
        y = y + bias
    return ACTIVATIONS[activation](y)


def lstm_step_ref(x_t, h_prev, s_prev, wr, bias):
    """One LSTM step. ``wr``: [C+K, 4K] stacked input+recurrent weights
    (gate order i, g, f, o); ``bias``: [4K]."""
    k = h_prev.shape[-1]
    z = jnp.concatenate([x_t, h_prev], axis=-1) @ wr + bias
    i = jax.nn.sigmoid(z[:, :k])
    g = jnp.tanh(z[:, k : 2 * k])
    f = jax.nn.sigmoid(z[:, 2 * k : 3 * k])
    o = jax.nn.sigmoid(z[:, 3 * k :])
    s_t = f * s_prev + i * g
    h_t = o * jnp.tanh(s_t)
    return h_t, s_t


def lstm_ref(x, wr, bias, h0=None, s0=None):
    """Full sequence LSTM: x [T, N, C] -> h [T, N, K]."""
    t, n, _ = x.shape
    k = wr.shape[1] // 4
    h = jnp.zeros((n, k), x.dtype) if h0 is None else h0
    s = jnp.zeros((n, k), x.dtype) if s0 is None else s0

    def step(carry, x_t):
        h, s = carry
        h, s = lstm_step_ref(x_t, h, s, wr, bias)
        return (h, s), h

    (_, _), hs = jax.lax.scan(step, (h, s), x)
    return hs


def conv2d_ref(x, w, stride=1, pad=0):
    """NHWC conv oracle via lax.conv_general_dilated.

    x: [N, H, W, C]; w: [R, S, C, K].
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
