"""L1: the batch-reduce GEMM kernel as a Pallas kernel.

This is the paper's single building block expressed for a tensor-compiler
backend (the role TVM plays in the paper's §4.3; here the compiler is
XLA and the kernel language is Pallas):

    C = beta * C + alpha * sum_i A_i @ B_i       (+ bias, activation)

TPU translation of the paper's register-blocking story (DESIGN.md
§Hardware-Adaptation):

* the paper pins an ``m_b x n_b`` accumulator tile in vector registers for
  the whole batch-reduce chain; here the accumulator is a VMEM scratch
  block that lives across the batch grid dimension,
* the paper's pointer arrays (A_ptrs/B_ptrs) become BlockSpec index maps
  over a leading batch axis,
* the paper's FMA outer products become MXU ``jnp.dot`` calls on
  ``(block_m, K) x (K, block_n)`` tiles,
* the fused epilogue (bias + activation applied while the block is hot)
  becomes the final-step store transform.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls, so the kernel is lowered to plain HLO (the numerics are
identical; TPU performance is estimated analytically in DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS: dict[str, Callable] = {
    "identity": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


def _pick_block(dim: int, pref: int) -> int:
    b = min(pref, dim)
    while dim % b != 0:
        b -= 1
    return b


def brgemm(
    a: jax.Array,
    b: jax.Array,
    c: jax.Array | None = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: jax.Array | None = None,
    activation: str = "identity",
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Batch-reduce GEMM: ``beta*C + alpha * sum_i a[i] @ b[i]``.

    Args:
      a: ``[BATCH, M, K]`` stack of A blocks.
      b: ``[BATCH, K, N]`` stack of B blocks.
      c: optional ``[M, N]`` accumulator input (required if ``beta != 0``).
      bias: optional ``[N]`` vector added before ``activation`` (the fused
        epilogue of the DL primitives).
      activation: one of ``identity|relu|sigmoid|tanh``.
      block_m/block_n: output register-tile block shape; defaults target
        MXU-friendly ``(128, 128)`` clamped to divisors of M/N.

    Returns: ``[M, N]`` output block (single accumulator — the defining
    difference from batched GEMM).
    """
    assert a.ndim == 3 and b.ndim == 3, (a.shape, b.shape)
    batch, m, k = a.shape
    batch_b, k_b, n = b.shape
    assert batch == batch_b and k == k_b, (a.shape, b.shape)
    if beta != 0.0:
        assert c is not None, "beta != 0 requires a C input"
    if c is None:
        c = jnp.zeros((m, n), a.dtype)
    if bias is None:
        bias_arr = jnp.zeros((n,), a.dtype)
    else:
        bias_arr = bias.astype(a.dtype)
        assert bias_arr.shape == (n,)
    act = ACTIVATIONS[activation]

    bm = block_m or _pick_block(m, 128)
    bn = block_n or _pick_block(n, 128)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)

    def kernel(a_ref, b_ref, c_ref, bias_ref, o_ref, acc_ref):
        # Load the accumulator tile once per output block (Algorithm 1 l.3).
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # Batch-reduce accumulation chain (Algorithm 1 l.4-7) on the MXU.
        acc_ref[...] += jnp.dot(
            a_ref[0], b_ref[0], preferred_element_type=jnp.float32
        )

        # Single store after the full chain, with the fused epilogue
        # applied while the tile is VMEM-hot (Algorithm 1 l.8).
        @pl.when(pl.program_id(2) == batch - 1)
        def _store():
            out = beta * c_ref[...].astype(jnp.float32) + alpha * acc_ref[...]
            out = out + bias_ref[...].astype(jnp.float32)
            o_ref[...] = act(out).astype(o_ref.dtype)

    grid = (m // bm, n // bn, batch)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k), lambda i, j, t: (t, i, 0)),
            pl.BlockSpec((1, k, bn), lambda i, j, t: (t, 0, j)),
            pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
            pl.BlockSpec((bn,), lambda i, j, t: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, c, bias_arr)


# ---------------------------------------------------------------------------
# Differentiable linear BRGEMM (custom VJP): the backward pass is itself
# expressed with the same building block, mirroring the paper's claim that
# bwd/upd kernels reuse BRGEMM.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def brgemm_linear(a, b, c, block_m=None, block_n=None):
    """Differentiable ``C + sum_i a[i] @ b[i]`` through the Pallas kernel."""
    return brgemm(a, b, c, beta=1.0, block_m=block_m, block_n=block_n)


def _brgemm_fwd(a, b, c, block_m, block_n):
    return brgemm_linear(a, b, c, block_m, block_n), (a, b)


def _brgemm_bwd(block_m, block_n, res, dy):
    a, b = res
    # dA_i = dY @ B_iᵀ and dB_i = A_iᵀ @ dY: per-pair products (no cross-i
    # reduction), i.e. BRGEMM calls of batch length 1 — run through the
    # same kernel, one grid instance per pair.
    da = jax.vmap(lambda bi: brgemm(dy[None], jnp.swapaxes(bi, 0, 1)[None]))(b)
    db = jax.vmap(lambda ai: brgemm(jnp.swapaxes(ai, 0, 1)[None], dy[None]))(a)
    return da, db, dy


brgemm_linear.defvjp(_brgemm_fwd, _brgemm_bwd)


def blocked_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_c: int = 128,
    bias: jax.Array | None = None,
    activation: str = "identity",
    block_m: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """``act(x @ w + bias)`` with the K dimension fed as a BRGEMM batch.

    Splits the contraction dim C into ``C/block_c`` blocks (the paper's
    ``Cb`` loop brought into the kernel's batch) — the FC/LSTM formulation
    of Algorithms 2/5 at the JAX level.
    """
    m, c = x.shape
    c2, n = w.shape
    assert c == c2
    bc = _pick_block(c, block_c)
    cb = c // bc
    a = jnp.swapaxes(x.reshape(m, cb, bc), 0, 1)  # [Cb, M, bc]
    b = w.reshape(cb, bc, n)  # [Cb, bc, N]
    return brgemm(
        a, b, bias=bias, activation=activation, block_m=block_m, block_n=block_n
    )


def blocked_matmul_linear(x: jax.Array, w: jax.Array, *, block_c: int = 128) -> jax.Array:
    """Differentiable ``x @ w`` through :func:`brgemm_linear`."""
    m, c = x.shape
    _, n = w.shape
    bc = _pick_block(c, block_c)
    cb = c // bc
    a = jnp.swapaxes(x.reshape(m, cb, bc), 0, 1)
    b = w.reshape(cb, bc, n)
    return brgemm_linear(a, b, jnp.zeros((m, n), x.dtype))
