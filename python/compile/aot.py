"""AOT pipeline: lower the L2 models to HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and executes via PJRT. HLO
text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME] [--list]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.models import cnn, lstm, mlp

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Entry definitions
# ---------------------------------------------------------------------------


class Entry:
    def __init__(self, name, fn, in_specs, flops, desc, outputs_desc=""):
        self.name = name
        self.fn = fn
        self.in_specs = in_specs
        self.flops = float(flops)
        self.desc = desc
        self.outputs_desc = outputs_desc


def _mlp_sizes():
    return [256, 512, 512, 10]


def _mlp_fwd_entry():
    sizes = _mlp_sizes()
    n = 64

    def fn(*flat):
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(sizes) - 1)]
        x = flat[-1]
        return (mlp.forward(params, x, block_c=128),)

    in_specs = []
    for c, k in zip(sizes[:-1], sizes[1:]):
        in_specs += [spec((c, k)), spec((k,))]
    in_specs.append(spec((n, sizes[0])))
    flops = 2.0 * n * sum(c * k for c, k in zip(sizes[:-1], sizes[1:]))
    return Entry(
        "mlp_fwd",
        fn,
        in_specs,
        flops,
        f"MLP forward {sizes}, batch {n}, BRGEMM FC layers (Alg. 5)",
        "(logits[N,10],)",
    )


def _mlp_train_step_entry():
    sizes = _mlp_sizes()
    n = 64
    lr = 0.05

    def fn(*flat):
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(sizes) - 1)]
        x, labels = flat[-2], flat[-1]
        new_params, loss = mlp.train_step(params, x, labels, lr, block_c=128)
        out = []
        for w, b in new_params:
            out += [w, b]
        out.append(loss)
        return tuple(out)

    in_specs = []
    for c, k in zip(sizes[:-1], sizes[1:]):
        in_specs += [spec((c, k)), spec((k,))]
    in_specs += [spec((n, sizes[0])), spec((n,), I32)]
    # fwd + bwd + upd ≈ 3x fwd flops
    flops = 6.0 * n * sum(c * k for c, k in zip(sizes[:-1], sizes[1:]))
    return Entry(
        "mlp_train_step",
        fn,
        in_specs,
        flops,
        f"One SGD step (softmax-CE) of MLP {sizes}, batch {n}, lr {lr}; "
        "backward through the BRGEMM custom VJP",
        "(w1,b1,w2,b2,w3,b3,loss)",
    )


def _lstm_entries():
    t, n, c, k = 8, 16, 64, 64
    flops = 2.0 * 4 * t * n * k * (c + k)

    def fwd(x, wr, bias):
        return (lstm.lstm_forward(x, wr, bias, block_f=64),)

    def fwd_large(x, wr, bias):
        return (lstm.lstm_forward_large_gemm(x, wr, bias),)

    specs = [spec((t, n, c)), spec((c + k, 4 * k)), spec((4 * k,))]
    return [
        Entry(
            "lstm_fwd",
            fwd,
            specs,
            flops,
            f"LSTM forward T={t} N={n} C=K={k}, fused BRGEMM cell (Alg. 2)",
            "(h[T,N,K],)",
        ),
        Entry(
            "lstm_fwd_large_gemm",
            fwd_large,
            specs,
            flops,
            "Baseline LSTM cell: large stacked GEMM per step (§3.1.1)",
            "(h[T,N,K],)",
        ),
    ]


def _gnmt_encoder_entry():
    t, n, k, layers = 8, 8, 128, 2
    flops = 2.0 * 4 * t * n * k * (k + k) * layers

    def fn(x, wr1, b1, wr2, b2):
        return (lstm.gnmt_encoder(x, [(wr1, b1), (wr2, b2)], block_f=64),)

    specs = [
        spec((t, n, k)),
        spec((2 * k, 4 * k)),
        spec((4 * k,)),
        spec((2 * k, 4 * k)),
        spec((4 * k,)),
    ]
    return Entry(
        "gnmt_encoder_2l",
        fn,
        specs,
        flops,
        f"2-layer GNMT-style LSTM encoder, T={t} N={n} K={k} (BRGEMM cells)",
        "(h[T,N,K],)",
    )


# Scaled Fig-11 inference layers (N=1): (name, H, C, K, R, stride, pad)
FIG11_LAYERS = [
    ("l28_64_64_r3", 28, 64, 64, 3, 1, 1),
    ("l28_64_128_r1", 28, 64, 128, 1, 1, 0),
    ("l14_128_128_r3", 14, 128, 128, 3, 1, 1),
]


def _conv_entries():
    out = []
    for name, h, c, k, r, stride, pad in FIG11_LAYERS:
        p = (h + 2 * pad - r) // stride + 1
        flops = 2.0 * 1 * k * c * r * r * p * p
        x_spec = spec((1, h, h, c))
        w_spec = spec((r, r, c, k))

        def mk(fn_impl, stride=stride, pad=pad):
            def fn(x, w):
                return (fn_impl(x, w, stride=stride, pad=pad),)

            return fn

        out.append(
            Entry(
                f"conv_brgemm_{name}",
                mk(functools.partial(cnn.conv2d_brgemm, block_c=64)),
                [x_spec, w_spec],
                flops,
                f"Direct conv via Pallas BRGEMM (Alg. 4), {name}, N=1 inference",
                "(y,)",
            )
        )
        out.append(
            Entry(
                f"conv_xla_{name}",
                mk(cnn.conv2d_xla),
                [x_spec, w_spec],
                flops,
                f"XLA native conv (vendor-library analogue), {name}",
                "(y,)",
            )
        )
        out.append(
            Entry(
                f"conv_im2col_{name}",
                mk(cnn.conv2d_im2col),
                [x_spec, w_spec],
                flops,
                f"im2col + large GEMM baseline (Fig. 1 yellow), {name}",
                "(y,)",
            )
        )
    return out


def _resnet_block_entry():
    h, cin, cmid = 14, 64, 32

    def fn(x, w1, w2, w3):
        return (cnn.resnet_block_brgemm(x, w1, w2, w3),)

    flops = 2.0 * h * h * (cin * cmid + 9 * cmid * cmid + cmid * cin)
    return Entry(
        "resnet_block",
        fn,
        [
            spec((1, h, h, cin)),
            spec((1, 1, cin, cmid)),
            spec((3, 3, cmid, cmid)),
            spec((1, 1, cmid, cin)),
        ],
        flops,
        "ResNet bottleneck block (1x1-3x3-1x1 + skip) via BRGEMM convs",
        "(y,)",
    )


def _brgemm_demo_entry():
    batch, m, k, n = 4, 8, 32, 64

    def fn(a, b):
        from compile.kernels.brgemm import brgemm

        return (brgemm(a, b, block_m=8, block_n=64),)

    return Entry(
        "brgemm_demo",
        fn,
        [spec((batch, m, k)), spec((batch, k, n))],
        2.0 * batch * m * k * n,
        "Standalone batch-reduce GEMM kernel (quickstart/integration test)",
        "(c[M,N],)",
    )


def entries() -> list[Entry]:
    return [
        _brgemm_demo_entry(),
        _mlp_fwd_entry(),
        _mlp_train_step_entry(),
        *_lstm_entries(),
        _gnmt_encoder_entry(),
        *_conv_entries(),
        _resnet_block_entry(),
    ]


def build(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "entries": []}
    for e in entries():
        if only and e.name != only:
            continue
        print(f"lowering {e.name} ...", flush=True)
        lowered = jax.jit(e.fn).lower(*e.in_specs)
        text = to_hlo_text(lowered)
        fname = f"{e.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        manifest["entries"].append(
            {
                "name": e.name,
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in e.in_specs
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)}
                    for o in jax.tree_util.tree_leaves(out_avals)
                ],
                "flops": e.flops,
                "desc": e.desc,
                "outputs_desc": e.outputs_desc,
            }
        )
        print(f"  -> {fname} ({len(text)} chars)")
    if not only:
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        print(f"wrote manifest with {len(manifest['entries'])} entries")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single entry")
    ap.add_argument("--list", action="store_true", help="list entries and exit")
    args = ap.parse_args()
    if args.list:
        for e in entries():
            print(f"{e.name:28s} {e.flops / 1e6:10.1f} MFLOP  {e.desc}")
        return
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
