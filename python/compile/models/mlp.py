"""MLP / fully-connected layers via the BRGEMM kernel (paper §3.3).

Includes the forward model, a softmax-cross-entropy training step (SGD)
whose backward pass flows through the kernel's custom VJP, and the
coarse-grained large-GEMM baseline of §3.3.1 for the compiled-path
comparison benches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import brgemm as kern


def init_params(rng_key, sizes):
    """Glorot-ish init for layer sizes [d0, d1, ..., dL]."""
    params = []
    keys = jax.random.split(rng_key, len(sizes) - 1)
    for key, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        scale = jnp.sqrt(2.0 / fan_in)
        w = scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)
        b = jnp.zeros((fan_out,), jnp.float32)
        params.append((w, b))
    return params


def forward(params, x, *, block_c: int = 128):
    """Inference forward pass: ReLU hidden layers, linear head.

    Every matmul is one BRGEMM call with the contraction dimension fed as
    the reduce batch and the bias+ReLU fused into the kernel epilogue.
    """
    h = x
    for w, b in params[:-1]:
        h = kern.blocked_matmul(h, w, bias=b, activation="relu", block_c=block_c)
    w, b = params[-1]
    return kern.blocked_matmul(h, w, bias=b, activation="identity", block_c=block_c)


def forward_diff(params, x, *, block_c: int = 128):
    """Differentiable forward (custom-VJP BRGEMM + jnp epilogues)."""
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(kern.blocked_matmul_linear(h, w, block_c=block_c) + b)
    w, b = params[-1]
    return kern.blocked_matmul_linear(h, w, block_c=block_c) + b


def forward_large_gemm(params, x):
    """Baseline (§3.3.1): plain jnp matmuls — coarse-grained library GEMMs
    with the element-wise stages exposed to the compiler's mercy."""
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return h @ w + b


def loss_fn(params, x, labels, *, block_c: int = 128):
    """Mean softmax cross entropy over integer labels."""
    logits = forward_diff(params, x, block_c=block_c)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def train_step(params, x, labels, lr: float, *, block_c: int = 128):
    """One SGD step; returns (new_params, loss). The whole step — forward,
    backward through the BRGEMM custom VJP, and the update — lowers to a
    single HLO module for the Rust runtime."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, labels, block_c=block_c)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_params, loss
