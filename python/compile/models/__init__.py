"""L2: JAX models formulated as loops around the L1 Pallas BRGEMM kernel.

Build-time only — these lower to HLO text via ``compile.aot`` and are
executed from the Rust runtime; Python never runs on the request path.
"""
