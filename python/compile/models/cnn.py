"""Direct convolution via the BRGEMM kernel (paper §3.2, Algorithm 4 at the
tensor-compiler level).

The BRGEMM batch enumerates ``(r, s, cb)`` exactly as Algorithm 4 lines
9-13: for each filter tap a *strided view* of the padded input (no im2col
materialisation into CRS-major) and the corresponding packed weight block
are pushed onto the batch; one kernel call reduces all of them into the
output block. This is the paper's pointer-array gather expressed with XLA
slices + the Pallas leading batch axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import brgemm as kern


def conv2d_brgemm(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 0,
    block_c: int = 64,
    activation: str = "identity",
    bias: jax.Array | None = None,
) -> jax.Array:
    """NHWC direct convolution. x: [N,H,W,C], w: [R,S,C,K] -> [N,P,Q,K]."""
    n, h, wd, c = x.shape
    r, s, c2, k = w.shape
    assert c == c2
    bc = min(block_c, c)
    while c % bc != 0:
        bc -= 1
    cb = c // bc
    p = (h + 2 * pad - r) // stride + 1
    q = (wd + 2 * pad - s) // stride + 1

    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))

    # Batch = (r, s, cb) taps: strided input views + packed weight blocks.
    a_blocks = []
    b_blocks = []
    for rr in range(r):
        for ss in range(s):
            # [N, P, Q, C] view of the tap (rr, ss)
            tap = jax.lax.slice(
                xp,
                (0, rr, ss, 0),
                (n, rr + (p - 1) * stride + 1, ss + (q - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            tap = tap.reshape(n * p * q, cb, bc)
            for icb in range(cb):
                a_blocks.append(tap[:, icb, :])
                b_blocks.append(w[rr, ss, icb * bc : (icb + 1) * bc, :])
    a = jnp.stack(a_blocks)  # [R*S*Cb, N*P*Q, bc]
    b = jnp.stack(b_blocks)  # [R*S*Cb, bc, K]
    y = kern.brgemm(a, b, bias=bias, activation=activation)
    return y.reshape(n, p, q, k)


def conv2d_im2col(x, w, *, stride: int = 1, pad: int = 0):
    """Baseline: explicit im2col + one large GEMM (Figure 1 yellow line)."""
    n, h, wd, c = x.shape
    r, s, _, k = w.shape
    p = (h + 2 * pad - r) // stride + 1
    q = (wd + 2 * pad - s) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = []
    for rr in range(r):
        for ss in range(s):
            tap = jax.lax.slice(
                xp,
                (0, rr, ss, 0),
                (n, rr + (p - 1) * stride + 1, ss + (q - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(tap.reshape(n * p * q, c))
    col = jnp.concatenate(cols, axis=1)  # [N*P*Q, R*S*C]
    wf = w.reshape(r * s * c, k)
    return (col @ wf).reshape(n, p, q, k)


def conv2d_xla(x, w, *, stride: int = 1, pad: int = 0):
    """Vendor-analogue baseline: XLA's native convolution (the black-box
    "library" conv the paper compares against as MKL-DNN)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def resnet_block_brgemm(x, w1, w2, w3, *, stride: int = 1):
    """A ResNet bottleneck (1x1 -> 3x3 -> 1x1 + skip) built from the BRGEMM
    convolution — the composable model-definition path used by the e2e
    CNN inference artifact."""
    y = conv2d_brgemm(x, w1, stride=1, activation="relu")
    y = conv2d_brgemm(y, w2, stride=stride, pad=1, activation="relu")
    y = conv2d_brgemm(y, w3, stride=1)
    if stride == 1 and x.shape[-1] == y.shape[-1]:
        y = y + x
    return jax.nn.relu(y)
