"""LSTM cell via the BRGEMM kernel (paper §3.1, Algorithm 2 at the
tensor-compiler level).

Per time-step, the four gate pre-activations are computed by a *single*
BRGEMM call whose reduce batch spans both the input-feature blocks of
``W·x_t`` and the hidden-feature blocks of ``R·h_{t-1}`` — the paper's two
back-to-back batch-reduce calls (Algorithm 2 lines 9-16) merged into one
accumulation chain over the stacked ``[x_t; h_{t-1}]`` blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import brgemm as kern


def init_params(rng_key, c: int, k: int):
    """Stacked weights ``wr: [C+K, 4K]`` (gates i,g,f,o) + bias ``[4K]``.

    The forget-gate bias is initialised to 1 (standard practice)."""
    kw, kr = jax.random.split(rng_key)
    w = jax.random.normal(kw, (c, 4 * k), jnp.float32) / jnp.sqrt(c)
    r = jax.random.normal(kr, (k, 4 * k), jnp.float32) / jnp.sqrt(k)
    wr = jnp.concatenate([w, r], axis=0)
    bias = jnp.zeros((4 * k,), jnp.float32).at[2 * k : 3 * k].set(1.0)
    return wr, bias


def _gates(z, k):
    i = jax.nn.sigmoid(z[:, :k])
    g = jnp.tanh(z[:, k : 2 * k])
    f = jax.nn.sigmoid(z[:, 2 * k : 3 * k])
    o = jax.nn.sigmoid(z[:, 3 * k :])
    return i, g, f, o


def lstm_forward(x, wr, bias, h0=None, s0=None, *, block_f: int = 64):
    """Sequence forward: ``x [T, N, C] -> h [T, N, K]``.

    ``block_f`` is the feature-block size (the paper's ``b_c``/``b_k``);
    it must divide both C and K so the stacked blocks are uniform.
    """
    t, n, c = x.shape
    k = wr.shape[1] // 4
    assert c % block_f == 0 and k % block_f == 0, (c, k, block_f)
    fb = (c + k) // block_f
    # Pre-block the stacked weights once: [Fb, bf, 4K] — the blocked
    # weight layout of §3.1.2, amortised across all time-steps.
    wr_blocks = wr.reshape(fb, block_f, 4 * k)

    h = jnp.zeros((n, k), x.dtype) if h0 is None else h0
    s = jnp.zeros((n, k), x.dtype) if s0 is None else s0

    def step(carry, x_t):
        h, s = carry
        # Stack [x_t; h] feature blocks as the BRGEMM batch: [Fb, N, bf].
        xh = jnp.concatenate([x_t, h], axis=1)
        a = jnp.swapaxes(xh.reshape(n, fb, block_f), 0, 1)
        z = kern.brgemm(a, wr_blocks, bias=bias)
        i, g, f, o = _gates(z, k)
        s_t = f * s + i * g
        h_t = o * jnp.tanh(s_t)
        return (h_t, s_t), h_t

    (_, _), hs = jax.lax.scan(step, (h, s), x)
    return hs


def lstm_forward_large_gemm(x, wr, bias, h0=None, s0=None):
    """Baseline (§3.1.1): one large GEMM per step on the stacked weights,
    with the element-wise stages applied to the cold full-size Z tensor."""
    t, n, c = x.shape
    k = wr.shape[1] // 4
    h = jnp.zeros((n, k), x.dtype) if h0 is None else h0
    s = jnp.zeros((n, k), x.dtype) if s0 is None else s0

    def step(carry, x_t):
        h, s = carry
        z = jnp.concatenate([x_t, h], axis=1) @ wr + bias
        i, g, f, o = _gates(z, k)
        s_t = f * s + i * g
        h_t = o * jnp.tanh(s_t)
        return (h_t, s_t), h_t

    (_, _), hs = jax.lax.scan(step, (h, s), x)
    return hs


def gnmt_encoder(x, layers, *, block_f: int = 64):
    """A GNMT-style stacked LSTM encoder: ``layers`` is a list of
    (wr, bias) tuples; layer i consumes layer i-1's output sequence."""
    h = x
    for wr, bias in layers:
        h = lstm_forward(h, wr, bias, block_f=block_f)
    return h
