"""L2 model tests: BRGEMM-formulated models vs pure-jnp / lax references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.models import cnn, lstm, mlp

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestMlp:
    def test_forward_matches_reference(self):
        params = mlp.init_params(jax.random.PRNGKey(0), [64, 32, 16])
        x = rand(jax.random.PRNGKey(1), (8, 64))
        got = mlp.forward(params, x, block_c=32)
        want = mlp.forward_large_gemm(params, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_forward_diff_matches(self):
        params = mlp.init_params(jax.random.PRNGKey(2), [32, 32, 8])
        x = rand(jax.random.PRNGKey(3), (4, 32))
        got = mlp.forward_diff(params, x, block_c=16)
        want = mlp.forward_large_gemm(params, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_train_step_decreases_loss(self):
        params = mlp.init_params(jax.random.PRNGKey(4), [16, 32, 4])
        kx, kl = keys(5, 2)
        x = rand(kx, (16, 16))
        labels = jax.random.randint(kl, (16,), 0, 4)
        step = jax.jit(lambda p, x, l: mlp.train_step(p, x, l, 0.5, block_c=16))
        loss0 = mlp.loss_fn(params, x, labels, block_c=16)
        p = params
        for _ in range(5):
            p, loss = step(p, x, labels)
        assert loss < loss0, (loss, loss0)

    def test_train_step_grads_match_large_gemm(self):
        params = mlp.init_params(jax.random.PRNGKey(6), [16, 16, 4])
        kx, kl = keys(7, 2)
        x = rand(kx, (8, 16))
        labels = jax.random.randint(kl, (8,), 0, 4)

        def loss_ref(params):
            logits = mlp.forward_large_gemm(params, x)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()

        g_kern = jax.grad(lambda p: mlp.loss_fn(p, x, labels, block_c=16))(params)
        g_ref = jax.grad(loss_ref)(params)
        for (gw1, gb1), (gw2, gb2) in zip(g_kern, g_ref):
            np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(gb1, gb2, rtol=1e-4, atol=1e-4)


class TestLstm:
    def test_forward_matches_reference(self):
        c, k, t, n = 32, 32, 4, 6
        wr, bias = lstm.init_params(jax.random.PRNGKey(0), c, k)
        x = rand(jax.random.PRNGKey(1), (t, n, c))
        got = lstm.lstm_forward(x, wr, bias, block_f=16)
        want = ref.lstm_ref(x, wr, bias)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_forward_with_initial_state(self):
        c, k, t, n = 16, 16, 3, 4
        wr, bias = lstm.init_params(jax.random.PRNGKey(2), c, k)
        kx, kh, ks = keys(3, 3)
        x = rand(kx, (t, n, c))
        h0 = rand(kh, (n, k), -0.5, 0.5)
        s0 = rand(ks, (n, k), -0.5, 0.5)
        got = lstm.lstm_forward(x, wr, bias, h0, s0, block_f=16)
        want = ref.lstm_ref(x, wr, bias, h0, s0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_large_gemm_baseline_matches(self):
        c, k, t, n = 16, 32, 5, 3
        wr, bias = lstm.init_params(jax.random.PRNGKey(4), c, k)
        x = rand(jax.random.PRNGKey(5), (t, n, c))
        got = lstm.lstm_forward_large_gemm(x, wr, bias)
        want = ref.lstm_ref(x, wr, bias)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_stacked_encoder_shapes_and_values(self):
        c = k = 16
        t, n = 3, 2
        layers = [lstm.init_params(jax.random.PRNGKey(i), c, k) for i in range(2)]
        x = rand(jax.random.PRNGKey(9), (t, n, c))
        got = lstm.gnmt_encoder(x, layers, block_f=16)
        want = x
        for wr, bias in layers:
            want = ref.lstm_ref(want, wr, bias)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestCnn:
    @pytest.mark.parametrize(
        "n,h,w,c,k,r,stride,pad",
        [
            (1, 6, 6, 8, 16, 3, 1, 1),
            (2, 8, 8, 4, 8, 1, 1, 0),
            (1, 8, 8, 8, 8, 1, 2, 0),
            (1, 9, 9, 4, 4, 3, 2, 1),
        ],
    )
    def test_conv_brgemm_matches_lax(self, n, h, w, c, k, r, stride, pad):
        kx, kw = keys(n * h + c, 2)
        x = rand(kx, (n, h, w, c))
        wt = rand(kw, (r, r, c, k), -0.5, 0.5)
        got = cnn.conv2d_brgemm(x, wt, stride=stride, pad=pad, block_c=4)
        want = ref.conv2d_ref(x, wt, stride=stride, pad=pad)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_conv_fused_bias_relu(self):
        kx, kw, kb = keys(11, 3)
        x = rand(kx, (1, 5, 5, 4))
        wt = rand(kw, (3, 3, 4, 8), -0.5, 0.5)
        bias = rand(kb, (8,))
        got = cnn.conv2d_brgemm(x, wt, pad=1, bias=bias, activation="relu")
        want = jax.nn.relu(ref.conv2d_ref(x, wt, pad=1) + bias)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_im2col_baseline_matches_lax(self):
        kx, kw = keys(12, 2)
        x = rand(kx, (2, 6, 6, 4))
        wt = rand(kw, (3, 3, 4, 8), -0.5, 0.5)
        got = cnn.conv2d_im2col(x, wt, stride=1, pad=1)
        want = ref.conv2d_ref(x, wt, stride=1, pad=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_resnet_block(self):
        kx, k1, k2, k3 = keys(13, 4)
        cin, cmid = 8, 4
        x = rand(kx, (1, 6, 6, cin))
        w1 = rand(k1, (1, 1, cin, cmid), -0.5, 0.5)
        w2 = rand(k2, (3, 3, cmid, cmid), -0.5, 0.5)
        w3 = rand(k3, (1, 1, cmid, cin), -0.5, 0.5)
        y = cnn.resnet_block_brgemm(x, w1, w2, w3)
        # reference chain
        t = jax.nn.relu(ref.conv2d_ref(x, w1))
        t = jax.nn.relu(ref.conv2d_ref(t, w2, pad=1))
        t = ref.conv2d_ref(t, w3)
        want = jax.nn.relu(t + x)
        np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([4, 8]),
    k=st.sampled_from([4, 8, 16]),
    r=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_hypothesis(c, k, r, stride, seed):
    pad = 1 if r == 3 else 0
    k1, k2 = keys(seed, 2)
    x = rand(k1, (1, 8, 8, c))
    wt = rand(k2, (r, r, c, k), -0.5, 0.5)
    got = cnn.conv2d_brgemm(x, wt, stride=stride, pad=pad, block_c=4)
    want = ref.conv2d_ref(x, wt, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
