"""L1 kernel tests: Pallas BRGEMM vs the pure-jnp oracle.

Hypothesis sweeps shapes, batch sizes, alpha/beta, epilogues; plus the
custom-VJP gradient checks against jax.grad of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import brgemm as kern
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestBrgemmBasic:
    def test_single_pair_is_matmul(self):
        k1, k2 = keys(0, 2)
        a = rand(k1, (1, 8, 16))
        b = rand(k2, (1, 16, 32))
        got = kern.brgemm(a, b)
        np.testing.assert_allclose(got, a[0] @ b[0], rtol=1e-5, atol=1e-5)

    def test_batch_reduces(self):
        k1, k2 = keys(1, 2)
        a = rand(k1, (5, 8, 8))
        b = rand(k2, (5, 8, 8))
        got = kern.brgemm(a, b)
        want = ref.brgemm_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_beta_accumulates_into_c(self):
        k1, k2, k3 = keys(2, 3)
        a = rand(k1, (2, 4, 8))
        b = rand(k2, (2, 8, 12))
        c = rand(k3, (4, 12))
        got = kern.brgemm(a, b, c, beta=1.0)
        want = ref.brgemm_ref(a, b, c, beta=1.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_alpha_scales(self):
        k1, k2 = keys(3, 2)
        a = rand(k1, (2, 4, 4))
        b = rand(k2, (2, 4, 4))
        got = kern.brgemm(a, b, alpha=2.5)
        want = ref.brgemm_ref(a, b, alpha=2.5)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("act", ["identity", "relu", "sigmoid", "tanh"])
    def test_fused_bias_activation(self, act):
        k1, k2, k3 = keys(4, 3)
        a = rand(k1, (3, 8, 8))
        b = rand(k2, (3, 8, 16))
        bias = rand(k3, (16,))
        got = kern.brgemm(a, b, bias=bias, activation=act)
        want = ref.brgemm_ref(a, b, bias=bias, activation=act)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_explicit_blocking(self):
        k1, k2 = keys(5, 2)
        a = rand(k1, (2, 12, 8))
        b = rand(k2, (2, 8, 24))
        got = kern.brgemm(a, b, block_m=4, block_n=8)
        want = ref.brgemm_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6).map(lambda v: v * 4),
    n=st.integers(1, 6).map(lambda v: v * 8),
    k=st.integers(1, 24),
    batch=st.integers(1, 6),
    alpha=st.sampled_from([1.0, 0.5, 2.0]),
    beta=st.sampled_from([0.0, 1.0, 0.5]),
    act=st.sampled_from(["identity", "relu", "sigmoid", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_brgemm_hypothesis(m, n, k, batch, alpha, beta, act, seed):
    k1, k2, k3, k4 = keys(seed, 4)
    a = rand(k1, (batch, m, k))
    b = rand(k2, (batch, k, n))
    c = rand(k3, (m, n))
    bias = rand(k4, (n,))
    got = kern.brgemm(a, b, c, alpha=alpha, beta=beta, bias=bias, activation=act)
    want = ref.brgemm_ref(a, b, c, alpha=alpha, beta=beta, bias=bias, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestBlockedMatmul:
    def test_matches_dense(self):
        k1, k2, k3 = keys(6, 3)
        x = rand(k1, (16, 96))
        w = rand(k2, (96, 32))
        bias = rand(k3, (32,))
        got = kern.blocked_matmul(x, w, bias=bias, activation="relu", block_c=32)
        want = ref.fc_ref(x, w, bias, "relu")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_non_divisible_block_c_falls_back(self):
        k1, k2 = keys(7, 2)
        x = rand(k1, (8, 40))
        w = rand(k2, (40, 16))
        got = kern.blocked_matmul(x, w, block_c=64)  # 64 > 40 -> bc=40
        np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


class TestCustomVjp:
    def test_forward_value(self):
        k1, k2, k3 = keys(8, 3)
        a = rand(k1, (3, 8, 8))
        b = rand(k2, (3, 8, 8))
        c = rand(k3, (8, 8))
        got = kern.brgemm_linear(a, b, c)
        want = ref.brgemm_ref(a, b, c, beta=1.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_grads_match_reference(self):
        k1, k2, k3 = keys(9, 3)
        a = rand(k1, (3, 4, 6))
        b = rand(k2, (3, 6, 8))
        c = rand(k3, (4, 8))

        def loss_kern(a, b, c):
            return jnp.sum(kern.brgemm_linear(a, b, c) ** 2)

        def loss_ref(a, b, c):
            return jnp.sum(ref.brgemm_ref(a, b, c, beta=1.0) ** 2)

        g1 = jax.grad(loss_kern, argnums=(0, 1, 2))(a, b, c)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(a, b, c)
        for got, want in zip(g1, g2):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_blocked_matmul_linear_grad(self):
        k1, k2 = keys(10, 2)
        x = rand(k1, (8, 32))
        w = rand(k2, (32, 16))

        def loss_kern(x, w):
            return jnp.sum(kern.blocked_matmul_linear(x, w, block_c=16) ** 2)

        def loss_ref(x, w):
            return jnp.sum((x @ w) ** 2)

        gx1, gw1 = jax.grad(loss_kern, argnums=(0, 1))(x, w)
        gx2, gw2 = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-4)
        # blocked weight grad comes back blocked: reshape to compare
        np.testing.assert_allclose(gw1.reshape(gw2.shape), gw2, rtol=1e-4, atol=1e-4)

    def test_jit_compatible(self):
        k1, k2, k3 = keys(11, 3)
        a = rand(k1, (2, 4, 4))
        b = rand(k2, (2, 4, 4))
        c = rand(k3, (4, 4))
        got = jax.jit(kern.brgemm_linear)(a, b, c)
        want = ref.brgemm_ref(a, b, c, beta=1.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
