//! End-to-end serving driver over the compiled (tensor-compiler) path:
//! load AOT artifacts, warm the executable cache, then serve batched
//! inference requests from the Rust request loop — Python never runs —
//! reporting latency percentiles and throughput per model.
//!
//! Run: `make artifacts && cargo run --release --example xla_serve_e2e`

use brgemm_dl::runtime::{DType, HostTensor, Runtime};
use brgemm_dl::util::rng::Rng;
use brgemm_dl::util::stats::{fmt_time, Summary};
use std::path::Path;

fn synth_inputs(rt: &Runtime, entry: &str, rng: &mut Rng) -> Vec<HostTensor> {
    rt.manifest
        .get(entry)
        .unwrap()
        .inputs
        .iter()
        .map(|t| match t.dtype {
            DType::F32 => HostTensor::f32(rng.vec_f32(t.element_count(), -0.5, 0.5), &t.shape),
            DType::I32 => HostTensor::i32(
                (0..t.element_count()).map(|_| rng.below(10) as i32).collect(),
                &t.shape,
            ),
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu(Path::new("artifacts"))?;
    println!("serving on PJRT platform: {}", rt.platform());

    // The "models" this server hosts: MLP classifier, LSTM encoder, and a
    // ResNet bottleneck block (N=1 latency-bound inference like Fig. 11).
    let models = ["mlp_fwd", "lstm_fwd", "gnmt_encoder_2l", "resnet_block"];
    rt.warmup(&models)?;
    println!("compiled + cached {} executables (off the request path)", models.len());

    let mut rng = Rng::new(7);
    let requests = 40usize;
    println!("\n{:<20} {:>9} {:>9} {:>9} {:>12}", "model", "p50", "p95", "max", "GFLOPS@p50");
    for entry in models {
        let meta = rt.manifest.get(entry)?.clone();
        let inputs = synth_inputs(&rt, entry, &mut rng);
        // Request loop (sequential closed-loop client).
        let mut lat = Vec::with_capacity(requests);
        for _ in 0..requests {
            let (outs, stats) = rt.execute(entry, &inputs)?;
            assert!(!outs.is_empty());
            lat.push(stats.secs);
        }
        let s = Summary::from(&lat);
        println!(
            "{:<20} {:>9} {:>9} {:>9} {:>12.2}",
            entry,
            fmt_time(s.p50),
            fmt_time(s.p95),
            fmt_time(s.max),
            meta.flops / s.p50 / 1e9,
        );
    }

    // Sanity: the served MLP must be deterministic (same input -> same
    // logits) — a serving-correctness invariant.
    let inputs = synth_inputs(&rt, "mlp_fwd", &mut Rng::new(123));
    let (a, _) = rt.execute("mlp_fwd", &inputs)?;
    let (b, _) = rt.execute("mlp_fwd", &inputs)?;
    assert_eq!(a[0].as_f32()?, b[0].as_f32()?, "serving must be deterministic");
    println!("\ndeterministic serving ✓");
    Ok(())
}
