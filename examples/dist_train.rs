//! Distributed data-parallel training over the coordinator's simulated
//! workers: real per-replica BRGEMM training, real ring-allreduce over the
//! gradient buffers, modelled Omnipath communication time (§4.2
//! methodology). Verifies synchronous-SGD invariants (replica consistency)
//! and prints the per-step cost split.
//!
//! Run: `cargo run --release --example dist_train`

use brgemm_dl::coordinator::data::ClassifyData;
use brgemm_dl::coordinator::trainer::DataParallelTrainer;
use brgemm_dl::util::rng::Rng;

fn main() {
    let sizes = [64usize, 256, 256, 10];
    let workers = 4usize;
    let local_batch = 24usize;
    let steps = 60usize;

    let mut rng = Rng::new(5);
    let data = ClassifyData::synth(4096, sizes[0], 10, 0.3, &mut rng);
    let mut dp = DataParallelTrainer::new(&sizes, local_batch, workers, 1, 0.08, 1234);
    println!(
        "data-parallel training: {:?} on {} workers × batch {} (global {})",
        sizes,
        workers,
        local_batch,
        workers * local_batch
    );

    let mut first = None;
    let mut last = 0.0f32;
    let mut compute_total = 0.0;
    let mut comm_total = 0.0;
    for step in 0..steps {
        let shards: Vec<_> =
            (0..workers).map(|w| data.batch(step * workers + w, local_batch)).collect();
        let s = dp.step(&shards);
        first.get_or_insert(s.loss);
        last = s.loss;
        compute_total += s.compute_secs;
        comm_total += s.comm_secs;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {:3}  loss {:.4}  compute {:6.1} ms  allreduce(model) {:5.2} ms",
                step,
                s.loss,
                s.compute_secs * 1e3,
                s.comm_secs * 1e3
            );
        }
    }
    assert!(dp.replicas_consistent(), "synchronous SGD must keep replicas identical");
    assert!(last < first.unwrap() * 0.6, "loss must decrease: {} -> {}", first.unwrap(), last);
    println!("----------------------------------------------------------------");
    println!(
        "loss {:.4} -> {:.4}; replicas bit-identical ✓; compute:comm = {:.0}:{:.0} ms",
        first.unwrap(),
        last,
        compute_total * 1e3,
        comm_total * 1e3
    );
    println!(
        "(comm is the α-β Omnipath model for {}-rank ring allreduce of the gradient)",
        workers
    );
}
