//! End-to-end training driver (EXPERIMENTS.md §E2E): train an MLP
//! classifier — every GEMM of which is a BRGEMM primitive call — on a
//! synthetic learnable dataset for a few hundred steps, logging the loss
//! curve, final accuracy and sustained throughput.
//!
//! Run: `cargo run --release --example mlp_train_e2e [-- --steps N]`

use brgemm_dl::coordinator::data::ClassifyData;
use brgemm_dl::coordinator::trainer::MlpModel;
use brgemm_dl::perfmodel;
use brgemm_dl::util::rng::Rng;
use std::time::Instant;

fn main() {
    let steps: usize = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    // ~3.3M parameters: 256 -> 1024 -> 1024 -> 1024 -> 10.
    let sizes = [256usize, 1024, 1024, 1024, 10];
    let batch = 96;
    let mut rng = Rng::new(2026);
    let data = ClassifyData::synth(8192, sizes[0], 10, 0.35, &mut rng);
    let mut model = MlpModel::new(&sizes, batch, 1, &mut rng);
    println!(
        "e2e MLP training: {:?}, {} params, batch {}, {} steps, synthetic 10-class data",
        sizes,
        model.param_count(),
        batch,
        steps
    );

    // flops per step ≈ 3 gemm passes (fwd, bwd, upd) × 2NCK per layer
    let step_flops: f64 = 6.0
        * batch as f64
        * sizes.windows(2).map(|w| (w[0] * w[1]) as f64).sum::<f64>();

    let mut losses = Vec::new();
    let t0 = Instant::now();
    for step in 0..steps {
        let (x, labels) = data.batch(step, batch);
        let loss = model.train_step(&x, &labels, 0.05);
        losses.push(loss);
        if step % 25 == 0 || step + 1 == steps {
            println!("step {:4}  loss {:.4}", step, loss);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let first10: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let last10: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    let acc = model.accuracy(&data, 32);
    let gf = step_flops * steps as f64 / secs / 1e9;
    let peak = perfmodel::host_peak_gflops();
    println!("--------------------------------------------------------------");
    println!("loss: first-10 mean {:.4} -> last-10 mean {:.4}", first10, last10);
    println!("accuracy on synthetic data: {:.1}%", acc * 100.0);
    println!(
        "throughput: {:.1} samples/s, {:.1} GFLOPS ({:.1}% of measured peak {:.1})",
        steps as f64 * batch as f64 / secs,
        gf,
        100.0 * gf / peak,
        peak
    );
    assert!(last10 < first10 * 0.5, "training must reduce loss");
    assert!(acc > 0.8, "model must learn the separable data");
    println!("e2e training OK ✓");
}
