//! GNMT-style LSTM throughput with the paper's sequence-length bucketing
//! (§4.2.1: "grouping sequences with similar length together ... yields up
//! to 1.5× speedup compared to classic input partitioning").
//!
//! Generates a WMT-like corpus, partitions it plainly vs bucketed, runs
//! the *real* BRGEMM LSTM cell on each batch (padded to the batch max
//! length) and reports useful words/second for both strategies.
//!
//! Run: `cargo run --release --example gnmt_bucketing`

use brgemm_dl::coordinator::data::SeqCorpus;
use brgemm_dl::primitives::lstm::{LstmConfig, LstmPrimitive, LstmWeights, LstmWorkspace};
use brgemm_dl::util::rng::Rng;
use std::time::Instant;

fn run_partition(
    name: &str,
    parts: &[Vec<Vec<usize>>],
    c: usize,
    k: usize,
    batch: usize,
    rng: &mut Rng,
) -> f64 {
    // Weights shared across batches; re-packed per (c,k) once.
    let mut total_words = 0usize;
    let t0 = Instant::now();
    let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * c, -0.2, 0.2)).collect();
    let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * k, -0.2, 0.2)).collect();
    let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect();
    let wr: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
    let rr: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
    let br: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
    for worker_batches in parts {
        for lens in worker_batches {
            if lens.is_empty() {
                continue;
            }
            let t = *lens.iter().max().unwrap(); // padded length
            let cfg = LstmConfig::new(batch, c, k, t);
            let prim = LstmPrimitive::new(cfg);
            let weights = LstmWeights::pack(cfg, &wr, &rr, &br);
            let x = rng.vec_f32(t * batch * c, -1.0, 1.0);
            let mut ws = LstmWorkspace::new(&cfg);
            prim.forward(&x, None, None, &weights, &mut ws);
            total_words += lens.iter().sum::<usize>(); // useful (unpadded)
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let wps = total_words as f64 / secs;
    println!(
        "{:<10} {:>8} useful words in {:>7.2}s  ->  {:>8.0} words/s",
        name, total_words, secs, wps
    );
    wps
}

fn main() {
    let (c, k, batch) = (64usize, 64usize, 16usize);
    let corpus_size = 512usize;
    let workers = 1; // single socket; the distributed view is in fig10a

    let mut rng = Rng::new(31);
    let corpus = SeqCorpus::synth(corpus_size, 18, 96, &mut rng);
    println!(
        "corpus: {} sequences, lengths {}..{} (WMT-like log-normal)",
        corpus_size,
        corpus.lengths.iter().min().unwrap(),
        corpus.lengths.iter().max().unwrap()
    );

    let plain = corpus.partition_plain(workers, batch);
    let bucketed = corpus.partition_bucketed(workers, batch);
    let (pp, pu) = plain.iter().map(|w| SeqCorpus::padded_cost(w)).fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1));
    let (bp, _) = bucketed.iter().map(|w| SeqCorpus::padded_cost(w)).fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1));
    println!(
        "padding overhead: plain {:.2}x useful, bucketed {:.2}x useful",
        pp as f64 / pu as f64,
        bp as f64 / pu as f64
    );

    let mut rng2 = Rng::new(77);
    let wps_plain = run_partition("plain", &plain, c, k, batch, &mut rng2);
    let wps_bucket = run_partition("bucketed", &bucketed, c, k, batch, &mut rng2);
    let speedup = wps_bucket / wps_plain;
    println!("bucketing speedup: {:.2}x (paper reports up to 1.5x)", speedup);
    assert!(speedup > 1.1, "bucketing should clearly win on skewed lengths");
}
