//! Quickstart: the single building block, top to bottom.
//!
//! 1. Run the native Rust BRGEMM kernel on a small batch.
//! 2. Build a fully-connected layer from nothing but that kernel.
//! 3. If artifacts are present, execute the *same* building block compiled
//!    through the tensor-compiler path (Pallas → XLA → PJRT) and check the
//!    two implementations agree.
//!
//! Run: `cargo run --release --example quickstart`

use brgemm_dl::brgemm::{BrgemmDesc, BrgemmKernel, Epilogue};
use brgemm_dl::primitives::eltwise::Act;
use brgemm_dl::runtime::{HostTensor, Runtime};
use brgemm_dl::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // --- 1. the kernel: C = Σ_i A_i · B_i -------------------------------
    let (batch, m, k, n) = (4usize, 8usize, 32usize, 64usize);
    let mut rng = Rng::new(42);
    let a = rng.vec_f32(batch * m * k, -1.0, 1.0);
    let b = rng.vec_f32(batch * k * n, -1.0, 1.0);
    let mut c = vec![0.0f32; m * n];

    let kernel = BrgemmKernel::new(BrgemmDesc::dense(m, n, k));
    let a_offs: Vec<usize> = (0..batch).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..batch).map(|i| i * k * n).collect();
    kernel.execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None);
    println!("brgemm: reduced a batch of {} {}x{}·{}x{} products into one {}x{} block",
             batch, m, k, k, n, m, n);
    println!("  c[0..4] = {:?}", &c[..4]);

    // --- 2. a DL primitive is just loops around the kernel --------------
    // One fused FC layer: bias + ReLU applied while the block is hot.
    let fused = BrgemmKernel::new(BrgemmDesc::dense(m, n, k))
        .with_epilogue(Epilogue::BiasAct(Act::Relu));
    let bias = rng.vec_f32(n, -0.5, 0.5);
    let mut y = vec![0.0f32; m * n];
    fused.execute_offs(&a, &a_offs, &b, &b_offs, &mut y, Some(&bias));
    let negatives = y.iter().filter(|v| **v < 0.0).count();
    println!("fused bias+relu epilogue: {} negative outputs (must be 0)", negatives);
    assert_eq!(negatives, 0);

    // --- 3. the same building block through the tensor compiler ---------
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = Runtime::cpu(dir)?;
        let (outs, stats) = rt.execute(
            "brgemm_demo",
            &[
                HostTensor::f32(a.clone(), &[batch, m, k]),
                HostTensor::f32(b.clone(), &[batch, k, n]),
            ],
        )?;
        let compiled = outs[0].as_f32()?;
        let max_err = c
            .iter()
            .zip(compiled)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        println!(
            "compiled Pallas BRGEMM via PJRT: {:.2} ms, max |native - compiled| = {:.2e}",
            stats.secs * 1e3,
            max_err
        );
        assert!(max_err < 1e-3);
        println!("native and tensor-compiler paths agree ✓");
    } else {
        println!("(run `make artifacts` to also exercise the compiled path)");
    }
    Ok(())
}
