//! Coordinator-level integration tests: config → trainer → metrics across
//! module boundaries, plus failure injection for the runtime loader.

use brgemm_dl::coordinator::config::{Backend, RunConfig, Workload};
use brgemm_dl::coordinator::data::{ClassifyData, SeqCorpus};
use brgemm_dl::telemetry::Metrics;
use brgemm_dl::coordinator::trainer::{DataParallelTrainer, MlpModel};
use brgemm_dl::runtime::Manifest;
use brgemm_dl::util::rng::Rng;
use std::path::Path;

#[test]
fn config_drives_native_training_run() {
    let cfg = RunConfig::from_json(
        r#"{"workload": {"kind": "mlp", "sizes": [16, 32, 4]},
            "backend": "native", "batch": 16, "steps": 40, "lr": 0.1}"#,
    )
    .unwrap();
    assert_eq!(cfg.backend, Backend::Native);
    let Workload::Mlp { sizes } = &cfg.workload else { panic!() };
    let mut rng = Rng::new(cfg.seed);
    let data = ClassifyData::synth(512, sizes[0], 4, 0.15, &mut rng);
    let mut model = MlpModel::new(sizes, cfg.batch, cfg.nthreads, &mut rng);
    let mut metrics = Metrics::new();
    let mut first = None;
    let mut last = 0.0;
    for step in 0..cfg.steps {
        let (x, labels) = data.batch(step, cfg.batch);
        last = metrics.time("train_step", || model.train_step(&x, &labels, cfg.lr as f32));
        first.get_or_insert(last);
        metrics.inc("steps", 1);
    }
    assert_eq!(metrics.counter("steps"), 40);
    assert!(metrics.timer_mean("train_step").unwrap() > 0.0);
    assert!(last < first.unwrap() * 0.7, "{} -> {}", first.unwrap(), last);
}

#[test]
fn multi_worker_run_stays_consistent_and_learns() {
    let mut rng = Rng::new(3);
    let data = ClassifyData::synth(1024, 24, 6, 0.2, &mut rng);
    let mut dp = DataParallelTrainer::new(&[24, 48, 6], 12, 3, 1, 0.08, 77);
    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..50 {
        let shards: Vec<_> = (0..3).map(|w| data.batch(step * 3 + w, 12)).collect();
        let s = dp.step(&shards);
        first.get_or_insert(s.loss);
        last = s.loss;
    }
    assert!(dp.replicas_consistent());
    assert!(last < first.unwrap() * 0.7);
}

#[test]
fn bucketing_end_to_end_reduces_padded_steps() {
    let mut rng = Rng::new(4);
    let corpus = SeqCorpus::synth(2048, 16, 80, &mut rng);
    for workers in [1usize, 2, 8] {
        let plain = corpus.partition_plain(workers, 16);
        let bucketed = corpus.partition_bucketed(workers, 16);
        let (pp, _) = plain
            .iter()
            .map(|w| SeqCorpus::padded_cost(w))
            .fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1));
        let (bp, _) = bucketed
            .iter()
            .map(|w| SeqCorpus::padded_cost(w))
            .fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1));
        assert!(bp < pp, "workers={}: bucketed {} !< plain {}", workers, bp, pp);
    }
}

#[test]
fn manifest_failure_injection() {
    // Missing directory → clear error.
    assert!(Manifest::load(Path::new("/nonexistent/dir")).is_err());
    // Entry pointing at a missing file → load-time error from the runtime.
    let dir = std::env::temp_dir().join("brgemm_dl_test_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format":1,"entries":[{"name":"ghost","file":"ghost.hlo.txt",
            "inputs":[],"outputs":[],"flops":0,"desc":"missing file"}]}"#,
    )
    .unwrap();
    let rt = brgemm_dl::runtime::Runtime::cpu(&dir).unwrap();
    assert!(rt.load("ghost").is_err(), "missing HLO file must error, not panic");
    // Corrupt HLO text → compile-time error surfaced cleanly.
    std::fs::write(dir.join("ghost.hlo.txt"), "this is not hlo").unwrap();
    assert!(rt.load("ghost").is_err(), "garbage HLO must error, not panic");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scaling_simulation_invariants() {
    use brgemm_dl::coordinator::dist::{strong_scaling, NetworkModel};
    let net = NetworkModel::omnipath();
    let pts = strong_scaling(&net, &[1, 2, 4, 8], 256, 1e-4, 0.0, 8 << 20, 1.0);
    // Efficiency is 1.0 at the base point and non-increasing thereafter
    // when per-sample time is constant (pure comm overhead).
    assert!((pts[0].efficiency - 1.0).abs() < 1e-9);
    for w in pts.windows(2) {
        assert!(w[1].efficiency <= w[0].efficiency + 1e-9);
        assert!(w[1].comm_secs >= w[0].comm_secs);
    }
}
