//! Integration tests: Rust runtime ↔ AOT artifacts (the L3↔L2 boundary).
//!
//! These require `make artifacts` to have run; they skip (with a notice)
//! when the artifact directory is absent so `cargo test` stays green on a
//! fresh checkout.

use brgemm_dl::brgemm::{BrgemmDesc, BrgemmKernel};
use brgemm_dl::runtime::{HostTensor, Runtime};
use brgemm_dl::util::rng::Rng;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(&dir).expect("runtime"))
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(rt) = runtime() else { return };
    for name in ["brgemm_demo", "mlp_fwd", "mlp_train_step", "lstm_fwd", "gnmt_encoder_2l"] {
        assert!(rt.manifest.get(name).is_ok(), "missing artifact {}", name);
    }
}

#[test]
fn brgemm_demo_matches_native_kernel() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.get("brgemm_demo").unwrap().clone();
    let (batch, m, k) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1], meta.inputs[0].shape[2]);
    let n = meta.inputs[1].shape[2];
    let mut rng = Rng::new(42);
    let a = rng.vec_f32(batch * m * k, -1.0, 1.0);
    let b = rng.vec_f32(batch * k * n, -1.0, 1.0);
    let (outs, stats) = rt
        .execute(
            "brgemm_demo",
            &[
                HostTensor::f32(a.clone(), &[batch, m, k]),
                HostTensor::f32(b.clone(), &[batch, k, n]),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape(), &[m, n]);
    assert!(stats.secs > 0.0);

    // Cross-check the compiled Pallas kernel against the native Rust BRGEMM
    // — the two implementations of the same building block must agree.
    let kern = BrgemmKernel::new(BrgemmDesc::dense(m, n, k));
    let a_offs: Vec<usize> = (0..batch).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..batch).map(|i| i * k * n).collect();
    let mut want = vec![0.0f32; m * n];
    kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut want, None);
    let got = outs[0].as_f32().unwrap();
    for i in 0..want.len() {
        assert!(
            (got[i] - want[i]).abs() < 1e-3,
            "pallas vs native at {}: {} vs {}",
            i, got[i], want[i]
        );
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let t0 = std::time::Instant::now();
    rt.load("brgemm_demo").unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    rt.load("brgemm_demo").unwrap();
    let second = t1.elapsed();
    assert!(second < first / 2, "cache hit {:?} vs compile {:?}", second, first);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("brgemm_demo", &[HostTensor::f32(vec![0.0; 4], &[2, 2])]);
    assert!(err.is_err(), "wrong arity must fail");
    let meta = rt.manifest.get("brgemm_demo").unwrap().clone();
    let bad: Vec<HostTensor> = meta
        .inputs
        .iter()
        .map(|t| HostTensor::f32(vec![0.0; t.element_count()], &t.shape))
        .rev() // swapped shapes
        .collect();
    if meta.inputs[0].shape != meta.inputs[1].shape {
        assert!(rt.execute("brgemm_demo", &bad).is_err(), "shape mismatch must fail");
    }
}

#[test]
fn mlp_train_step_reduces_loss_over_iterations() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.get("mlp_train_step").unwrap().clone();
    let mut rng = Rng::new(7);
    // params: (w,b) pairs then x, labels per the manifest order.
    let mut tensors: Vec<HostTensor> = Vec::new();
    for t in &meta.inputs {
        match t.dtype {
            brgemm_dl::runtime::DType::F32 => {
                let fan_in = t.shape[0] as f32;
                let scale = if t.shape.len() == 2 { (2.0 / fan_in).sqrt() } else { 0.0 };
                tensors.push(HostTensor::f32(
                    rng.vec_f32(t.element_count(), -scale.max(0.5) * 0.1, scale.max(0.5) * 0.1),
                    &t.shape,
                ));
            }
            brgemm_dl::runtime::DType::I32 => {
                let labels: Vec<i32> =
                    (0..t.element_count()).map(|_| rng.below(10) as i32).collect();
                tensors.push(HostTensor::i32(labels, &t.shape));
            }
        }
    }
    // Iterate the step: params come back as outputs[0..n-1], loss last.
    let mut losses = Vec::new();
    for _ in 0..4 {
        let (outs, _) = rt.execute("mlp_train_step", &tensors).unwrap();
        let loss = outs.last().unwrap().as_f32().unwrap()[0];
        losses.push(loss);
        for (i, out) in outs[..outs.len() - 1].iter().enumerate() {
            tensors[i] = out.clone();
        }
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should decrease: {:?}",
        losses
    );
}
