//! Figure 11 (right): the single building block inside a tensor compiler.
//!
//! Paper: forward ResNet-50 convolutions at N=1 (inference), BRGEMM
//! embedded in TVM reaches 2361 GF/s — within 5.3% of the hand-written C
//! kernels (2492), 2% faster than auto-tuned AutoTVM, 1.24× MKL-DNN.
//!
//! Here the tensor compiler is XLA and the kernel language is Pallas: for
//! each scaled layer the bench runs (a) the Pallas-BRGEMM conv artifact,
//! (b) XLA's native conv (the vendor-library analogue), (c) the im2col
//! formulation under the same compiler, and (d) the native Rust BRGEMM
//! conv — all through the same Rust request path.
//!
//! Figure 11 (left) — Gen9 iGPU vs clDNN — cannot be exercised (no GPU
//! in this environment); its portability claim is represented by the
//! second backend exercised here. See DESIGN.md §5.5.

mod common;

use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::{ConvConfig, ConvPrimitive};
use brgemm_dl::runtime::{HostTensor, Runtime};
use brgemm_dl::tensor::layout;
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;
use std::path::Path;

// Must match FIG11_LAYERS in python/compile/aot.py.
const LAYERS: [(&str, usize, usize, usize, usize, usize, usize); 3] = [
    ("l28_64_64_r3", 28, 64, 64, 3, 1, 1),
    ("l28_64_128_r1", 28, 64, 128, 1, 1, 0),
    ("l14_128_128_r3", 14, 128, 128, 3, 1, 1),
];

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let rt = match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig11 requires artifacts (`make artifacts`): {:#}", e);
            std::process::exit(0);
        }
    };
    let mut table =
        Table::with_peak("Fig. 11R — conv N=1 inference under the tensor compiler", peak);
    let mut rng = Rng::new(12);

    for (name, h, c, k, r, stride, pad) in LAYERS {
        let x = rng.vec_f32(h * h * c, -1.0, 1.0);
        let w = rng.vec_f32(r * r * c * k, -0.3, 0.3);
        let x_t = HostTensor::f32(x.clone(), &[1, h, h, c]);
        let w_t = HostTensor::f32(w.clone(), &[r, r, c, k]);
        let meta = rt.manifest.get(&format!("conv_brgemm_{}", name)).unwrap().clone();
        let flops = meta.flops;

        for variant in ["brgemm", "xla", "im2col"] {
            let entry = format!("conv_{}_{}", variant, name);
            rt.warmup(&[&entry]).unwrap();
            let label = name.to_string();
            let impl_name = format!("pallas-{}", variant);
            let inputs = [x_t.clone(), w_t.clone()];
            table.case(&label, &impl_name, flops, opts, || {
                black_box(rt.execute(&entry, &inputs).unwrap());
            });
        }

        // Native Rust BRGEMM conv at the same shape (NCHW side).
        // Convert NHWC input to NCHW for the native primitive.
        let mut x_nchw = vec![0.0f32; c * h * h];
        for hh in 0..h {
            for ww in 0..h {
                for cc in 0..c {
                    x_nchw[(cc * h + hh) * h + ww] = x[(hh * h + ww) * c + cc];
                }
            }
        }
        let mut w_kcrs = vec![0.0f32; k * c * r * r];
        for rr in 0..r {
            for ss in 0..r {
                for cc in 0..c {
                    for kk in 0..k {
                        w_kcrs[((kk * c + cc) * r + rr) * r + ss] =
                            w[((rr * r + ss) * c + cc) * k + kk];
                    }
                }
            }
        }
        let cfg = ConvConfig::new(1, c, k, h, h, r, r, stride, pad);
        let prim = ConvPrimitive::new(cfg);
        let xp = layout::pack_conv_act(&x_nchw, 1, c, h, h, cfg.bc, pad, pad);
        let wp = layout::pack_conv_weights(&w_kcrs, k, c, r, r, cfg.bk, cfg.bc);
        let mut out = vec![0.0f32; cfg.output_len()];
        table.case(name, "native-rust", flops, opts, || {
            prim.forward(&xp, &wp, None, &mut out);
            black_box(&out);
        });
    }

    println!("{}", table.render());
    println!("== weighted GF/s per implementation ==");
    for impl_name in ["pallas-brgemm", "pallas-xla", "pallas-im2col", "native-rust"] {
        println!("  {:<16} {:>8.2} GF/s", impl_name, table.weighted_gflops(impl_name));
    }
    common::paper_note(
        "Fig11R",
        "TVM+brgemm 2361 GF = within 5.3% of C impl; 1.24x MKL-DNN",
        "compiled-brgemm vs XLA-native vs im2col vs native-rust above",
    );
    common::paper_note(
        "Fig11L (iGPU)",
        "brgemm OpenCL within 3% of clDNN on Gen9",
        "not reproducible (no GPU); portability shown via the XLA backend",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig11.json", table.to_json().to_string_pretty()).ok();
}
