//! Figure 10b: ResNet-50 distributed training scaling — images/sec vs
//! node count up to 32 nodes.
//!
//! Paper: single node 149 img/s (1.45× over MKL-DNN+TF at 103); scaling
//! to 32 nodes at 95.3% parallel efficiency → 4432 img/s (2 cores/node
//! dedicated to MLSL communication).
//!
//! Here: per-image training compute (fwd+bwd+upd over the full Table-2
//! topology, rep-weighted) is measured on the real BRGEMM conv primitives
//! at bench scale; the allreduce of ResNet-50's 25.5M-parameter gradient
//! uses the α-β Omnipath model. Shape claims: near-linear scaling (conv
//! nets are compute-dominated), efficiency >> the GNMT curves of fig10a.
//!
//! The upd share times `update_weights` (dW only) — the paper-exact UPD
//! pass; the optional conv bias gradient is a separate `update_bias` call
//! that this figure, like the paper, does not charge.

mod common;

use brgemm_dl::coordinator::dist::{strong_scaling, NetworkModel};
use brgemm_dl::primitives::conv::ConvPrimitive;
use brgemm_dl::util::bench::{measure_samples, Opts};
use brgemm_dl::util::json::{obj, Json};
use brgemm_dl::util::rng::Rng;
use brgemm_dl::util::stats::Summary;

/// Repetitions of the per-layer fwd+bwd+upd timing: a fixed count so the
/// i-th samples of every layer pair up into the i-th whole-net sample
/// (per-image noise accounting needs aligned samples, not a per-layer
/// adaptive budget).
const SAMPLE_REPS: usize = 3;

fn main() {
    let mut rng = Rng::new(11);
    let cases = common::conv_cases(&mut rng);
    // Measured per-image training time: Σ_layers reps × (fwd + bwd + upd),
    // sampled SAMPLE_REPS times so the figure carries `{median, mad}`.
    let mut per_image_samples = vec![0.0f64; SAMPLE_REPS];
    let opts = Opts {
        warmup_iters: 1,
        min_iters: SAMPLE_REPS,
        max_iters: SAMPLE_REPS,
        max_seconds: f64::INFINITY,
    };
    for case in &cases {
        let cfg = case.cfg;
        let prim = ConvPrimitive::new(cfg);
        let mut out = vec![0.0f32; cfg.output_len()];
        // The stem (layer 1) needs no data gradient; charge fwd+upd only.
        let dual = (case.layer.id != 1).then(|| prim.dual_weights(&case.w_packed));
        let samples = measure_samples(opts, || {
            prim.forward(&case.x_packed, &case.w_packed, None, &mut out);
            if let Some(dual) = &dual {
                let _ = prim.backward_data_pre(&out, dual);
            }
            let _ = prim.update_weights(&case.x_packed, &out);
        });
        for (acc, s) in per_image_samples.iter_mut().zip(&samples) {
            *acc += case.layer.reps as f64 * s / common::BENCH_N as f64;
        }
    }
    let per_image_stats = Summary::from(&per_image_samples);
    let per_image = per_image_stats.median();
    println!(
        "measured per-image training compute (bench scale, 53 conv layers): \
         {:.1} ms (median of {}, MAD {:.2} ms)",
        per_image * 1e3,
        per_image_stats.n,
        per_image_stats.mad * 1e3
    );

    // ResNet-50 gradient: 25.5M params.
    let grad_bytes = 25_500_000 * 4;
    let net = NetworkModel::omnipath();
    let nodes = [1usize, 2, 4, 8, 16, 32];
    let local_batch = 56usize; // paper's per-node mini-batch
    println!(
        "\n{:<8} {:>12} {:>12} {:>12} {:>8}",
        "nodes", "compute ms", "comm ms", "img/s", "eff%"
    );
    // Weak scaling like the paper (fixed local batch): global = 56×nodes.
    let mut rows: Vec<Json> = Vec::new();
    let mut base: Option<f64> = None;
    for &p in &nodes {
        let compute = per_image * local_batch as f64;
        let comm = net.ring_allreduce_secs(grad_bytes, p);
        // One img/s estimate per whole-net compute sample → median/MAD in
        // rate space for the noise-aware baselines.
        let imgs_samples: Vec<f64> = per_image_samples
            .iter()
            .map(|pi| (local_batch * p) as f64 / (pi * local_batch as f64 + comm))
            .collect();
        let imgs_stats = Summary::from(&imgs_samples);
        let imgs = imgs_stats.median();
        let per_node = imgs / p as f64;
        let eff = 100.0 * per_node / *base.get_or_insert(per_node);
        println!(
            "{:<8} {:>12.1} {:>12.2} {:>12.1} {:>8.1}",
            p,
            compute * 1e3,
            comm * 1e3,
            imgs,
            eff
        );
        rows.push(obj([
            ("nodes", p.into()),
            ("compute_ms", (compute * 1e3).into()),
            ("comm_ms", (comm * 1e3).into()),
            ("imgs_per_s", imgs.into()),
            ("imgs_per_s_mad", imgs_stats.mad.into()),
            ("iters", imgs_stats.n.into()),
            ("eff_pct", eff.into()),
        ]));
    }
    // Also show the strong-scaling view at a fixed global batch.
    println!("\nstrong scaling at global batch 224:");
    let pts = strong_scaling(&net, &nodes, 224, per_image, 0.0, grad_bytes, 1.0);
    let mut strong_rows: Vec<Json> = Vec::new();
    for p in &pts {
        println!(
            "  {:>2} nodes: {:>8.1} img/s  eff {:>5.1}%",
            p.nodes,
            p.throughput,
            100.0 * p.efficiency
        );
        strong_rows.push(obj([
            ("nodes", p.nodes.into()),
            ("imgs_per_s", p.throughput.into()),
            ("eff_pct", (100.0 * p.efficiency).into()),
        ]));
    }
    let out = obj([
        ("title", "Fig10b: ResNet-50 distributed training scaling".into()),
        ("per_image_ms", (per_image * 1e3).into()),
        ("per_image_mad_ms", (per_image_stats.mad * 1e3).into()),
        ("rows", Json::Arr(rows)),
        ("strong_rows", Json::Arr(strong_rows)),
    ]);
    std::fs::create_dir_all("bench_results").ok();
    if std::fs::write("bench_results/fig10b.json", out.to_string_pretty()).is_ok() {
        println!("rows written to bench_results/fig10b.json");
    }
    common::paper_note(
        "Fig10b",
        "149 img/s/node, 95.3% eff at 32 nodes (4432 img/s)",
        "expect near-linear weak scaling, eff >> fig10a's LSTM curves",
    );
}
