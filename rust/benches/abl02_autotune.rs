//! Ablation: autotuned blockings vs. the seed-default heuristic picks, on
//! paper-relevant ResNet-50 layer shapes (Table 2).
//!
//! For each layer the tuner generates the candidate space, prunes it with
//! the analytic cost model, measures the shortlist and persists the winner
//! in `bench_results/tuning_cache.json`; the bench then times the
//! seed-default config against a primitive rebuilt from the cached winner
//! — i.e. exactly what `ConvPrimitive::tuned` would construct.
//!
//! Because the default candidate is always part of the measured shortlist,
//! tuned ≥ default up to measurement noise; the interesting output is *how
//! much* headroom the heuristic leaves on each shape.

use brgemm_dl::autotune::space::apply_conv;
use brgemm_dl::autotune::{tuner, TuneOpts, TuningCache};
use brgemm_dl::coordinator::resnet::RESNET50_LAYERS;
use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::ConvPrimitive;
use brgemm_dl::tensor::layout;
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let mut table = Table::with_peak("Ablation — autotuned vs seed-default blockings", peak);
    std::fs::create_dir_all("bench_results").ok();
    let mut cache = TuningCache::at("bench_results/tuning_cache.json");
    let topts = TuneOpts { top_k: 10, bench: Opts::quick(), train: false };
    let mut rng = Rng::new(1);

    // A spread of Table-2 shapes: 1×1 with small and large K, and 3×3.
    let ids = [3usize, 4, 9, 13];
    let mut speedups = Vec::new();
    for layer in RESNET50_LAYERS.iter().filter(|l| ids.contains(&l.id)) {
        let cfg = layer.conv_config(1, 1);
        let rep = tuner::tune_conv_cached(&cfg, &topts, &mut cache);
        let tuned_cfg = apply_conv(cfg, &rep.best().cand);

        let x = rng.vec_f32(cfg.n * cfg.c * cfg.h * cfg.w, -1.0, 1.0);
        let w = rng.vec_f32(cfg.weights_len(), -0.3, 0.3);
        for (impl_name, c) in [("default", cfg), ("tuned", tuned_cfg)] {
            let prim = ConvPrimitive::new(c);
            let xp = layout::pack_conv_act(&x, c.n, c.c, c.h, c.w, c.bc, c.pad, c.pad);
            let wp = layout::pack_conv_weights(&w, c.k, c.c, c.r, c.s, c.bk, c.bc);
            let mut y = vec![0.0f32; c.output_len()];
            table.case(&layer.label(), impl_name, cfg.flops(), opts, || {
                prim.forward(&xp, &wp, None, &mut y);
                black_box(&y);
            });
        }
        let rows = &table.rows[table.rows.len() - 2..];
        let sp = rows[0].time.min / rows[1].time.min;
        speedups.push((layer.label(), rep.best().cand.label(rep.kind), sp));
    }

    println!("{}", table.render());
    println!("tuned blocking per layer (winner of the ranked candidate table):");
    for (label, cand, sp) in &speedups {
        println!("  {:<28} {:<34} {:>6.2}x vs default", label, cand, sp);
    }
    match cache.save() {
        Ok(p) => println!("tuning cache persisted to {}", p.display()),
        Err(e) => println!("cache save failed: {}", e),
    }
    std::fs::write("bench_results/abl02.json", table.to_json().to_string_pretty()).ok();
}
