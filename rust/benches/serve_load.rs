//! serve_load: sustained open-loop inference serving through the dynamic
//! batcher — the serving analogue of the paper-figure benches.
//!
//! An MLP, a small CNN, and an LSTM sequence classifier each serve a
//! deterministic Poisson workload end to end (queue → batch buckets →
//! worker pool → masked responses);
//! the bench reports throughput, p50/p95/p99 latency and the batch-fill
//! histogram, and writes the same rows as JSON to
//! `bench_results/serve_load.json` (EXPERIMENTS.md tooling shape).
//!
//! The final two rows drive GNMT-style variable-length traffic through a
//! stacked (2-layer) LSTM twice — routed through the length-bucket ladder
//! vs padded to the model's full T — and score both on **useful words/s**
//! (true sequence steps served, padding excluded); bucketing must win.
//!
//! `--quick` / `BENCH_QUICK=1` shrinks the request counts for CI-ish runs.

use brgemm_dl::coordinator::cnn::CnnSpec;
use brgemm_dl::coordinator::rnn::RnnSpec;
use brgemm_dl::serve::{
    run_open_loop, run_open_loop_with, seq_request_len, InferenceModel, LoadSpec, NetSpec,
    ServeOpts,
};
use brgemm_dl::util::json::{obj, Json};
use brgemm_dl::util::rng::Rng;
use brgemm_dl::util::stats::Summary;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

struct Case {
    name: &'static str,
    spec: NetSpec,
    load: LoadSpec,
    opts: ServeOpts,
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let (mlp_requests, cnn_requests) = if quick { (400, 120) } else { (4000, 800) };
    let rnn_requests = if quick { 200 } else { 1500 };
    let cases = [
        Case {
            name: "mlp 64-128-10",
            spec: NetSpec::Mlp { sizes: vec![64, 128, 10] },
            load: LoadSpec { requests: mlp_requests, rate_rps: 20_000.0, seed: 42 },
            opts: ServeOpts { max_batch: 16, workers: 2, ..ServeOpts::default() },
        },
        Case {
            name: "cnn resnet-mini",
            spec: NetSpec::Cnn(CnnSpec::resnet_mini(8, 2, 8)),
            load: LoadSpec { requests: cnn_requests, rate_rps: 2_000.0, seed: 43 },
            opts: ServeOpts { max_batch: 8, workers: 2, ..ServeOpts::default() },
        },
        // Same MLP workload with a batching delay: the fill window trades
        // a bounded latency add for fuller buckets — compare this row's
        // batch-fill histogram (and p50) against the greedy row above.
        Case {
            name: "mlp 64-128-10 wait-fill",
            spec: NetSpec::Mlp { sizes: vec![64, 128, 10] },
            load: LoadSpec { requests: mlp_requests, rate_rps: 20_000.0, seed: 42 },
            opts: ServeOpts { max_batch: 16, workers: 2, wait_for_fill_us: 500, ..ServeOpts::default() },
        },
        // Sequence requests: each request is one flattened [T][C]
        // sequence through the per-bucket forward-only LSTM plans (one
        // Arc-shared packed weight copy behind every bucket).
        Case {
            name: "rnn c16 k32 t8",
            spec: NetSpec::Rnn(RnnSpec { c: 16, k: 32, t: 8, classes: 4, layers: 1 }),
            load: LoadSpec { requests: rnn_requests, rate_rps: 5_000.0, seed: 44 },
            opts: ServeOpts { max_batch: 8, workers: 2, ..ServeOpts::default() },
        },
    ];

    // Repeat each case: the last run's report becomes the row, while the
    // per-run throughputs become `{median, mad, iters}` noise accounting
    // (what `perfcheck --baseline` widens its allowance with).
    let bench_iters = if quick { 2 } else { 3 };

    let mut rows: Vec<Json> = Vec::new();
    for case in &cases {
        let mut tput: Vec<f64> = Vec::with_capacity(bench_iters);
        let mut last = None;
        for _ in 0..bench_iters {
            let mut rng = Rng::new(case.load.seed);
            let model =
                InferenceModel::from_spec(&case.spec, case.opts.max_batch, 1, false, &mut rng);
            assert_eq!(
                model.weight_alloc_ids().len(),
                model.layer_count(),
                "packed weights must be allocated exactly once per layer"
            );
            let (report, responses) = run_open_loop(model, case.opts, &case.load);
            assert_eq!(responses.len(), case.load.requests, "open loop must sustain the load");
            tput.push(report.throughput_rps);
            last = Some(report);
        }
        let report = last.expect("at least one iteration");
        let tput = Summary::from(&tput);
        println!("\n== serve_load: {} ==", case.name);
        print!("{}", report.render());
        println!(
            "throughput over {} runs: median {:.1} rps, MAD {:.2}",
            tput.n,
            tput.median(),
            tput.mad
        );
        let mut row = report.to_json();
        if let Json::Obj(map) = &mut row {
            map.insert("case".to_string(), Json::Str(case.name.to_string()));
            map.insert("rate_rps".to_string(), Json::Num(case.load.rate_rps));
            map.insert("max_batch".to_string(), Json::Num(case.opts.max_batch as f64));
            map.insert("workers".to_string(), Json::Num(case.opts.workers as f64));
            map.insert(
                "wait_fill_us".to_string(),
                Json::Num(case.opts.wait_for_fill_us as f64),
            );
            // The row's throughput leaf is the noise-robust median; the
            // single-run value remains visible in wall_s/requests.
            map.insert("throughput_rps".to_string(), Json::Num(tput.median()));
            map.insert("throughput_rps_mad".to_string(), Json::Num(tput.mad));
            map.insert("iters".to_string(), Json::Num(tput.n as f64));
        }
        rows.push(row);
    }

    // Variable-length GNMT-style traffic through the same stacked model,
    // served two ways from identical arrivals (same seed ⇒ same schedule,
    // lengths, and step contents): routed through the length-bucket
    // ladder, vs padded to the full T=24 up front (what a fixed-shape
    // server forces). The honest rate is useful words/s — true sequence
    // steps delivered, padding excluded — and bucketing must win it: a
    // typical-8 request costs a t_run≈8 prefix instead of 24 full steps.
    // Appended after the fixed cases so the baseline rows pair by index.
    let seq = RnnSpec { c: 16, k: 32, t: 24, classes: 4, layers: 2 };
    let seq_requests = if quick { 300 } else { 2000 };
    // Over-drive the arrival rate so the pool is compute-bound; open loop
    // lets the backlog grow and both runs drain the same request set.
    let seq_load = LoadSpec { requests: seq_requests, rate_rps: 50_000.0, seed: 45 };
    let seq_opts = ServeOpts { max_batch: 8, workers: 2, ..ServeOpts::default() };
    let typical = 8;
    let mut useful = [0.0f64; 2];
    for (mode, pad_to_max) in [("bucketed", false), ("pad-to-max", true)] {
        let mut wps_samples: Vec<f64> = Vec::with_capacity(bench_iters);
        let mut tput: Vec<f64> = Vec::with_capacity(bench_iters);
        let mut last = None;
        for _ in 0..bench_iters {
            let mut rng = Rng::new(seq_load.seed);
            let model = InferenceModel::from_spec(
                &NetSpec::Rnn(seq),
                seq_opts.max_batch,
                1,
                false,
                &mut rng,
            );
            let words = Arc::new(AtomicUsize::new(0));
            let w = Arc::clone(&words);
            let (c, t) = (seq.c, seq.t);
            let (report, responses) =
                run_open_loop_with(model, seq_opts, &seq_load, move |rng, _i| {
                    let len = seq_request_len(rng, typical, t);
                    w.fetch_add(len, Ordering::Relaxed);
                    let mut v = rng.vec_f32(len * c, -1.0, 1.0);
                    if pad_to_max {
                        v.resize(t * c, 0.0);
                    }
                    v
                });
            assert_eq!(responses.len(), seq_requests, "open loop must sustain the load");
            wps_samples.push(words.load(Ordering::Relaxed) as f64 / report.wall_secs);
            tput.push(report.throughput_rps);
            last = Some(report);
        }
        let report = last.expect("at least one iteration");
        let wps = Summary::from(&wps_samples);
        let tput = Summary::from(&tput);
        // Score on the median: one lucky or unlucky run must not decide
        // the bucketed-vs-padded verdict (or the stored baseline).
        let useful_wps = wps.median();
        useful[usize::from(pad_to_max)] = useful_wps;
        println!("\n== serve_load: rnn mixed-len {} ==", mode);
        print!("{}", report.render());
        println!(
            "useful words/s (padding excluded): median {:.0} over {} runs, MAD {:.1}",
            useful_wps, wps.n, wps.mad
        );
        let mut row = report.to_json();
        if let Json::Obj(map) = &mut row {
            map.insert("case".to_string(), Json::Str(format!("rnn mixed-len {}", mode)));
            map.insert("rate_rps".to_string(), Json::Num(seq_load.rate_rps));
            map.insert("max_batch".to_string(), Json::Num(seq_opts.max_batch as f64));
            map.insert("workers".to_string(), Json::Num(seq_opts.workers as f64));
            map.insert("wait_fill_us".to_string(), Json::Num(0.0));
            map.insert("useful_wps".to_string(), Json::Num(useful_wps));
            map.insert("useful_wps_mad".to_string(), Json::Num(wps.mad));
            map.insert("throughput_rps".to_string(), Json::Num(tput.median()));
            map.insert("throughput_rps_mad".to_string(), Json::Num(tput.mad));
            map.insert("iters".to_string(), Json::Num(wps.n as f64));
        }
        rows.push(row);
    }
    assert!(
        useful[0] > useful[1],
        "length bucketing must beat pad-to-max on useful words/s ({:.0} vs {:.0})",
        useful[0],
        useful[1]
    );
    println!(
        "\nbucketed vs pad-to-max useful words/s: {:.0} vs {:.0} ({:.2}x)",
        useful[0],
        useful[1],
        useful[0] / useful[1]
    );

    let out = obj([("title", "serve_load — open-loop dynamic-batching serving".into()),
        ("rows", Json::Arr(rows))]);
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/serve_load.json", out.to_string_pretty()).ok();
    println!("\nwrote bench_results/serve_load.json");
}
