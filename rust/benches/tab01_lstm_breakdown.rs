//! Table 1: LSTM cell time breakdown (C = K = 1024, N = 168, T = 50).
//!
//! Paper: fwd = 93.3% batch-reduce GEMM (at 2550 GF/s = 84% peak) /
//! 5.3% element-wise / 1.4% reformat; bwd&upd = 91.2% / 5.3% / 3.5%.
//!
//! Here: the paper-exact shape (C=K=1024, N=168) at T=25 (halved to fit
//! the 1-core time budget), plus GEMM-phase efficiency vs measured peak.

mod common;

use brgemm_dl::perfmodel;
use brgemm_dl::primitives::lstm::{LstmConfig, LstmPrimitive, LstmWeights, LstmWorkspace};
use brgemm_dl::util::rng::Rng;

fn main() {
    let (n, c, k, t) = (168usize, 1024usize, 1024usize, 25usize);
    let cfg = LstmConfig::new(n, c, k, t);
    let prim = LstmPrimitive::new(cfg);
    let mut rng = Rng::new(2);
    let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * c, -0.2, 0.2)).collect();
    let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * k, -0.2, 0.2)).collect();
    let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect();
    let wref: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
    let rref: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
    let bref: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
    let x = rng.vec_f32(t * n * c, -1.0, 1.0);

    println!("== Table 1 — LSTM cell breakdown (bench scale C=K={}, N={}, T={}) ==", k, n, t);
    let peak = perfmodel::host_peak_gflops();

    // Averages over several runs; weight packing repeated per run so the
    // reformat share is measured, then amortisation is reported separately.
    let reps = 2;
    let mut fwd = brgemm_dl::primitives::lstm::LstmBreakdown::default();
    let mut bwd = brgemm_dl::primitives::lstm::LstmBreakdown::default();
    for _ in 0..reps {
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        let mut ws = LstmWorkspace::new(&cfg);
        let b1 = prim.forward(&x, None, None, &weights, &mut ws);
        fwd.gemm_secs += b1.gemm_secs;
        fwd.eltwise_secs += b1.eltwise_secs;
        fwd.reformat_secs += b1.reformat_secs;
        let wt = weights.transposed();
        let dh = vec![1.0f32; t * n * k];
        let (_, b2) = prim.backward(&x, &dh, &wt, &ws);
        bwd.gemm_secs += b2.gemm_secs;
        bwd.eltwise_secs += b2.eltwise_secs;
        bwd.reformat_secs += b2.reformat_secs;
    }

    let report = |name: &str, bd: &brgemm_dl::primitives::lstm::LstmBreakdown, flops: f64| {
        let total = bd.total();
        let gemm_gf = flops * reps as f64 / bd.gemm_secs / 1e9;
        println!(
            "{:<9} total {:>8.1} ms | brgemm {:>5.1}% ({:.0} GF/s = {:.0}% peak) | eltwise {:>4.1}% | reformat {:>4.1}%",
            name,
            total * 1e3,
            100.0 * bd.gemm_secs / total,
            gemm_gf,
            100.0 * gemm_gf / peak,
            100.0 * bd.eltwise_secs / total,
            100.0 * bd.reformat_secs / total,
        );
    };
    report("fwd", &fwd, cfg.fwd_flops());
    report("bwd&upd", &bwd, cfg.bwdupd_flops());
    common::paper_note(
        "Table 1 fwd",
        "93.3% brgemm (84% peak) / 5.3% eltwise / 1.4% reformat",
        "see fwd row above",
    );
    common::paper_note(
        "Table 1 bwd&upd",
        "91.2% brgemm (77% peak) / 5.3% eltwise / 3.5% reformat",
        "see bwd&upd row above",
    );
}
