//! Figure 8: ResNet-50 weight-update (UPD) pass per layer.
//!
//! Paper (N=28): weighted efficiency 73.6% (vs MKL-DNN 68.9%); ~10% below
//! FWD/BWD because of the weight-tensor reduction and the activation
//! transpose (reformat). The bench reports the same split (GEMM vs
//! reformat) per layer.
//!
//! The timed pass is `update_weights` — dW only, exactly the paper's UPD
//! methodology. The conv bias gradient is a separate `update_bias` pass
//! that training drivers add when the layer's bias is learnable.

mod common;

use brgemm_dl::coordinator::resnet::weighted_gflops;
use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::ConvPrimitive;
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let mut rng = Rng::new(8);
    let cases = common::conv_cases(&mut rng);
    let mut table = Table::with_peak("Fig. 8 — ResNet-50 conv UPD per layer", peak);
    let mut rows = Vec::new();
    let mut reformat_share = Vec::new();

    for case in &cases {
        let cfg = case.cfg;
        let label = case.layer.label();
        let flops = cfg.flops();
        let prim = ConvPrimitive::new(cfg);
        let mut out = vec![0.0f32; cfg.output_len()];
        prim.forward(&case.x_packed, &case.w_packed, None, &mut out);

        table.case(&label, "brgemm upd", flops, opts, || {
            black_box(prim.update_weights(&case.x_packed, &out));
        });
        rows.push((case.layer, flops, table.rows.last().unwrap().time.min));
        let (_, bd) = prim.update_weights(&case.x_packed, &out);
        reformat_share.push((case.layer.id, bd.reformat_secs / (bd.gemm_secs + bd.reformat_secs)));
    }

    println!("{}", table.render());
    let m: Vec<_> = rows.iter().map(|(l, f, t)| (*l, *f, *t)).collect();
    let wg = weighted_gflops(&m);
    println!("== weighted UPD efficiency: {:.2} GF/s = {:.1}% of peak ==", wg, 100.0 * wg / peak);
    println!("reformat share per layer (activation transpose):");
    for (id, share) in &reformat_share {
        println!("  id{:02}: {:>5.1}%", id, 100.0 * share);
    }
    common::paper_note(
        "Fig8",
        "UPD 73.6% wgt-eff, ~10% below FWD/BWD (reduction + transposes)",
        "expect UPD below the fig07 FWD number, reformat share visible",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig08.json", table.to_json().to_string_pretty()).ok();
}
