//! Figure 10a: GNMT (4-layer LSTM) distributed strong scaling — KWPS vs
//! node count for three global batch sizes.
//!
//! Paper (32×2S-SKX + Omnipath): N=1344 scales at 84% to 4 nodes but only
//! 38% to 16 (35.8 KWPS); N=2688 → 58% (52.5 KWPS); N=5376 → 75.2%
//! (65.9 KWPS). The paper attributes the loss explicitly to the *small
//! per-socket mini-batch* under strong scaling — the LSTM cell's own
//! efficiency drops, not the network.
//!
//! This bench reproduces that mechanism: the BRGEMM LSTM cell's per-word
//! training time is **measured at each local batch size** the scaling
//! sweep produces, so the efficiency curve comes from the real cell, and
//! the α-β Omnipath model adds the (secondary) allreduce term. Batch sizes
//! are the paper's ÷28 (one bench lane per paper core).

mod common;

use brgemm_dl::coordinator::dist::NetworkModel;
use brgemm_dl::coordinator::rnn::{RnnModel, RnnSpec};
use brgemm_dl::primitives::lstm::{LstmConfig, LstmPrimitive, LstmWeights, LstmWorkspace};
use brgemm_dl::util::bench::{measure_samples, Opts};
use brgemm_dl::util::json::{obj, Json};
use brgemm_dl::util::rng::Rng;
use brgemm_dl::util::stats::Summary;
use std::collections::BTreeMap;
use std::time::Instant;

/// Measured per-word training seconds of the 4-layer stack at local batch n.
fn per_word_secs(n: usize, c: usize, k: usize, t: usize, layers: usize) -> f64 {
    let cfg = LstmConfig::new(n, c, k, t);
    let prim = LstmPrimitive::new(cfg);
    let mut rng = Rng::new(n as u64);
    let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * c, -0.2, 0.2)).collect();
    let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * k, -0.2, 0.2)).collect();
    let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect();
    let wref: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
    let rref: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
    let bref: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
    let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
    let wt = weights.transposed();
    let x = rng.vec_f32(t * n * c, -1.0, 1.0);
    let mut ws = LstmWorkspace::new(&cfg);
    let dh = vec![1.0f32; t * n * k];
    prim.forward(&x, None, None, &weights, &mut ws); // warmup
    let reps = 2;
    let t0 = Instant::now();
    for _ in 0..reps {
        prim.forward(&x, None, None, &weights, &mut ws);
        let _ = prim.backward(&x, &dh, &wt, &ws);
    }
    t0.elapsed().as_secs_f64() / (reps * n * t) as f64 * layers as f64
}

fn main() {
    let (c, k, t, layers) = (256usize, 256usize, 10usize, 4usize);
    // Paper batches ÷ 28 (one bench lane per paper core): local batches
    // encountered by the sweep are global/nodes.
    let globals = [(48usize, 1344usize), (96, 2688), (192, 5376)];
    let nodes = [1usize, 2, 4, 8, 16];

    // Measure the cell at every local batch the sweep will use.
    let mut cache: BTreeMap<usize, f64> = BTreeMap::new();
    for (g, _) in globals {
        for p in nodes {
            let local = (g / p).max(1);
            cache.entry(local).or_insert(0.0);
        }
    }
    println!("measuring BRGEMM LSTM cell (4-layer, C=K={}) per local batch:", k);
    let keys: Vec<usize> = cache.keys().copied().collect();
    for local in keys {
        let s = per_word_secs(local, c, k, t, layers);
        println!("  local batch {:>3}: {:>7.1} µs/word", local, s * 1e6);
        cache.insert(local, s);
    }

    let params = 4 * layers * (4 * (k * c + k * k) + 4 * k);
    let grad_bytes = params; // 4 bytes/param × params/4... (params already ×4 gates)
    let net = NetworkModel::omnipath();

    println!(
        "\n{:<16} {:>6} {:>12} {:>10} {:>10} {:>8}",
        "batch(paper)", "nodes", "compute ms", "comm ms", "KWPS", "eff%"
    );
    let mut rows: Vec<Json> = Vec::new();
    for (g, paper_g) in globals {
        let mut base: Option<f64> = None;
        for &p in &nodes {
            let local = (g / p).max(1);
            let per_word = cache[&local];
            let compute = per_word * local as f64 * t as f64;
            let comm = net.ring_allreduce_secs(grad_bytes, p);
            let step = compute + comm;
            let kwps = (g * t) as f64 / step / 1e3;
            let per_node = kwps / p as f64;
            let eff = 100.0 * per_node / *base.get_or_insert(per_node);
            println!(
                "{:<16} {:>6} {:>12.1} {:>10.2} {:>10.2} {:>8.1}",
                format!("{} (={}⁄28)", g, paper_g),
                p,
                compute * 1e3,
                comm * 1e3,
                kwps,
                eff
            );
            rows.push(obj([
                ("global_batch", g.into()),
                ("paper_batch", paper_g.into()),
                ("nodes", p.into()),
                ("kwps", kwps.into()),
                ("eff_pct", eff.into()),
            ]));
        }
        println!();
    }
    // Trained `{"model": "rnn"}` row: the full sequence driver — a
    // **genuinely 4-layer stacked** RnnModel (BPTT through every cell,
    // FC softmax head, SGD update) measured per local batch, so the
    // scaling table reflects the end-to-end training step the coordinator
    // actually runs — no per-layer extrapolation, unlike the raw-cell
    // rows above. Same strong-scaling mechanism: the per-word cost rises
    // as the local batch shrinks.
    let (g0, paper_g0) = globals[0];
    let spec = RnnSpec { c, k, t, classes: 16, layers };
    println!(
        "trained {{\"model\": \"rnn\"}} driver ({}-layer stack, cell+head+SGD), \
         global batch {} (={}⁄28):",
        layers, g0, paper_g0
    );
    println!("{:<6} {:>12} {:>12} {:>10} {:>8}", "nodes", "µs/word", "KWPS(med)", "±MAD", "eff%");
    let mut trained_rows: Vec<Json> = Vec::new();
    let mut base: Option<f64> = None;
    for &p in &nodes {
        let local = (g0 / p).max(1);
        let mut rng = Rng::new(7);
        let mut model = RnnModel::new(&spec, local, 1, &mut rng);
        let x = rng.vec_f32(local * spec.input_dim(), -1.0, 1.0);
        let labels: Vec<i32> = (0..local).map(|i| (i % spec.classes) as i32).collect();
        // Repeated timed steps; each sample becomes a KWPS estimate so
        // the row can carry `{median, mad, iters}` noise accounting.
        let opts = Opts { warmup_iters: 1, min_iters: 3, max_iters: 9, max_seconds: 1.5 };
        let step_samples = measure_samples(opts, || {
            std::hint::black_box(model.train_step(&x, &labels, 0.01));
        });
        let comm = net.ring_allreduce_secs(grad_bytes, p);
        // The model already stacks all `layers` cells — per-word cost is
        // the measured step time directly, with no ×layers scaling.
        let kwps_samples: Vec<f64> =
            step_samples.iter().map(|s| (g0 * t) as f64 / (s + comm) / 1e3).collect();
        let kwps = Summary::from(&kwps_samples);
        let per_word =
            step_samples.iter().cloned().fold(f64::INFINITY, f64::min) / (local * t) as f64;
        let per_node = kwps.median() / p as f64;
        let eff = 100.0 * per_node / *base.get_or_insert(per_node);
        println!(
            "{:<6} {:>12.1} {:>12.1} {:>10.2} {:>8.1}",
            p,
            per_word * 1e6,
            kwps.median(),
            kwps.mad,
            eff
        );
        trained_rows.push(obj([
            ("global_batch", g0.into()),
            ("nodes", p.into()),
            ("kwps", kwps.median().into()),
            ("kwps_mad", kwps.mad.into()),
            ("iters", kwps.n.into()),
            ("eff_pct", eff.into()),
        ]));
    }
    println!();

    let out = obj([
        ("title", "Fig10a: GNMT LSTM distributed strong scaling".into()),
        ("rows", Json::Arr(rows)),
        ("trained_rows", Json::Arr(trained_rows)),
    ]);
    std::fs::create_dir_all("bench_results").ok();
    if std::fs::write("bench_results/fig10a.json", out.to_string_pretty()).is_ok() {
        println!("rows written to bench_results/fig10a.json");
    }

    common::paper_note(
        "Fig10a",
        "N=1344: 38% eff @16 (35.8 KWPS); N=5376: 75.2% (65.9 KWPS)",
        "efficiency loss driven by small local batch, larger global batch scales better",
    );
}
