//! Shared helpers for the paper-figure benches (included via `mod common`).

#![allow(dead_code)]

use brgemm_dl::coordinator::resnet::{ResnetLayer, RESNET50_LAYERS};
use brgemm_dl::primitives::conv::ConvConfig;
use brgemm_dl::tensor::layout;
use brgemm_dl::util::rng::Rng;

/// Mini-batch used by the conv benches (paper: N=28 on 28 cores; here:
/// N=1 on 1 core — same per-core workload; spatial and channel dims are
/// the paper's exact Table-2 shapes, see DESIGN.md §5.1).
pub const BENCH_N: usize = 1;
pub const BENCH_SCALE: usize = 1;

/// Inputs for one convolution layer bench, pre-packed in every layout the
/// implementations need.
pub struct ConvCase {
    pub layer: ResnetLayer,
    pub cfg: ConvConfig,
    pub x_plain: Vec<f32>,
    pub w_plain: Vec<f32>,
    pub x_packed: Vec<f32>,
    pub w_packed: Vec<f32>,
}

impl ConvCase {
    pub fn new(layer: ResnetLayer, n: usize, scale: usize, rng: &mut Rng) -> ConvCase {
        let cfg = layer.conv_config(n, scale);
        let x_plain = rng.vec_f32(n * cfg.c * cfg.h * cfg.w, -1.0, 1.0);
        let w_plain = rng.vec_f32(cfg.weights_len(), -0.3, 0.3);
        let x_packed =
            layout::pack_conv_act(&x_plain, n, cfg.c, cfg.h, cfg.w, cfg.bc, cfg.pad, cfg.pad);
        let w_packed =
            layout::pack_conv_weights(&w_plain, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc);
        ConvCase { layer, cfg, x_plain, w_plain, x_packed, w_packed }
    }
}

/// All 20 Table-2 layers at bench scale.
pub fn conv_cases(rng: &mut Rng) -> Vec<ConvCase> {
    RESNET50_LAYERS.iter().map(|&l| ConvCase::new(l, BENCH_N, BENCH_SCALE, rng)).collect()
}

/// Print a paper-vs-measured comparison line.
pub fn paper_note(what: &str, paper: &str, ours: &str) {
    println!("  [paper] {:<38} {:<22} [ours] {}", what, paper, ours);
}
