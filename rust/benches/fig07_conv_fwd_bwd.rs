//! Figure 7: ResNet-50 convolutions — forward and backward-by-data,
//! per layer, BRGEMM vs the small-GEMM baseline.
//!
//! Paper (N=28): FWD weighted efficiency 83% (vs MKL-DNN 81%), BWD 80%
//! (vs 78.9%); 3×3 layers ≈ 90% of peak, 1×1 ≈ 80% (more reuse in large
//! spatial filters); layer 2 is write-bandwidth-bound at 65%.

mod common;

use brgemm_dl::coordinator::resnet::weighted_gflops;
use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::{conv_forward_small_gemm, ConvPrimitive};
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let mut rng = Rng::new(7);
    let cases = common::conv_cases(&mut rng);
    let mut table = Table::with_peak("Fig. 7 — ResNet-50 conv FWD + BWD per layer", peak);
    let mut rows = Vec::new();

    for case in &cases {
        let cfg = case.cfg;
        let label = case.layer.label();
        let flops = cfg.flops();
        let prim = ConvPrimitive::new(cfg);
        let mut out = vec![0.0f32; cfg.output_len()];

        table.case(&label, "brgemm fwd", flops, opts, || {
            prim.forward(&case.x_packed, &case.w_packed, None, &mut out);
            black_box(&out);
        });
        rows.push((case.layer, "brgemm fwd", flops, table.rows.last().unwrap().time.min));

        table.case(&label, "small-gemm fwd", flops, opts, || {
            conv_forward_small_gemm(&cfg, &case.x_packed, &case.w_packed, &mut out);
            black_box(&out);
        });
        rows.push((case.layer, "small-gemm fwd", flops, table.rows.last().unwrap().time.min));

        // BWD by data (dual conv). Skip the stem (input gradient unused in
        // training, and 7x7/s2 takes the documented naive fallback).
        if case.layer.id != 1 {
            prim.forward(&case.x_packed, &case.w_packed, None, &mut out);
            // Dual weights are computed once per weight version in real
            // training; amortised out of the per-call timing (paper §3.1.2
            // amortisation argument, applied to the conv transpose).
            let dual = prim.dual_weights(&case.w_packed);
            table.case(&label, "brgemm bwd", flops, opts, || {
                black_box(prim.backward_data_pre(&out, &dual));
            });
            rows.push((case.layer, "brgemm bwd", flops, table.rows.last().unwrap().time.min));
        }
    }

    println!("{}", table.render());
    println!("== weighted efficiency (ResNet-50 topology) ==");
    for impl_name in ["brgemm fwd", "small-gemm fwd", "brgemm bwd"] {
        let m: Vec<_> = rows
            .iter()
            .filter(|(_, i, _, _)| *i == impl_name)
            .map(|(l, _, f, t)| (*l, *f, *t))
            .collect();
        let wg = weighted_gflops(&m);
        println!("  {:<16} {:>8.2} GF/s = {:>5.1}% of peak", impl_name, wg, 100.0 * wg / peak);
    }
    common::paper_note(
        "Fig7",
        "FWD 83% wgt-eff (3x3 ~90%, 1x1 ~80%); BWD 80%",
        "expect 3x3 > 1x1 efficiency; bwd slightly below fwd",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig07.json", table.to_json().to_string_pretty()).ok();
}
