//! Microkernel shape explorer (perf-pass tool, not a paper figure).
use brgemm_dl::brgemm::*;
use brgemm_dl::perfmodel;
use std::time::Instant;

fn bench_shape(m: usize, n: usize, k: usize, batch: usize, spread: bool) -> f64 {
    let d = BrgemmDesc::dense(m, n, k);
    let kern = BrgemmKernel::new(d);
    // `spread`: blocks laid out apart (conv/FC reality) vs packed tight.
    let a_stride = if spread { m * k + 64 } else { m * k };
    let b_stride = if spread { k * n + 64 } else { k * n };
    let a = vec![1.0f32; batch * a_stride + 64];
    let b = vec![1.0f32; batch * b_stride + 64];
    let mut c = vec![0.0f32; m * n];
    let a_offs: Vec<usize> = (0..batch).map(|i| i * a_stride).collect();
    let b_offs: Vec<usize> = (0..batch).map(|i| i * b_stride).collect();
    for _ in 0..5 { kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None); }
    let iters = ((2e9 / d.flops(batch)) as usize).max(3);
    let t0 = Instant::now();
    for _ in 0..iters { kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None); }
    std::hint::black_box(&c);
    d.flops(batch) * iters as f64 / t0.elapsed().as_secs_f64() / 1e9
}

fn lstm_step_shape(n: usize, c: usize, k: usize) -> f64 {
    // One LSTM timestep's GEMM work, laid out exactly as the primitive does:
    // A = x[t] rows strided by C from a big activation tensor; B = packed
    // gate weights; C blocks = gate tensor rows strided by K.
    use brgemm_dl::util::rng::Rng;
    let (bn, bc, bk) = (n.min(24), 64usize, 64usize);
    let (cb, kb) = (c / bc, k / bk);
    let mut rng = Rng::new(1);
    let x = rng.vec_f32(n * c, -1.0, 1.0);
    let h = rng.vec_f32(n * k, -1.0, 1.0);
    let w = rng.vec_f32(4 * k * c, -0.2, 0.2);
    let r = rng.vec_f32(4 * k * k, -0.2, 0.2);
    let mut gates = vec![0.0f32; 4 * n * k];
    let wx = BrgemmKernel::new(BrgemmDesc { m: bn, n: bk, k: bc, lda: c, ldb: bk, ldc: k, a_kstride: 1, alpha: 1.0, beta: 0.0 });
    let rh = BrgemmKernel::new(BrgemmDesc { m: bn, n: bk, k: bk, lda: k, ldb: bk, ldc: k, a_kstride: 1, alpha: 1.0, beta: 1.0 });
    let flops = 2.0 * 4.0 * n as f64 * k as f64 * (c + k) as f64;
    let mut run = || {
        for z in 0..4 {
            for ikb in 0..kb {
                for inb in 0..n / bn {
                    let a_offs: Vec<usize> = (0..cb).map(|icb| inb * bn * c + icb * bc).collect();
                    let b_offs: Vec<usize> = (0..cb).map(|icb| z * k * c + (ikb * cb + icb) * bc * bk).collect();
                    let g0 = z * n * k + inb * bn * k + ikb * bk;
                    wx.execute_offs(&x, &a_offs, &w, &b_offs, &mut gates[g0..], None);
                    let a2: Vec<usize> = (0..kb).map(|i| inb * bn * k + i * bk).collect();
                    let b2: Vec<usize> = (0..kb).map(|i| z * k * k + (ikb * kb + i) * bk * bk).collect();
                    rh.execute_offs(&h, &a2, &r, &b2, &mut gates[g0..], None);
                }
            }
        }
    };
    for _ in 0..3 { run(); }
    let iters = ((3e8 / flops) as usize).max(3);
    let t0 = Instant::now();
    for _ in 0..iters { run(); }
    std::hint::black_box(&gates);
    flops * iters as f64 / t0.elapsed().as_secs_f64() / 1e9
}

fn main() {
    let peak = perfmodel::host_peak_gflops();
    println!("measured peak: {:.1} GF/s", peak);
    for &(n, c, k) in &[(24usize, 256usize, 256usize), (24, 512, 512), (24, 1024, 1024)] {
        let g = lstm_step_shape(n, c, k);
        println!("lstm step n{} c{} k{}: {:>7.1} GF/s ({:>4.1}%)", n, c, k, g, 100.0*g/peak);
    }
    for &(m, n, k, batch) in &[
        (64usize, 64usize, 64usize, 16usize),
        (49, 64, 64, 32),   // fig11 l28 1x1 flat strip
        (28, 64, 64, 9),    // 3x3 conv strip
        (24, 64, 64, 4),    // FC block
        (6, 64, 64, 16),
        (12, 64, 64, 16),
        (24, 64, 512, 1),
        (49, 64, 2048, 1),  // same flops as (49,64,64,32) but one long k
    ] {
        let g = bench_shape(m, n, k, batch, false);
        println!("m{:>3} n{:>3} k{:>4} b{:>3}: {:>7.1} GF/s ({:>4.1}%)", m, n, k, batch, g, 100.0*g/peak);
    }
}
