//! Figure 6: LSTM cell performance vs hidden size, BRGEMM data-flow cell
//! vs the large-GEMM baseline cell.
//!
//! Paper (N=168, T=50, C=K ∈ {256..2048}): fwd runs at 60-70% of peak and
//! is 1.2-1.3× the vendor (large-GEMM-style) cell; bwd&upd 1.1-1.7×; the
//! advantage shrinks as C,K grow (GEMM cost dominates the eltwise fusion
//! win). Here: N=24, T=10, C=K ∈ {64,128,256,512} on 1 core.

mod common;

use brgemm_dl::perfmodel;
use brgemm_dl::primitives::lstm::{
    LstmConfig, LstmLargeGemm, LstmPrimitive, LstmWeights, LstmWorkspace,
};
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let (n, t) = (168usize, 10usize);
    let mut table = Table::with_peak("Fig. 6 — LSTM cell fwd + bwd/upd vs hidden size", peak);
    let mut speedups = Vec::new();

    for ck in [128usize, 256, 512, 1024] {
        let (c, k) = (ck, ck);
        let cfg = LstmConfig::new(n, c, k, t);
        let prim = LstmPrimitive::new(cfg);
        let mut rng = Rng::new(ck as u64);
        let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * c, -0.2, 0.2)).collect();
        let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * k, -0.2, 0.2)).collect();
        let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect();
        let wref: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
        let rref: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
        let bref: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
        let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        let x = rng.vec_f32(t * n * c, -1.0, 1.0);
        let mut ws = LstmWorkspace::new(&cfg);
        let label = format!("C=K={}", ck);

        table.case(&label, "brgemm fwd", cfg.fwd_flops(), opts, || {
            prim.forward(&x, None, None, &weights, &mut ws);
            black_box(&ws.h);
        });
        let brgemm_fwd = table.rows.last().unwrap().time.min;

        let baseline = LstmLargeGemm::new(cfg, &wref, &rref, &bref);
        table.case(&label, "large-gemm fwd", cfg.fwd_flops(), opts, || {
            black_box(baseline.forward(&x));
        });
        let large_fwd = table.rows.last().unwrap().time.min;
        speedups.push((ck, "fwd", large_fwd / brgemm_fwd));

        // bwd & upd (BRGEMM cell only — the paper's baseline numbers come
        // from the vendor library; ours is the fused pass + its breakdown).
        prim.forward(&x, None, None, &weights, &mut ws);
        let wt = weights.transposed();
        let dh = vec![1.0f32; t * n * k];
        table.case(&label, "brgemm bwd+upd", cfg.bwdupd_flops(), opts, || {
            black_box(prim.backward(&x, &dh, &wt, &ws));
        });
    }

    println!("{}", table.render());
    println!("== BRGEMM cell speedup over large-GEMM cell (fwd) ==");
    for (ck, pass, s) in &speedups {
        println!("  C=K={:<5} {}  {:.2}x", ck, pass, s);
    }
    common::paper_note(
        "Fig6",
        "fwd 1.2-1.3x, advantage shrinks with size",
        "speedups above; expect >1x at small/mid sizes, ~1x at large",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig06.json", table.to_json().to_string_pretty()).ok();
}
