//! Ablation (DESIGN.md §3 #1, §Perf iteration 4): consuming the "Aᵀ"
//! operand of the weight-update GEMMs *in place* via the kernel's
//! `a_kstride` extension vs. a *physical transpose* + unit-stride reads.
//!
//! The in-place read costs nothing extra at small strides (the broadcast
//! load hits the same cache lines), but at large strides every k-step
//! touches a fresh cache line — the transpose's O(MK) copy wins as soon
//! as the GEMM re-reads A enough times. This bench quantifies the
//! crossover that motivated switching the LSTM UPD pass to physical
//! transposes while FC UPD (stride = bc = 64 floats) kept `a_kstride`.

use brgemm_dl::brgemm::{BrgemmDesc, BrgemmKernel};
use brgemm_dl::perfmodel;
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let mut table = Table::with_peak(
        "Ablation — upd-style GEMM: in-place a_kstride vs physical transpose",
        peak,
    );
    // dW-shaped problem: m=bc=64 channel rows, n=bk=64, k=N batch dim.
    let (m, n, k) = (64usize, 64usize, 168usize);
    let batch = 8; // accumulation chain length (e.g. T·Nb slices)
    let mut rng = Rng::new(1);

    // `reuse` = how many output blocks consume the same A slices (LSTM
    // UPD: 4 gates × Kb blocks ⇒ dozens; FC UPD at small K: a handful).
    for &(stride, reuse) in
        &[(64usize, 1usize), (64, 16), (256, 1), (256, 16), (1024, 1), (1024, 16), (4096, 16)]
    {
        let label = format!("stride {} reuse {}", stride, reuse);
        // Activation tensor big enough for the strided walk.
        let a = rng.vec_f32(batch * k * stride + m, -1.0, 1.0);
        let b = rng.vec_f32(batch * k * n, -1.0, 1.0);
        let mut c = vec![0.0f32; m * n];
        let flops = 2.0 * (m * n * k * batch) as f64;

        // (a) in-place: rows are channels (lda=1), k walks the batch dim
        // at `stride` elements per step.
        let kern = BrgemmKernel::new(
            BrgemmDesc::dense(m, n, k).with_ld(1, n, n).with_a_kstride(stride).with_beta(1.0),
        );
        let a_offs: Vec<usize> = (0..batch).map(|i| i * k * stride).collect();
        let b_offs: Vec<usize> = (0..batch).map(|i| i * k * n).collect();
        let flops = flops * reuse as f64;
        table.case(&label, "a_kstride in-place", flops, opts, || {
            for _ in 0..reuse {
                kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None);
            }
            black_box(&c);
        });

        // (b) physical transpose into [batch][m][k] scratch, then unit-
        // stride BRGEMM; the transpose is charged to the measurement.
        let kern_t = BrgemmKernel::new(BrgemmDesc::dense(m, n, k).with_beta(1.0));
        let mut at = vec![0.0f32; batch * m * k];
        let at_offs: Vec<usize> = (0..batch).map(|i| i * m * k).collect();
        table.case(&label, "transpose + unit", flops, opts, || {
            // transpose once ...
            for i in 0..batch {
                let src = i * k * stride;
                let dst = i * m * k;
                for kk in 0..k {
                    for r in 0..m {
                        at[dst + r * k + kk] = a[src + kk * stride + r];
                    }
                }
            }
            // ... amortised over every consumer block.
            for _ in 0..reuse {
                kern_t.execute_offs(&at, &at_offs, &b, &b_offs, &mut c, None);
            }
            black_box(&c);
        });
    }

    println!("{}", table.render());
    println!(
        "crossover: in-place wins at reuse=1 (any stride) and at the FC-UPD\n\
         point (stride 64, any reuse); the transpose wins from (stride >= 256,\n\
         reuse >= 16) — the LSTM-UPD regime, validating §Perf iteration 4."
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/abl01.json", table.to_json().to_string_pretty()).ok();
}
