use brgemm_dl::coordinator::resnet::RESNET50_LAYERS;
use brgemm_dl::primitives::conv::ConvPrimitive;
use brgemm_dl::util::rng::Rng;
fn main() {
    let mut rng = Rng::new(1);
    for l in RESNET50_LAYERS.iter().filter(|l| [4usize, 9, 13, 14].contains(&l.id)) {
        let cfg = l.conv_config(1, 1);
        let prim = ConvPrimitive::new(cfg);
        let x = rng.vec_f32(cfg.n * cfg.c * cfg.h * cfg.w, -1.0, 1.0);
        let w = rng.vec_f32(cfg.weights_len(), -0.3, 0.3);
        let xp = brgemm_dl::tensor::layout::pack_conv_act(&x, cfg.n, cfg.c, cfg.h, cfg.w, cfg.bc, cfg.pad, cfg.pad);
        let wp = brgemm_dl::tensor::layout::pack_conv_weights(&w, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc);
        let mut out = vec![0.0f32; cfg.output_len()];
        prim.forward(&xp, &wp, None, &mut out);
        // time split
        let dual = prim.dual_weights(&wp);
        let (_, _) = prim.backward_data_pre(&out, &dual); // warm
        let t0 = std::time::Instant::now();
        let (_, bd) = prim.backward_data_pre(&out, &dual);
        let total = t0.elapsed().as_secs_f64();
        println!("id{:02}: total {:.2}ms gemm {:.2}ms reformat {:.2}ms other {:.2}ms  ({:.1} GF/s gemm-only)",
            l.id, total*1e3, bd.gemm_secs*1e3, bd.reformat_secs*1e3, (total-bd.gemm_secs-bd.reformat_secs)*1e3,
            cfg.flops()/bd.gemm_secs/1e9);
    }
}
