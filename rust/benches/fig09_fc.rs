//! Figure 9: Fully-connected layers — FWD / BWD / UPD, BRGEMM blocked
//! formulation vs the coarse-grained large-GEMM baseline.
//!
//! Paper (N=1344): BRGEMM achieves 64/76/76% of peak for C=K =
//! 256/512/1024 vs 55/56/70% for the large-GEMM cells — 1.16×/1.36×/1.09×.
//! UPD/BWD trail FWD at small sizes (less parallelism, weight transpose).
//! Here: N=192 on 1 core, C=K ∈ {128, 256, 512}.

mod common;

use brgemm_dl::perfmodel;
use brgemm_dl::primitives::eltwise::Act;
use brgemm_dl::primitives::fc::{fc_forward_large_gemm, FcConfig, FcPrimitive};
use brgemm_dl::tensor::layout;
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let n = 192usize;
    let mut table = Table::with_peak("Fig. 9 — FC layers fwd/bwd/upd, brgemm vs large-GEMM", peak);
    let mut speedups = Vec::new();

    for ck in [128usize, 256, 512] {
        let (c, k) = (ck, ck);
        let cfg = FcConfig::new(n, c, k, Act::Relu);
        let prim = FcPrimitive::new(cfg);
        let mut rng = Rng::new(ck as u64);
        let x = rng.vec_f32(n * c, -1.0, 1.0);
        let w = rng.vec_f32(k * c, -0.3, 0.3);
        let bias = rng.vec_f32(k, -0.1, 0.1);
        let xp = layout::pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
        let wp = layout::pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
        let label = format!("C=K={}", ck);
        let flops = cfg.flops();

        let mut y = vec![0.0f32; n * k];
        table.case(&label, "brgemm fwd", flops, opts, || {
            prim.forward(&xp, &wp, &bias, &mut y);
            black_box(&y);
        });
        let t_brgemm = table.rows.last().unwrap().time.min;

        let mut y2 = vec![0.0f32; n * k];
        table.case(&label, "large-gemm fwd", flops, opts, || {
            fc_forward_large_gemm(n, c, k, &x, &w, &bias, Act::Relu, &mut y2);
            black_box(&y2);
        });
        let t_large = table.rows.last().unwrap().time.min;
        speedups.push((ck, t_large / t_brgemm));

        // BWD (includes the amortisable weight transpose, charged here).
        prim.forward(&xp, &wp, &bias, &mut y);
        let dy = vec![1.0f32; n * k];
        let mut dz = vec![0.0f32; n * k];
        prim.dz_from_dy(&dy, &y, &mut dz);
        let mut dx = vec![0.0f32; n * c];
        table.case(&label, "brgemm bwd", flops, opts, || {
            let wt = layout::transpose_packed_2d(&wp, k, c, cfg.bk, cfg.bc);
            prim.backward_data(&dz, &wt, &mut dx);
            black_box(&dx);
        });

        // UPD
        let mut dw = vec![0.0f32; k * c];
        let mut db = vec![0.0f32; k];
        table.case(&label, "brgemm upd", flops, opts, || {
            prim.update(&xp, &dz, &mut dw, &mut db);
            black_box(&dw);
        });
    }

    println!("{}", table.render());
    println!("== BRGEMM FC speedup over large-GEMM (fwd) ==");
    for (ck, s) in &speedups {
        println!("  C=K={:<5} {:.2}x", ck, s);
    }
    common::paper_note(
        "Fig9",
        "brgemm 64/76/76% vs large-gemm 55/56/70% (1.16x/1.36x/1.09x)",
        "speedups above; expect >1x, larger in the mid sizes",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig09.json", table.to_json().to_string_pretty()).ok();
}
