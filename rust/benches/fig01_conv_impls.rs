//! Figure 1: ResNet-50 forward convolutions under four implementation
//! strategies.
//!
//! Paper result (weighted efficiency on 28-core SKX): small-GEMM loops
//! 61%, im2col + batched GEMM 49%, MKL-DNN specialized 81%, **BRGEMM 83%**
//! — the single building block beats the ad-hoc vendor kernels.
//!
//! Here: the same four-way comparison with in-repo implementations
//! (the vendor-specialized comparator is the XLA-native conv on the
//! compiled path, see fig11; this bench covers the three native-path
//! strategies) at bench scale (N=2, spatial ÷4, channels exact).

mod common;

use brgemm_dl::coordinator::resnet::weighted_gflops;
use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::{conv_forward_im2col, conv_forward_small_gemm, ConvPrimitive};
use brgemm_dl::util::bench::{black_box, Opts, Table};
use brgemm_dl::util::rng::Rng;

fn main() {
    let opts = Opts::from_env();
    let peak = perfmodel::host_peak_gflops();
    let mut rng = Rng::new(1);
    let cases = common::conv_cases(&mut rng);
    let mut table = Table::with_peak("Fig. 1 — ResNet-50 FWD convolutions, 4 strategies", peak);
    let mut rows: Vec<(brgemm_dl::coordinator::resnet::ResnetLayer, &str, f64, f64)> = Vec::new();

    for case in &cases {
        let cfg = case.cfg;
        let label = case.layer.label();
        let flops = cfg.flops();

        // Strategy (ii)-analog: BRGEMM direct conv (Algorithm 4).
        let prim = ConvPrimitive::new(cfg);
        let mut out = vec![0.0f32; cfg.output_len()];
        table.case(&label, "brgemm", flops, opts, || {
            prim.forward(&case.x_packed, &case.w_packed, None, &mut out);
            black_box(&out);
        });
        rows.push((case.layer, "brgemm", flops, table.rows.last().unwrap().time.min));

        // Strategy (i)a: small-GEMM loop nest, no batch reduction.
        table.case(&label, "small-gemm", flops, opts, || {
            conv_forward_small_gemm(&cfg, &case.x_packed, &case.w_packed, &mut out);
            black_box(&out);
        });
        rows.push((case.layer, "small-gemm", flops, table.rows.last().unwrap().time.min));

        // Strategy (i)b: im2col + one large GEMM.
        let mut y_plain = vec![0.0f32; cfg.output_len()];
        table.case(&label, "im2col", flops, opts, || {
            conv_forward_im2col(&cfg, &case.x_plain, &case.w_plain, &mut y_plain);
            black_box(&y_plain);
        });
        rows.push((case.layer, "im2col", flops, table.rows.last().unwrap().time.min));
    }

    println!("{}", table.render());
    println!("== weighted efficiency over the ResNet-50 topology ==");
    for impl_name in ["brgemm", "small-gemm", "im2col"] {
        let m: Vec<_> = rows
            .iter()
            .filter(|(_, i, _, _)| *i == impl_name)
            .map(|(l, _, f, t)| (*l, *f, *t))
            .collect();
        let wg = weighted_gflops(&m);
        println!("  {:<12} {:>8.2} GF/s  = {:>5.1}% of peak", impl_name, wg, 100.0 * wg / peak);
    }
    common::paper_note(
        "Fig1 weighted efficiency",
        "brgemm 83% > mkl-dnn 81% > small-gemm 61% > im2col 49%",
        "expect brgemm > small-gemm > im2col (vendor comparator: see fig11)",
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig01.json", table.to_json().to_string_pretty()).ok();
}
