//! Measurement harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with GFLOPS accounting and the
//! paper-style table output used by every `benches/` target. Benches are
//! plain binaries (`harness = false` in Cargo.toml) built on this module.

use super::stats::{fmt_time, Summary};
use std::time::Instant;

/// One measured configuration: a row in a paper table/figure.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub impl_name: String,
    pub flops: f64,
    pub time: Summary,
}

impl Row {
    /// Best-case rate (min time) — the "machine capability" number.
    pub fn gflops(&self) -> f64 {
        crate::telemetry::achieved_gflops(self.flops, self.time.min)
    }

    /// Median-based rate — the noise-robust number the efficiency column
    /// and the `{median, mad, iters}` JSON rows report.
    pub fn median_gflops(&self) -> f64 {
        crate::telemetry::achieved_gflops(self.flops, self.time.median())
    }
}

/// Measurement options. `quick()` is used by `make bench-quick` and CI-ish
/// runs; `full()` matches the paper's 400-repetition protocol scaled down.
#[derive(Debug, Clone, Copy)]
pub struct Opts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much wall time has been spent measuring a case.
    pub max_seconds: f64,
}

impl Opts {
    pub fn full() -> Opts {
        Opts { warmup_iters: 3, min_iters: 10, max_iters: 400, max_seconds: 2.0 }
    }

    pub fn quick() -> Opts {
        Opts { warmup_iters: 1, min_iters: 3, max_iters: 20, max_seconds: 0.25 }
    }

    /// Select via `BENCH_QUICK=1` env or `--quick` argv flag.
    pub fn from_env() -> Opts {
        let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
            || std::env::args().any(|a| a == "--quick");
        if quick {
            Opts::quick()
        } else {
            Opts::full()
        }
    }
}

/// Time `f` under `opts`; returns the summarised per-iteration samples.
pub fn measure<F: FnMut()>(opts: Opts, f: F) -> Summary {
    Summary::from(&measure_samples(opts, f))
}

/// Time `f` under `opts`; returns the raw per-iteration samples in seconds.
/// Benches that derive a rate per sample (words/s, images/s) use this so
/// their rows can report `{median, mad, iters}` in rate space.
pub fn measure_samples<F: FnMut()>(opts: Opts, mut f: F) -> Vec<f64> {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.min_iters);
    let budget = Instant::now();
    for i in 0..opts.max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if i + 1 >= opts.min_iters && budget.elapsed().as_secs_f64() > opts.max_seconds {
            break;
        }
    }
    samples
}

/// A named collection of rows, printed as a paper-style table.
pub struct Table {
    pub title: String,
    pub rows: Vec<Row>,
    /// Peak GFLOPS used for the efficiency column (from `perfmodel`).
    pub peak_gflops: Option<f64>,
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table { title: title.to_string(), rows: Vec::new(), peak_gflops: None }
    }

    pub fn with_peak(title: &str, peak_gflops: f64) -> Table {
        Table { title: title.to_string(), rows: Vec::new(), peak_gflops: Some(peak_gflops) }
    }

    /// Measure one case and append a row.
    pub fn case<F: FnMut()>(&mut self, label: &str, impl_name: &str, flops: f64, opts: Opts, f: F) {
        let time = measure(opts, f);
        let row = Row { label: label.into(), impl_name: impl_name.into(), flops, time };
        eprintln!(
            "  {:<22} {:<18} {:>10.2} GF/s  min {}",
            row.label,
            row.impl_name,
            row.gflops(),
            fmt_time(row.time.min),
        );
        self.rows.push(row);
    }

    /// Weighted efficiency over rows matching `impl_name`, weights = flops
    /// (the paper's "weighted efficiency" for full topologies).
    pub fn weighted_gflops(&self, impl_name: &str) -> f64 {
        let (fl, t): (f64, f64) = self
            .rows
            .iter()
            .filter(|r| r.impl_name == impl_name)
            .fold((0.0, 0.0), |(fl, t), r| (fl + r.flops, t + r.time.min));
        crate::telemetry::achieved_gflops(fl, t)
    }

    /// Render the table. If `peak_gflops` is set, adds an efficiency column.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&format!(
            "{:<22} {:<18} {:>12} {:>12} {:>10}",
            "case", "impl", "min time", "GFLOPS", "eff%"
        ));
        out.push('\n');
        for r in &self.rows {
            // Efficiency from the *median* rate: robust to a single noisy
            // best iteration, matching the `{median, mad, iters}` JSON rows.
            let eff = self
                .peak_gflops
                .map(|p| format!("{:>9.1}%", 100.0 * r.median_gflops() / p))
                .unwrap_or_else(|| "      n/a".to_string());
            out.push_str(&format!(
                "{:<22} {:<18} {:>12} {:>12.2} {:>10}\n",
                r.label,
                r.impl_name,
                fmt_time(r.time.min),
                r.gflops(),
                eff
            ));
        }
        out
    }

    /// Emit rows as a JSON array (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                obj([
                    ("label", r.label.as_str().into()),
                    ("impl", r.impl_name.as_str().into()),
                    ("flops", r.flops.into()),
                    ("min_s", r.time.min.into()),
                    ("mean_s", r.time.mean.into()),
                    ("median_s", r.time.median().into()),
                    ("mad_s", r.time.mad.into()),
                    ("iters", (r.time.n as f64).into()),
                    ("gflops", r.gflops().into()),
                    ("median_gflops", r.median_gflops().into()),
                ])
            })
            .collect();
        obj([
            ("title", self.title.as_str().into()),
            ("peak_gflops", self.peak_gflops.map(Json::Num).unwrap_or(Json::Null)),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Prevent the optimizer from eliding a computed value (ptr read_volatile
/// based; stable-Rust equivalent of `std::hint::black_box` semantics strong
/// enough for our f32 buffers).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0usize;
        let opts = Opts { warmup_iters: 2, min_iters: 5, max_iters: 5, max_seconds: 10.0 };
        let s = measure(opts, || n += 1);
        assert_eq!(n, 7); // 2 warmup + 5 measured
        assert_eq!(s.n, 5);
    }

    #[test]
    fn measure_respects_budget() {
        let opts = Opts { warmup_iters: 0, min_iters: 2, max_iters: 1000, max_seconds: 0.02 };
        let s = measure(opts, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(s.n >= 2 && s.n < 1000, "n={}", s.n);
    }

    #[test]
    fn gflops_accounting() {
        let r = Row {
            label: "x".into(),
            impl_name: "y".into(),
            flops: 2e9,
            time: Summary::from(&[1.0, 2.0]),
        };
        assert!((r.gflops() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_gflops_pools_flops_and_time() {
        let mut t = Table::new("t");
        t.rows.push(Row {
            label: "a".into(),
            impl_name: "brgemm".into(),
            flops: 1e9,
            time: Summary::from(&[1.0]),
        });
        t.rows.push(Row {
            label: "b".into(),
            impl_name: "brgemm".into(),
            flops: 3e9,
            time: Summary::from(&[1.0]),
        });
        // 4 GFLOP in 2 s = 2 GF/s
        assert!((t.weighted_gflops("brgemm") - 2.0).abs() < 1e-12);
        assert_eq!(t.weighted_gflops("missing"), 0.0);
    }

    #[test]
    fn table_renders_and_jsons() {
        let mut t = Table::with_peak("demo", 100.0);
        t.rows.push(Row {
            label: "a".into(),
            impl_name: "x".into(),
            flops: 5e10,
            time: Summary::from(&[1.0]),
        });
        let s = t.render();
        // Single sample: min == median, so eff% is unchanged at 50%.
        assert!(s.contains("demo") && s.contains("50.0%"), "{}", s);
        let j = t.to_json().to_string_compact();
        assert!(j.contains("\"gflops\""));
        assert!(j.contains("\"median_s\"") && j.contains("\"mad_s\"") && j.contains("\"iters\""));
    }

    #[test]
    fn median_gflops_resists_a_lucky_iteration() {
        // One anomalously fast sample inflates min-based gflops; the
        // median-based rate stays at the typical iteration.
        let r = Row {
            label: "x".into(),
            impl_name: "y".into(),
            flops: 1e9,
            time: Summary::from(&[0.1, 1.0, 1.0, 1.0, 1.0]),
        };
        assert!((r.gflops() - 10.0).abs() < 1e-9);
        assert!((r.median_gflops() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn measure_samples_returns_raw_samples() {
        let opts = Opts { warmup_iters: 1, min_iters: 4, max_iters: 4, max_seconds: 10.0 };
        let samples = measure_samples(opts, || {
            black_box(std::hint::black_box(1u64) + 1);
        });
        assert_eq!(samples.len(), 4);
        assert!(samples.iter().all(|s| *s >= 0.0));
    }
}
