//! Leveled stderr logger for the coordinator and CLI.
//!
//! Level is process-global, settable once from the CLI (`-v/-q`) or the
//! `BRGEMM_DL_LOG` env var (`error|warn|info|debug|trace`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for relative timestamps.
fn epoch() -> Instant {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Set the global level; also reads `BRGEMM_DL_LOG` when `None`.
pub fn init(level: Option<Level>) {
    let l = level
        .or_else(|| std::env::var("BRGEMM_DL_LOG").ok().as_deref().and_then(Level::parse))
        .unwrap_or(Level::Info);
    LEVEL.store(l as u8, Ordering::Relaxed);
    epoch(); // pin t=0
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Core log call; prefer the [`crate::log_info`]-style macros.
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let t = epoch().elapsed().as_secs_f64();
        eprintln!("[{:>9.3}s {} {}] {}", t, level.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_gating() {
        init(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        init(Some(Level::Info)); // restore default for other tests
    }

    #[test]
    fn trace_gating() {
        // Trace is the most verbose level: off at the Info default, on
        // only when explicitly requested — so per-batch serve trace lines
        // cost one atomic load unless BRGEMM_DL_LOG=trace.
        init(Some(Level::Info));
        assert!(!enabled(Level::Trace));
        init(Some(Level::Trace));
        assert!(enabled(Level::Trace));
        assert!(enabled(Level::Debug));
        init(Some(Level::Info)); // restore default for other tests
    }
}
