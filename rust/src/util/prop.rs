//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Usage:
//! ```
//! use brgemm_dl::util::prop::{Prop, Gen};
//! # std::env::remove_var("PROP_SEED");
//! Prop::new("reverse twice is identity")
//!     .cases(200)
//!     .run(|g| {
//!         let xs: Vec<u32> = g.vec(0..=64, |g| g.u32(0..=1000));
//!         let mut ys = xs.clone();
//!         ys.reverse();
//!         ys.reverse();
//!         if ys != xs { return Err(format!("{:?} != {:?}", ys, xs)); }
//!         Ok(())
//!     });
//! ```
//!
//! On failure the framework re-runs the property with geometrically smaller
//! size bounds to report a small counterexample seed, then panics with the
//! seed so the case can be replayed deterministically
//! (`PROP_SEED=<n> cargo test`).

use super::rng::Rng;
use std::ops::RangeInclusive;

/// Random value source handed to properties; wraps [`Rng`] with a size
/// parameter that the shrinking loop reduces.
pub struct Gen {
    rng: Rng,
    /// Scale factor in (0,1]; shrink passes reduce it to bias generated
    /// collection sizes and magnitudes downward.
    pub size: f64,
}

impl Gen {
    pub fn u32(&mut self, r: RangeInclusive<u32>) -> u32 {
        let (lo, hi) = (*r.start(), *r.end());
        let hi_scaled = lo + (((hi - lo) as f64 * self.size) as u32);
        lo + (self.rng.next_u64() % (u64::from(hi_scaled - lo) + 1)) as u32
    }

    pub fn usize(&mut self, r: RangeInclusive<usize>) -> usize {
        self.u32(*r.start() as u32..=*r.end() as u32) as usize
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = (self.rng.next_u64() % xs.len() as u64) as usize;
        &xs[i]
    }

    /// A vector whose length is drawn from `len` (size-scaled) and whose
    /// elements come from `f`.
    pub fn vec<T>(&mut self, len: RangeInclusive<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// A vector of exactly n f32s in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }
}

/// A property runner.
pub struct Prop {
    name: String,
    cases: usize,
    seed: u64,
}

impl Prop {
    pub fn new(name: &str) -> Prop {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB5_2E_55);
        Prop { name: name.to_string(), cases: 100, seed }
    }

    /// Number of random cases to run (default 100).
    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Prop {
        self.seed = s;
        self
    }

    /// Run the property; panics with the failing seed + message on failure.
    pub fn run<F>(self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        let mut root = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = root.next_u64();
            let mut g = Gen { rng: Rng::new(case_seed), size: 1.0 };
            if let Err(msg) = prop(&mut g) {
                // Shrink: retry the same stream at smaller sizes to find a
                // smaller counterexample before reporting.
                let mut best: Option<(f64, String)> = None;
                for &size in &[0.05, 0.1, 0.25, 0.5] {
                    let mut g = Gen { rng: Rng::new(case_seed), size };
                    if let Err(m) = prop(&mut g) {
                        best = Some((size, m));
                        break;
                    }
                }
                let (size, shown) = best.unwrap_or((1.0, msg));
                panic!(
                    "property '{}' failed (case {}, seed {:#x}, size {}):\n  {}\n\
                     replay with PROP_SEED={}",
                    self.name, case, case_seed, size, shown, self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("add commutes").cases(50).run(|g| {
            let a = g.u32(0..=1000);
            let b = g.u32(0..=1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        Prop::new("always fails").cases(5).run(|_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_ranges() {
        Prop::new("ranges").cases(200).run(|g| {
            let x = g.usize(3..=17);
            if !(3..=17).contains(&x) {
                return Err(format!("usize out of range: {}", x));
            }
            let f = g.f32(-2.0, 2.0);
            if !(-2.0..2.0).contains(&f) {
                return Err(format!("f32 out of range: {}", f));
            }
            let v = g.vec(0..=8, |g| g.bool());
            if v.len() > 8 {
                return Err("vec too long".into());
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut out = Vec::new();
            Prop::new("det").seed(seed).cases(10).run(|g| {
                out.push(g.u32(0..=u32::MAX / 2));
                Ok(())
            });
            out
        };
        assert_eq!(collect(99), collect(99));
        assert_ne!(collect(99), collect(100));
    }
}
