//! Small numeric helpers shared across layers (blocking arithmetic).

/// Largest divisor of `d` that is ≤ `pref` (and ≥ 1). The canonical
/// block-size rounding used by every config's `with_blocking` and by the
/// autotuner's candidate generation.
pub fn largest_divisor_le(d: usize, pref: usize) -> usize {
    assert!(d >= 1, "dimension must be >= 1");
    let mut b = pref.min(d).max(1);
    while d % b != 0 {
        b -= 1;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_down_to_divisors() {
        assert_eq!(largest_divisor_le(64, 48), 32);
        assert_eq!(largest_divisor_le(64, 64), 64);
        assert_eq!(largest_divisor_le(64, 1000), 64);
        assert_eq!(largest_divisor_le(7, 4), 1);
        assert_eq!(largest_divisor_le(1, 1), 1);
        assert_eq!(largest_divisor_le(56, 28), 28);
    }
}
