//! Timing statistics for the bench harness and the coordinator's metrics.

/// Summary statistics over a set of timing samples (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
    /// Median absolute deviation from the median — the robust spread the
    /// noise-aware perf baselines compare against (`k·MAD` widens the
    /// regression allowance; a few outlier samples barely move it, unlike
    /// `std`).
    pub mad: f64,
}

impl Summary {
    /// Compute from raw samples. Panics on an empty slice.
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&sorted, 0.50);
        let mut dev: Vec<f64> = sorted.iter().map(|x| (x - p50).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50,
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
            mad: percentile(&dev, 0.50),
        }
    }

    /// The median sample — the noise-robust central value the bench JSON
    /// rows report (alias of `p50`, named for the `{median, mad, iters}`
    /// row schema).
    pub fn median(&self) -> f64 {
        self.p50
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/min/max accumulator (used by the coordinator's metric sinks,
/// which cannot afford to store every sample).
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn new() -> Online {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Reconstruct from moments (used by the exact parallel-Welford merge
    /// in `crate::telemetry`).
    pub fn from_moments(n: usize, mean: f64, m2: f64, min: f64, max: f64) -> Online {
        Online { n, mean, m2, min, max }
    }

    /// Welford update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n > 1 {
            (self.m2 / (self.n - 1) as f64).sqrt()
        } else {
            0.0
        }
    }
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::from(&[0.5]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 0.5);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.median(), 0.5);
    }

    #[test]
    fn mad_is_robust_to_outliers() {
        // [1,1,1,1,100]: median 1, |dev| = [0,0,0,0,99] → MAD 0, while the
        // std is blown up by the outlier. That robustness is the point.
        let s = Summary::from(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median(), 1.0);
        assert_eq!(s.mad, 0.0);
        assert!(s.std > 10.0);
        // Symmetric spread: [1,2,3,4,5] → median 3, |dev| sorted [0,1,1,2,2]
        // → MAD 1.
        let t = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(t.median(), 3.0);
        assert!((t.mad - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::from(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
        assert_eq!(o.min, s.min);
        assert_eq!(o.max, s.max);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(0.002), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
