//! Minimal JSON value model, parser and serializer.
//!
//! Used for the artifact manifest written by `python/compile/aot.py` and for
//! model/run configuration files. Supports the full JSON grammar except for
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII manifests,
//! and errors loudly otherwise rather than mis-decoding).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a [`BTreeMap`] so serialization is
/// deterministic (handy for golden tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error raised by [`Json::parse`], with byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing whitespace is allowed,
    /// trailing garbage is an error.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

/// Convenience constructors used by config/metric writers.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            if (0xD800..0xE000).contains(&cp) {
                                return Err(self.err("surrogate \\u escapes unsupported"));
                            }
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str so slicing is safe).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number '{}'", s) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\ud800\"").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"m":16,"n":64,"k":[1,2,3],"name":"brgemm \"x\"","f":null,"t":true}"#,
            r#"[[],{},[[1.5]],"nested\tescape"]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string_compact();
            assert_eq!(Json::parse(&s).unwrap(), v, "round trip failed for {}", c);
        }
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":"d"}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integral_floats_print_as_ints() {
        assert_eq!(Json::Num(64.0).to_string_compact(), "64");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn obj_builder() {
        let v = obj([("x", 1.0.into()), ("y", "z".into())]);
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("y").unwrap().as_str(), Some("z"));
    }
}
