//! Deterministic PRNG: xoshiro256** (Blackman & Vigna).
//!
//! Seedable, splittable, no global state. Used everywhere randomness is
//! needed — tensor initialisation, synthetic workload generation, and the
//! property-testing framework — so that every test and bench is exactly
//! reproducible from its seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via splitmix64, per the
    /// reference implementation's recommendation).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Snapshot the generator state (for checkpointing — the model-artifact
    /// format persists it so a resumed run can restore the exact stream).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Restore a generator from a [`Rng::state`] snapshot. The stream
    /// continues exactly where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // bias is < 2^-53 relative for all n we use.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f32(&mut self, xs: &mut [f32], lo: f32, hi: f32) {
        for x in xs {
            *x = self.f32_range(lo, hi);
        }
    }

    /// A fresh vec of uniform values in `[lo, hi)`.
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_f32(&mut v, lo, hi);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed, "restored state must continue the stream");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        let eq = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(eq, 0);
    }
}
