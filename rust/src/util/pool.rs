//! OpenMP-style parallel regions over std threads.
//!
//! The paper's C implementation parallelises every primitive with a
//! `#pragma omp parallel` region and *static* work partitioning computed
//! from `thread_id` (see Algorithm 2 line 2 and Algorithm 5 line 1). This
//! module reproduces that model: [`parallel_region`] runs a closure on
//! `nthreads` logical threads, each receiving its `tid`, and
//! [`chunk_range`] computes the contiguous static partition of a
//! 1-D iteration space.
//!
//! On this 1-core host `nthreads == 1` short-circuits to an inline call
//! (no spawn), so the threading layer adds zero overhead to the measured
//! hot paths while remaining fully exercised by the multi-threaded tests.

/// Run `f(tid)` for `tid in 0..nthreads`, on real threads when
/// `nthreads > 1`. Panics in workers propagate to the caller.
pub fn parallel_region<F>(nthreads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(nthreads > 0);
    if nthreads == 1 {
        f(0);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::with_capacity(nthreads);
        for tid in 0..nthreads {
            handles.push(s.spawn(move || f(tid)));
        }
        for h in handles {
            h.join().expect("worker thread panicked");
        }
    });
}

/// Static partition of `0..n` into `nthreads` contiguous chunks; returns
/// `(start, end)` for `tid`. Remainder items go to the leading threads, so
/// chunk sizes differ by at most one (the paper's load-balance property).
pub fn chunk_range(n: usize, nthreads: usize, tid: usize) -> (usize, usize) {
    debug_assert!(tid < nthreads);
    let base = n / nthreads;
    let rem = n % nthreads;
    let start = tid * base + tid.min(rem);
    let len = base + usize::from(tid < rem);
    (start, start + len)
}

/// Parallel-for over `0..n` with static chunking: `f(tid, i)` per item.
pub fn parallel_for<F>(nthreads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    parallel_region(nthreads, |tid| {
        let (lo, hi) = chunk_range(n, nthreads, tid);
        for i in lo..hi {
            f(tid, i);
        }
    });
}

/// Write-disjoint parallel map: splits `out` into per-thread sub-slices
/// aligned with [`chunk_range`] and hands each thread mutable access to its
/// own chunk — the safe-Rust equivalent of the paper's threads writing
/// disjoint output blocks.
pub fn parallel_chunks_mut<T, F>(nthreads: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = out.len();
    if nthreads == 1 {
        f(0, 0, out);
        return;
    }
    // Pre-split into exactly the chunk_range partition.
    let mut chunks: Vec<(usize, &mut [T])> = Vec::with_capacity(nthreads);
    let mut rest = out;
    let mut consumed = 0;
    for tid in 0..nthreads {
        let (lo, hi) = chunk_range(n, nthreads, tid);
        debug_assert_eq!(lo, consumed);
        let (head, tail) = rest.split_at_mut(hi - lo);
        chunks.push((lo, head));
        rest = tail;
        consumed = hi;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::with_capacity(nthreads);
        for (tid, (offset, chunk)) in chunks.into_iter().enumerate() {
            handles.push(s.spawn(move || f(tid, offset, chunk)));
        }
        for h in handles {
            h.join().expect("worker thread panicked");
        }
    });
}

/// Shared mutable buffer for threads writing *disjoint* regions
/// (defaulting to the primitives' f32 tensors; `MaxPool` shares its u32
/// argmax buffer the same way).
///
/// The primitives' parallelisation writes each output block from exactly
/// one task, and each task runs on exactly one thread (invariants tested in
/// `primitives::partition`). `SharedMut` is the narrow unsafe window that
/// expresses this to the borrow checker.
pub struct SharedMut<'a, T = f32> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SharedMut<'_, T> {}
unsafe impl<T: Send> Send for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    pub fn new(buf: &'a mut [T]) -> SharedMut<'a, T> {
        SharedMut { ptr: buf.as_mut_ptr(), len: buf.len(), _marker: std::marker::PhantomData }
    }

    /// # Safety
    /// `[off, off+len)` must not overlap any region concurrently handed out
    /// to another thread.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, off: usize, len: usize) -> &mut [T] {
        debug_assert!(off + len <= self.len, "SharedMut slice out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shared_mut_disjoint_writes() {
        let mut buf = vec![0.0f32; 64];
        let shared = SharedMut::new(&mut buf);
        parallel_region(4, |tid| {
            let s = unsafe { shared.slice(tid * 16, 16) };
            for (i, x) in s.iter_mut().enumerate() {
                *x = (tid * 16 + i) as f32;
            }
        });
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn chunk_ranges_cover_and_are_disjoint() {
        for n in [0usize, 1, 7, 64, 1000] {
            for t in [1usize, 2, 3, 8, 13] {
                let mut covered = vec![0u8; n];
                let mut prev_end = 0;
                for tid in 0..t {
                    let (lo, hi) = chunk_range(n, t, tid);
                    assert_eq!(lo, prev_end, "contiguous");
                    prev_end = hi;
                    for c in &mut covered[lo..hi] {
                        *c += 1;
                    }
                }
                assert_eq!(prev_end, n);
                assert!(covered.iter().all(|&c| c == 1));
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        for n in [10usize, 97, 1000] {
            for t in [3usize, 7, 16] {
                let sizes: Vec<usize> =
                    (0..t).map(|tid| { let (l, h) = chunk_range(n, t, tid); h - l }).collect();
                let mx = *sizes.iter().max().unwrap();
                let mn = *sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "n={} t={} sizes={:?}", n, t, sizes);
            }
        }
    }

    #[test]
    fn parallel_for_visits_every_item_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(4, n, |_tid, i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_chunks_mut_partitions_writes() {
        let mut out = vec![0usize; 100];
        parallel_chunks_mut(7, &mut out, |tid, offset, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = tid * 1000 + offset + j;
            }
        });
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x % 1000, i, "item {} written with its global index", i);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let mut hit = false;
        parallel_chunks_mut(1, std::slice::from_mut(&mut hit), |tid, off, c| {
            assert_eq!((tid, off), (0, 0));
            c[0] = true;
        });
        assert!(hit);
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn worker_panic_propagates() {
        parallel_region(2, |tid| {
            if tid == 1 {
                panic!("boom");
            }
        });
    }
}
