//! Self-contained substrate utilities.
//!
//! The execution environment has no network access to crates.io, so the
//! usual ecosystem crates (serde, clap, criterion, rayon, proptest, …) are
//! unavailable. Everything a production library would pull from those is
//! implemented here, scoped to what this repo needs:
//!
//! * [`json`] — minimal JSON parser + serializer (artifact manifests,
//!   model configs).
//! * [`rng`] — deterministic xoshiro256** PRNG (data generation, property
//!   tests); no global state, seedable, split-able.
//! * [`stats`] — timing statistics used by the bench harness.
//! * [`pool`] — a scoped thread pool with static partitioning, mirroring
//!   the OpenMP-style parallel regions of the paper's C implementation.
//! * [`bench`] — the measurement harness (criterion replacement): warmup,
//!   repetition, GFLOPS accounting, paper-style table output.
//! * [`prop`] — a small property-based testing framework (proptest
//!   replacement): random case generation + iterative shrinking.
//! * [`logger`] — leveled stderr logger for the coordinator.

pub mod bench;
pub mod json;
pub mod logger;
pub mod num;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
