//! Low-level binary encoding for model artifacts.
//!
//! Everything is little-endian and length-prefixed; the [`Dec`] reader
//! returns a hard error (with byte offset) on any truncation or
//! out-of-range length instead of panicking, so a corrupted or cut-off
//! artifact file is always rejected with a clear message. [`crc32`] is the
//! standard IEEE-802.3 polynomial (reflected, `0xEDB88320`), computed over
//! the payload so header and body corruption are both caught.

use anyhow::{bail, Result};

/// CRC-32 (IEEE) over `data` — table-free bitwise form; artifacts are a
/// few MB at most, so simplicity beats a lookup table here.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Append-only little-endian writer.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed (u64) f32 slice.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    /// Length-prefixed (u32) usize slice (stored as u32s — dims, sizes).
    pub fn usize_slice(&mut self, v: &[usize]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x as u32);
        }
    }
}

/// Little-endian reader with offset-carrying errors.
pub struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    pub fn offset(&self) -> usize {
        self.i
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub fn done(&self) -> bool {
        self.i == self.b.len()
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated artifact: need {} bytes for {} at offset {}, only {} left",
                n,
                what,
                self.i,
                self.remaining()
            );
        }
        let b: &'a [u8] = self.b;
        let start = self.i;
        self.i += n;
        Ok(&b[start..start + n])
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// u64-length-prefixed f32 slice; the length is bounds-checked against
    /// the remaining bytes *before* allocating, so a corrupted length can
    /// neither OOM nor panic.
    pub fn f32_slice(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u64(what)? as usize;
        if self.remaining() < n.saturating_mul(4) {
            bail!(
                "truncated artifact: {} claims {} f32s at offset {}, only {} bytes left",
                what,
                n,
                self.i,
                self.remaining()
            );
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32(what)?);
        }
        Ok(out)
    }

    /// u32-length-prefixed u32 slice widened to usizes.
    pub fn usize_slice(&mut self, what: &str) -> Result<Vec<usize>> {
        let n = self.u32(what)? as usize;
        if self.remaining() < n.saturating_mul(4) {
            bail!(
                "truncated artifact: {} claims {} entries at offset {}, only {} bytes left",
                what,
                n,
                self.i,
                self.remaining()
            );
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32(what)? as usize);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn enc_dec_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEAD_BEEF);
        e.u64(1 << 40);
        e.f32(-1.5);
        e.f64(std::f64::consts::PI);
        e.f32_slice(&[1.0, 2.0, 3.5]);
        e.usize_slice(&[64, 128, 10]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8("a").unwrap(), 7);
        assert_eq!(d.u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64("c").unwrap(), 1 << 40);
        assert_eq!(d.f32("d").unwrap(), -1.5);
        assert_eq!(d.f64("e").unwrap(), std::f64::consts::PI);
        assert_eq!(d.f32_slice("f").unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(d.usize_slice("g").unwrap(), vec![64, 128, 10]);
        assert!(d.done());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.f32_slice(&[1.0; 16]);
        for cut in [0, 3, 8, 11, e.buf.len() - 1] {
            let err = Dec::new(&e.buf[..cut]).f32_slice("weights").unwrap_err();
            assert!(err.to_string().contains("truncated"), "cut {}: {}", cut, err);
        }
    }

    #[test]
    fn absurd_length_prefix_rejected_before_allocating() {
        let mut e = Enc::new();
        e.u64(u64::MAX); // claims ~2^64 f32s follow
        let err = Dec::new(&e.buf).f32_slice("weights").unwrap_err();
        assert!(err.to_string().contains("claims"), "{}", err);
    }
}
