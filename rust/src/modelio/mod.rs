//! Model artifacts: the persistence layer between the trainer and the
//! server (train → checkpoint → serve).
//!
//! The paper's thesis is that DL primitives are loops around one BRGEMM
//! kernel with layout/blocking as a *tuning detail*. The artifact format
//! takes that seriously: weights are stored in **canonical unblocked**
//! form (`[K][C]` / `[K][C][R][S]` row-major, little-endian f32) and are
//! re-packed on load for whatever blocking the loader's tuner picks —
//! unlike vendor-library handles, a trained model is never baked into one
//! execution layout. Packing is a pure index permutation, so
//! save-under-one-blocking / load-under-another round-trips to
//! bit-identical parameters.
//!
//! # File format (version 1)
//!
//! ```text
//!   magic    8  b"BRGMMDL\0"
//!   version  u32 (little-endian; readers reject other versions)
//!   length   u64 payload byte count
//!   crc32    u32 IEEE CRC of the payload
//!   payload  arch descriptor + training metadata + per-layer params
//! ```
//!
//! The payload is length-prefixed throughout (see [`format`]); corrupted,
//! truncated, or stale-version files are rejected with a precise error —
//! never a panic, never a silently wrong model.
//!
//! # Train → serve walkthrough
//!
//! Train an MLP with per-epoch checkpointing (`examples/checkpoint.json`):
//!
//! ```text
//!   brgemm-dl run --config examples/checkpoint.json
//!   # -> checkpoints/mlp.bin after every epoch
//! ```
//!
//! Resume a longer schedule from the snapshot (bit-identical to a run
//! that never stopped — the artifact carries the step cursor and RNG
//! state, and the synthetic data pipeline is regenerated from the stored
//! seed):
//!
//! ```text
//!   brgemm-dl run --config examples/checkpoint.json --epochs 3 \
//!       --resume checkpoints/mlp.bin
//! ```
//!
//! Serve the trained weights — every batch-bucket plan is built from the
//! artifact through the shared-weight structs, and `--min-accuracy`
//! replays the training distribution through the server to prove the
//! learned model (not a random init) is answering:
//!
//! ```text
//!   brgemm-dl serve --model-path checkpoints/mlp.bin --min-accuracy 0.5
//! ```
//!
//! A running server hot-reloads a newer artifact atomically
//! ([`crate::serve::Server::reload`]): in-flight batches finish on the
//! weights they started with, later batches use the new set, and the swap
//! count lands in the serve metrics. A long-running server can watch the
//! artifact file itself (`serve ... --watch-model`,
//! [`crate::serve::ModelWatcher`]): every atomic checkpoint rename a
//! concurrent trainer performs is picked up by header-signature polling
//! and applied through the same reload path.
//!
//! # The RNN path
//!
//! The same pipeline covers sequence models. An `{"model": "rnn"}` config
//! trains the stacked LSTM sequence classifier (`examples/rnn.json` is a
//! 2-layer stack; `"layers"` is honored, never coerced) with the
//! identical checkpoint/resume contract. The artifact's [`Arch::Rnn`]
//! stores each cell of the stack as one [`LayerKind::Lstm`] layer —
//! canonical unblocked per-gate `W`/`R`/`b` (gate order i, g, f, o),
//! layer 0 shaped `c -> k`, deeper layers `k -> k` — plus the FC head,
//! so export → import round-trips bit-identically under any
//! `{bn, bc, bk, threads}`. Single-layer specs still encode in the
//! pre-stack byte format (arch tag 2), so old artifacts and old readers
//! stay compatible in both directions; stacked specs use tag 3:
//!
//! ```text
//!   brgemm-dl run --config examples/rnn.json
//!   brgemm-dl run --config examples/rnn.json --epochs 3 --resume checkpoints/rnn.bin
//!   brgemm-dl serve --model-path checkpoints/rnn.bin --min-accuracy 0.5
//! ```
//!
//! A served sequence model also accepts **variable-length** requests: any
//! whole number of steps up to the trained `T` is routed through the
//! batcher's length-bucket ladder and computed as a prefix of the
//! full-length plans (`serve --model-path checkpoints/rnn.bin
//! --seq-len-typical 4` drives a GNMT-style mixed-length load; responses
//! are bit-identical to solo full-padding runs).

pub mod format;

use crate::coordinator::cnn::CnnSpec;
use crate::coordinator::rnn::RnnSpec;
use anyhow::{anyhow, bail, Result};
use self::format::{crc32, Dec, Enc};
use std::path::{Path, PathBuf};

/// File magic (8 bytes).
pub const MAGIC: [u8; 8] = *b"BRGMMDL\0";
/// Schema version this build writes and reads.
pub const SCHEMA_VERSION: u32 = 1;

/// The architecture descriptor: which network the stored parameters
/// belong to. Mirrors the run-config workloads (and converts to the
/// serving [`NetSpec`](crate::serve::NetSpec)).
#[derive(Debug, Clone, PartialEq)]
pub enum Arch {
    /// `sizes = [d_in, h1, ..., classes]`; hidden ReLU, linear head.
    Mlp { sizes: Vec<usize> },
    /// Conv stack + pool + FC head (the CNN training driver's topology).
    Cnn(CnnSpec),
    /// Stacked LSTM cells over `[T][N][C]` sequences + FC softmax head on
    /// the top layer's final hidden state (the RNN training driver's
    /// topology): `spec.layers` cells, layer 0 `c -> k`, deeper layers
    /// `k -> k`. Encoded as tag 2 (the pre-stack format) when
    /// `layers == 1` and tag 3 otherwise, so artifacts written before the
    /// stack refactor load unchanged.
    Rnn(RnnSpec),
}

/// What one layer of an [`Arch`] must look like in the artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerShape {
    pub kind: LayerKind,
    /// `Fc`: `[k, c]`; `Conv`: `[k, c, r, s]`.
    pub dims: Vec<usize>,
}

impl Arch {
    pub fn input_dim(&self) -> usize {
        match self {
            Arch::Mlp { sizes } => sizes[0],
            Arch::Cnn(spec) => spec.input_dim(),
            Arch::Rnn(spec) => spec.input_dim(),
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            Arch::Mlp { sizes } => *sizes.last().unwrap(),
            Arch::Cnn(spec) => spec.classes,
            Arch::Rnn(spec) => spec.classes,
        }
    }

    /// Short human-readable form for logs.
    pub fn describe(&self) -> String {
        match self {
            Arch::Mlp { sizes } => format!("mlp {:?}", sizes),
            Arch::Cnn(spec) => format!(
                "cnn {}x{}x{} ({} convs, {} classes)",
                spec.in_c,
                spec.in_h,
                spec.in_w,
                spec.convs.len(),
                spec.classes
            ),
            Arch::Rnn(spec) => format!(
                "rnn c{} k{} t{} x{} ({} classes)",
                spec.c, spec.k, spec.t, spec.layers, spec.classes
            ),
        }
    }

    /// Semantic validation: every decoded arch must describe a network
    /// the model constructors can actually build. Checked *before* any
    /// geometry-deriving call ([`Self::layer_shapes`],
    /// `CnnSpec::conv_configs`), so a hostile-but-well-checksummed
    /// artifact errors instead of panicking (divide-by-zero strides,
    /// filters larger than the padded input, pool windows larger than
    /// the final feature map).
    pub fn validate(&self) -> Result<()> {
        match self {
            Arch::Mlp { sizes } => {
                if sizes.len() < 2 {
                    bail!("mlp arch needs >= 2 sizes, got {:?}", sizes);
                }
                if sizes.iter().any(|&s| s == 0) {
                    bail!("mlp arch sizes must all be >= 1, got {:?}", sizes);
                }
            }
            Arch::Cnn(spec) => {
                if spec.convs.is_empty() {
                    bail!("cnn arch has no conv layers");
                }
                if spec.in_c == 0 || spec.in_h == 0 || spec.in_w == 0 {
                    bail!(
                        "cnn arch input {}x{}x{} must be >= 1 in every dim",
                        spec.in_c, spec.in_h, spec.in_w
                    );
                }
                if spec.classes < 2 {
                    bail!("cnn arch needs >= 2 classes, got {}", spec.classes);
                }
                let (mut h, mut w) = (spec.in_h, spec.in_w);
                for (i, cv) in spec.convs.iter().enumerate() {
                    if cv.k == 0 || cv.r == 0 || cv.s == 0 || cv.stride == 0 {
                        bail!(
                            "cnn arch conv {}: k/r/s/stride must all be >= 1, got {:?}",
                            i, cv
                        );
                    }
                    if h + 2 * cv.pad < cv.r || w + 2 * cv.pad < cv.s {
                        bail!(
                            "cnn arch conv {}: {}x{} filter exceeds its {}x{} padded input",
                            i,
                            cv.r,
                            cv.s,
                            h + 2 * cv.pad,
                            w + 2 * cv.pad
                        );
                    }
                    h = (h + 2 * cv.pad - cv.r) / cv.stride + 1;
                    w = (w + 2 * cv.pad - cv.s) / cv.stride + 1;
                }
                // Windowed pooling must fit the final feature map (global
                // pooling — pool_win 0 — always fits; the pool stride is
                // clamped to >= 1 by PoolConfig).
                if spec.pool_win > 0 && (spec.pool_win > h || spec.pool_win > w) {
                    bail!(
                        "cnn arch pool window {} exceeds the {}x{} final feature map",
                        spec.pool_win, h, w
                    );
                }
            }
            Arch::Rnn(spec) => {
                if spec.c == 0 || spec.k == 0 || spec.t == 0 {
                    bail!(
                        "rnn arch c/k/t must all be >= 1, got c{} k{} t{}",
                        spec.c, spec.k, spec.t
                    );
                }
                if spec.classes < 2 {
                    bail!("rnn arch needs >= 2 classes, got {}", spec.classes);
                }
                if spec.layers == 0 {
                    bail!("rnn arch needs >= 1 stacked layer, got 0");
                }
            }
        }
        Ok(())
    }

    /// The per-layer shapes an artifact of this arch must carry, in the
    /// canonical layer order ([`crate::coordinator::trainer::Model`]'s
    /// export order): MLP layers first-to-last; CNN conv stack in chain
    /// order, then the FC head; RNN: the LSTM cell, then the FC head.
    /// Call [`Self::validate`] first — this derives geometry and assumes
    /// a well-formed arch.
    pub fn layer_shapes(&self) -> Vec<LayerShape> {
        match self {
            Arch::Mlp { sizes } => sizes
                .windows(2)
                .map(|wd| LayerShape { kind: LayerKind::Fc, dims: vec![wd[1], wd[0]] })
                .collect(),
            Arch::Cnn(spec) => {
                let cfgs = spec.conv_configs(1, 1);
                let mut out: Vec<LayerShape> = cfgs
                    .iter()
                    .map(|c| LayerShape {
                        kind: LayerKind::Conv,
                        dims: vec![c.k, c.c, c.r, c.s],
                    })
                    .collect();
                // The pooled spatial dims are batch-independent, so the
                // head's input width is a pure property of the arch.
                let feat = spec.head_features(1);
                out.push(LayerShape { kind: LayerKind::Fc, dims: vec![spec.classes, feat] });
                out
            }
            Arch::Rnn(spec) => {
                // One Lstm layer per stacked cell (bottom-up: c -> k, then
                // k -> k), then the head — kind-aware validation falls out
                // of the shared per-layer dims/length checks.
                let mut out: Vec<LayerShape> = (0..spec.layers)
                    .map(|i| LayerShape {
                        kind: LayerKind::Lstm,
                        dims: vec![spec.k, if i == 0 { spec.c } else { spec.k }],
                    })
                    .collect();
                out.push(LayerShape {
                    kind: LayerKind::Fc,
                    dims: vec![spec.classes, spec.k],
                });
                out
            }
        }
    }

    fn encode(&self, e: &mut Enc) {
        match self {
            Arch::Mlp { sizes } => {
                e.u8(0);
                e.usize_slice(sizes);
            }
            Arch::Cnn(spec) => {
                e.u8(1);
                e.u32(spec.in_c as u32);
                e.u32(spec.in_h as u32);
                e.u32(spec.in_w as u32);
                e.u32(spec.convs.len() as u32);
                for c in &spec.convs {
                    e.usize_slice(&[c.k, c.r, c.s, c.stride, c.pad]);
                }
                e.u32(spec.pool_win as u32);
                e.u32(spec.pool_stride as u32);
                e.u32(spec.classes as u32);
            }
            Arch::Rnn(spec) => {
                // Tag 2 is the pre-stack single-cell format (no layer
                // count; the payload runs straight into TrainMeta, so the
                // field cannot be appended in place). A 1-layer spec
                // writes it byte-identically — old readers and new
                // artifacts interoperate — and only a real stack uses the
                // tag-3 form with the explicit depth.
                if spec.layers == 1 {
                    e.u8(2);
                } else {
                    e.u8(3);
                }
                e.u32(spec.c as u32);
                e.u32(spec.k as u32);
                e.u32(spec.t as u32);
                e.u32(spec.classes as u32);
                if spec.layers != 1 {
                    e.u32(spec.layers as u32);
                }
            }
        }
    }

    fn decode(d: &mut Dec) -> Result<Arch> {
        match d.u8("arch tag")? {
            0 => {
                let sizes = d.usize_slice("mlp sizes")?;
                if sizes.len() < 2 {
                    bail!("artifact mlp arch needs >= 2 sizes, got {:?}", sizes);
                }
                Ok(Arch::Mlp { sizes })
            }
            1 => {
                let in_c = d.u32("cnn in_c")? as usize;
                let in_h = d.u32("cnn in_h")? as usize;
                let in_w = d.u32("cnn in_w")? as usize;
                let n_convs = d.u32("cnn conv count")? as usize;
                let mut convs = Vec::with_capacity(n_convs);
                for i in 0..n_convs {
                    let v = d.usize_slice("conv spec")?;
                    if v.len() != 5 {
                        bail!("artifact conv {} spec needs 5 fields, got {}", i, v.len());
                    }
                    convs.push(crate::coordinator::cnn::ConvSpec {
                        k: v[0],
                        r: v[1],
                        s: v[2],
                        stride: v[3],
                        pad: v[4],
                    });
                }
                if convs.is_empty() {
                    bail!("artifact cnn arch has no conv layers");
                }
                let pool_win = d.u32("pool_win")? as usize;
                let pool_stride = d.u32("pool_stride")? as usize;
                let classes = d.u32("classes")? as usize;
                Ok(Arch::Cnn(CnnSpec {
                    in_c,
                    in_h,
                    in_w,
                    convs,
                    pool_win,
                    pool_stride,
                    classes,
                }))
            }
            tag @ (2 | 3) => {
                let c = d.u32("rnn c")? as usize;
                let k = d.u32("rnn k")? as usize;
                let t = d.u32("rnn t")? as usize;
                let classes = d.u32("rnn classes")? as usize;
                // Tag 2 = the pre-stack format: exactly one cell.
                let layers =
                    if tag == 2 { 1 } else { d.u32("rnn layers")? as usize };
                Ok(Arch::Rnn(RnnSpec { c, k, t, classes, layers }))
            }
            t => bail!("unknown arch tag {} in artifact", t),
        }
    }
}

/// Which primitive a stored layer belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Fc,
    Conv,
    /// A whole LSTM cell: all four gates' input and recurrent weights.
    Lstm,
}

/// One layer's canonical (unblocked) parameters. `Fc`: `w` is row-major
/// `[K][C]`, dims `[k, c]`, `b` is `[K]`. `Conv`: `w` is row-major
/// `[K][C][R][S]`, dims `[k, c, r, s]`, `b` is `[K]`. `Lstm`: dims
/// `[k, c]` (hidden width, per-step input width); `w` is the gate-major
/// concatenation `[4][K][C]` (input weights W) followed by `[4][K][K]`
/// (recurrent weights R), `b` is `[4][K]` — gate order i, g, f, o
/// throughout ([`crate::primitives::lstm::GATE_ACTS`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    pub kind: LayerKind,
    pub dims: Vec<usize>,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl LayerParams {
    pub fn fc(k: usize, c: usize, w: Vec<f32>, b: Vec<f32>) -> LayerParams {
        LayerParams { kind: LayerKind::Fc, dims: vec![k, c], w, b }
    }

    pub fn conv(
        k: usize,
        c: usize,
        r: usize,
        s: usize,
        w: Vec<f32>,
        b: Vec<f32>,
    ) -> LayerParams {
        LayerParams { kind: LayerKind::Conv, dims: vec![k, c, r, s], w, b }
    }

    /// One LSTM cell (`k` = hidden width, `c` = per-step input width):
    /// `w = [W: 4·K·C | R: 4·K·K]`, `b = [4][K]`, gate-major.
    pub fn lstm(k: usize, c: usize, w: Vec<f32>, b: Vec<f32>) -> LayerParams {
        LayerParams { kind: LayerKind::Lstm, dims: vec![k, c], w, b }
    }

    /// Output-channel count (`K`) — `dims[0]` for every layer kind.
    pub fn k(&self) -> usize {
        self.dims[0]
    }

    /// Check this stored layer against the kind + dims a model expects at
    /// that position — the one mismatch gate every import path
    /// (trainer re-pack, CNN re-pack, serving weight-set build) goes
    /// through, so the check and its error message can never drift.
    pub fn expect(&self, what: &str, kind: LayerKind, dims: &[usize]) -> Result<()> {
        fn name(k: LayerKind) -> &'static str {
            match k {
                LayerKind::Fc => "fc",
                LayerKind::Conv => "conv",
                LayerKind::Lstm => "lstm",
            }
        }
        if self.kind != kind || self.dims != dims {
            bail!(
                "{}: model expects {} {:?}, artifact has {} {:?}",
                what,
                name(kind),
                dims,
                name(self.kind),
                self.dims
            );
        }
        Ok(())
    }

    fn weight_len(&self) -> usize {
        match self.kind {
            // One LSTM cell stores all four gates' W ([4][K][C]) and R
            // ([4][K][K]) back to back.
            LayerKind::Lstm => 4 * self.dims[0] * (self.dims[1] + self.dims[0]),
            _ => self.dims.iter().product(),
        }
    }

    fn bias_len(&self) -> usize {
        match self.kind {
            LayerKind::Lstm => 4 * self.k(),
            _ => self.k(),
        }
    }
}

/// Training-state metadata carried alongside the parameters, so a resumed
/// run continues exactly where the snapshot was taken.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainMeta {
    /// Completed epochs at snapshot time.
    pub epoch: u64,
    /// Global step cursor (the synthetic data pipeline indexes batches by
    /// step, so this is all a resumed run needs to replay the schedule).
    pub step: u64,
    /// The run seed — regenerates the synthetic dataset (and the serving
    /// eval set) deterministically.
    pub seed: u64,
    /// Training RNG state ([`crate::util::rng::Rng::state`]).
    pub rng: [u64; 4],
    /// Last training loss at snapshot time.
    pub loss: f32,
    /// Eval accuracy at snapshot time (fraction in `[0, 1]`).
    pub accuracy: f64,
}

impl TrainMeta {
    /// Metadata for a model that was not produced by the training driver
    /// (e.g. hand-built in a test).
    pub fn fresh(seed: u64) -> TrainMeta {
        TrainMeta {
            epoch: 0,
            step: 0,
            seed,
            rng: crate::util::rng::Rng::new(seed).state(),
            loss: 0.0,
            accuracy: 0.0,
        }
    }

    fn encode(&self, e: &mut Enc) {
        e.u64(self.epoch);
        e.u64(self.step);
        e.u64(self.seed);
        for s in self.rng {
            e.u64(s);
        }
        e.f32(self.loss);
        e.f64(self.accuracy);
    }

    fn decode(d: &mut Dec) -> Result<TrainMeta> {
        Ok(TrainMeta {
            epoch: d.u64("meta epoch")?,
            step: d.u64("meta step")?,
            seed: d.u64("meta seed")?,
            rng: [
                d.u64("meta rng")?,
                d.u64("meta rng")?,
                d.u64("meta rng")?,
                d.u64("meta rng")?,
            ],
            loss: d.f32("meta loss")?,
            accuracy: d.f64("meta accuracy")?,
        })
    }
}

/// A complete model artifact: arch + training metadata + canonical
/// per-layer parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    pub arch: Arch,
    pub meta: TrainMeta,
    pub layers: Vec<LayerParams>,
}

impl ModelArtifact {
    pub fn new(arch: Arch, meta: TrainMeta, layers: Vec<LayerParams>) -> ModelArtifact {
        ModelArtifact { arch, meta, layers }
    }

    /// Structural validation: the arch must be semantically buildable
    /// ([`Arch::validate`]), the stored layers must match its expected
    /// layer list exactly (kind, dims, weight/bias lengths), and every
    /// parameter must be finite. Run on every load; callable on
    /// hand-built artifacts too.
    pub fn validate(&self) -> Result<()> {
        self.arch.validate()?;
        let want = self.arch.layer_shapes();
        if self.layers.len() != want.len() {
            bail!(
                "artifact has {} layers, arch {} expects {}",
                self.layers.len(),
                self.arch.describe(),
                want.len()
            );
        }
        for (i, (l, w)) in self.layers.iter().zip(&want).enumerate() {
            if l.kind != w.kind || l.dims != w.dims {
                bail!(
                    "artifact layer {}: stored {:?}{:?}, arch expects {:?}{:?}",
                    i, l.kind, l.dims, w.kind, w.dims
                );
            }
            if l.w.len() != l.weight_len() {
                bail!(
                    "artifact layer {}: {} weight values for dims {:?} (want {})",
                    i,
                    l.w.len(),
                    l.dims,
                    l.weight_len()
                );
            }
            if l.b.len() != l.bias_len() {
                bail!(
                    "artifact layer {}: {} bias values, want {}",
                    i,
                    l.b.len(),
                    l.bias_len()
                );
            }
            if let Some(j) = l.w.iter().chain(&l.b).position(|v| !v.is_finite()) {
                bail!("artifact layer {}: non-finite parameter at flat index {}", i, j);
            }
        }
        Ok(())
    }

    /// Total stored parameter count (weights + biases).
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Serialize to the full file byte layout (header + checksummed
    /// payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Enc::new();
        self.arch.encode(&mut p);
        self.meta.encode(&mut p);
        p.u32(self.layers.len() as u32);
        for l in &self.layers {
            p.u8(match l.kind {
                LayerKind::Fc => 0,
                LayerKind::Conv => 1,
                LayerKind::Lstm => 2,
            });
            p.usize_slice(&l.dims);
            p.f32_slice(&l.w);
            p.f32_slice(&l.b);
        }
        let mut e = Enc::new();
        e.buf.extend_from_slice(&MAGIC);
        e.u32(SCHEMA_VERSION);
        e.u64(p.buf.len() as u64);
        e.u32(crc32(&p.buf));
        e.buf.extend_from_slice(&p.buf);
        e.buf
    }

    /// Parse + verify the full file byte layout. Magic, version, length
    /// and checksum are all hard gates; the decoded artifact is then
    /// structurally [`Self::validate`]d.
    pub fn decode(bytes: &[u8]) -> Result<ModelArtifact> {
        let mut d = Dec::new(bytes);
        let magic = (0..8)
            .map(|_| d.u8("magic"))
            .collect::<Result<Vec<u8>>>()
            .map_err(|_| anyhow!("not a model artifact: file shorter than the header"))?;
        if magic != MAGIC {
            bail!("not a model artifact: bad magic {:02x?}", &magic[..]);
        }
        let version = d.u32("schema version")?;
        if version != SCHEMA_VERSION {
            bail!(
                "artifact schema version {} not supported (this build reads version {}); \
                 re-export the model with a matching build",
                version,
                SCHEMA_VERSION
            );
        }
        let payload_len = d.u64("payload length")? as usize;
        let want_crc = d.u32("checksum")?;
        if d.remaining() != payload_len {
            bail!(
                "artifact payload is {} bytes, header promises {} — file truncated or \
                 trailing garbage",
                d.remaining(),
                payload_len
            );
        }
        let payload = &bytes[bytes.len() - payload_len..];
        let got_crc = crc32(payload);
        if got_crc != want_crc {
            bail!(
                "artifact checksum mismatch (stored {:08x}, computed {:08x}) — file corrupted",
                want_crc,
                got_crc
            );
        }
        let mut d = Dec::new(payload);
        let arch = Arch::decode(&mut d)?;
        let meta = TrainMeta::decode(&mut d)?;
        let n_layers = d.u32("layer count")? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let kind = match d.u8("layer kind")? {
                0 => LayerKind::Fc,
                1 => LayerKind::Conv,
                2 => LayerKind::Lstm,
                t => bail!("artifact layer {}: unknown kind tag {}", i, t),
            };
            let dims = d.usize_slice("layer dims")?;
            let w = d.f32_slice("layer weights")?;
            let b = d.f32_slice("layer bias")?;
            layers.push(LayerParams { kind, dims, w, b });
        }
        if !d.done() {
            bail!("artifact payload has {} trailing bytes after the last layer", d.remaining());
        }
        let art = ModelArtifact { arch, meta, layers };
        art.validate()?;
        Ok(art)
    }

    /// Write to `path` atomically: encode, write a sibling temp file, then
    /// rename over the target — a hot-reloading server never observes a
    /// half-written artifact.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<PathBuf> {
        let path = path.as_ref();
        self.validate()?;
        let bytes = self.encode();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| anyhow!("creating artifact dir {}: {}", dir.display(), e))?;
            }
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &bytes)
            .map_err(|e| anyhow!("writing artifact {}: {}", tmp.display(), e))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow!("renaming artifact into {}: {}", path.display(), e))?;
        Ok(path.to_path_buf())
    }

    /// Read + verify an artifact file.
    pub fn load(path: impl AsRef<Path>) -> Result<ModelArtifact> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow!("reading artifact {}: {}", path.display(), e))?;
        ModelArtifact::decode(&bytes)
            .map_err(|e| anyhow!("artifact {}: {}", path.display(), e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cnn::{CnnSpec, ConvSpec};
    use crate::util::rng::Rng;

    fn mlp_artifact() -> ModelArtifact {
        let mut rng = Rng::new(5);
        let arch = Arch::Mlp { sizes: vec![6, 8, 3] };
        let layers = vec![
            LayerParams::fc(8, 6, rng.vec_f32(48, -1.0, 1.0), rng.vec_f32(8, -0.1, 0.1)),
            LayerParams::fc(3, 8, rng.vec_f32(24, -1.0, 1.0), rng.vec_f32(3, -0.1, 0.1)),
        ];
        ModelArtifact::new(arch, TrainMeta::fresh(5), layers)
    }

    fn cnn_artifact() -> ModelArtifact {
        let mut rng = Rng::new(6);
        let spec = CnnSpec {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            convs: vec![
                ConvSpec { k: 3, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 1, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        };
        let layers = vec![
            LayerParams::conv(3, 2, 3, 3, rng.vec_f32(54, -1.0, 1.0), rng.vec_f32(3, -0.1, 0.1)),
            LayerParams::conv(4, 3, 1, 1, rng.vec_f32(12, -1.0, 1.0), rng.vec_f32(4, -0.1, 0.1)),
            LayerParams::fc(3, 4, rng.vec_f32(12, -1.0, 1.0), rng.vec_f32(3, -0.1, 0.1)),
        ];
        ModelArtifact::new(Arch::Cnn(spec), TrainMeta::fresh(6), layers)
    }

    fn rnn_artifact() -> ModelArtifact {
        let mut rng = Rng::new(7);
        let spec = crate::coordinator::rnn::RnnSpec { c: 3, k: 4, t: 2, classes: 3, layers: 1 };
        let layers = vec![
            LayerParams::lstm(
                4,
                3,
                rng.vec_f32(4 * 4 * (3 + 4), -1.0, 1.0),
                rng.vec_f32(4 * 4, -0.1, 0.1),
            ),
            LayerParams::fc(3, 4, rng.vec_f32(12, -1.0, 1.0), rng.vec_f32(3, -0.1, 0.1)),
        ];
        ModelArtifact::new(Arch::Rnn(spec), TrainMeta::fresh(7), layers)
    }

    fn stacked_rnn_artifact() -> ModelArtifact {
        let mut rng = Rng::new(8);
        let spec = crate::coordinator::rnn::RnnSpec { c: 3, k: 4, t: 2, classes: 3, layers: 3 };
        let mut layers = vec![LayerParams::lstm(
            4,
            3,
            rng.vec_f32(4 * 4 * (3 + 4), -1.0, 1.0),
            rng.vec_f32(4 * 4, -0.1, 0.1),
        )];
        for _ in 1..3 {
            layers.push(LayerParams::lstm(
                4,
                4,
                rng.vec_f32(4 * 4 * (4 + 4), -1.0, 1.0),
                rng.vec_f32(4 * 4, -0.1, 0.1),
            ));
        }
        layers.push(LayerParams::fc(3, 4, rng.vec_f32(12, -1.0, 1.0), rng.vec_f32(3, -0.1, 0.1)));
        ModelArtifact::new(Arch::Rnn(spec), TrainMeta::fresh(8), layers)
    }

    #[test]
    fn encode_decode_roundtrip_all_arches() {
        for art in [mlp_artifact(), cnn_artifact(), rnn_artifact(), stacked_rnn_artifact()] {
            let bytes = art.encode();
            let back = ModelArtifact::decode(&bytes).unwrap();
            assert_eq!(art, back, "decode(encode(x)) must be x");
        }
    }

    #[test]
    fn single_layer_rnn_artifact_keeps_the_pre_stack_byte_format() {
        // Back-compat is a byte-level contract: a layers=1 arch must
        // encode to exactly the pre-stack tag-2 payload (no trailing
        // depth field — the old format runs straight into TrainMeta), and
        // a hand-built old-format payload must decode as layers=1.
        let art = rnn_artifact();
        let bytes = art.encode();
        // Header is magic(8) + version(4) + len(8) + crc(4) = 24 bytes;
        // the first payload byte is the arch tag.
        assert_eq!(bytes[24], 2, "layers=1 writes the pre-stack arch tag");
        let spec = match &art.arch {
            Arch::Rnn(s) => *s,
            _ => unreachable!(),
        };
        // Rebuild the payload exactly as a pre-stack writer would have.
        let mut p = Enc::new();
        p.u8(2);
        p.u32(spec.c as u32);
        p.u32(spec.k as u32);
        p.u32(spec.t as u32);
        p.u32(spec.classes as u32);
        art.meta.encode(&mut p);
        p.u32(art.layers.len() as u32);
        for l in &art.layers {
            p.u8(match l.kind {
                LayerKind::Fc => 0,
                LayerKind::Conv => 1,
                LayerKind::Lstm => 2,
            });
            p.usize_slice(&l.dims);
            p.f32_slice(&l.w);
            p.f32_slice(&l.b);
        }
        let mut e = Enc::new();
        e.buf.extend_from_slice(&MAGIC);
        e.u32(SCHEMA_VERSION);
        e.u64(p.buf.len() as u64);
        e.u32(crc32(&p.buf));
        e.buf.extend_from_slice(&p.buf);
        assert_eq!(bytes, e.buf, "layers=1 byte layout unchanged from pre-stack");
        let back = ModelArtifact::decode(&e.buf).unwrap();
        assert_eq!(back, art, "old-format bytes decode as a layers=1 stack");
        // And a real stack takes the tag-3 form.
        let stacked = stacked_rnn_artifact().encode();
        assert_eq!(stacked[24], 3, "layers>1 uses the explicit-depth tag");
    }

    #[test]
    fn stacked_rnn_artifact_validation_is_per_cell() {
        // A deep cell must be k -> k; lying about its input width is
        // caught by the kind-aware per-layer shape check.
        let mut art = stacked_rnn_artifact();
        art.layers[1] = LayerParams::lstm(
            4,
            3,
            vec![0.0; 4 * 4 * (3 + 4)],
            vec![0.0; 16],
        );
        let err = art.validate().unwrap_err();
        assert!(err.to_string().contains("layer 1"), "{}", err);
        // Wrong depth: arch says 3 cells + head, artifact carries 2 + head.
        let mut art = stacked_rnn_artifact();
        art.layers.remove(1);
        assert!(art.validate().unwrap_err().to_string().contains("expects 4"));
        // layers=0 is unbuildable and must error on decode, not panic.
        let mut art = rnn_artifact();
        art.arch = Arch::Rnn(crate::coordinator::rnn::RnnSpec {
            c: 3,
            k: 4,
            t: 2,
            classes: 3,
            layers: 0,
        });
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains("stacked layer"), "{}", err);
    }

    #[test]
    fn rnn_artifact_validation_catches_lies() {
        // Truncated cell weights (W+R concat too short).
        let mut art = rnn_artifact();
        art.layers[0].w.pop();
        assert!(art.validate().unwrap_err().to_string().contains("weight values"));
        // Gate biases must be [4][K], not [K].
        let mut art = rnn_artifact();
        art.layers[0].b.truncate(4);
        assert!(art.validate().unwrap_err().to_string().contains("bias values"));
        // Arch/layer kind mismatch.
        let mut art = rnn_artifact();
        art.layers[0] = LayerParams::fc(4, 3, vec![0.0; 12], vec![0.0; 4]);
        assert!(art.validate().is_err(), "fc layer where the arch expects an lstm cell");
        // Hostile arch values error on decode, never panic downstream.
        let mut art = rnn_artifact();
        art.arch =
            Arch::Rnn(crate::coordinator::rnn::RnnSpec { c: 3, k: 4, t: 0, classes: 3, layers: 1 });
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{}", err);
        let mut art = rnn_artifact();
        art.arch =
            Arch::Rnn(crate::coordinator::rnn::RnnSpec { c: 3, k: 4, t: 2, classes: 1, layers: 1 });
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains("classes"), "{}", err);
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("brgemm_modelio_test");
        let path = dir.join("roundtrip.bin");
        let art = mlp_artifact();
        art.save(&path).unwrap();
        let back = ModelArtifact::load(&path).unwrap();
        assert_eq!(art, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = mlp_artifact().encode();
        bytes[0] ^= 0xFF;
        let err = ModelArtifact::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{}", err);
        // A short junk file is "not an artifact", not a panic.
        let err = ModelArtifact::decode(b"hi").unwrap_err();
        assert!(err.to_string().contains("not a model artifact"), "{}", err);
    }

    #[test]
    fn stale_version_rejected_with_clear_error() {
        let mut bytes = mlp_artifact().encode();
        bytes[8..12].copy_from_slice(&(SCHEMA_VERSION + 1).to_le_bytes());
        let err = ModelArtifact::decode(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("schema version") && msg.contains("not supported"), "{}", msg);
    }

    #[test]
    fn corruption_rejected_by_checksum() {
        let art = mlp_artifact();
        let bytes = art.encode();
        // Flip one payload bit anywhere: the CRC must catch it.
        for at in [24usize, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x01;
            let err = ModelArtifact::decode(&bad).unwrap_err();
            assert!(
                err.to_string().contains("checksum mismatch"),
                "byte {}: {}",
                at,
                err
            );
        }
    }

    #[test]
    fn truncation_rejected() {
        let bytes = mlp_artifact().encode();
        for cut in [10, 20, bytes.len() / 2, bytes.len() - 1] {
            let err = ModelArtifact::decode(&bytes[..cut]).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("truncated") || msg.contains("shorter"),
                "cut {}: {}",
                cut,
                msg
            );
        }
    }

    #[test]
    fn validate_catches_shape_lies() {
        // Wrong layer count.
        let mut art = mlp_artifact();
        art.layers.pop();
        assert!(art.validate().unwrap_err().to_string().contains("expects 2"));
        // Wrong dims.
        let mut art = mlp_artifact();
        art.layers[0].dims = vec![8, 7];
        assert!(art.validate().is_err());
        // Weight length disagrees with dims (forge dims+weights together so
        // the dims check passes and the length check has to catch it).
        let mut art = mlp_artifact();
        art.layers[0].w.pop();
        assert!(art.validate().unwrap_err().to_string().contains("weight values"));
        // Non-finite parameter.
        let mut art = mlp_artifact();
        art.layers[1].w[3] = f32::NAN;
        assert!(art.validate().unwrap_err().to_string().contains("non-finite"));
        // A forged-but-consistent artifact still fails against its arch.
        let mut art = cnn_artifact();
        art.layers[0] = LayerParams::conv(3, 2, 1, 1, vec![0.0; 6], vec![0.0; 3]);
        assert!(art.validate().is_err(), "conv dims must match the arch's filter shape");
    }

    #[test]
    fn hostile_arch_rejected_with_error_not_panic() {
        // A well-checksummed artifact whose *arch* is unbuildable must
        // error on decode, never divide-by-zero or assert downstream.
        let mut art = cnn_artifact();
        if let Arch::Cnn(spec) = &mut art.arch {
            spec.convs[1].stride = 0;
        }
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains("stride"), "{}", err);

        let mut art = cnn_artifact();
        if let Arch::Cnn(spec) = &mut art.arch {
            spec.convs[0].r = 99; // filter larger than the padded input
        }
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{}", err);

        let mut art = cnn_artifact();
        if let Arch::Cnn(spec) = &mut art.arch {
            spec.pool_win = 50; // window larger than the feature map
        }
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains("pool window"), "{}", err);

        let mut art = mlp_artifact();
        art.arch = Arch::Mlp { sizes: vec![6, 0, 3] };
        let err = ModelArtifact::decode(&art.encode()).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{}", err);
    }

    #[test]
    fn meta_survives_roundtrip() {
        let mut art = mlp_artifact();
        art.meta = TrainMeta {
            epoch: 7,
            step: 901,
            seed: 42,
            rng: [1, 2, 3, 4],
            loss: 0.125,
            accuracy: 0.9375,
        };
        let back = ModelArtifact::decode(&art.encode()).unwrap();
        assert_eq!(back.meta, art.meta);
    }
}
