//! AVX-512 BRGEMM microkernel.
//!
//! Row-major mirror of the paper's Figure 2(b) outer-product microkernel:
//! the accumulator tile is `MR` rows × `NRV` zmm vectors (16 f32 lanes
//! each) and is pinned in registers for the whole batch-reduce chain. Per
//! `k` step the kernel loads the `NRV` vectors of one `B_i` row, then
//! performs `MR` broadcast+FMA rank-1 updates — with the default
//! `MR = 6, NRV = 4` tile this uses 24 accumulator + 4 B + 1 broadcast
//! registers = 29 of the 32 zmm registers, the same occupancy strategy as
//! the paper's 64×6 column-major tile.
//!
//! Ragged edges are handled with AVX-512 write-masks on the last vector
//! column and const-generic dispatch on the remaining rows, so arbitrary
//! (m, n, k) shapes run through the same code path (no scalar cleanup
//! loop) — this is what lets the DL primitives use small, odd blocking
//! factors (paper §3.1.2 "our batch-reduce GEMM allows small blocking
//! values").

#![allow(unsafe_op_in_unsafe_fn)]

use super::BrgemmDesc;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

pub(super) const VLEN: usize = 16;
/// Max register-tile rows.
pub(super) const MR_MAX: usize = 6;
/// Max register-tile width in vectors.
pub(super) const NRV_MAX: usize = 4;

/// # Safety
/// Same contract as [`super::scalar::brgemm_offs`]; additionally requires
/// the CPU to support AVX-512F (guaranteed by the [`super::Isa`] dispatch).
#[cfg(target_arch = "x86_64")]
pub(super) unsafe fn brgemm_offs(
    d: &BrgemmDesc,
    a: &[f32],
    a_offs: &[usize],
    b: &[f32],
    b_offs: &[usize],
    c: &mut [f32],
) {
    brgemm_offs_avx512(d, a.as_ptr(), a_offs, b.as_ptr(), b_offs, c.as_mut_ptr());
}

#[cfg(not(target_arch = "x86_64"))]
pub(super) unsafe fn brgemm_offs(
    d: &BrgemmDesc,
    a: &[f32],
    a_offs: &[usize],
    b: &[f32],
    b_offs: &[usize],
    c: &mut [f32],
) {
    super::scalar::brgemm_offs(d, a, a_offs, b, b_offs, c)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn brgemm_offs_avx512(
    d: &BrgemmDesc,
    a: *const f32,
    a_offs: &[usize],
    b: *const f32,
    b_offs: &[usize],
    c: *mut f32,
) {
    let (m, n) = (d.m, d.n);
    let mut inn = 0;
    while inn < n {
        // Column block: up to NRV_MAX full vectors; the final (possibly
        // partial) vector gets a lane mask.
        let nb = (NRV_MAX * VLEN).min(n - inn);
        let nrv = nb.div_ceil(VLEN);
        let tail = nb - (nrv - 1) * VLEN; // lanes in the last vector, 1..=16
        let mask: __mmask16 = if tail == VLEN { 0xFFFF } else { (1u16 << tail) - 1 };
        let mut im = 0;
        while im < m {
            let mb = MR_MAX.min(m - im);
            dispatch_tile(d, a, a_offs, b, b_offs, c, im, inn, mb, nrv, mask);
            im += mb;
        }
        inn += nb;
    }
}

/// Const-generic dispatch over (rows, vector-columns) of the tile.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
#[allow(clippy::too_many_arguments)]
unsafe fn dispatch_tile(
    d: &BrgemmDesc,
    a: *const f32,
    a_offs: &[usize],
    b: *const f32,
    b_offs: &[usize],
    c: *mut f32,
    im: usize,
    inn: usize,
    mb: usize,
    nrv: usize,
    mask: __mmask16,
) {
    macro_rules! go {
        ($mr:literal, $nrv:literal) => {
            tile::<$mr, $nrv>(d, a, a_offs, b, b_offs, c, im, inn, mask)
        };
    }
    macro_rules! by_nrv {
        ($mr:literal) => {
            match nrv {
                1 => go!($mr, 1),
                2 => go!($mr, 2),
                3 => go!($mr, 3),
                _ => go!($mr, 4),
            }
        };
    }
    match mb {
        1 => by_nrv!(1),
        2 => by_nrv!(2),
        3 => by_nrv!(3),
        4 => by_nrv!(4),
        5 => by_nrv!(5),
        _ => by_nrv!(6),
    }
}

/// One register tile: `MR` rows × `NRV` vectors, last vector masked.
///
/// The accumulators live in `[[__m512; NRV]; MR]`; with const bounds the
/// loops fully unroll and LLVM keeps the array in zmm registers.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile<const MR: usize, const NRV: usize>(
    d: &BrgemmDesc,
    a: *const f32,
    a_offs: &[usize],
    b: *const f32,
    b_offs: &[usize],
    c: *mut f32,
    im: usize,
    inn: usize,
    mask: __mmask16,
) {
    let mut acc = [[_mm512_setzero_ps(); NRV]; MR];
    let full_mask = mask == 0xFFFF;

    // Batch-reduce loop: the accumulation chain spans every (A_i, B_i) pair.
    for (ao, bo) in a_offs.iter().zip(b_offs) {
        let a_base = a.add(ao + im * d.lda);
        let b_base = b.add(bo + inn);
        for kk in 0..d.k {
            // Load one row of B_i (NRV vectors; last one masked).
            let b_row = b_base.add(kk * d.ldb);
            let mut bv = [_mm512_setzero_ps(); NRV];
            for v in 0..NRV {
                bv[v] = if v + 1 < NRV || full_mask {
                    _mm512_loadu_ps(b_row.add(v * VLEN))
                } else {
                    _mm512_maskz_loadu_ps(mask, b_row.add(v * VLEN))
                };
            }
            // MR broadcast+FMA rank-1 updates.
            for r in 0..MR {
                let av = _mm512_set1_ps(*a_base.add(r * d.lda + kk * d.a_kstride));
                for v in 0..NRV {
                    acc[r][v] = _mm512_fmadd_ps(av, bv[v], acc[r][v]);
                }
            }
        }
    }

    // Store once after the full chain, applying β·C + α·acc.
    let alpha = _mm512_set1_ps(d.alpha);
    let beta = _mm512_set1_ps(d.beta);
    let simple = d.alpha == 1.0 && d.beta == 0.0;
    for r in 0..MR {
        let crow = c.add((im + r) * d.ldc + inn);
        for v in 0..NRV {
            let dst = crow.add(v * VLEN);
            let last = v + 1 == NRV && !full_mask;
            let val = if simple {
                acc[r][v]
            } else {
                let old = if last {
                    _mm512_maskz_loadu_ps(mask, dst)
                } else {
                    _mm512_loadu_ps(dst)
                };
                _mm512_fmadd_ps(beta, old, _mm512_mul_ps(alpha, acc[r][v]))
            };
            if last {
                _mm512_mask_storeu_ps(dst, mask, val);
            } else {
                _mm512_storeu_ps(dst, val);
            }
        }
    }
}
