//! The single building block: the **batch-reduce GEMM** kernel.
//!
//! Materialises the paper's Equation (§2):
//!
//! ```text
//!   C = β·C + α · Σ_{i=0..N-1} A_i · B_i
//! ```
//!
//! where each `A_i` is an `m×k` block, each `B_i` a `k×n` block, and the
//! partial products of the whole *batch* are **reduced into a single
//! accumulator block C** that stays resident in registers for the entire
//! accumulation chain (Algorithm 1 of the paper). This is the property that
//! distinguishes BRGEMM from batched GEMM (`C_i = β·C_i + α·A_i·B_i`, one
//! output per pair, no reduction, no output-register reuse).
//!
//! ## Memory convention
//!
//! All matrices are **row-major**: `A_i` is `m×k` with leading dimension
//! `lda ≥ k`, `B_i` is `k×n` with `ldb ≥ n`, `C` is `m×n` with `ldc ≥ n`.
//! The microkernel therefore vectorises along `n` (rows of `B` / `C` are
//! contiguous) and broadcasts elements of `A` — the row-major mirror image
//! of the paper's Figure 2(b) column-major outer-product microkernel; the
//! register blocking analysis is identical with the roles of `m_b`/`n_b`
//! exchanged.
//!
//! ## Variants (paper §2)
//!
//! * **address list** — [`BrgemmKernel::execute_offs`]: arbitrary block
//!   positions in the input tensors, given as element offsets. This is the
//!   variant the paper's pointer arrays (`A_ptrs`/`B_ptrs`) correspond to,
//!   and what the convolutions use (blocks at `(r, s, c_b)`-dependent
//!   positions, including overlapping input windows).
//! * **strided** — [`BrgemmKernel::execute_strided`]: fixed element stride
//!   between consecutive blocks (the `strided-batch-gemm` special case).
//! * **single** — [`BrgemmKernel::execute_single`]: batch of one, i.e. a
//!   plain small GEMM; used by baselines and the eltwise-free paths.
//!
//! ## Fused epilogues
//!
//! The kernel optionally applies a bias and/or an activation to the output
//! block right after the accumulation chain while it is cache-hot
//! ([`Epilogue`]), which is how the DL primitives fuse the element-wise
//! stages of LSTM/MLP into the GEMM (paper §3.1.2, §3.3.2).

mod avx512;
mod gemm;
mod scalar;

pub use gemm::{batched_gemm, gemm, gemm_at, Gemm};

use crate::primitives::eltwise::Act;

/// Immutable problem descriptor for a BRGEMM kernel instance.
///
/// Mirrors a LIBXSMM kernel-generation request: one descriptor = one JIT'd
/// kernel in the paper; here one descriptor = one dispatched/monomorphised
/// microkernel configuration, constructed once and reused across calls.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrgemmDesc {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Leading dimensions (row-major: distance between consecutive rows).
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
    /// Element stride of A along the k axis (normally 1). The microkernel
    /// reads A by scalar broadcast, so a non-unit k-stride is free — this
    /// lets the weight-update passes consume activations "transposed"
    /// without a physical reformat (an extension over LIBXSMM's interface;
    /// benchmarked against the reformat path as an ablation).
    pub a_kstride: usize,
    pub alpha: f32,
    /// β = 0 ⇒ C is overwritten (no read of the destination);
    /// β = 1 ⇒ accumulate into C. Other values scale C on load.
    pub beta: f32,
}

impl BrgemmDesc {
    /// Dense descriptor: `lda = k`, `ldb = ldc = n`, α = 1, β = 0.
    pub fn dense(m: usize, n: usize, k: usize) -> BrgemmDesc {
        BrgemmDesc { m, n, k, lda: k, ldb: n, ldc: n, a_kstride: 1, alpha: 1.0, beta: 0.0 }
    }

    pub fn with_beta(mut self, beta: f32) -> BrgemmDesc {
        self.beta = beta;
        self
    }

    pub fn with_alpha(mut self, alpha: f32) -> BrgemmDesc {
        self.alpha = alpha;
        self
    }

    pub fn with_ld(mut self, lda: usize, ldb: usize, ldc: usize) -> BrgemmDesc {
        self.lda = lda;
        self.ldb = ldb;
        self.ldc = ldc;
        self
    }

    pub fn with_a_kstride(mut self, s: usize) -> BrgemmDesc {
        self.a_kstride = s;
        self
    }

    /// Flop count of one kernel invocation with batch length `batch`.
    pub fn flops(&self, batch: usize) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64 * batch as f64
    }

    fn validate(&self) {
        assert!(self.m > 0 && self.n > 0 && self.k > 0, "empty gemm {:?}", self);
        assert!(self.a_kstride >= 1, "a_kstride must be >= 1");
        // NOTE: no `lda >= k` requirement — A rows may legitimately overlap
        // (convolution input windows with stride < taps, transposed views
        // via a_kstride); bounds are enforced per call from `a_extent`.
        assert!(self.ldb >= self.n, "ldb {} < n {}", self.ldb, self.n);
        assert!(self.ldc >= self.n, "ldc {} < n {}", self.ldc, self.n);
    }

    /// Largest element offset (+1) an A block touches.
    fn a_extent(&self) -> usize {
        (self.m - 1) * self.lda + (self.k - 1) * self.a_kstride + 1
    }
}

/// Fused post-op applied to the output block while it is register/cache hot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Epilogue {
    /// Store C as-is.
    None,
    /// `C = act(C)`.
    Act(Act),
    /// `C = act(C + bias)`, `bias` broadcast along rows (length `n`).
    /// This matches the LSTM/FC usage where the bias initialises the
    /// accumulator; supplying it in the epilogue instead lets β=0 kernels
    /// skip the C pre-load entirely.
    BiasAct(Act),
}

/// Instruction set selected for the microkernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx512,
}

impl Isa {
    /// Runtime detection with env-var override (`BRGEMM_ISA=scalar|avx512`).
    pub fn detect() -> Isa {
        if let Ok(v) = std::env::var("BRGEMM_ISA") {
            if let Some(isa) = Isa::parse(&v) {
                return isa;
            }
        }
        if is_x86_feature_detected!("avx512f") {
            Isa::Avx512
        } else {
            Isa::Scalar
        }
    }

    /// Stable name used in tuning-cache keys and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx512 => "avx512",
        }
    }

    /// Inverse of [`Isa::name`].
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx512" => Some(Isa::Avx512),
            _ => None,
        }
    }

    /// Register-tile geometry of the microkernel as `(rows, f32 lanes per
    /// vector)` — the granularity the autotuner's cost model uses to score
    /// how well a blocking fills the accumulator tile.
    pub fn microkernel_tile(self) -> (usize, usize) {
        match self {
            Isa::Scalar => (1, 1),
            Isa::Avx512 => (avx512::MR_MAX, avx512::VLEN),
        }
    }
}

/// A configured batch-reduce GEMM kernel.
///
/// Construction performs the (cheap) dispatch work — ISA detection and
/// register-tile selection — so the hot path is a direct call into the
/// monomorphised microkernel, mirroring the JIT-once/call-many usage of
/// LIBXSMM kernels in the paper.
#[derive(Debug, Clone)]
pub struct BrgemmKernel {
    pub desc: BrgemmDesc,
    pub isa: Isa,
    pub epilogue: Epilogue,
}

impl BrgemmKernel {
    pub fn new(desc: BrgemmDesc) -> BrgemmKernel {
        desc.validate();
        BrgemmKernel { desc, isa: Isa::detect(), epilogue: Epilogue::None }
    }

    pub fn with_isa(desc: BrgemmDesc, isa: Isa) -> BrgemmKernel {
        desc.validate();
        BrgemmKernel { desc, isa, epilogue: Epilogue::None }
    }

    pub fn with_epilogue(mut self, e: Epilogue) -> BrgemmKernel {
        self.epilogue = e;
        self
    }

    /// Address-list variant: block `i` of A starts at `a[a_offs[i]]`,
    /// block `i` of B at `b[b_offs[i]]`. Offsets are in elements.
    ///
    /// `bias` must be `Some(len n)` iff the epilogue is `BiasAct`.
    pub fn execute_offs(
        &self,
        a: &[f32],
        a_offs: &[usize],
        b: &[f32],
        b_offs: &[usize],
        c: &mut [f32],
        bias: Option<&[f32]>,
    ) {
        let d = &self.desc;
        assert_eq!(a_offs.len(), b_offs.len(), "batch length mismatch");
        let batch = a_offs.len();
        // Bounds: the last element a block touches is
        // (rows-1)*ld + cols-1 from its offset.
        let a_extent = d.a_extent();
        let b_extent = (d.k - 1) * d.ldb + d.n;
        for i in 0..batch {
            assert!(
                a_offs[i] + a_extent <= a.len(),
                "A block {} out of bounds: off {} extent {} len {}",
                i, a_offs[i], a_extent, a.len()
            );
            assert!(
                b_offs[i] + b_extent <= b.len(),
                "B block {} out of bounds: off {} extent {} len {}",
                i, b_offs[i], b_extent, b.len()
            );
        }
        assert!((d.m - 1) * d.ldc + d.n <= c.len(), "C out of bounds");
        if let Epilogue::BiasAct(_) = self.epilogue {
            let bias = bias.expect("BiasAct epilogue requires a bias");
            assert!(bias.len() >= d.n, "bias too short");
        }

        // Safety: all block extents validated above.
        unsafe {
            match self.isa {
                Isa::Scalar => scalar::brgemm_offs(d, a, a_offs, b, b_offs, c),
                Isa::Avx512 => avx512::brgemm_offs(d, a, a_offs, b, b_offs, c),
            }
        }
        self.apply_epilogue(c, bias);
    }

    /// Strided variant: block `i` of A starts at `a_base + i*stride_a`
    /// (elements), likewise for B.
    pub fn execute_strided(
        &self,
        a: &[f32],
        stride_a: usize,
        b: &[f32],
        stride_b: usize,
        batch: usize,
        c: &mut [f32],
        bias: Option<&[f32]>,
    ) {
        // Strided is lowered onto the address-list path (the validation is
        // shared); the offset arrays live on the stack for the chain
        // lengths the primitives use, so this variant never heap-allocates
        // on the hot path.
        const STACK_BATCH: usize = 64;
        if batch <= STACK_BATCH {
            let mut a_offs = [0usize; STACK_BATCH];
            let mut b_offs = [0usize; STACK_BATCH];
            for i in 0..batch {
                a_offs[i] = i * stride_a;
                b_offs[i] = i * stride_b;
            }
            self.execute_offs(a, &a_offs[..batch], b, &b_offs[..batch], c, bias);
        } else {
            let a_offs: Vec<usize> = (0..batch).map(|i| i * stride_a).collect();
            let b_offs: Vec<usize> = (0..batch).map(|i| i * stride_b).collect();
            self.execute_offs(a, &a_offs, b, &b_offs, c, bias);
        }
    }

    /// Batch-of-one: a plain small GEMM through the same microkernel.
    pub fn execute_single(&self, a: &[f32], b: &[f32], c: &mut [f32], bias: Option<&[f32]>) {
        self.execute_offs(a, &[0], b, &[0], c, bias);
    }

    fn apply_epilogue(&self, c: &mut [f32], bias: Option<&[f32]>) {
        let d = &self.desc;
        match self.epilogue {
            Epilogue::None => {}
            Epilogue::Act(act) => {
                for r in 0..d.m {
                    let row = &mut c[r * d.ldc..r * d.ldc + d.n];
                    act.apply_slice(row);
                }
            }
            Epilogue::BiasAct(act) => {
                let bias = bias.unwrap();
                for r in 0..d.m {
                    let row = &mut c[r * d.ldc..r * d.ldc + d.n];
                    for (x, bv) in row.iter_mut().zip(bias) {
                        *x += bv;
                    }
                    act.apply_slice(row);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    /// Naive oracle: independently computed, no shared code with the
    /// kernels under test.
    fn oracle(
        d: &BrgemmDesc,
        a: &[f32],
        a_offs: &[usize],
        b: &[f32],
        b_offs: &[usize],
        c0: &[f32],
    ) -> Vec<f32> {
        let mut c = c0.to_vec();
        for r in 0..d.m {
            for col in 0..d.n {
                let mut acc = 0.0f64;
                for (ao, bo) in a_offs.iter().zip(b_offs) {
                    for kk in 0..d.k {
                        acc += a[ao + r * d.lda + kk] as f64 * b[bo + kk * d.ldb + col] as f64;
                    }
                }
                let idx = r * d.ldc + col;
                c[idx] = d.beta * c0[idx] + d.alpha * acc as f32;
            }
        }
        c
    }

    fn check_case(isa: Isa, m: usize, n: usize, k: usize, batch: usize, alpha: f32, beta: f32) {
        let mut rng = Rng::new((m * 31 + n * 7 + k * 3 + batch) as u64);
        let d = BrgemmDesc::dense(m, n, k).with_alpha(alpha).with_beta(beta);
        // Pack blocks contiguously with a little slack between them.
        let a_block = m * k;
        let b_block = k * n;
        let a = rng.vec_f32(batch * a_block + 5, -1.0, 1.0);
        let b = rng.vec_f32(batch * b_block + 5, -1.0, 1.0);
        let a_offs: Vec<usize> = (0..batch).map(|i| i * a_block).collect();
        let b_offs: Vec<usize> = (0..batch).map(|i| i * b_block).collect();
        let c0 = rng.vec_f32(m * n, -1.0, 1.0);
        let mut c = c0.clone();
        let kern = BrgemmKernel::with_isa(d, isa);
        kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None);
        let want = oracle(&d, &a, &a_offs, &b, &b_offs, &c0);
        for i in 0..c.len() {
            let tol = 1e-4 * (k * batch) as f32;
            assert!(
                (c[i] - want[i]).abs() <= tol.max(1e-5),
                "isa {:?} m{} n{} k{} batch{}: c[{}] = {} want {}",
                isa, m, n, k, batch, i, c[i], want[i]
            );
        }
    }

    fn isas() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar];
        if is_x86_feature_detected!("avx512f") {
            v.push(Isa::Avx512);
        }
        v
    }

    #[test]
    fn exact_tile_sizes() {
        for isa in isas() {
            check_case(isa, 6, 64, 8, 3, 1.0, 0.0);
            check_case(isa, 12, 32, 16, 2, 1.0, 1.0);
            check_case(isa, 28, 16, 4, 1, 1.0, 0.0);
        }
    }

    #[test]
    fn ragged_edges() {
        for isa in isas() {
            // n not a multiple of 16, m not a multiple of the tile height.
            check_case(isa, 7, 17, 5, 2, 1.0, 0.0);
            check_case(isa, 1, 1, 1, 1, 1.0, 0.0);
            check_case(isa, 5, 3, 9, 4, 1.0, 1.0);
            check_case(isa, 13, 66, 11, 3, 1.0, 0.0);
            check_case(isa, 64, 6, 64, 2, 1.0, 0.5);
        }
    }

    #[test]
    fn alpha_beta_combos() {
        for isa in isas() {
            for &(al, be) in &[(1.0, 0.0), (1.0, 1.0), (2.0, 0.0), (0.5, -1.0), (-1.0, 2.0)] {
                check_case(isa, 9, 24, 6, 2, al, be);
            }
        }
    }

    #[test]
    fn strided_variant_matches_addr() {
        let mut rng = Rng::new(77);
        let d = BrgemmDesc::dense(8, 24, 8).with_beta(1.0);
        let batch = 4;
        let a = rng.vec_f32(batch * 64 + 11, -1.0, 1.0);
        let b = rng.vec_f32(batch * 8 * 24 + 3, -1.0, 1.0);
        let c0 = rng.vec_f32(8 * 24, -1.0, 1.0);
        let kern = BrgemmKernel::new(d);
        let mut c1 = c0.clone();
        kern.execute_strided(&a, 64, &b, 8 * 24, batch, &mut c1, None);
        let a_offs: Vec<usize> = (0..batch).map(|i| i * 64).collect();
        let b_offs: Vec<usize> = (0..batch).map(|i| i * 8 * 24).collect();
        let mut c2 = c0.clone();
        kern.execute_offs(&a, &a_offs, &b, &b_offs, &mut c2, None);
        assert_eq!(c1, c2);
    }

    #[test]
    fn leading_dimensions_respected() {
        // Blocks embedded inside larger tensors (lda > k etc.) — the whole
        // point of the address-list interface.
        let mut rng = Rng::new(5);
        let (m, n, k) = (4, 20, 3);
        let (lda, ldb, ldc) = (10, 33, 26);
        let d = BrgemmDesc { m, n, k, lda, ldb, ldc, a_kstride: 1, alpha: 1.0, beta: 0.0 };
        let a = rng.vec_f32(2 * m * lda + 40, -1.0, 1.0);
        let b = rng.vec_f32(2 * k * ldb + 40, -1.0, 1.0);
        let a_offs = vec![3, m * lda + 7];
        let b_offs = vec![1, k * ldb + 5];
        let c0 = rng.vec_f32(m * ldc, 9.0, 10.0); // sentinel values in the gaps
        for isa in isas() {
            let mut c = c0.clone();
            BrgemmKernel::with_isa(d, isa).execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None);
            let want = oracle(&d, &a, &a_offs, &b, &b_offs, &c0);
            for r in 0..m {
                for col in 0..n {
                    let i = r * ldc + col;
                    assert!((c[i] - want[i]).abs() < 1e-4, "isa {:?} ({},{})", isa, r, col);
                }
                // Gap columns must be untouched.
                for col in n..ldc {
                    assert_eq!(c[r * ldc + col], c0[r * ldc + col], "gap touched at ({},{})", r, col);
                }
            }
        }
    }

    #[test]
    fn epilogue_bias_act() {
        use crate::primitives::eltwise::Act;
        let mut rng = Rng::new(9);
        let d = BrgemmDesc::dense(5, 12, 7);
        let a = rng.vec_f32(5 * 7, -1.0, 1.0);
        let b = rng.vec_f32(7 * 12, -1.0, 1.0);
        let bias = rng.vec_f32(12, -0.5, 0.5);
        let mut c = vec![0.0; 5 * 12];
        BrgemmKernel::new(d)
            .with_epilogue(Epilogue::BiasAct(Act::Sigmoid))
            .execute_single(&a, &b, &mut c, Some(&bias));
        let plain = {
            let mut c = vec![0.0; 5 * 12];
            BrgemmKernel::new(d).execute_single(&a, &b, &mut c, None);
            c
        };
        for r in 0..5 {
            for col in 0..12 {
                let want = 1.0 / (1.0 + (-(plain[r * 12 + col] + bias[col])).exp());
                assert!((c[r * 12 + col] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_block_rejected() {
        let d = BrgemmDesc::dense(4, 4, 4);
        let a = vec![0.0; 16];
        let b = vec![0.0; 16];
        let mut c = vec![0.0; 16];
        BrgemmKernel::new(d).execute_offs(&a, &[1], &b, &[0], &mut c, None);
    }

    #[test]
    fn property_random_shapes_all_isas() {
        Prop::new("brgemm matches oracle on random shapes").cases(60).run(|g| {
            let m = g.usize(1..=33);
            let n = g.usize(1..=70);
            let k = g.usize(1..=20);
            let batch = g.usize(1..=6);
            let alpha = *g.choose(&[1.0f32, 0.5, 2.0]);
            let beta = *g.choose(&[0.0f32, 1.0, 0.5]);
            let d = BrgemmDesc::dense(m, n, k).with_alpha(alpha).with_beta(beta);
            let a = g.vec_f32(batch * m * k, -1.0, 1.0);
            let b = g.vec_f32(batch * k * n, -1.0, 1.0);
            let a_offs: Vec<usize> = (0..batch).map(|i| i * m * k).collect();
            let b_offs: Vec<usize> = (0..batch).map(|i| i * k * n).collect();
            let c0 = g.vec_f32(m * n, -1.0, 1.0);
            let want = oracle(&d, &a, &a_offs, &b, &b_offs, &c0);
            for isa in isas() {
                let mut c = c0.clone();
                BrgemmKernel::with_isa(d, isa).execute_offs(&a, &a_offs, &b, &b_offs, &mut c, None);
                for i in 0..c.len() {
                    let tol = (1e-4 * (k * batch) as f32).max(1e-5);
                    if (c[i] - want[i]).abs() > tol {
                        return Err(format!(
                            "isa {:?} m{} n{} k{} b{}: c[{}]={} want {}",
                            isa, m, n, k, batch, i, c[i], want[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
