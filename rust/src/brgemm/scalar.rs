//! Scalar (ISA-independent) BRGEMM microkernel.
//!
//! Serves three roles: the portable fallback, the correctness oracle for
//! the vectorised paths, and a faithful transcription of the paper's
//! Algorithm 1 — including the register-blocking structure, so that the
//! scalar and AVX-512 paths differ only in the width of the "register".
//!
//! The accumulator tile is kept in a stack array across the *entire*
//! batch-reduce loop (the paper's key property: the C sub-block is loaded
//! once before the batch loop and stored once after it, instead of per
//! GEMM as a batched-GEMM formulation would).

use super::BrgemmDesc;

/// Register-tile height used by the scalar path; chosen to match the
/// AVX-512 path's default so blocking behaviour is comparable.
const MR: usize = 6;
/// Register-tile width (elements).
const NR: usize = 16;

/// # Safety
/// Caller must have validated that every `a_offs[i]` block of extent
/// `(m-1)*lda + k`, every `b_offs[i]` block of extent `(k-1)*ldb + n`, and
/// the C block of extent `(m-1)*ldc + n` are in bounds.
pub(super) unsafe fn brgemm_offs(
    d: &BrgemmDesc,
    a: &[f32],
    a_offs: &[usize],
    b: &[f32],
    b_offs: &[usize],
    c: &mut [f32],
) {
    let (m, n, k) = (d.m, d.n, d.k);
    let mut im = 0;
    while im < m {
        let mb = MR.min(m - im);
        let mut inn = 0;
        while inn < n {
            let nb = NR.min(n - inn);
            // Load/initialise the accumulator tile once (Algorithm 1 line 3).
            let mut acc = [[0.0f32; NR]; MR];
            // Batch-reduce loop (line 4): accumulate every A_i·B_i into the
            // same register tile.
            for (ao, bo) in a_offs.iter().zip(b_offs) {
                for kk in 0..k {
                    // Outer-product update (lines 5-7): one column-broadcast
                    // of A against one row of B.
                    let b_row = bo + kk * d.ldb + inn;
                    for r in 0..mb {
                        let av = *a.get_unchecked(ao + (im + r) * d.lda + kk * d.a_kstride);
                        for cc in 0..nb {
                            acc[r][cc] = av.mul_add(*b.get_unchecked(b_row + cc), acc[r][cc]);
                        }
                    }
                }
            }
            // Store once after the full accumulation chain (line 8).
            for r in 0..mb {
                let crow = (im + r) * d.ldc + inn;
                for cc in 0..nb {
                    let dst = c.get_unchecked_mut(crow + cc);
                    *dst = d.beta * *dst + d.alpha * acc[r][cc];
                }
            }
            inn += nb;
        }
        im += mb;
    }
}
