//! Plain and batched GEMM on top of the single building block.
//!
//! These exist for two reasons:
//!
//! 1. They are the **baselines** the paper compares against (strategy (i):
//!    coarse-grained library GEMM calls — the large-GEMM LSTM/FC cells and
//!    the im2col / batched-GEMM convolutions of Figure 1).
//! 2. They demonstrate the paper's thesis in miniature: a full GEMM *is*
//!    a BRGEMM with batch length 1 plus cache-blocking loops, so nothing
//!    beyond the single kernel needs low-level optimisation.

use super::{BrgemmDesc, BrgemmKernel};

/// Cache-blocking tile sizes for the large-GEMM driver. `MC`/`NC` bound the
/// C tile handed to one kernel call; `KC` bounds the accumulation depth per
/// kernel call so the A/B panels stay cache-resident.
const MC: usize = 96;
const NC: usize = 192;
const KC: usize = 256;

/// A reusable dense GEMM: `C = beta*C + alpha * A(m×k) · B(k×n)`,
/// row-major, arbitrary leading dimensions.
#[derive(Debug, Clone)]
pub struct Gemm {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub lda: usize,
    pub ldb: usize,
    pub ldc: usize,
    pub alpha: f32,
    pub beta: f32,
}

impl Gemm {
    pub fn dense(m: usize, n: usize, k: usize) -> Gemm {
        Gemm { m, n, k, lda: k, ldb: n, ldc: n, alpha: 1.0, beta: 0.0 }
    }

    pub fn with_ld(mut self, lda: usize, ldb: usize, ldc: usize) -> Gemm {
        self.lda = lda;
        self.ldb = ldb;
        self.ldc = ldc;
        self
    }

    pub fn with_alpha_beta(mut self, alpha: f32, beta: f32) -> Gemm {
        self.alpha = alpha;
        self.beta = beta;
        self
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Execute. The k dimension is split into `KC` panels; the first panel
    /// applies the caller's β, subsequent panels accumulate (β = 1) — the
    /// long-accumulation-chain structure the paper attributes to BRGEMM,
    /// recovered here through the strided variant over k-panels.
    pub fn execute(&self, a: &[f32], b: &[f32], c: &mut [f32]) {
        let mut ic = 0;
        while ic < self.m {
            let mb = MC.min(self.m - ic);
            let mut jc = 0;
            while jc < self.n {
                let nb = NC.min(self.n - jc);
                // K-panels become the batch of a single BRGEMM call: block i
                // of A is the i-th k-panel of this row stripe, likewise B.
                let k_panels = self.k.div_ceil(KC);
                let full = self.k - (k_panels - 1) * KC;
                // Full-size panels first (batch), remainder panel separately
                // if its k differs.
                if k_panels == 1 || full == KC {
                    let desc = BrgemmDesc {
                        m: mb,
                        n: nb,
                        k: KC.min(self.k),
                        lda: self.lda,
                        ldb: self.ldb,
                        ldc: self.ldc,
                        a_kstride: 1,
                        alpha: self.alpha,
                        beta: self.beta,
                    };
                    let kern = BrgemmKernel::new(desc);
                    let a_offs: Vec<usize> =
                        (0..k_panels).map(|p| ic * self.lda + p * KC).collect();
                    let b_offs: Vec<usize> =
                        (0..k_panels).map(|p| p * KC * self.ldb + jc).collect();
                    let c_off = ic * self.ldc + jc;
                    kern.execute_offs(a, &a_offs, b, &b_offs, &mut c[c_off..], None);
                } else {
                    // Mixed panel sizes: lead batch with full panels, then a
                    // β=1 tail call for the remainder.
                    let desc = BrgemmDesc {
                        m: mb,
                        n: nb,
                        k: KC,
                        lda: self.lda,
                        ldb: self.ldb,
                        ldc: self.ldc,
                        a_kstride: 1,
                        alpha: self.alpha,
                        beta: self.beta,
                    };
                    let kern = BrgemmKernel::new(desc);
                    let a_offs: Vec<usize> =
                        (0..k_panels - 1).map(|p| ic * self.lda + p * KC).collect();
                    let b_offs: Vec<usize> =
                        (0..k_panels - 1).map(|p| p * KC * self.ldb + jc).collect();
                    let c_off = ic * self.ldc + jc;
                    kern.execute_offs(a, &a_offs, b, &b_offs, &mut c[c_off..], None);
                    let tail = BrgemmKernel::new(BrgemmDesc {
                        k: full,
                        beta: 1.0,
                        ..desc
                    });
                    let p = k_panels - 1;
                    tail.execute_offs(
                        a,
                        &[ic * self.lda + p * KC],
                        b,
                        &[p * KC * self.ldb + jc],
                        &mut c[c_off..],
                        None,
                    );
                }
                jc += nb;
            }
            ic += mb;
        }
    }
}

/// One-shot dense GEMM, `C = A·B` (α=1, β=0).
pub fn gemm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    Gemm::dense(m, n, k).execute(a, b, c);
}

/// `C = Aᵀ(m×k) · B(k×n)` where A is stored k×m: transposes A into scratch
/// then multiplies. The bwd/upd primitives use this; its copy cost is the
/// "tensor reformatting" the paper accounts for in Table 1.
pub fn gemm_at(m: usize, n: usize, k: usize, a_kxm: &[f32], b: &[f32], c: &mut [f32]) {
    let mut at = vec![0.0f32; m * k];
    for i in 0..k {
        for j in 0..m {
            at[j * k + i] = a_kxm[i * m + j];
        }
    }
    gemm(m, n, k, &at, b, c);
}

/// Batched GEMM baseline: `C_i = beta*C_i + alpha*A_i·B_i` for each i —
/// the [19]/strided-batch-gemm semantics the paper contrasts with BRGEMM:
/// every pair gets its own output block, so there is **no** cross-pair
/// accumulation-chain register reuse.
#[allow(clippy::too_many_arguments)]
pub fn batched_gemm(
    desc: &BrgemmDesc,
    batch: usize,
    a: &[f32],
    stride_a: usize,
    b: &[f32],
    stride_b: usize,
    c: &mut [f32],
    stride_c: usize,
) {
    let kern = BrgemmKernel::new(*desc);
    for i in 0..batch {
        let c_off = i * stride_c;
        kern.execute_offs(a, &[i * stride_a], b, &[i * stride_b], &mut c[c_off..], None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn naive(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(m, n, k) in &[(1, 1, 1), (4, 4, 4), (17, 23, 9), (64, 64, 64)] {
            let a = rng.vec_f32(m * k, -1.0, 1.0);
            let b = rng.vec_f32(k * n, -1.0, 1.0);
            let mut c = vec![0.0; m * n];
            gemm(m, n, k, &a, &b, &mut c);
            let want = naive(m, n, k, &a, &b);
            for i in 0..c.len() {
                assert!((c[i] - want[i]).abs() < 1e-3, "({},{},{}) at {}", m, n, k, i);
            }
        }
    }

    #[test]
    fn gemm_k_panel_split() {
        // k > KC exercises the multi-panel batch path, including the
        // non-divisible remainder.
        let mut rng = Rng::new(2);
        for k in [256, 300, 512, 700] {
            let (m, n) = (5, 19);
            let a = rng.vec_f32(m * k, -1.0, 1.0);
            let b = rng.vec_f32(k * n, -1.0, 1.0);
            let mut c = vec![0.0; m * n];
            gemm(m, n, k, &a, &b, &mut c);
            let want = naive(m, n, k, &a, &b);
            for i in 0..c.len() {
                assert!((c[i] - want[i]).abs() < 2e-3, "k={} at {}", k, i);
            }
        }
    }

    #[test]
    fn gemm_at_transposes() {
        let mut rng = Rng::new(3);
        let (m, n, k) = (7, 11, 5);
        let a_kxm = rng.vec_f32(k * m, -1.0, 1.0);
        let b = rng.vec_f32(k * n, -1.0, 1.0);
        let mut c = vec![0.0; m * n];
        gemm_at(m, n, k, &a_kxm, &b, &mut c);
        // oracle: c[i][j] = sum_k a_kxm[k][i] * b[k][j]
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a_kxm[kk * m + i] * b[kk * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn batched_gemm_is_independent_products() {
        let mut rng = Rng::new(4);
        let d = BrgemmDesc::dense(3, 8, 4);
        let batch = 5;
        let a = rng.vec_f32(batch * 12, -1.0, 1.0);
        let b = rng.vec_f32(batch * 32, -1.0, 1.0);
        let mut c = vec![0.0; batch * 24];
        batched_gemm(&d, batch, &a, 12, &b, 32, &mut c, 24);
        for i in 0..batch {
            let want = naive(3, 8, 4, &a[i * 12..i * 12 + 12], &b[i * 32..i * 32 + 32]);
            for j in 0..24 {
                assert!((c[i * 24 + j] - want[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn property_gemm_random_shapes() {
        Prop::new("blocked gemm = naive").cases(40).run(|g| {
            let m = g.usize(1..=50);
            let n = g.usize(1..=80);
            let k = g.usize(1..=300);
            let a = g.vec_f32(m * k, -1.0, 1.0);
            let b = g.vec_f32(k * n, -1.0, 1.0);
            let mut c = vec![0.0; m * n];
            gemm(m, n, k, &a, &b, &mut c);
            let want = naive(m, n, k, &a, &b);
            for i in 0..c.len() {
                if (c[i] - want[i]).abs() > 1e-3 {
                    return Err(format!("({},{},{}): c[{}]={} want {}", m, n, k, i, c[i], want[i]));
                }
            }
            Ok(())
        });
    }
}
