//! Gated span tracer: the causal layer on top of the profiler's sums.
//!
//! The sibling profiler ([`crate::telemetry`]) answers "where did the
//! microseconds go *in aggregate*"; this module answers "where did
//! *this request* (or *this training step*) spend its time". The same
//! contract applies:
//!
//! * **Explicitly installed.** [`install`] creates a process-global
//!   [`Tracer`]; when none is installed every instrumentation site pays
//!   a single branch and nothing else — no clock reads, no allocation.
//!   Enabling tracing must never change the math (the training and
//!   serving bit-identity tests cover it).
//! * **Zero heap allocation on the hot path.** Spans are `Copy` values
//!   accumulated into a stack-resident [`TraceGroup`] (a fixed inline
//!   array) and pushed into a pre-allocated per-worker [`SpanRing`] in
//!   one mutex-guarded `VecDeque` operation per *group*, not per span.
//! * **Deterministic sampling.** A request is traced iff
//!   `trace_id % sample_every == 0`. Request ids are minted sequentially
//!   at `Server::submit`, so for a fixed load seed the sampled set is
//!   exactly reproducible.
//! * **Whole-trace eviction.** Rings store complete groups; overflow
//!   drops the *oldest group* and counts it. A drained trace never
//!   contains a partial span set for a request.
//!
//! Export is Chrome trace-event JSON (`{"traceEvents": [...]}`) with
//! `ph:"X"` complete events (`ts`/`dur` in microseconds since the
//! tracer epoch) plus `ph:"s"`/`ph:"f"` flow events linking each batch
//! span to the member request spans it served — load the file in
//! Perfetto or chrome://tracing and follow the arrows.

use crate::util::json::{obj, Json};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Every span family the tracer knows. The `cat` string groups spans
/// into Chrome trace categories (the CI gate asserts a dump carries at
/// least two distinct categories, i.e. tracing reached more than one
/// subsystem layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole request life: enqueue → respond (serve path).
    Request,
    /// Enqueue → dequeue: time spent waiting in a length/batch bucket.
    QueueWait,
    /// Dequeue → respond: time inside the formed batch.
    InBatch,
    /// Whole batch life on a worker: dequeue → responses sent.
    Batch,
    /// Batch formation: dequeue → padded input staged.
    BatchForm,
    /// Batch compute: the bucket plan's forward pass.
    BatchCompute,
    /// One layer of the forward pass (fc / conv / pool / lstm / head).
    Layer,
    /// Training forward pass (per worker).
    Fwd,
    /// Training backward pass (per worker).
    BwdData,
    /// Ring allreduce over worker gradients.
    Allreduce,
    /// Optimizer update.
    Upd,
    /// The data-parallel worker-pool region of one step (all fwd+bwd).
    Pool,
    /// One whole training step.
    Step,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::InBatch => "in_batch",
            SpanKind::Batch => "batch",
            SpanKind::BatchForm => "form",
            SpanKind::BatchCompute => "compute",
            SpanKind::Layer => "layer",
            SpanKind::Fwd => "fwd",
            SpanKind::BwdData => "bwd_data",
            SpanKind::Allreduce => "allreduce",
            SpanKind::Upd => "upd",
            SpanKind::Pool => "pool",
            SpanKind::Step => "step",
        }
    }

    /// Chrome trace category. One category per subsystem layer.
    pub fn cat(self) -> &'static str {
        match self {
            SpanKind::Request | SpanKind::QueueWait | SpanKind::InBatch => "serve.request",
            SpanKind::Batch | SpanKind::BatchForm | SpanKind::BatchCompute => "serve.batch",
            SpanKind::Layer => "serve.layer",
            SpanKind::Fwd => "train.fwd",
            SpanKind::BwdData => "train.bwd",
            SpanKind::Allreduce => "train.allreduce",
            SpanKind::Upd => "train.upd",
            SpanKind::Pool => "train.pool",
            SpanKind::Step => "train.step",
        }
    }
}

/// One recorded span. `Copy` and fixed-size by construction: the hot
/// path moves these by value into inline arrays, never boxes them.
/// `a`/`b` are kind-specific small payloads (bucket/fill, layer index,
/// worker id, ...) surfaced under `args` in the export.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Optional static display name override ("" → `kind.name()`);
    /// layer spans use it to show "fc" / "conv" / "lstm" / ...
    pub label: &'static str,
    pub trace_id: u64,
    /// Lane in the trace viewer: serve/train worker index.
    pub tid: u32,
    /// Microseconds since the tracer epoch.
    pub start_us: u64,
    pub dur_us: u64,
    pub a: u32,
    pub b: u32,
}

impl SpanEvent {
    pub fn display_name(&self) -> &'static str {
        if self.label.is_empty() {
            self.kind.name()
        } else {
            self.label
        }
    }

    /// End of the span in epoch microseconds.
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }
}

const ZERO_SPAN: SpanEvent = SpanEvent {
    kind: SpanKind::Request,
    label: "",
    trace_id: 0,
    tid: 0,
    start_us: 0,
    dur_us: 0,
    a: 0,
    b: 0,
};

/// `inner` strictly inside `outer` (inclusive bounds) — the
/// well-nestedness predicate the trace-correctness tests assert.
pub fn well_nested(outer: &SpanEvent, inner: &SpanEvent) -> bool {
    inner.start_us >= outer.start_us && inner.end_us() <= outer.end_us()
}

/// Spans one group can hold. A serve batch group carries
/// batch + form + compute + one span per layer; 16 covers every model
/// this repo builds, and overflow is *counted*, never partially stored.
pub const MAX_GROUP_SPANS: usize = 16;

/// All spans of one trace (one sampled request, one batch, one training
/// step), recorded atomically: a group enters the ring complete and
/// leaves it complete. Fixed-size and `Copy` so building one is pure
/// stack work.
#[derive(Debug, Clone, Copy)]
pub struct TraceGroup {
    spans: [SpanEvent; MAX_GROUP_SPANS],
    len: u32,
    /// Cross-group link: for request groups, the batch trace id the
    /// request was served in (0 = none). The exporter turns it into a
    /// Chrome flow arrow batch → request.
    pub link: u64,
    /// Spans that did not fit in the inline array (dropped whole).
    pub truncated: u32,
}

impl TraceGroup {
    pub fn new(link: u64) -> TraceGroup {
        TraceGroup { spans: [ZERO_SPAN; MAX_GROUP_SPANS], len: 0, link, truncated: 0 }
    }

    pub fn push(&mut self, span: SpanEvent) {
        if (self.len as usize) < MAX_GROUP_SPANS {
            self.spans[self.len as usize] = span;
            self.len += 1;
        } else {
            self.truncated += 1;
        }
    }

    pub fn spans(&self) -> &[SpanEvent] {
        &self.spans[..self.len as usize]
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The group's identity: its first span's trace id (0 when empty).
    pub fn trace_id(&self) -> u64 {
        self.spans().first().map(|s| s.trace_id).unwrap_or(0)
    }

    pub fn find(&self, kind: SpanKind) -> Option<&SpanEvent> {
        self.spans().iter().find(|s| s.kind == kind)
    }
}

/// A fixed-capacity ring of whole trace groups. One per worker thread;
/// the only shared state is a mutex taken once per *group* push (a
/// request respond or a batch completion — far off the per-span path).
#[derive(Debug)]
pub struct SpanRing {
    inner: Mutex<RingInner>,
}

#[derive(Debug)]
struct RingInner {
    groups: VecDeque<TraceGroup>,
    cap: usize,
    dropped_groups: u64,
}

impl SpanRing {
    fn with_capacity(cap: usize) -> SpanRing {
        assert!(cap >= 1, "ring capacity must be >= 1");
        SpanRing {
            inner: Mutex::new(RingInner {
                // Pre-allocated: once full, evict-then-push never
                // reallocates, so the steady state is allocation-free.
                groups: VecDeque::with_capacity(cap),
                cap,
                dropped_groups: 0,
            }),
        }
    }

    /// Push a complete group; on overflow the *oldest whole group* is
    /// evicted (and counted) — never individual spans.
    pub fn push(&self, g: TraceGroup) {
        if g.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.groups.len() == inner.cap {
            inner.groups.pop_front();
            inner.dropped_groups += 1;
        }
        inner.groups.push_back(g);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take everything out (oldest first) and reset the drop counter.
    pub fn drain(&self) -> (Vec<TraceGroup>, u64) {
        let mut inner = self.inner.lock().unwrap();
        let dropped = inner.dropped_groups;
        inner.dropped_groups = 0;
        (inner.groups.drain(..).collect(), dropped)
    }
}

/// Everything a [`Tracer::drain`] returned: groups oldest-first per
/// ring, plus how many whole groups overflow evicted since last drain.
#[derive(Debug, Default)]
pub struct Drained {
    pub groups: Vec<TraceGroup>,
    pub dropped_groups: u64,
}

impl Drained {
    pub fn to_chrome(&self) -> Json {
        chrome_trace_with(&self.groups, self.dropped_groups)
    }
}

/// The process-global trace plane: an epoch for timestamps, the
/// sampling modulus, and the registry of per-worker rings.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    sample_every: u64,
    ring_cap: usize,
    rings: Mutex<Vec<Arc<SpanRing>>>,
    batch_seq: AtomicU64,
    step_seq: AtomicU64,
}

impl Tracer {
    pub fn new(sample_every: u64, ring_cap: usize) -> Tracer {
        assert!(sample_every >= 1, "sample_every must be >= 1");
        Tracer {
            epoch: Instant::now(),
            sample_every,
            ring_cap,
            rings: Mutex::new(Vec::new()),
            batch_seq: AtomicU64::new(0),
            step_seq: AtomicU64::new(0),
        }
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Deterministic 1-in-N sampling keyed off the trace id. Ids are
    /// minted sequentially at submit, so a fixed load seed yields a
    /// fixed sampled set.
    pub fn sampled(&self, trace_id: u64) -> bool {
        trace_id % self.sample_every == 0
    }

    /// Register a fresh ring (call once per worker thread).
    pub fn ring(&self) -> Arc<SpanRing> {
        let r = Arc::new(SpanRing::with_capacity(self.ring_cap));
        self.rings.lock().unwrap().push(r.clone());
        r
    }

    /// Microseconds from the tracer epoch to `t`, saturating to 0 for
    /// instants captured before install.
    pub fn us_since(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).map(|d| d.as_micros() as u64).unwrap_or(0)
    }

    /// `(start_us, dur_us)` for a `[start, end]` interval.
    pub fn span_us(&self, start: Instant, end: Instant) -> (u64, u64) {
        let s = self.us_since(start);
        (s, self.us_since(end).saturating_sub(s))
    }

    /// Mint a nonzero batch trace id (0 is the "no link" sentinel).
    pub fn next_batch_id(&self) -> u64 {
        self.batch_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Mint a training-step trace id (sequential from 0, so step
    /// sampling is deterministic too).
    pub fn next_step_id(&self) -> u64 {
        self.step_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Drain every registered ring (registration order, oldest first
    /// within a ring).
    pub fn drain(&self) -> Drained {
        let rings = self.rings.lock().unwrap().clone();
        let mut out = Drained::default();
        for r in rings {
            let (groups, dropped) = r.drain();
            out.groups.extend(groups);
            out.dropped_groups += dropped;
        }
        out
    }
}

/// Serialize groups as a Chrome trace-event document.
pub fn chrome_trace(groups: &[TraceGroup]) -> Json {
    chrome_trace_with(groups, 0)
}

fn chrome_trace_with(groups: &[TraceGroup], dropped_groups: u64) -> Json {
    let mut events = Vec::new();
    for g in groups {
        for s in g.spans() {
            events.push(obj([
                ("name", s.display_name().into()),
                ("cat", s.kind.cat().into()),
                ("ph", "X".into()),
                ("ts", (s.start_us as f64).into()),
                ("dur", (s.dur_us as f64).into()),
                ("pid", 1usize.into()),
                ("tid", (s.tid as usize).into()),
                (
                    "args",
                    obj([
                        ("trace_id", (s.trace_id as f64).into()),
                        ("a", (s.a as f64).into()),
                        ("b", (s.b as f64).into()),
                    ]),
                ),
            ]));
        }
    }
    // Flow arrows: each sampled request group links (via `link`) to the
    // batch group that served it. The start event rides inside the
    // batch span's slice; the finish binds to the request span's end
    // (`bp:"e"`). Skip links whose batch group was evicted — a dangling
    // arrow is worse than none.
    let batches: BTreeMap<u64, &TraceGroup> = groups
        .iter()
        .filter(|g| g.find(SpanKind::Batch).is_some())
        .map(|g| (g.trace_id(), g))
        .collect();
    for g in groups {
        if g.link == 0 {
            continue;
        }
        let (Some(req), Some(bg)) = (g.find(SpanKind::Request), batches.get(&g.link)) else {
            continue;
        };
        let bspan = bg.find(SpanKind::Batch).unwrap();
        events.push(obj([
            ("name", "served_in".into()),
            ("cat", "flow".into()),
            ("ph", "s".into()),
            ("id", (req.trace_id as f64).into()),
            ("ts", (bspan.start_us as f64).into()),
            ("pid", 1usize.into()),
            ("tid", (bspan.tid as usize).into()),
        ]));
        events.push(obj([
            ("name", "served_in".into()),
            ("cat", "flow".into()),
            ("ph", "f".into()),
            ("bp", "e".into()),
            ("id", (req.trace_id as f64).into()),
            ("ts", (req.end_us() as f64).into()),
            ("pid", 1usize.into()),
            ("tid", (req.tid as usize).into()),
        ]));
    }
    obj([
        ("traceEvents", Json::Arr(events)),
        ("dropped_groups", (dropped_groups as f64).into()),
    ])
}

// ---- process-global install, mirroring the profiler's contract ----------

pub const DEFAULT_SAMPLE_EVERY: u64 = 1;
pub const DEFAULT_RING_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static TRACER: Mutex<Option<Arc<Tracer>>> = Mutex::new(None);

/// Install a fresh global tracer and return it. Workers that start from
/// now on pick it up; like the profiler, already-running workers keep
/// the tracer (or the `None`) they captured at thread start.
pub fn install(sample_every: u64, ring_cap: usize) -> Arc<Tracer> {
    let t = Arc::new(Tracer::new(sample_every, ring_cap));
    *TRACER.lock().unwrap() = Some(t.clone());
    ENABLED.store(true, Ordering::Release);
    t
}

/// Remove the global tracer (test isolation, not mid-run toggling).
pub fn uninstall() {
    ENABLED.store(false, Ordering::Release);
    *TRACER.lock().unwrap() = None;
}

/// Whether a tracer is installed (one atomic load — the entire cost of
/// a disabled instrumentation site).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The installed tracer, if any. Capture once per worker thread, not
/// per event.
pub fn current() -> Option<Arc<Tracer>> {
    if !enabled() {
        return None;
    }
    TRACER.lock().unwrap().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, trace_id: u64, start_us: u64, dur_us: u64) -> SpanEvent {
        SpanEvent { kind, label: "", trace_id, tid: 0, start_us, dur_us, a: 0, b: 0 }
    }

    #[test]
    fn sampling_is_deterministic_by_id() {
        let t = Tracer::new(4, 8);
        assert!(t.sampled(0) && t.sampled(4) && t.sampled(8));
        assert!(!t.sampled(1) && !t.sampled(3) && !t.sampled(7));
        // Same modulus, same decisions — the property the fixed-seed
        // load test builds on.
        let u = Tracer::new(4, 8);
        for id in 0..64 {
            assert_eq!(t.sampled(id), u.sampled(id));
        }
        let every = Tracer::new(1, 8);
        assert!((0..64).all(|id| every.sampled(id)));
    }

    #[test]
    fn ring_overflow_drops_oldest_whole_groups() {
        let ring = SpanRing::with_capacity(3);
        for id in 0..5u64 {
            let mut g = TraceGroup::new(0);
            g.push(span(SpanKind::Request, id, id * 10, 5));
            g.push(span(SpanKind::QueueWait, id, id * 10, 2));
            ring.push(g);
        }
        let (groups, dropped) = ring.drain();
        assert_eq!(dropped, 2);
        let ids: Vec<u64> = groups.iter().map(|g| g.trace_id()).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest evicted first");
        // Whole-trace eviction: every surviving group still carries its
        // complete span set.
        assert!(groups.iter().all(|g| g.spans().len() == 2));
        let (again, dropped2) = ring.drain();
        assert!(again.is_empty());
        assert_eq!(dropped2, 0, "drop counter resets on drain");
    }

    #[test]
    fn group_truncates_beyond_capacity_never_partial() {
        let mut g = TraceGroup::new(0);
        for i in 0..(MAX_GROUP_SPANS + 3) {
            g.push(span(SpanKind::Layer, 1, i as u64, 1));
        }
        assert_eq!(g.spans().len(), MAX_GROUP_SPANS);
        assert_eq!(g.truncated, 3);
    }

    #[test]
    fn empty_groups_never_enter_the_ring() {
        let ring = SpanRing::with_capacity(2);
        ring.push(TraceGroup::new(0));
        assert!(ring.is_empty());
    }

    #[test]
    fn span_bounds_and_nesting() {
        let outer = span(SpanKind::Request, 1, 10, 20);
        let inner = span(SpanKind::QueueWait, 1, 12, 5);
        let late = span(SpanKind::InBatch, 1, 25, 10);
        assert!(well_nested(&outer, &inner));
        assert!(!well_nested(&outer, &late));
        assert_eq!(outer.end_us(), 30);
    }

    #[test]
    fn us_since_saturates_before_epoch() {
        let before = Instant::now();
        let t = Tracer::new(1, 8);
        assert_eq!(t.us_since(before), 0);
        let (s, d) = t.span_us(before, before);
        assert_eq!((s, d), (0, 0));
    }

    #[test]
    fn batch_and_step_ids_are_sequential() {
        let t = Tracer::new(1, 8);
        assert_eq!(t.next_batch_id(), 1, "batch ids start nonzero (0 = no link)");
        assert_eq!(t.next_batch_id(), 2);
        assert_eq!(t.next_step_id(), 0);
        assert_eq!(t.next_step_id(), 1);
    }

    #[test]
    fn chrome_export_shape_and_flow_links() {
        let batch_id = 7u64;
        let mut bg = TraceGroup::new(0);
        bg.push(SpanEvent {
            kind: SpanKind::Batch,
            label: "",
            trace_id: batch_id,
            tid: 1,
            start_us: 100,
            dur_us: 50,
            a: 8,
            b: 6,
        });
        bg.push(span(SpanKind::BatchForm, batch_id, 100, 10));
        bg.push(SpanEvent {
            kind: SpanKind::Layer,
            label: "fc",
            trace_id: batch_id,
            tid: 1,
            start_us: 115,
            dur_us: 20,
            a: 0,
            b: 0,
        });
        let mut rg = TraceGroup::new(batch_id);
        rg.push(span(SpanKind::Request, 4, 90, 70));
        rg.push(span(SpanKind::QueueWait, 4, 90, 10));
        let doc = chrome_trace(&[bg, rg]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 5 duration events + 1 flow start + 1 flow finish.
        assert_eq!(events.len(), 7);
        let cats: std::collections::BTreeSet<&str> = events
            .iter()
            .filter_map(|e| e.get("cat").and_then(|c| c.as_str()))
            .collect();
        assert!(cats.len() >= 3, "multiple span categories: {:?}", cats);
        for e in events {
            assert!(e.get("name").is_some() && e.get("ph").is_some());
            assert!(e.get("ts").unwrap().as_f64().is_some());
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
        }
        let layer = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("fc"))
            .expect("layer span uses its label as the display name");
        assert_eq!(layer.get("cat").unwrap().as_str(), Some("serve.layer"));
        let start = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("s"))
            .expect("flow start present");
        let finish = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("f"))
            .expect("flow finish present");
        assert_eq!(start.get("id"), finish.get("id"), "flow ids pair up");
        assert_eq!(start.get("id").unwrap().as_f64(), Some(4.0), "flow id = request trace id");
        assert_eq!(finish.get("ts").unwrap().as_f64(), Some(160.0), "finish at request end");
        // The whole document round-trips through the JSON writer/parser.
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn flow_skipped_when_batch_group_evicted() {
        let mut rg = TraceGroup::new(99); // links to a batch nobody kept
        rg.push(span(SpanKind::Request, 4, 90, 70));
        let doc = chrome_trace(&[rg]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1, "no dangling flow arrows");
    }

    #[test]
    fn install_gating() {
        let _g = crate::telemetry::test_lock();
        uninstall();
        assert!(!enabled());
        assert!(current().is_none());
        let t = install(2, 16);
        assert!(enabled());
        assert!(current().is_some());
        assert_eq!(current().unwrap().sample_every(), 2);
        let ring = t.ring();
        let mut g = TraceGroup::new(0);
        g.push(span(SpanKind::Step, 0, 0, 5));
        ring.push(g);
        let d = t.drain();
        assert_eq!(d.groups.len(), 1);
        assert_eq!(d.dropped_groups, 0);
        uninstall();
        assert!(current().is_none());
    }
}
