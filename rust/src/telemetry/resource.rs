//! Resource plane: allocation, RSS and CPU accounting — the third gated
//! observability plane, mirroring the profiler ([`crate::telemetry`]),
//! tracer ([`crate::telemetry::trace`]) and health
//! ([`crate::telemetry::health`]) pattern: a process-global monitor behind
//! one `AtomicBool`, installed only when something can observe it, with a
//! branch-only cost when off.
//!
//! Two collectors feed one [`ResourceSnapshot`]:
//!
//! * A **counting global allocator** ([`CountingAlloc`], declared with
//!   `#[global_allocator]` in `lib.rs`): when counting is enabled it tallies
//!   allocation calls/bytes (process-wide atomics plus per-thread cells) and
//!   free calls, then forwards to [`System`] untouched — allocation
//!   *behaviour* is never altered, so instrumented runs stay bit-identical
//!   to uninstrumented ones. When counting is off the wrapper costs exactly
//!   one relaxed load and a branch per call. [`AllocGauge`] scopes the
//!   counters over a region, turning the ad-hoc "zero steady-state
//!   allocation" serve assertions into a first-class measurement.
//!
//! * An **OS sampler** parsing `/proc/self/status` (VmRSS/VmHWM,
//!   voluntary/involuntary context switches) and `/proc/self/stat` (minor/
//!   major faults, utime/stime) on a periodic watchdog-style thread, so the
//!   RSS peak is tracked even between report points. The parsers are pure
//!   functions over the file text (fixture-tested, tolerant of kernels that
//!   omit fields); on non-Linux hosts the reads fail and the snapshot
//!   degrades to zeros rather than erroring.
//!
//! The snapshot flows into `ServeReport` JSON (and therefore admin `stats`),
//! the Prometheus exposition (`brgemm_resource_*` families), and every
//! training `--metrics-out` epoch line.

use crate::util::json::{obj, Json};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---- counting global allocator ----

/// Wrapper around [`System`] that counts calls when the resource plane (or
/// an [`AllocGauge`]) enables counting. Declared as the `#[global_allocator]`
/// in `lib.rs`, so it covers the binary, tests and benches alike.
pub struct CountingAlloc;

/// Counting switch: off = one relaxed load + branch per alloc/dealloc.
/// Driven by a refcount ([`COUNT_REFS`]) so the plane and any number of
/// gauges can overlap without stomping each other.
static COUNTING: AtomicBool = AtomicBool::new(false);
static COUNT_REFS: AtomicUsize = AtomicUsize::new(0);

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static FREE_CALLS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    // const-initialised Cells without Drop: no lazy allocation on first
    // touch and no destructor, so they are safe to reach from inside the
    // allocator itself at any point in a thread's life.
    static TL_ALLOC_CALLS: Cell<u64> = const { Cell::new(0) };
    static TL_ALLOC_BYTES: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn note_alloc(bytes: usize) {
    ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
    ALLOC_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    let _ = TL_ALLOC_CALLS.try_with(|c| c.set(c.get() + 1));
    let _ = TL_ALLOC_BYTES.try_with(|c| c.set(c.get() + bytes as u64));
}

// SAFETY: every method forwards verbatim to `System`; the wrapper only
// observes, never changes size, alignment or placement.
unsafe impl GlobalAlloc for CountingAlloc {
    #[inline]
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            note_alloc(layout.size());
        }
        System.alloc(layout)
    }

    #[inline]
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            note_alloc(layout.size());
        }
        System.alloc_zeroed(layout)
    }

    #[inline]
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        if COUNTING.load(Ordering::Relaxed) {
            FREE_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.dealloc(ptr, layout)
    }

    #[inline]
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            // A realloc is one allocation of the new size (and implicitly
            // one free); counting it as such keeps call parity with dealloc.
            note_alloc(new_size);
            FREE_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

fn counting_acquire() {
    if COUNT_REFS.fetch_add(1, Ordering::AcqRel) == 0 {
        COUNTING.store(true, Ordering::Release);
    }
}

fn counting_release() {
    if COUNT_REFS.fetch_sub(1, Ordering::AcqRel) == 1 {
        COUNTING.store(false, Ordering::Release);
    }
}

/// Whether allocation counting is currently on (plane installed or a gauge
/// live somewhere).
pub fn counting_enabled() -> bool {
    COUNTING.load(Ordering::Relaxed)
}

/// Allocation totals since process start *while counting was enabled*:
/// `(alloc calls, alloc bytes, free calls)`.
pub fn alloc_totals() -> (u64, u64, u64) {
    (
        ALLOC_CALLS.load(Ordering::Relaxed),
        ALLOC_BYTES.load(Ordering::Relaxed),
        FREE_CALLS.load(Ordering::Relaxed),
    )
}

/// Scoped allocation measurement for the calling thread. Construction
/// enables counting (refcounted — nesting and overlap with the installed
/// plane are fine); `Drop` releases it. [`AllocGauge::thread_delta`] reads
/// how many allocations *this thread* made since the gauge started — the
/// first-class form of the serve path's "zero steady-state allocation"
/// assertions.
pub struct AllocGauge {
    calls0: u64,
    bytes0: u64,
}

impl AllocGauge {
    pub fn start() -> AllocGauge {
        counting_acquire();
        AllocGauge {
            calls0: TL_ALLOC_CALLS.with(Cell::get),
            bytes0: TL_ALLOC_BYTES.with(Cell::get),
        }
    }

    /// `(calls, bytes)` allocated by the calling thread since `start`.
    /// Only meaningful on the thread that created the gauge.
    pub fn thread_delta(&self) -> (u64, u64) {
        (
            TL_ALLOC_CALLS.with(Cell::get) - self.calls0,
            TL_ALLOC_BYTES.with(Cell::get) - self.bytes0,
        )
    }
}

impl Drop for AllocGauge {
    fn drop(&mut self) {
        counting_release();
    }
}

// ---- /proc parsers (pure, fixture-testable) ----

/// Fields scraped from `/proc/self/status`. Every field is optional:
/// kernels omit `VmHWM`/`VmRSS` for kernel threads, and older kernels
/// lack the context-switch counters entirely.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatusSample {
    /// Resident set size, kB.
    pub vm_rss_kb: Option<u64>,
    /// Peak resident set size ("high water mark"), kB.
    pub vm_hwm_kb: Option<u64>,
    pub voluntary_ctxt_switches: Option<u64>,
    pub nonvoluntary_ctxt_switches: Option<u64>,
}

/// Parse the `Key:\tvalue [unit]` lines of `/proc/self/status`. Unknown
/// keys and malformed values are skipped, never an error.
pub fn parse_proc_status(text: &str) -> StatusSample {
    let mut s = StatusSample::default();
    for line in text.lines() {
        let Some((key, rest)) = line.split_once(':') else { continue };
        let num = rest.split_whitespace().next().and_then(|w| w.parse::<u64>().ok());
        match key.trim() {
            "VmRSS" => s.vm_rss_kb = num,
            "VmHWM" => s.vm_hwm_kb = num,
            "voluntary_ctxt_switches" => s.voluntary_ctxt_switches = num,
            "nonvoluntary_ctxt_switches" => s.nonvoluntary_ctxt_switches = num,
            _ => {}
        }
    }
    s
}

/// Fields scraped from `/proc/self/stat`. Optional for the same reason as
/// [`StatusSample`]: a truncated or nonstandard line yields `None`s, not
/// an error.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatSample {
    pub minor_faults: Option<u64>,
    pub major_faults: Option<u64>,
    /// User-mode CPU time, clock ticks (see [`CLK_TCK_HZ`]).
    pub utime_ticks: Option<u64>,
    /// Kernel-mode CPU time, clock ticks.
    pub stime_ticks: Option<u64>,
}

/// Kernel clock-tick rate assumed when converting `utime`/`stime` to
/// seconds. `sysconf(_SC_CLK_TCK)` needs libc (unavailable here); USER_HZ
/// has been 100 on every mainstream Linux configuration since 2.6.
pub const CLK_TCK_HZ: f64 = 100.0;

/// Parse the single space-separated line of `/proc/self/stat`. The `comm`
/// field (2) is parenthesised and may itself contain spaces and `)` —
/// fields are taken after the **last** `)`, per proc(5). After that split,
/// 0-indexed positions: state=0, …, minflt=7, majflt=9, utime=11, stime=12.
pub fn parse_proc_stat(text: &str) -> StatSample {
    let Some(close) = text.rfind(')') else { return StatSample::default() };
    let fields: Vec<&str> = text[close + 1..].split_whitespace().collect();
    let num = |i: usize| fields.get(i).and_then(|w| w.parse::<u64>().ok());
    StatSample {
        minor_faults: num(7),
        major_faults: num(9),
        utime_ticks: num(11),
        stime_ticks: num(12),
    }
}

// ---- the monitor ----

/// Point-in-time resource readout: OS sampler state + allocator counters.
/// All fields degrade to zero where the OS gives nothing (non-Linux, or a
/// kernel omitting fields) — the block's *presence* signals the plane was
/// on, exactly like the SLO block.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceSnapshot {
    /// Resident set size at the snapshot, MB.
    pub rss_mb: f64,
    /// Peak RSS: max of the kernel's VmHWM and every periodic sample, MB.
    pub rss_peak_mb: f64,
    pub minor_faults: u64,
    pub major_faults: u64,
    /// Cumulative user / kernel CPU seconds of the process.
    pub cpu_utime_s: f64,
    pub cpu_stime_s: f64,
    /// CPU seconds burned per wall second since install (cores' worth of
    /// CPU; 2.0 = two cores fully busy).
    pub cpu_util: f64,
    pub ctx_voluntary: u64,
    pub ctx_involuntary: u64,
    /// Allocator calls/bytes observed while counting was enabled.
    pub alloc_count: u64,
    pub alloc_bytes: u64,
    pub free_count: u64,
    /// Periodic sampler ticks folded into the peak (plus the on-demand
    /// sample every snapshot takes).
    pub samples: u64,
}

impl ResourceSnapshot {
    pub fn to_json(&self) -> Json {
        obj([
            ("rss_mb", self.rss_mb.into()),
            ("rss_peak_mb", self.rss_peak_mb.into()),
            ("minor_faults", (self.minor_faults as f64).into()),
            ("major_faults", (self.major_faults as f64).into()),
            ("cpu_utime_s", self.cpu_utime_s.into()),
            ("cpu_stime_s", self.cpu_stime_s.into()),
            ("cpu_util", self.cpu_util.into()),
            ("ctx_voluntary", (self.ctx_voluntary as f64).into()),
            ("ctx_involuntary", (self.ctx_involuntary as f64).into()),
            ("alloc_count", (self.alloc_count as f64).into()),
            ("alloc_bytes", (self.alloc_bytes as f64).into()),
            ("free_count", (self.free_count as f64).into()),
            ("samples", (self.samples as f64).into()),
        ])
    }

    /// One log line for `report.render()`.
    pub fn render(&self) -> String {
        format!(
            "resource: rss {:.1} MB (peak {:.1})  cpu {:.2} cores (u {:.2}s s {:.2}s)  \
             faults {}/{}  ctx {}/{}  allocs {} ({} KB)\n",
            self.rss_mb,
            self.rss_peak_mb,
            self.cpu_util,
            self.cpu_utime_s,
            self.cpu_stime_s,
            self.minor_faults,
            self.major_faults,
            self.ctx_voluntary,
            self.ctx_involuntary,
            self.alloc_count,
            self.alloc_bytes / 1024,
        )
    }
}

#[derive(Debug)]
struct SamplerState {
    start: Instant,
    /// utime+stime ticks at install — the utilization baseline.
    start_cpu_ticks: u64,
    /// Max VmRSS/VmHWM seen over every sample, kB.
    peak_rss_kb: u64,
    samples: u64,
    status: StatusSample,
    stat: StatSample,
}

/// The installed monitor: sampled periodically by the plane's thread and
/// on demand by every [`ResourceMonitor::snapshot`].
#[derive(Debug)]
pub struct ResourceMonitor {
    state: Mutex<SamplerState>,
}

impl ResourceMonitor {
    fn new() -> ResourceMonitor {
        let stat = std::fs::read_to_string("/proc/self/stat")
            .map(|t| parse_proc_stat(&t))
            .unwrap_or_default();
        let start_cpu_ticks =
            stat.utime_ticks.unwrap_or(0) + stat.stime_ticks.unwrap_or(0);
        ResourceMonitor {
            state: Mutex::new(SamplerState {
                start: Instant::now(),
                start_cpu_ticks,
                peak_rss_kb: 0,
                samples: 0,
                status: StatusSample::default(),
                stat,
            }),
        }
    }

    /// Read `/proc` once and fold into the state (peak tracking).
    pub fn sample(&self) {
        let status = std::fs::read_to_string("/proc/self/status")
            .map(|t| parse_proc_status(&t))
            .unwrap_or_default();
        let stat = std::fs::read_to_string("/proc/self/stat")
            .map(|t| parse_proc_stat(&t))
            .unwrap_or_default();
        let mut s = self.state.lock().unwrap();
        s.samples += 1;
        let observed_peak =
            status.vm_hwm_kb.unwrap_or(0).max(status.vm_rss_kb.unwrap_or(0));
        s.peak_rss_kb = s.peak_rss_kb.max(observed_peak);
        s.status = status;
        s.stat = stat;
    }

    /// Fresh sample + full readout.
    pub fn snapshot(&self) -> ResourceSnapshot {
        self.sample();
        let s = self.state.lock().unwrap();
        let kb_to_mb = |kb: u64| kb as f64 / 1024.0;
        let utime = s.stat.utime_ticks.unwrap_or(0);
        let stime = s.stat.stime_ticks.unwrap_or(0);
        let wall = s.start.elapsed().as_secs_f64();
        let cpu_delta_s =
            (utime + stime).saturating_sub(s.start_cpu_ticks) as f64 / CLK_TCK_HZ;
        let (alloc_count, alloc_bytes, free_count) = alloc_totals();
        ResourceSnapshot {
            rss_mb: kb_to_mb(s.status.vm_rss_kb.unwrap_or(0)),
            rss_peak_mb: kb_to_mb(s.peak_rss_kb),
            minor_faults: s.stat.minor_faults.unwrap_or(0),
            major_faults: s.stat.major_faults.unwrap_or(0),
            cpu_utime_s: utime as f64 / CLK_TCK_HZ,
            cpu_stime_s: stime as f64 / CLK_TCK_HZ,
            cpu_util: if wall > 0.0 { cpu_delta_s / wall } else { 0.0 },
            ctx_voluntary: s.status.voluntary_ctxt_switches.unwrap_or(0),
            ctx_involuntary: s.status.nonvoluntary_ctxt_switches.unwrap_or(0),
            alloc_count,
            alloc_bytes,
            free_count,
            samples: s.samples,
        }
    }
}

// ---- install / uninstall gating (profiler/tracer/health pattern) ----

struct Installed {
    monitor: Arc<ResourceMonitor>,
    stop: Arc<AtomicBool>,
    sampler: Option<std::thread::JoinHandle<()>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static MONITOR: Mutex<Option<Installed>> = Mutex::new(None);

/// Period between `/proc` samples of the plane's background thread.
pub const SAMPLE_PERIOD: Duration = Duration::from_millis(200);
/// The sampler sleeps in slices so `uninstall` joins promptly (the same
/// discipline as the health watchdog).
const SAMPLE_SLICE: Duration = Duration::from_millis(25);

/// Install the resource plane: enable allocation counting, take a first
/// sample, and start the periodic `/proc` sampler thread. Replaces any
/// previous installation.
pub fn install() -> Arc<ResourceMonitor> {
    uninstall();
    counting_acquire();
    let monitor = Arc::new(ResourceMonitor::new());
    monitor.sample();
    let stop = Arc::new(AtomicBool::new(false));
    let (m, st) = (Arc::clone(&monitor), Arc::clone(&stop));
    let sampler = std::thread::Builder::new()
        .name("brgemm-resource".to_string())
        .spawn(move || {
            let mut slept = Duration::ZERO;
            loop {
                std::thread::sleep(SAMPLE_SLICE);
                if st.load(Ordering::Acquire) {
                    return;
                }
                slept += SAMPLE_SLICE;
                if slept >= SAMPLE_PERIOD {
                    slept = Duration::ZERO;
                    m.sample();
                }
            }
        })
        .ok();
    *MONITOR.lock().unwrap() = Some(Installed { monitor: Arc::clone(&monitor), stop, sampler });
    ENABLED.store(true, Ordering::Release);
    monitor
}

/// Remove the plane: stop and join the sampler thread, release the
/// allocation-counting reference. Idempotent.
pub fn uninstall() {
    let installed = MONITOR.lock().unwrap().take();
    ENABLED.store(false, Ordering::Release);
    if let Some(i) = installed {
        i.stop.store(true, Ordering::Release);
        if let Some(h) = i.sampler {
            h.join().ok();
        }
        counting_release();
    }
}

/// Whether the plane is installed (one atomic load).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The installed monitor, if any.
pub fn current() -> Option<Arc<ResourceMonitor>> {
    MONITOR.lock().unwrap().as_ref().map(|i| Arc::clone(&i.monitor))
}

/// Fresh snapshot from the installed monitor — `None` when the plane is
/// off, so report blocks appear only when configured (the SLO pattern).
pub fn snapshot() -> Option<ResourceSnapshot> {
    if !enabled() {
        return None;
    }
    current().map(|m| m.snapshot())
}

#[cfg(test)]
mod tests {
    use super::*;

    const STATUS_FIXTURE: &str = "Name:\tbrgemm-dl\n\
        Umask:\t0022\n\
        State:\tR (running)\n\
        VmPeak:\t  270468 kB\n\
        VmHWM:\t   16132 kB\n\
        VmRSS:\t   15872 kB\n\
        Threads:\t3\n\
        voluntary_ctxt_switches:\t150\n\
        nonvoluntary_ctxt_switches:\t7\n";

    #[test]
    fn status_parser_reads_rss_peak_and_ctx_switches() {
        let s = parse_proc_status(STATUS_FIXTURE);
        assert_eq!(s.vm_rss_kb, Some(15872));
        assert_eq!(s.vm_hwm_kb, Some(16132));
        assert_eq!(s.voluntary_ctxt_switches, Some(150));
        assert_eq!(s.nonvoluntary_ctxt_switches, Some(7));
    }

    #[test]
    fn status_parser_tolerates_missing_fields() {
        // Kernel threads have no Vm* lines; pre-2.6.23 kernels lack the
        // ctxt-switch counters. Absence must parse as None, not error.
        let s = parse_proc_status("Name:\tkthreadd\nState:\tS (sleeping)\nThreads:\t1\n");
        assert_eq!(s, StatusSample::default());
        // Garbage values are skipped, not propagated.
        let g = parse_proc_status("VmRSS:\tnot-a-number kB\nVmHWM:\t12 kB\n");
        assert_eq!(g.vm_rss_kb, None);
        assert_eq!(g.vm_hwm_kb, Some(12));
    }

    #[test]
    fn stat_parser_handles_hostile_comm_names() {
        // comm may contain spaces and ')' — fields must be taken after the
        // LAST ')'. Layout after comm: state ppid pgrp session tty_nr
        // tpgid flags minflt cminflt majflt cmajflt utime stime ...
        let line = "1234 (a (we)ird) name) R 1 1234 1234 0 -1 4194304 \
                    2500 0 42 0 360 40 0 0 20 0 3 0 8000 276959232 3968";
        let s = parse_proc_stat(line);
        assert_eq!(s.minor_faults, Some(2500));
        assert_eq!(s.major_faults, Some(42));
        assert_eq!(s.utime_ticks, Some(360));
        assert_eq!(s.stime_ticks, Some(40));
    }

    #[test]
    fn stat_parser_tolerates_truncation_and_garbage() {
        // Truncated after majflt: utime/stime read as None, earlier fields
        // still parse.
        let s = parse_proc_stat("77 (x) R 1 77 77 0 -1 4194304 9 0 3 0");
        assert_eq!(s.minor_faults, Some(9));
        assert_eq!(s.major_faults, Some(3));
        assert_eq!(s.utime_ticks, None);
        assert_eq!(s.stime_ticks, None);
        // No comm parens at all → everything None.
        assert_eq!(parse_proc_stat("complete garbage"), StatSample::default());
        assert_eq!(parse_proc_stat(""), StatSample::default());
    }

    #[test]
    fn alloc_gauge_counts_this_threads_allocations() {
        let _guard = crate::telemetry::test_lock();
        let gauge = AllocGauge::start();
        assert!(counting_enabled());
        let v: Vec<u8> = Vec::with_capacity(4096);
        std::hint::black_box(&v);
        let (calls, bytes) = gauge.thread_delta();
        assert!(calls >= 1, "the 4 KB Vec must be counted (calls={})", calls);
        assert!(bytes >= 4096, "bytes={}", bytes);
        drop(v);
        drop(gauge);
    }

    #[test]
    fn gauge_refcount_nests_with_the_plane() {
        let _guard = crate::telemetry::test_lock();
        let m = install();
        assert!(enabled() && counting_enabled());
        {
            let _g = AllocGauge::start();
            assert!(counting_enabled());
        }
        // Dropping the gauge must not turn counting off under the plane.
        assert!(counting_enabled());
        let snap = m.snapshot();
        assert!(snap.samples >= 2, "install + snapshot sample at least twice");
        uninstall();
        assert!(!enabled());
    }

    #[test]
    fn snapshot_reads_real_proc_on_linux() {
        let _guard = crate::telemetry::test_lock();
        install();
        // Touch some memory so RSS is comfortably nonzero.
        let buf = vec![1u8; 1 << 20];
        std::hint::black_box(&buf);
        let snap = snapshot().expect("plane installed");
        if cfg!(target_os = "linux") {
            assert!(snap.rss_mb > 0.0, "VmRSS must be nonzero ({:?})", snap);
            assert!(snap.rss_peak_mb >= snap.rss_mb - 1.0, "{:?}", snap);
        }
        assert!(snap.alloc_count > 0, "the 1 MB buffer allocation was counted");
        uninstall();
        assert!(snapshot().is_none(), "plane off → no block");
    }

    #[test]
    fn training_is_bit_identical_with_the_plane_off_vs_on() {
        use crate::coordinator::rnn::{RnnModel, RnnSpec};
        use crate::util::rng::Rng;
        let _guard = crate::telemetry::test_lock();
        let spec = RnnSpec { c: 4, k: 4, t: 2, classes: 2, layers: 1 };
        let run = || -> Vec<u32> {
            let mut rng = Rng::new(3);
            let mut model = RnnModel::new(&spec, 2, 1, &mut rng);
            let x = rng.vec_f32(2 * spec.input_dim(), -1.0, 1.0);
            let labels = vec![0i32, 1];
            (0..3).map(|_| model.train_step(&x, &labels, 0.05).to_bits()).collect()
        };
        let plain = run();
        install();
        let instrumented = run();
        uninstall();
        assert_eq!(
            plain, instrumented,
            "the counting allocator and sampler must not perturb training numerics"
        );
    }

    #[test]
    fn snapshot_json_carries_every_field() {
        let snap = ResourceSnapshot {
            rss_mb: 15.5,
            rss_peak_mb: 16.0,
            minor_faults: 2500,
            major_faults: 1,
            cpu_utime_s: 3.6,
            cpu_stime_s: 0.4,
            cpu_util: 1.25,
            ctx_voluntary: 150,
            ctx_involuntary: 7,
            alloc_count: 1234,
            alloc_bytes: 1 << 20,
            free_count: 1200,
            samples: 5,
        };
        let j = snap.to_json();
        for key in [
            "rss_mb",
            "rss_peak_mb",
            "minor_faults",
            "major_faults",
            "cpu_utime_s",
            "cpu_stime_s",
            "cpu_util",
            "ctx_voluntary",
            "ctx_involuntary",
            "alloc_count",
            "alloc_bytes",
            "free_count",
            "samples",
        ] {
            assert!(j.get(key).is_some(), "missing {}", key);
        }
        assert!(snap.render().contains("rss 15.5 MB"));
    }
}
