//! Health plane: per-worker heartbeats, a watchdog thread, and a
//! `Starting → Ready → Degraded(reason) → Draining` state machine.
//!
//! The profiler ([`super`]) answers *how fast*, the tracer
//! ([`super::trace`]) answers *in what order*; this module answers the
//! operator's first question: **is the process still alive, and if not,
//! which worker wedged?** The design follows the same explicit-install
//! gating contract as the other two planes:
//!
//! * Off (the default): nothing is registered, [`enabled`] is one atomic
//!   load, and every instrumentation site reduces to a single branch.
//!   Enabling health monitoring must never change the math — the
//!   instrumented-vs-uninstrumented bit-identity tests cover this plane
//!   too.
//! * On ([`install`]): workers register a [`HeartbeatGroup`] (one atomic
//!   counter per worker — serve workers bump per batch *and per idle
//!   wake*, trainer workers per step) and a watchdog thread re-derives
//!   the health state every few hundred milliseconds, logging
//!   transitions.
//!
//! The state machine is deliberately re-derived from raw signals on
//! every [`Health::evaluate`] call rather than kept as mutable state:
//! there is nothing to get out of sync, and the `admin health` command
//! and the watchdog see exactly the same function of the same atomics.
//! Priority order: Draining (intentional shutdown is not a failure) >
//! Starting (a worker that never beat cannot be distinguished from one
//! that is still warming up) > Degraded (stalled heartbeat, queue
//! saturation, recent reload failure, or SLO burn rate over threshold,
//! with the reason naming the culprit) > Ready.

use crate::util::json::{obj, Json};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Degradation thresholds. Defaults are production-ish; tests shrink
/// `stall_secs` to force transitions quickly.
#[derive(Debug, Clone, Copy)]
pub struct HealthThresholds {
    /// A worker whose heartbeat has not advanced for this long is
    /// considered stalled.
    pub stall_secs: f64,
    /// A queue depth observation above this is saturation.
    pub queue_saturation: u64,
    /// A short-window SLO burn rate above this is degradation (burn 1.0
    /// = spending the error budget exactly at the sustainable rate).
    pub burn_rate_max: f64,
    /// A reload failure within this window keeps the state degraded.
    pub reload_failure_window_secs: f64,
}

impl Default for HealthThresholds {
    fn default() -> HealthThresholds {
        HealthThresholds {
            stall_secs: 5.0,
            queue_saturation: 10_000,
            burn_rate_max: 10.0,
            reload_failure_window_secs: 30.0,
        }
    }
}

/// One named pool of heartbeat counters — "serve" for the batcher's
/// worker pool, "train" for the data-parallel trainer. Workers bump
/// their own counter with a relaxed atomic add: no ordering is needed,
/// the watchdog only asks "did this number change recently?".
#[derive(Debug)]
pub struct HeartbeatGroup {
    name: String,
    beats: Vec<AtomicU64>,
    active: AtomicBool,
}

impl HeartbeatGroup {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn len(&self) -> usize {
        self.beats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.beats.is_empty()
    }

    /// Worker `i`'s heartbeat: one relaxed fetch-add.
    pub fn beat(&self, i: usize) {
        self.beats[i].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self, i: usize) -> u64 {
        self.beats[i].load(Ordering::Relaxed)
    }

    /// Take the group out of stall detection (workers are exiting on
    /// purpose — drain, shutdown, end of training).
    pub fn retire(&self) {
        self.active.store(false, Ordering::Release);
    }

    pub fn active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }
}

/// The derived state, in priority order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Starting,
    Ready,
    Degraded,
    Draining,
}

impl HealthState {
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Starting => "starting",
            HealthState::Ready => "ready",
            HealthState::Degraded => "degraded",
            HealthState::Draining => "draining",
        }
    }

    /// Numeric encoding for the Prometheus `brgemm_health_state` gauge.
    pub fn code(self) -> u64 {
        match self {
            HealthState::Starting => 0,
            HealthState::Ready => 1,
            HealthState::Degraded => 2,
            HealthState::Draining => 3,
        }
    }
}

/// Watchdog-side bookkeeping for one worker: the last counter value seen
/// and when it last changed.
#[derive(Debug)]
struct WorkerTrack {
    last_count: u64,
    last_change: Instant,
}

#[derive(Debug)]
struct GroupState {
    group: Arc<HeartbeatGroup>,
    tracks: Vec<WorkerTrack>,
}

/// The process-global health monitor. All signal feeds are lock-free
/// atomics; the only mutex guards the (cold) group registry, taken by
/// `register` and `evaluate` — never on a worker's hot path.
#[derive(Debug)]
pub struct Health {
    thresholds: HealthThresholds,
    started: Instant,
    draining: AtomicBool,
    queue_depth: AtomicU64,
    /// Latest short-window burn rate, stored as f64 bits (0 = none yet).
    burn_rate_bits: AtomicU64,
    reload_failures: AtomicU64,
    /// Nanos-since-start of the last reload failure, +1 so 0 = never.
    last_reload_failure: AtomicU64,
    groups: Mutex<Vec<GroupState>>,
}

impl Health {
    pub fn new(thresholds: HealthThresholds) -> Health {
        Health {
            thresholds,
            started: Instant::now(),
            draining: AtomicBool::new(false),
            queue_depth: AtomicU64::new(0),
            burn_rate_bits: AtomicU64::new(0),
            reload_failures: AtomicU64::new(0),
            last_reload_failure: AtomicU64::new(0),
            groups: Mutex::new(Vec::new()),
        }
    }

    pub fn thresholds(&self) -> &HealthThresholds {
        &self.thresholds
    }

    /// Register a pool of `n` workers under `name`. The returned group is
    /// what the workers hold; the monitor keeps its own `Arc`.
    pub fn register(&self, name: &str, n: usize) -> Arc<HeartbeatGroup> {
        let group = Arc::new(HeartbeatGroup {
            name: name.to_string(),
            beats: (0..n).map(|_| AtomicU64::new(0)).collect(),
            active: AtomicBool::new(true),
        });
        let now = Instant::now();
        self.groups.lock().unwrap().push(GroupState {
            group: group.clone(),
            tracks: (0..n).map(|_| WorkerTrack { last_count: 0, last_change: now }).collect(),
        });
        group
    }

    /// Intentional shutdown has begun: everything from here on is
    /// Draining, never Degraded.
    pub fn set_draining(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn observe_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Feed the latest short-window SLO burn rate.
    pub fn observe_burn_rate(&self, burn: f64) {
        self.burn_rate_bits.store(burn.to_bits(), Ordering::Relaxed);
    }

    /// A hot reload failed (bad path, corrupt artifact, ...). Degrades
    /// the state for `reload_failure_window_secs`.
    pub fn reload_failed(&self) {
        self.reload_failures.fetch_add(1, Ordering::Relaxed);
        let nanos = self.started.elapsed().as_nanos() as u64;
        self.last_reload_failure.store(nanos + 1, Ordering::Relaxed);
    }

    fn burn_rate(&self) -> f64 {
        f64::from_bits(self.burn_rate_bits.load(Ordering::Relaxed))
    }

    /// Derive the current state from the raw signals. Called by the
    /// watchdog on its poll cadence and by `admin health` on demand —
    /// both see the same pure function of the same atomics.
    pub fn evaluate(&self) -> HealthSnapshot {
        let now = Instant::now();
        let draining = self.draining.load(Ordering::Acquire);
        let mut groups_out = Vec::new();
        let mut starting = false;
        let mut stall_reason: Option<String> = None;
        {
            let mut groups = self.groups.lock().unwrap();
            if groups.is_empty() {
                starting = true;
            }
            for gs in groups.iter_mut() {
                let active = gs.group.active();
                let mut beats = Vec::with_capacity(gs.tracks.len());
                let mut stalled = Vec::new();
                for (i, track) in gs.tracks.iter_mut().enumerate() {
                    let count = gs.group.count(i);
                    if count != track.last_count {
                        track.last_count = count;
                        track.last_change = now;
                    }
                    beats.push(count);
                    if !active {
                        continue;
                    }
                    if count == 0 {
                        // Never beat: still warming up, not stalled.
                        starting = true;
                        continue;
                    }
                    let quiet = now.duration_since(track.last_change).as_secs_f64();
                    if quiet > self.thresholds.stall_secs {
                        stalled.push(i);
                        if stall_reason.is_none() {
                            stall_reason = Some(format!(
                                "worker {} in group '{}' stalled ({:.1}s since last heartbeat)",
                                i,
                                gs.group.name(),
                                quiet
                            ));
                        }
                    }
                }
                groups_out.push(GroupSnapshot {
                    name: gs.group.name().to_string(),
                    active,
                    beats,
                    stalled,
                });
            }
        }

        let queue_depth = self.queue_depth.load(Ordering::Relaxed);
        let burn_rate = self.burn_rate();
        let reload_failures = self.reload_failures.load(Ordering::Relaxed);
        let last_fail = self.last_reload_failure.load(Ordering::Relaxed);
        let recent_reload_failure = last_fail > 0 && {
            let ago = (self.started.elapsed().as_nanos() as u64).saturating_sub(last_fail - 1);
            (ago as f64 / 1e9) <= self.thresholds.reload_failure_window_secs
        };

        let (state, reason) = if draining {
            (HealthState::Draining, None)
        } else if starting {
            (HealthState::Starting, None)
        } else if let Some(r) = stall_reason {
            (HealthState::Degraded, Some(r))
        } else if queue_depth > self.thresholds.queue_saturation {
            (
                HealthState::Degraded,
                Some(format!(
                    "queue saturated (depth {} > {})",
                    queue_depth, self.thresholds.queue_saturation
                )),
            )
        } else if recent_reload_failure {
            (
                HealthState::Degraded,
                Some(format!("recent reload failure ({} total)", reload_failures)),
            )
        } else if burn_rate > self.thresholds.burn_rate_max {
            (
                HealthState::Degraded,
                Some(format!(
                    "SLO burn rate {:.1} over threshold {:.1}",
                    burn_rate, self.thresholds.burn_rate_max
                )),
            )
        } else {
            (HealthState::Ready, None)
        };

        HealthSnapshot {
            state,
            reason,
            uptime_secs: self.started.elapsed().as_secs_f64(),
            queue_depth,
            burn_rate,
            reload_failures,
            groups: groups_out,
        }
    }
}

/// One group's read-out inside a [`HealthSnapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupSnapshot {
    pub name: String,
    pub active: bool,
    pub beats: Vec<u64>,
    pub stalled: Vec<usize>,
}

/// Point-in-time health read-out (the `admin health` reply body).
#[derive(Debug, Clone, PartialEq)]
pub struct HealthSnapshot {
    pub state: HealthState,
    pub reason: Option<String>,
    pub uptime_secs: f64,
    pub queue_depth: u64,
    pub burn_rate: f64,
    pub reload_failures: u64,
    pub groups: Vec<GroupSnapshot>,
}

impl HealthSnapshot {
    pub fn to_json(&self) -> Json {
        let groups = Json::Arr(
            self.groups
                .iter()
                .map(|g| {
                    obj([
                        ("name", g.name.as_str().into()),
                        ("active", g.active.into()),
                        (
                            "beats",
                            Json::Arr(g.beats.iter().map(|&b| (b as f64).into()).collect()),
                        ),
                        (
                            "stalled",
                            Json::Arr(g.stalled.iter().map(|&i| (i as f64).into()).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        obj([
            ("state", self.state.name().into()),
            (
                "reason",
                self.reason.as_deref().map_or(Json::Null, |r| r.into()),
            ),
            ("uptime_secs", self.uptime_secs.into()),
            ("queue_depth", (self.queue_depth as f64).into()),
            ("burn_rate", self.burn_rate.into()),
            ("reload_failures", (self.reload_failures as f64).into()),
            ("groups", groups),
        ])
    }
}

struct Installed {
    health: Arc<Health>,
    stop: Arc<AtomicBool>,
    watchdog: Option<JoinHandle<()>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static MONITOR: Mutex<Option<Installed>> = Mutex::new(None);

/// Watchdog poll cadence. Transitions are detected within one tick; the
/// tick itself sleeps in short slices so [`uninstall`] joins promptly.
const WATCHDOG_TICK: Duration = Duration::from_millis(250);
const WATCHDOG_SLICE: Duration = Duration::from_millis(25);

/// Install a fresh global health monitor and start its watchdog thread.
/// Replaces any previous monitor (uninstalling it first). Mirrors the
/// profiler/tracer contract: explicit install, [`enabled`] is one atomic
/// load, instrumentation sites fetch [`current`] once and cache it.
pub fn install(thresholds: HealthThresholds) -> Arc<Health> {
    uninstall();
    let health = Arc::new(Health::new(thresholds));
    let stop = Arc::new(AtomicBool::new(false));
    let (h2, s2) = (health.clone(), stop.clone());
    let watchdog = std::thread::Builder::new()
        .name("health-watchdog".into())
        .spawn(move || {
            let mut last = HealthState::Starting;
            let mut elapsed = Duration::ZERO;
            while !s2.load(Ordering::Acquire) {
                std::thread::sleep(WATCHDOG_SLICE);
                elapsed += WATCHDOG_SLICE;
                if elapsed < WATCHDOG_TICK {
                    continue;
                }
                elapsed = Duration::ZERO;
                let snap = h2.evaluate();
                if snap.state != last {
                    crate::log_info!(
                        "health: {} -> {}{}",
                        last.name(),
                        snap.state.name(),
                        snap.reason.as_deref().map(|r| format!(" ({})", r)).unwrap_or_default()
                    );
                    last = snap.state;
                }
            }
        })
        .expect("spawn health watchdog");
    *MONITOR.lock().unwrap() = Some(Installed { health: health.clone(), stop, watchdog: Some(watchdog) });
    ENABLED.store(true, Ordering::Release);
    health
}

/// Stop the watchdog and remove the global monitor. Groups held by live
/// workers keep their atomics (beats into a detached group are harmless);
/// only new [`current`] calls see `None`.
pub fn uninstall() {
    ENABLED.store(false, Ordering::Release);
    let installed = MONITOR.lock().unwrap().take();
    if let Some(mut m) = installed {
        m.stop.store(true, Ordering::Release);
        if let Some(h) = m.watchdog.take() {
            let _ = h.join();
        }
    }
}

/// Whether a health monitor is installed (one atomic load).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The installed monitor, or `None` (the common case). Callers on hot
/// paths fetch this once at startup and cache the `Option` — the per-
/// event cost when off is the cached `None` branch.
pub fn current() -> Option<Arc<Health>> {
    if !enabled() {
        return None;
    }
    MONITOR.lock().unwrap().as_ref().map(|m| m.health.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> HealthThresholds {
        HealthThresholds { stall_secs: 0.05, ..HealthThresholds::default() }
    }

    #[test]
    fn starts_in_starting_until_every_worker_beats() {
        let h = Health::new(fast());
        // No groups registered at all: still starting.
        assert_eq!(h.evaluate().state, HealthState::Starting);
        let g = h.register("serve", 2);
        assert_eq!(h.evaluate().state, HealthState::Starting);
        g.beat(0);
        // One worker warm, one never beat: still starting.
        assert_eq!(h.evaluate().state, HealthState::Starting);
        g.beat(1);
        assert_eq!(h.evaluate().state, HealthState::Ready);
    }

    #[test]
    fn forced_stall_degrades_and_names_the_stalled_worker() {
        let h = Health::new(fast());
        let g = h.register("serve", 2);
        g.beat(0);
        g.beat(1);
        assert_eq!(h.evaluate().state, HealthState::Ready);
        // Worker 1 wedges; worker 0 keeps beating past the stall window.
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(30));
            g.beat(0);
        }
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Degraded);
        let reason = snap.reason.expect("degraded carries a reason");
        assert!(
            reason.contains("worker 1") && reason.contains("'serve'"),
            "reason names the stalled worker: {}",
            reason
        );
        assert_eq!(snap.groups[0].stalled, vec![1]);
        // The wedged worker recovers: back to Ready.
        g.beat(1);
        assert_eq!(h.evaluate().state, HealthState::Ready);
    }

    #[test]
    fn retired_groups_are_exempt_from_stall_detection() {
        let h = Health::new(fast());
        let g = h.register("train", 1);
        g.beat(0);
        g.retire();
        std::thread::sleep(Duration::from_millis(80));
        // Long past the stall window, but the group exited on purpose.
        assert_eq!(h.evaluate().state, HealthState::Ready);
    }

    #[test]
    fn draining_wins_over_everything() {
        let h = Health::new(fast());
        let g = h.register("serve", 1);
        g.beat(0);
        h.observe_queue_depth(1_000_000);
        h.set_draining();
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Draining);
        assert!(snap.reason.is_none());
    }

    #[test]
    fn queue_saturation_and_burn_rate_degrade_with_reasons() {
        let h = Health::new(fast());
        let g = h.register("serve", 1);
        g.beat(0);
        h.observe_queue_depth(h.thresholds().queue_saturation + 1);
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Degraded);
        assert!(snap.reason.unwrap().contains("queue saturated"));
        h.observe_queue_depth(0);
        assert_eq!(h.evaluate().state, HealthState::Ready);

        h.observe_burn_rate(h.thresholds().burn_rate_max * 2.0);
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Degraded);
        assert!(snap.reason.unwrap().contains("burn rate"));
        h.observe_burn_rate(0.5);
        assert_eq!(h.evaluate().state, HealthState::Ready);
    }

    #[test]
    fn reload_failure_degrades_within_its_window() {
        let mut t = fast();
        t.reload_failure_window_secs = 0.05;
        let h = Health::new(t);
        let g = h.register("serve", 1);
        g.beat(0);
        h.reload_failed();
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Degraded);
        assert!(snap.reason.unwrap().contains("reload failure"));
        assert_eq!(snap.reload_failures, 1);
        std::thread::sleep(Duration::from_millis(80));
        // Outside the window the failure stops degrading (but stays
        // counted).
        let snap = h.evaluate();
        assert_eq!(snap.state, HealthState::Ready);
        assert_eq!(snap.reload_failures, 1);
    }

    #[test]
    fn snapshot_json_shape() {
        let h = Health::new(fast());
        let g = h.register("serve", 2);
        g.beat(0);
        g.beat(0);
        g.beat(1);
        let j = h.evaluate().to_json();
        assert_eq!(j.get("state").and_then(|s| s.as_str()), Some("ready"));
        assert!(j.get("uptime_secs").is_some());
        let groups = match j.get("groups").unwrap() {
            Json::Arr(g) => g.clone(),
            _ => panic!("groups is an array"),
        };
        assert_eq!(groups[0].get("name").and_then(|n| n.as_str()), Some("serve"));
        let beats = match groups[0].get("beats").unwrap() {
            Json::Arr(b) => b.iter().filter_map(|x| x.as_f64()).collect::<Vec<_>>(),
            _ => panic!("beats is an array"),
        };
        assert_eq!(beats, vec![2.0, 1.0]);
    }

    #[test]
    fn install_gating_contract() {
        let _g = crate::telemetry::test_lock();
        uninstall();
        assert!(!enabled());
        assert!(current().is_none());
        let h = install(fast());
        assert!(enabled());
        let c = current().expect("monitor installed");
        assert!(Arc::ptr_eq(&h, &c));
        uninstall();
        assert!(!enabled());
        assert!(current().is_none());
    }

    #[test]
    fn state_codes_are_stable() {
        // The Prometheus gauge documents these values; changing them is
        // a dashboard-breaking change.
        assert_eq!(HealthState::Starting.code(), 0);
        assert_eq!(HealthState::Ready.code(), 1);
        assert_eq!(HealthState::Degraded.code(), 2);
        assert_eq!(HealthState::Draining.code(), 3);
    }
}
