//! Crate-wide telemetry: metric registries, and a gated per-primitive
//! BRGEMM profiler.
//!
//! Two complementary pieces live here:
//!
//! * [`Metrics`] — named counters and timers owned by one worker (no
//!   shared mutable state on the hot path) and merged exactly at the end
//!   via the parallel-Welford merge ([`merge_online`]). The training
//!   drivers export these as JSON lines through `run --metrics-out`.
//! * The **profiler** — a process-global, explicitly installed registry of
//!   per-primitive [`PrimSlot`]s. Every `FcPrimitive` / `ConvPrimitive` /
//!   `LstmPrimitive` asks [`register`] for a slot at construction; when no
//!   profiler is installed that returns `None` and the hot path pays a
//!   single branch per pass — nothing else. When installed, each pass
//!   records BRGEMM invocations, flops, bytes moved, and wall time with
//!   relaxed atomics, and [`Profiler::snapshot`] turns that into achieved
//!   GFLOPS and efficiency-vs-roofline using the measured host peak from
//!   [`crate::perfmodel`].
//!
//! A third piece, the span tracer, lives in [`trace`]: where the
//! profiler sums microseconds per primitive, the tracer records *causal
//! spans* (per-request, per-batch, per-training-step) into bounded ring
//! buffers and exports Chrome trace-event JSON. It follows the same
//! install/enabled gating contract as the profiler.
//!
//! A fourth, the health plane, lives in [`health`]: per-worker heartbeat
//! atomics and a watchdog deriving a Starting → Ready → Degraded →
//! Draining state machine, again behind the same explicit-install gate.
//!
//! A fifth, the resource plane, lives in [`resource`]: a counting global
//! allocator plus a periodic `/proc` sampler (RSS, faults, CPU time,
//! context switches), behind the same gate — the machine-side complement
//! to the profiler's kernel-side counters.
//!
//! Instrumentation never touches the math: enabling the profiler changes
//! timing side channels only, so instrumented and uninstrumented runs are
//! bit-identical (tested below).

pub mod health;
pub mod resource;
pub mod trace;

use crate::perfmodel::{host_platform, roofline_secs};
use crate::util::json::{obj, Json};
use crate::util::stats::Online;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A metric registry. Not thread-safe by design — each worker owns one and
/// they are merged at the end (the same pattern the primitives use for
/// outputs: no shared mutable state on the hot path).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, Online>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_secs(&mut self, name: &str, secs: f64) {
        self.timers.entry(name.to_string()).or_insert_with(Online::new).push(secs);
    }

    /// Time a closure and record it.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe_secs(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer_mean(&self, name: &str) -> Option<f64> {
        self.timers.get(name).map(|o| o.mean())
    }

    /// Merge another registry into this one (post-run worker merge).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, o) in &other.timers {
            let mine = self.timers.entry(k.clone()).or_insert_with(Online::new);
            *mine = merge_online(mine, o);
        }
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let timers = Json::Obj(
            self.timers
                .iter()
                .map(|(k, o)| {
                    (
                        k.clone(),
                        obj([
                            ("n", o.n.into()),
                            ("mean_s", o.mean().into()),
                            ("std_s", o.std().into()),
                            ("min_s", o.min.into()),
                            ("max_s", o.max.into()),
                        ]),
                    )
                })
                .collect(),
        );
        obj([("counters", counters), ("timers", timers)])
    }
}

/// Chan et al. parallel-Welford merge (exact). Public so anything merging
/// per-worker [`Online`] accumulators gets the same numerics as
/// [`Metrics::merge`].
pub fn merge_online(a: &Online, b: &Online) -> Online {
    if b.n == 0 {
        return a.clone();
    }
    if a.n == 0 {
        return b.clone();
    }
    let (na, nb) = (a.n as f64, b.n as f64);
    let delta = b.mean() - a.mean();
    let mean = a.mean() + delta * nb / (na + nb);
    let m2 = a.std().powi(2) * (na - 1.0).max(0.0)
        + b.std().powi(2) * (nb - 1.0).max(0.0)
        + delta * delta * na * nb / (na + nb);
    Online::from_moments(a.n + b.n, mean, m2, a.min.min(b.min), a.max.max(b.max))
}

/// Achieved GFLOPS — the one flop-rate formula shared by the bench
/// harness, the profiler snapshot, and the CLI's `primitive` report.
pub fn achieved_gflops(flops: f64, secs: f64) -> f64 {
    if secs > 0.0 {
        flops / secs / 1e9
    } else {
        0.0
    }
}

/// The three primitive passes a slot distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Fwd = 0,
    Bwd = 1,
    Upd = 2,
}

impl Pass {
    pub fn name(self) -> &'static str {
        match self {
            Pass::Fwd => "fwd",
            Pass::Bwd => "bwd",
            Pass::Upd => "upd",
        }
    }
}

const PASSES: [Pass; 3] = [Pass::Fwd, Pass::Bwd, Pass::Upd];

/// Per-pass accumulators. Relaxed atomics: slots are shared between the
/// serving worker pool's threads and counters only ever accumulate — no
/// ordering is needed, and a snapshot mid-run is allowed to be slightly
/// torn (it is a monitoring read, not a consistency point).
#[derive(Debug, Default)]
struct PassCounters {
    calls: AtomicU64,
    brgemm_calls: AtomicU64,
    flops: AtomicU64,
    bytes: AtomicU64,
    nanos: AtomicU64,
}

/// A read-out of one pass of one slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PassSnapshot {
    pub calls: u64,
    pub brgemm_calls: u64,
    pub flops: u64,
    pub bytes: u64,
    pub secs: f64,
}

/// One instrumented primitive instance: a `kind` ("fc" | "conv" | "lstm"),
/// a shape label, and per-pass counters.
#[derive(Debug)]
pub struct PrimSlot {
    kind: &'static str,
    label: String,
    passes: [PassCounters; 3],
}

impl PrimSlot {
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Record one executed pass: how many BRGEMM kernel invocations it
    /// issued, the flops and bytes it moved, and how long it took.
    pub fn record(&self, pass: Pass, brgemm_calls: u64, flops: f64, bytes: u64, took: Duration) {
        let p = &self.passes[pass as usize];
        p.calls.fetch_add(1, Ordering::Relaxed);
        p.brgemm_calls.fetch_add(brgemm_calls, Ordering::Relaxed);
        p.flops.fetch_add(flops as u64, Ordering::Relaxed);
        p.bytes.fetch_add(bytes, Ordering::Relaxed);
        p.nanos.fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn pass_snapshot(&self, pass: Pass) -> PassSnapshot {
        let p = &self.passes[pass as usize];
        PassSnapshot {
            calls: p.calls.load(Ordering::Relaxed),
            brgemm_calls: p.brgemm_calls.load(Ordering::Relaxed),
            flops: p.flops.load(Ordering::Relaxed),
            bytes: p.bytes.load(Ordering::Relaxed),
            secs: p.nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// The process-global slot registry. Primitives register at construction;
/// [`Profiler::snapshot`] reads everything out as JSON.
#[derive(Debug, Default)]
pub struct Profiler {
    slots: Mutex<Vec<Arc<PrimSlot>>>,
}

impl Profiler {
    pub fn slots(&self) -> Vec<Arc<PrimSlot>> {
        self.slots.lock().unwrap().clone()
    }

    /// Per-slot, per-pass read-out with achieved GFLOPS and
    /// efficiency-vs-roofline (roofline time / actual time, clamped to 1;
    /// the roofline uses the measured single-core host peak and the
    /// modelled stream bandwidth from [`crate::perfmodel`]).
    pub fn snapshot(&self) -> Json {
        let platform = host_platform();
        let rows: Vec<Json> = self
            .slots()
            .iter()
            .filter_map(|slot| {
                let passes: Vec<Json> = PASSES
                    .iter()
                    .filter_map(|&pass| {
                        let s = slot.pass_snapshot(pass);
                        if s.calls == 0 {
                            return None;
                        }
                        let gflops = achieved_gflops(s.flops as f64, s.secs);
                        let roof = roofline_secs(s.flops as f64, s.bytes as f64, &platform);
                        let efficiency =
                            if s.secs > 0.0 { (roof / s.secs).min(1.0) } else { 0.0 };
                        Some(obj([
                            ("pass", pass.name().into()),
                            ("calls", (s.calls as f64).into()),
                            ("brgemm_calls", (s.brgemm_calls as f64).into()),
                            ("flops", (s.flops as f64).into()),
                            ("bytes", (s.bytes as f64).into()),
                            ("secs", s.secs.into()),
                            ("gflops", gflops.into()),
                            ("efficiency", efficiency.into()),
                        ]))
                    })
                    .collect();
                if passes.is_empty() {
                    return None;
                }
                Some(obj([
                    ("kind", slot.kind.into()),
                    ("label", slot.label.as_str().into()),
                    ("passes", Json::Arr(passes)),
                ]))
            })
            .collect();
        Json::Arr(rows)
    }

    /// Render the snapshot as aligned text lines (the `--metrics-out`
    /// JSON is the machine form; this is for the log).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for slot in self.slots() {
            for &pass in &PASSES {
                let p = slot.pass_snapshot(pass);
                if p.calls == 0 {
                    continue;
                }
                let gf = achieved_gflops(p.flops as f64, p.secs);
                s.push_str(&format!(
                    "  {:<5} {:<28} {:>4} {:>6} calls  {:>8} brgemm  {:>8.2} GF/s\n",
                    slot.kind,
                    slot.label,
                    pass.name(),
                    p.calls,
                    p.brgemm_calls,
                    gf
                ));
            }
        }
        s
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static PROFILER: Mutex<Option<Arc<Profiler>>> = Mutex::new(None);

/// Install a fresh global profiler and return it. Primitives constructed
/// from now on register slots in it. Idempotent: installing again replaces
/// the registry (slots held by live primitives keep accumulating into
/// their own `Arc`s, but they leave the new snapshot).
pub fn install() -> Arc<Profiler> {
    let p = Arc::new(Profiler::default());
    *PROFILER.lock().unwrap() = Some(p.clone());
    ENABLED.store(true, Ordering::Release);
    p
}

/// Remove the global profiler. Already-constructed primitives drop to the
/// branch-only disabled path on their next pass? No — they keep their
/// slot `Arc` and keep recording into it; only *new* primitives skip
/// registration. Uninstall is for test isolation, not mid-run toggling.
pub fn uninstall() {
    ENABLED.store(false, Ordering::Release);
    *PROFILER.lock().unwrap() = None;
}

/// Whether a profiler is currently installed (one atomic load).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The installed profiler, if any — what `admin metrics` renders the
/// primitive families from.
pub fn current() -> Option<Arc<Profiler>> {
    PROFILER.lock().unwrap().clone()
}

/// Called by primitive constructors: a slot in the installed profiler, or
/// `None` (the common case) when profiling is off — the primitive then
/// pays one branch per pass and nothing else.
pub fn register(kind: &'static str, label: String) -> Option<Arc<PrimSlot>> {
    if !enabled() {
        return None;
    }
    let guard = PROFILER.lock().unwrap();
    let profiler = guard.as_ref()?;
    let slot = Arc::new(PrimSlot { kind, label, passes: Default::default() });
    profiler.slots.lock().unwrap().push(slot.clone());
    Some(slot)
}

/// Serialises tests (and anything else) that install the global profiler,
/// so concurrent `cargo test` threads cannot swap it under each other.
/// Lock poisoning from a failed test is ignored — the lock only provides
/// exclusion, it guards no data.
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let mut m = Metrics::new();
        m.inc("requests", 2);
        m.inc("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe_secs("step", 0.1);
        m.observe_secs("step", 0.3);
        assert!((m.timer_mean("step").unwrap() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn time_records_and_returns() {
        let mut m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.timers.get("op").unwrap().n, 1);
    }

    #[test]
    fn merge_combines_exactly() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        for x in [1.0, 2.0, 3.0] {
            a.observe_secs("t", x);
        }
        for x in [4.0, 5.0] {
            b.observe_secs("t", x);
        }
        a.inc("c", 1);
        b.inc("c", 2);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        let mut whole = Metrics::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            whole.observe_secs("t", x);
        }
        let got = a.timers.get("t").unwrap();
        let want = whole.timers.get("t").unwrap();
        assert_eq!(got.n, want.n);
        assert!((got.mean() - want.mean()).abs() < 1e-12);
        assert!((got.std() - want.std()).abs() < 1e-9);
        assert_eq!(got.min, want.min);
        assert_eq!(got.max, want.max);
    }

    #[test]
    fn merge_single_sample_registries() {
        // n=1 on both sides: (na-1) and (nb-1) weights are zero, the
        // variance comes entirely from the delta term.
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.observe_secs("t", 2.0);
        b.observe_secs("t", 4.0);
        a.merge(&b);
        let got = a.timers.get("t").unwrap();
        let mut whole = Online::new();
        whole.push(2.0);
        whole.push(4.0);
        assert_eq!(got.n, 2);
        assert!((got.mean() - whole.mean()).abs() < 1e-12);
        assert!((got.std() - whole.std()).abs() < 1e-12);
        assert_eq!(got.min, 2.0);
        assert_eq!(got.max, 4.0);
    }

    #[test]
    fn merge_empty_into_nonempty_and_back() {
        let mut a = Metrics::new();
        for x in [1.0, 3.0] {
            a.observe_secs("t", x);
        }
        let before = a.timers.get("t").unwrap().clone();
        a.merge(&Metrics::new()); // empty other: a unchanged
        let after = a.timers.get("t").unwrap();
        assert_eq!(after.n, before.n);
        assert!((after.mean() - before.mean()).abs() < 1e-15);
        assert_eq!(after.min, before.min);

        let mut empty = Metrics::new();
        empty.merge(&a); // empty self: becomes a copy
        let got = empty.timers.get("t").unwrap();
        assert_eq!(got.n, 2);
        assert!((got.mean() - 2.0).abs() < 1e-12);
        assert_eq!(got.min, 1.0);
        assert_eq!(got.max, 3.0);
    }

    #[test]
    fn merge_counter_only_registries() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.inc("steps", 7);
        b.inc("steps", 5);
        b.inc("evals", 1);
        a.merge(&b);
        assert_eq!(a.counter("steps"), 12);
        assert_eq!(a.counter("evals"), 1);
        assert!(a.timers.is_empty());
    }

    #[test]
    fn merge_online_is_exact_across_splits() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut whole = Online::new();
        for &x in &xs {
            whole.push(x);
        }
        for split in 1..xs.len() {
            let (l, r) = xs.split_at(split);
            let mut a = Online::new();
            let mut b = Online::new();
            l.iter().for_each(|&x| a.push(x));
            r.iter().for_each(|&x| b.push(x));
            let m = merge_online(&a, &b);
            assert_eq!(m.n, whole.n);
            assert!((m.mean() - whole.mean()).abs() < 1e-12);
            assert!((m.std() - whole.std()).abs() < 1e-9);
        }
    }

    #[test]
    fn json_export_shape() {
        let mut m = Metrics::new();
        m.inc("x", 1);
        m.observe_secs("t", 0.5);
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get("x").unwrap().as_f64(), Some(1.0));
        assert!(j.get("timers").unwrap().get("t").unwrap().get("mean_s").is_some());
    }

    #[test]
    fn profiler_register_gating() {
        let _g = test_lock();
        uninstall();
        assert!(!enabled());
        assert!(register("fc", "off".into()).is_none());
        let p = install();
        assert!(enabled());
        let slot = register("fc", "on".into()).expect("profiler installed");
        slot.record(Pass::Fwd, 6, 100.0, 50, Duration::from_micros(10));
        slot.record(Pass::Fwd, 6, 100.0, 50, Duration::from_micros(10));
        let s = slot.pass_snapshot(Pass::Fwd);
        assert_eq!(s.calls, 2);
        assert_eq!(s.brgemm_calls, 12);
        assert_eq!(s.flops, 200);
        assert_eq!(s.bytes, 100);
        assert!(s.secs > 0.0);
        assert_eq!(p.slots().len(), 1);
        uninstall();
    }

    #[test]
    fn snapshot_reports_efficiency_in_unit_interval() {
        let _g = test_lock();
        let p = install();
        let slot = register("fc", "eff-test".into()).unwrap();
        // A plausible pass: 1 GFLOP in 10 ms -> 100 GF/s. Efficiency must
        // land in (0, 1] whatever the measured host peak is.
        slot.record(Pass::Fwd, 4, 1e9, 1 << 20, Duration::from_millis(10));
        let j = p.snapshot();
        let row = match &j {
            Json::Arr(rows) => rows
                .iter()
                .find(|r| r.get("label").and_then(|l| l.as_str()) == Some("eff-test"))
                .expect("slot present"),
            _ => panic!("snapshot is an array"),
        };
        let pass = match row.get("passes").unwrap() {
            Json::Arr(ps) => ps[0].clone(),
            _ => panic!("passes is an array"),
        };
        assert_eq!(pass.get("pass").unwrap().as_str(), Some("fwd"));
        assert_eq!(pass.get("brgemm_calls").unwrap().as_f64(), Some(4.0));
        let eff = pass.get("efficiency").unwrap().as_f64().unwrap();
        assert!(eff > 0.0 && eff <= 1.0, "efficiency {}", eff);
        let gf = pass.get("gflops").unwrap().as_f64().unwrap();
        assert!((gf - 100.0).abs() < 1.0, "gflops {}", gf);
        uninstall();
    }

    #[test]
    fn achieved_gflops_formula() {
        assert!((achieved_gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(achieved_gflops(1e9, 0.0), 0.0);
    }
}
