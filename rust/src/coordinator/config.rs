//! Run configuration: JSON config files for the launcher.
//!
//! A config names a workload (mlp / cnn / rnn / lstm / resnet), its
//! shape, and the execution backend (native BRGEMM primitives or compiled
//! XLA artifacts) — the coordinator's equivalent of a framework's model +
//! run spec. Two equivalent spellings are accepted:
//!
//! * the explicit form, e.g.
//!   `{"workload": {"kind": "cnn", "scale": 8, "depth": 2, "classes": 8}}`;
//! * the `model` shorthand, e.g. `{"model": "cnn", "tune": true}`, which
//!   selects the workload's default shape (`mlp`: 64→128→10, optionally
//!   overridden by a top-level `sizes` key; `cnn`: the ResNet-mini stack
//!   of `coordinator::cnn::CnnSpec::resnet_mini` at scale 8, depth 2,
//!   8 classes — optionally overridden by top-level
//!   `scale`/`depth`/`classes` keys; `rnn`: the LSTM sequence classifier
//!   at c 16, k 32, t 8, 4 classes — optionally overridden by top-level
//!   `c`/`k`/`t`/`classes` keys).
//!
//! With `{"tune": true}` the launcher tunes every layer shape before the
//! first training step and builds the model through the primitives'
//! `tuned()` constructors (for `cnn`: `ConvPrimitive::tuned`).
//!
//! A `"serve"` section switches the run from training to inference
//! serving (see `examples/serve.json`): the workload names the model
//! topology, and `{"serve": {"rate": 2000, "requests": 512, "max_batch":
//! 8, "workers": 2}}` shapes the open-loop load and the worker pool.

use crate::serve::slo::SloSpec;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Which execution engine runs the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust BRGEMM primitives (the paper's C-kernel analogue).
    Native,
    /// AOT-compiled XLA artifacts via PJRT (the tensor-compiler analogue).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => bail!("unknown backend '{}' (native|xla)", other),
        }
    }
}

/// Workload family + shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    Mlp { sizes: Vec<usize> },
    /// End-to-end CNN training (conv stack + pool + FC head); shape is the
    /// ResNet-mini stack at spatial `56/scale` with `depth` conv layers.
    Cnn { scale: usize, depth: usize, classes: usize },
    /// End-to-end RNN training (`layers` stacked LSTM cells + FC softmax
    /// head on the top layer's final hidden state) over length-`t`
    /// sequences of `c`-dim steps. `layers` is honored, never silently
    /// coerced: a 2-layer config trains a genuinely 2-layer stack.
    Rnn { c: usize, k: usize, t: usize, classes: usize, layers: usize },
    Lstm { c: usize, k: usize, t: usize, layers: usize },
    Resnet { scale: usize },
}

/// Inference-serving parameters (the `"serve"` config section): an
/// open-loop synthetic load plus the batcher/worker-pool shape, and
/// optionally a trained model artifact to serve.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Mean arrival rate of the Poisson open-loop load (requests/second).
    pub rate: f64,
    /// Total requests to generate.
    pub requests: usize,
    /// Top of the batch-bucket ladder (1/2/4/…/max_batch).
    pub max_batch: usize,
    /// Serving worker threads pulling batches off the queue.
    pub workers: usize,
    /// Batching delay: microseconds a worker may wait for its bucket to
    /// fill before dispatching a partial batch (0 = greedy dispatch, the
    /// previous behaviour).
    pub wait_for_fill_us: u64,
    /// Serve trained weights from this model artifact instead of a random
    /// init; the artifact's arch descriptor decides the topology.
    pub model_path: Option<String>,
    /// With `model_path`: replay the training distribution through the
    /// server and fail the run if response accuracy falls below this
    /// fraction — the end-to-end proof that the trained weights (not a
    /// random init) are answering.
    pub min_accuracy: Option<f64>,
    /// With `model_path`: poll the artifact file for content changes and
    /// hot-reload it into the running server (a concurrent trainer's
    /// atomic checkpoint renames are picked up automatically; reload
    /// events land in the serve metrics).
    pub watch_model: bool,
    /// Poll cadence of the artifact watcher in milliseconds (with
    /// `watch_model`; previously hard-coded at the spawn site).
    pub watch_poll_ms: u64,
    /// Sequence workloads only: generate a *mixed-length* open-loop load
    /// instead of full-`T` requests — per-request lengths drawn from the
    /// truncated log-normal GNMT-style distribution around this typical
    /// length (clamped to `[2, T]`), routed through the length-bucket
    /// ladder. `None` = every request at the arch's full `T`.
    pub seq_len_typical: Option<usize>,
    /// Log a point-in-time serving snapshot (one compact JSON line at
    /// info level) every this many seconds while the load runs.
    pub metrics_every: Option<f64>,
    /// Bind a Unix-domain-socket admin endpoint at this path for the
    /// run's duration: line-delimited JSON `stats` / `trace` / `reload` /
    /// `drain` commands against the live server (the push-style superset
    /// of `watch_model`).
    pub admin_sock: Option<String>,
    /// Span-tracer sampling period: trace 1 request in every
    /// `trace_sample` (deterministic, keyed off the request id). `1` =
    /// every request. Only meaningful when tracing is on (`--trace-out`
    /// or an `admin_sock` `trace` consumer).
    pub trace_sample: u64,
    /// Latency SLO (the nested `"slo"` object: `{"latency_ms": 50,
    /// "objective": 0.99}`): every request gets a deadline, the run
    /// reports attainment, violation attribution, burn rate and error
    /// budget ([`crate::serve::slo`]). `None` = no SLO accounting.
    pub slo: Option<SloSpec>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            rate: 2000.0,
            requests: 512,
            max_batch: 8,
            workers: 2,
            wait_for_fill_us: 0,
            model_path: None,
            min_accuracy: None,
            watch_model: false,
            watch_poll_ms: 50,
            seq_len_typical: None,
            metrics_every: None,
            admin_sock: None,
            trace_sample: 1,
            slo: None,
        }
    }
}

impl ServeConfig {
    /// Shared by the JSON parser and the `serve` CLI flags, so the two
    /// entry points can never drift on what a legal serving run is.
    pub fn validate(&self) -> Result<()> {
        if self.rate <= 0.0 || !self.rate.is_finite() {
            bail!("serve.rate must be a positive, finite req/s value");
        }
        if self.requests == 0 || self.max_batch == 0 || self.workers == 0 {
            bail!("serve needs requests/max_batch/workers >= 1");
        }
        if let Some(acc) = self.min_accuracy {
            if self.model_path.is_none() {
                bail!("serve.min_accuracy requires serve.model_path (a trained artifact)");
            }
            if !(0.0..=1.0).contains(&acc) {
                bail!("serve.min_accuracy must be a fraction in [0, 1]");
            }
        }
        if self.watch_model && self.model_path.is_none() {
            bail!("serve.watch_model requires serve.model_path (the artifact file to watch)");
        }
        if self.watch_poll_ms == 0 {
            bail!("serve.watch_poll_ms must be >= 1 (watcher poll cadence in ms)");
        }
        if let Some(l) = self.seq_len_typical {
            if l == 0 {
                bail!("serve.seq_len_typical must be >= 1 (typical sequence length)");
            }
        }
        if let Some(e) = self.metrics_every {
            if e <= 0.0 || !e.is_finite() {
                bail!("serve.metrics_every must be a positive, finite number of seconds");
            }
        }
        if matches!(self.admin_sock.as_deref(), Some("")) {
            bail!("serve.admin_sock must be a non-empty socket path");
        }
        if self.trace_sample == 0 {
            bail!("serve.trace_sample must be >= 1 (trace 1 request in every N)");
        }
        if let Some(slo) = &self.slo {
            slo.validate()?;
        }
        Ok(())
    }
}

/// Training-checkpoint parameters (the `"checkpoint"` config section):
/// the trainer snapshots the model to `path` (a versioned, checksummed
/// model artifact — see [`crate::modelio`]) every `every_epochs` epochs,
/// and `run --resume <artifact>` continues a schedule from a snapshot
/// with results identical to an uninterrupted run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// Artifact path; each snapshot atomically overwrites the previous
    /// one (temp file + rename), so a hot-reloading server can watch it.
    pub path: String,
    /// Snapshot cadence in epochs (an epoch = one pass over the synthetic
    /// training set).
    pub every_epochs: usize,
}

impl CheckpointConfig {
    pub fn validate(&self) -> Result<()> {
        if self.path.is_empty() {
            bail!("checkpoint.path must be a non-empty file path");
        }
        if self.every_epochs == 0 {
            bail!("checkpoint.every_epochs must be >= 1");
        }
        Ok(())
    }
}

/// A full run specification.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub workload: Workload,
    pub backend: Backend,
    pub batch: usize,
    pub steps: usize,
    pub lr: f64,
    pub workers: usize,
    pub nthreads: usize,
    pub seed: u64,
    /// Autotune the workload's layer shapes (persisting winners in the
    /// tuning cache) before the first training step, and build the model
    /// through the primitives' `tuned()` path.
    pub tune: bool,
    /// When set, the run serves inference traffic instead of training:
    /// the workload names the topology, `serve` shapes load and pool.
    pub serve: Option<ServeConfig>,
    /// When set, train for `epochs` passes over the synthetic training
    /// set (overriding `steps`); checkpoint cadence is counted in these.
    pub epochs: Option<usize>,
    /// Periodic training snapshots to a model artifact.
    pub checkpoint: Option<CheckpointConfig>,
    /// Write run metrics as JSON lines to this path: one line per epoch
    /// (per-pass timer breakdown) plus a final line with the per-primitive
    /// BRGEMM profile. Enables the telemetry profiler for the run.
    pub metrics_out: Option<String>,
    /// Write a Chrome trace-event JSON document (Perfetto /
    /// chrome://tracing viewable) to this path at the end of the run.
    /// Enables the span tracer: per-request spans on serve runs,
    /// per-worker per-pass spans on training runs.
    pub trace_out: Option<String>,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            workload: Workload::Mlp { sizes: vec![64, 128, 10] },
            backend: Backend::Native,
            batch: 32,
            steps: 100,
            lr: 0.05,
            workers: 1,
            nthreads: 1,
            seed: 42,
            tune: false,
            serve: None,
            epochs: None,
            checkpoint: None,
            metrics_out: None,
            trace_out: None,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document, e.g.
    /// `{"workload": {"kind": "mlp", "sizes": [64,128,10]}, "batch": 32,
    ///   "steps": 200, "lr": 0.05, "workers": 4, "backend": "native"}`.
    pub fn from_json(text: &str) -> Result<RunConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("config: {}", e))?;
        let mut cfg = RunConfig::default();
        if let Some(w) = j.get("workload") {
            let kind = w
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("workload.kind required"))?;
            cfg.workload = match kind {
                "mlp" => {
                    let arr = w
                        .get("sizes")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("mlp needs sizes"))?;
                    Workload::Mlp { sizes: parse_sizes(arr)? }
                }
                "cnn" => Workload::Cnn {
                    scale: get_usize(w, "scale", 8)?,
                    depth: get_usize(w, "depth", 2)?,
                    classes: get_usize(w, "classes", 8)?,
                },
                "rnn" => Workload::Rnn {
                    c: get_usize(w, "c", 16)?,
                    k: get_usize(w, "k", 32)?,
                    t: get_usize(w, "t", 8)?,
                    classes: get_usize(w, "classes", 4)?,
                    layers: get_usize(w, "layers", 1)?,
                },
                "lstm" => Workload::Lstm {
                    c: get_usize(w, "c", 64)?,
                    k: get_usize(w, "k", 64)?,
                    t: get_usize(w, "t", 16)?,
                    layers: get_usize(w, "layers", 1)?,
                },
                "resnet" => Workload::Resnet { scale: get_usize(w, "scale", 4)? },
                other => bail!("unknown workload kind '{}'", other),
            };
        }
        // `model` shorthand: the workload's default shape (top-level
        // scale/depth/classes apply for cnn). Mutually exclusive with the
        // explicit `workload` object.
        if let Some(mv) = j.get("model") {
            let m = mv.as_str().ok_or_else(|| anyhow!("model must be a string (mlp|cnn|rnn)"))?;
            if j.get("workload").is_some() {
                bail!("'model' and 'workload' are mutually exclusive; use one");
            }
            cfg.workload = match m {
                "mlp" => {
                    let sizes = match j.get("sizes") {
                        None => vec![64, 128, 10],
                        Some(v) => parse_sizes(
                            v.as_arr().ok_or_else(|| anyhow!("sizes must be an array"))?,
                        )?,
                    };
                    Workload::Mlp { sizes }
                }
                "cnn" => Workload::Cnn {
                    scale: get_usize(&j, "scale", 8)?,
                    depth: get_usize(&j, "depth", 2)?,
                    classes: get_usize(&j, "classes", 8)?,
                },
                "rnn" => Workload::Rnn {
                    c: get_usize(&j, "c", 16)?,
                    k: get_usize(&j, "k", 32)?,
                    t: get_usize(&j, "t", 8)?,
                    classes: get_usize(&j, "classes", 4)?,
                    layers: get_usize(&j, "layers", 1)?,
                },
                other => bail!("unknown model '{}' (mlp|cnn|rnn)", other),
            };
        }
        if let Some(b) = j.get("backend").and_then(Json::as_str) {
            cfg.backend = Backend::parse(b)?;
        }
        cfg.batch = get_usize(&j, "batch", cfg.batch)?;
        cfg.steps = get_usize(&j, "steps", cfg.steps)?;
        cfg.workers = get_usize(&j, "workers", cfg.workers)?;
        cfg.nthreads = get_usize(&j, "nthreads", cfg.nthreads)?;
        cfg.seed = get_usize(&j, "seed", cfg.seed as usize)? as u64;
        if let Some(lr) = j.get("lr").and_then(Json::as_f64) {
            cfg.lr = lr;
        }
        if let Some(t) = j.get("tune").and_then(Json::as_bool) {
            cfg.tune = t;
        }
        if let Some(sv) = j.get("serve") {
            if sv.as_obj().is_none() {
                bail!("serve must be an object, e.g. {{\"serve\": {{\"rate\": 2000}}}}");
            }
            let d = ServeConfig::default();
            let sc = ServeConfig {
                rate: get_f64(sv, "rate", d.rate)?,
                requests: get_usize(sv, "requests", d.requests)?,
                max_batch: get_usize(sv, "max_batch", d.max_batch)?,
                workers: get_usize(sv, "workers", d.workers)?,
                wait_for_fill_us: get_usize(sv, "wait_for_fill_us", 0)? as u64,
                model_path: get_opt_str(sv, "model_path")?,
                min_accuracy: get_opt_f64(sv, "min_accuracy")?,
                watch_model: match sv.get("watch_model") {
                    None | Some(Json::Null) => false,
                    Some(v) => v
                        .as_bool()
                        .ok_or_else(|| anyhow!("watch_model must be a boolean"))?,
                },
                watch_poll_ms: get_usize(sv, "watch_poll_ms", d.watch_poll_ms as usize)? as u64,
                seq_len_typical: match sv.get("seq_len_typical") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.as_usize().ok_or_else(|| {
                        anyhow!("seq_len_typical must be a non-negative integer")
                    })?),
                },
                metrics_every: get_opt_f64(sv, "metrics_every")?,
                admin_sock: get_opt_str(sv, "admin_sock")?,
                trace_sample: get_usize(sv, "trace_sample", d.trace_sample as usize)? as u64,
                slo: match sv.get("slo") {
                    None | Some(Json::Null) => None,
                    Some(v) => {
                        if v.as_obj().is_none() {
                            bail!(
                                "serve.slo must be an object, e.g. \
                                 {{\"slo\": {{\"latency_ms\": 50, \"objective\": 0.99}}}}"
                            );
                        }
                        let ds = SloSpec::default();
                        Some(SloSpec {
                            latency_ms: get_f64(v, "latency_ms", ds.latency_ms)?,
                            objective: get_f64(v, "objective", ds.objective)?,
                        })
                    }
                },
            };
            sc.validate()?;
            cfg.serve = Some(sc);
        }
        if let Some(ep) = j.get("epochs") {
            let e = ep
                .as_usize()
                .ok_or_else(|| anyhow!("epochs must be a non-negative integer"))?;
            if e == 0 {
                bail!("epochs must be >= 1");
            }
            cfg.epochs = Some(e);
        }
        if let Some(cv) = j.get("checkpoint") {
            if cv.as_obj().is_none() {
                bail!(
                    "checkpoint must be an object, e.g. \
                     {{\"checkpoint\": {{\"path\": \"ckpt.bin\", \"every_epochs\": 1}}}}"
                );
            }
            let ck = CheckpointConfig {
                path: cv
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("checkpoint.path (string) required"))?
                    .to_string(),
                every_epochs: get_usize(cv, "every_epochs", 1)?,
            };
            ck.validate()?;
            cfg.checkpoint = Some(ck);
        }
        cfg.metrics_out = get_opt_str(&j, "metrics_out")?;
        if matches!(cfg.metrics_out.as_deref(), Some("")) {
            bail!("metrics_out must be a non-empty file path");
        }
        cfg.trace_out = get_opt_str(&j, "trace_out")?;
        if matches!(cfg.trace_out.as_deref(), Some("")) {
            bail!("trace_out must be a non-empty file path");
        }
        if cfg.batch == 0 || cfg.workers == 0 || cfg.nthreads == 0 {
            bail!("batch/workers/nthreads must be positive");
        }
        if let Workload::Cnn { scale, depth, classes } = &cfg.workload {
            if *scale == 0 || *depth == 0 || *classes < 2 {
                bail!("cnn workload needs scale >= 1, depth >= 1, classes >= 2");
            }
        }
        if let Workload::Rnn { c, k, t, classes, layers } = &cfg.workload {
            if *c == 0 || *k == 0 || *t == 0 || *classes < 2 {
                bail!("rnn workload needs c/k/t >= 1 and classes >= 2");
            }
            if *layers == 0 {
                bail!("rnn workload needs layers >= 1 (stacked LSTM depth)");
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {}: {}", path, e))?;
        RunConfig::from_json(&text)
    }
}

/// Parse an MLP `sizes` array (shared by the explicit-workload and
/// `model`-shorthand spellings, so validation can't drift between them).
fn parse_sizes(arr: &[Json]) -> Result<Vec<usize>> {
    let sizes = arr
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad size")))
        .collect::<Result<Vec<_>>>()?;
    if sizes.len() < 2 {
        bail!("mlp sizes needs >= 2 entries");
    }
    Ok(sizes)
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| anyhow!("{} must be a non-negative integer", key)),
    }
}

/// Like [`get_usize`]: absent → default, present-but-not-a-number → error
/// (never a silent fallback).
fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| anyhow!("{} must be a number", key)),
    }
}

/// Optional string field: absent or `null` → `None`, a string → `Some`,
/// anything else → error.
fn get_opt_str(j: &Json, key: &str) -> Result<Option<String>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| anyhow!("{} must be a string (or null)", key)),
    }
}

/// Optional number field: absent or `null` → `None`.
fn get_opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("{} must be a number (or null)", key)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_json(
            r#"{"workload": {"kind": "mlp", "sizes": [32, 64, 10]},
                "backend": "xla", "batch": 16, "steps": 7, "lr": 0.1,
                "workers": 4, "nthreads": 2, "seed": 9}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, Workload::Mlp { sizes: vec![32, 64, 10] });
        assert_eq!(cfg.backend, Backend::Xla);
        assert_eq!((cfg.batch, cfg.steps, cfg.workers, cfg.nthreads), (16, 7, 4, 2));
        assert!((cfg.lr - 0.1).abs() < 1e-12);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn defaults_apply() {
        let cfg = RunConfig::from_json(r#"{}"#).unwrap();
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(cfg.batch, 32);
        assert!(!cfg.tune, "tune-before-train defaults off");
    }

    #[test]
    fn tune_flag_parses() {
        let cfg = RunConfig::from_json(r#"{"tune": true}"#).unwrap();
        assert!(cfg.tune);
        let cfg = RunConfig::from_json(r#"{"tune": false}"#).unwrap();
        assert!(!cfg.tune);
    }

    #[test]
    fn cnn_workload_and_model_shorthand() {
        let cfg = RunConfig::from_json(
            r#"{"workload": {"kind": "cnn", "scale": 4, "depth": 3, "classes": 5}}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, Workload::Cnn { scale: 4, depth: 3, classes: 5 });
        // Shorthand picks the default shape…
        let cfg = RunConfig::from_json(r#"{"model": "cnn", "tune": true}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Cnn { scale: 8, depth: 2, classes: 8 });
        assert!(cfg.tune);
        // …with optional top-level overrides.
        let cfg = RunConfig::from_json(r#"{"model": "cnn", "scale": 2, "classes": 4}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Cnn { scale: 2, depth: 2, classes: 4 });
        let cfg = RunConfig::from_json(r#"{"model": "mlp"}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Mlp { sizes: vec![64, 128, 10] });
        // The mlp shorthand honors a top-level sizes override, like cnn's
        // scale/depth/classes.
        let cfg = RunConfig::from_json(r#"{"model": "mlp", "sizes": [784, 256, 10]}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Mlp { sizes: vec![784, 256, 10] });
        assert!(RunConfig::from_json(r#"{"model": "mlp", "sizes": [5]}"#).is_err());
        // Wrong-typed sizes/model must error, not silently fall back to
        // defaults.
        assert!(RunConfig::from_json(r#"{"model": "mlp", "sizes": 784}"#).is_err());
        assert!(RunConfig::from_json(r#"{"model": 5}"#).is_err());
        // Unknown model / ambiguous forms are rejected.
        assert!(RunConfig::from_json(r#"{"model": "gpt"}"#).is_err());
        assert!(RunConfig::from_json(
            r#"{"model": "cnn", "workload": {"kind": "mlp", "sizes": [4, 2]}}"#
        )
        .is_err());
        assert!(RunConfig::from_json(r#"{"model": "cnn", "depth": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"model": "cnn", "classes": 1}"#).is_err());
    }

    #[test]
    fn rnn_workload_and_model_shorthand() {
        let cfg = RunConfig::from_json(
            r#"{"workload": {"kind": "rnn", "c": 8, "k": 16, "t": 5, "classes": 3}}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, Workload::Rnn { c: 8, k: 16, t: 5, classes: 3, layers: 1 });
        // Shorthand picks the default shape…
        let cfg = RunConfig::from_json(r#"{"model": "rnn", "tune": true}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Rnn { c: 16, k: 32, t: 8, classes: 4, layers: 1 });
        assert!(cfg.tune);
        // …with optional top-level overrides.
        let cfg = RunConfig::from_json(r#"{"model": "rnn", "t": 12, "classes": 6}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Rnn { c: 16, k: 32, t: 12, classes: 6, layers: 1 });
        // Invalid shapes rejected, not silently defaulted.
        assert!(RunConfig::from_json(r#"{"model": "rnn", "t": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"model": "rnn", "classes": 1}"#).is_err());
        assert!(RunConfig::from_json(r#"{"workload": {"kind": "rnn", "c": 0}}"#).is_err());
    }

    #[test]
    fn rnn_layers_parse_in_both_spellings_and_zero_is_rejected() {
        // Honor-or-error: a layers field must reach the workload (the
        // model constructor then builds a genuinely stacked RnnModel) —
        // it can never be silently dropped to 1 again.
        let cfg = RunConfig::from_json(
            r#"{"workload": {"kind": "rnn", "c": 8, "k": 16, "t": 5, "classes": 3, "layers": 2}}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, Workload::Rnn { c: 8, k: 16, t: 5, classes: 3, layers: 2 });
        let cfg = RunConfig::from_json(r#"{"model": "rnn", "layers": 4}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Rnn { c: 16, k: 32, t: 8, classes: 4, layers: 4 });
        assert!(RunConfig::from_json(r#"{"model": "rnn", "layers": 0}"#).is_err());
        assert!(
            RunConfig::from_json(r#"{"workload": {"kind": "rnn", "layers": 0}}"#).is_err()
        );
        assert!(RunConfig::from_json(r#"{"model": "rnn", "layers": "four"}"#).is_err());
    }

    #[test]
    fn watch_model_parses_and_requires_model_path() {
        let cfg = RunConfig::from_json(
            r#"{"serve": {"model_path": "checkpoints/rnn.bin", "watch_model": true}}"#,
        )
        .unwrap();
        assert!(cfg.serve.unwrap().watch_model);
        // Defaults off; null tolerated (lets examples carry the key).
        let cfg = RunConfig::from_json(r#"{"serve": {}}"#).unwrap();
        assert!(!cfg.serve.unwrap().watch_model);
        let cfg = RunConfig::from_json(
            r#"{"serve": {"model_path": "m.bin", "watch_model": null}}"#,
        )
        .unwrap();
        assert!(!cfg.serve.unwrap().watch_model);
        // Watching nothing is meaningless; wrong types error.
        assert!(RunConfig::from_json(r#"{"serve": {"watch_model": true}}"#).is_err());
        assert!(RunConfig::from_json(
            r#"{"serve": {"model_path": "m.bin", "watch_model": "yes"}}"#
        )
        .is_err());
    }

    #[test]
    fn watch_poll_ms_parses_with_default_and_bounds() {
        // Default matches the previously hard-coded spawn-site cadence.
        let cfg = RunConfig::from_json(r#"{"serve": {}}"#).unwrap();
        assert_eq!(cfg.serve.unwrap().watch_poll_ms, 50);
        let cfg = RunConfig::from_json(
            r#"{"serve": {"model_path": "m.bin", "watch_model": true, "watch_poll_ms": 5}}"#,
        )
        .unwrap();
        assert_eq!(cfg.serve.unwrap().watch_poll_ms, 5);
        // Zero would spin the watcher; wrong types error.
        assert!(RunConfig::from_json(r#"{"serve": {"watch_poll_ms": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"watch_poll_ms": "fast"}}"#).is_err());
    }

    #[test]
    fn seq_len_typical_parses() {
        let cfg = RunConfig::from_json(r#"{"serve": {}}"#).unwrap();
        assert!(cfg.serve.unwrap().seq_len_typical.is_none(), "full-T load by default");
        let cfg = RunConfig::from_json(
            r#"{"model": "rnn", "serve": {"seq_len_typical": 6}}"#,
        )
        .unwrap();
        assert_eq!(cfg.serve.unwrap().seq_len_typical, Some(6));
        // null tolerated (lets examples carry the key); invalid rejected.
        let cfg = RunConfig::from_json(r#"{"serve": {"seq_len_typical": null}}"#).unwrap();
        assert!(cfg.serve.unwrap().seq_len_typical.is_none());
        assert!(RunConfig::from_json(r#"{"serve": {"seq_len_typical": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"seq_len_typical": "short"}}"#).is_err());
    }

    #[test]
    fn serve_section_parses_with_defaults_and_overrides() {
        let cfg = RunConfig::from_json(r#"{}"#).unwrap();
        assert!(cfg.serve.is_none(), "serving is opt-in");
        let cfg = RunConfig::from_json(r#"{"model": "mlp", "serve": {}}"#).unwrap();
        assert_eq!(cfg.serve.unwrap(), ServeConfig::default());
        let cfg = RunConfig::from_json(
            r#"{"model": "cnn", "serve":
                {"rate": 500.5, "requests": 64, "max_batch": 4, "workers": 3}}"#,
        )
        .unwrap();
        let sc = cfg.serve.unwrap();
        assert!((sc.rate - 500.5).abs() < 1e-12);
        assert_eq!((sc.requests, sc.max_batch, sc.workers), (64, 4, 3));
        // Invalid shapes rejected, not silently defaulted.
        assert!(RunConfig::from_json(r#"{"serve": 5}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"rate": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"rate": "500"}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"requests": "many"}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"max_batch": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"workers": 0}}"#).is_err());
    }

    #[test]
    fn serve_trained_model_fields_parse() {
        let cfg = RunConfig::from_json(
            r#"{"serve": {"model_path": "checkpoints/mlp.bin", "min_accuracy": 0.5,
                          "wait_for_fill_us": 250}}"#,
        )
        .unwrap();
        let sc = cfg.serve.unwrap();
        assert_eq!(sc.model_path.as_deref(), Some("checkpoints/mlp.bin"));
        assert_eq!(sc.min_accuracy, Some(0.5));
        assert_eq!(sc.wait_for_fill_us, 250);
        // null model_path = absent (lets examples carry the key).
        let cfg = RunConfig::from_json(r#"{"serve": {"model_path": null}}"#).unwrap();
        assert!(cfg.serve.unwrap().model_path.is_none());
        // min_accuracy without a model to serve is meaningless.
        assert!(RunConfig::from_json(r#"{"serve": {"min_accuracy": 0.5}}"#).is_err());
        assert!(RunConfig::from_json(
            r#"{"serve": {"model_path": "x.bin", "min_accuracy": 1.5}}"#
        )
        .is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"model_path": 7}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"wait_for_fill_us": -3}}"#).is_err());
    }

    #[test]
    fn checkpoint_and_epochs_parse() {
        let cfg = RunConfig::from_json(
            r#"{"epochs": 2, "checkpoint": {"path": "checkpoints/mlp.bin",
                                           "every_epochs": 1}}"#,
        )
        .unwrap();
        assert_eq!(cfg.epochs, Some(2));
        let ck = cfg.checkpoint.unwrap();
        assert_eq!(ck.path, "checkpoints/mlp.bin");
        assert_eq!(ck.every_epochs, 1);
        // Defaults: cadence 1, both sections opt-in.
        let cfg = RunConfig::from_json(r#"{"checkpoint": {"path": "c.bin"}}"#).unwrap();
        assert_eq!(cfg.checkpoint.unwrap().every_epochs, 1);
        assert!(RunConfig::from_json(r#"{}"#).unwrap().checkpoint.is_none());
        // Invalid shapes rejected, not silently defaulted.
        assert!(RunConfig::from_json(r#"{"checkpoint": {}}"#).is_err(), "path required");
        assert!(RunConfig::from_json(r#"{"checkpoint": "c.bin"}"#).is_err());
        assert!(RunConfig::from_json(
            r#"{"checkpoint": {"path": "c.bin", "every_epochs": 0}}"#
        )
        .is_err());
        assert!(RunConfig::from_json(r#"{"epochs": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"epochs": "two"}"#).is_err());
    }

    #[test]
    fn metrics_keys_parse() {
        // Top-level metrics_out; serve-section metrics_every.
        let cfg = RunConfig::from_json(r#"{"metrics_out": "metrics.jsonl"}"#).unwrap();
        assert_eq!(cfg.metrics_out.as_deref(), Some("metrics.jsonl"));
        assert!(RunConfig::from_json(r#"{}"#).unwrap().metrics_out.is_none());
        // null tolerated (lets examples carry the key).
        let cfg = RunConfig::from_json(r#"{"metrics_out": null}"#).unwrap();
        assert!(cfg.metrics_out.is_none());
        assert!(RunConfig::from_json(r#"{"metrics_out": ""}"#).is_err());
        assert!(RunConfig::from_json(r#"{"metrics_out": 7}"#).is_err());
        let cfg =
            RunConfig::from_json(r#"{"serve": {"metrics_every": 0.5}}"#).unwrap();
        assert_eq!(cfg.serve.unwrap().metrics_every, Some(0.5));
        assert!(RunConfig::from_json(r#"{"serve": {"metrics_every": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"metrics_every": "fast"}}"#).is_err());
    }

    #[test]
    fn trace_and_admin_keys_parse() {
        // Top-level trace_out (training + serve); serve-section
        // admin_sock and trace_sample.
        let cfg = RunConfig::from_json(r#"{"trace_out": "trace.json"}"#).unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("trace.json"));
        assert!(RunConfig::from_json(r#"{}"#).unwrap().trace_out.is_none());
        // null tolerated (lets examples carry the key); empty rejected.
        let cfg = RunConfig::from_json(r#"{"trace_out": null}"#).unwrap();
        assert!(cfg.trace_out.is_none());
        assert!(RunConfig::from_json(r#"{"trace_out": ""}"#).is_err());
        assert!(RunConfig::from_json(r#"{"trace_out": 7}"#).is_err());

        let cfg = RunConfig::from_json(
            r#"{"serve": {"admin_sock": "/tmp/srv.sock", "trace_sample": 8}}"#,
        )
        .unwrap();
        let sc = cfg.serve.unwrap();
        assert_eq!(sc.admin_sock.as_deref(), Some("/tmp/srv.sock"));
        assert_eq!(sc.trace_sample, 8);
        // Defaults: no socket, sample every request.
        let sc = RunConfig::from_json(r#"{"serve": {}}"#).unwrap().serve.unwrap();
        assert!(sc.admin_sock.is_none());
        assert_eq!(sc.trace_sample, 1);
        let sc = RunConfig::from_json(r#"{"serve": {"admin_sock": null}}"#)
            .unwrap()
            .serve
            .unwrap();
        assert!(sc.admin_sock.is_none());
        // Invalid shapes rejected, not silently defaulted.
        assert!(RunConfig::from_json(r#"{"serve": {"admin_sock": ""}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"admin_sock": 5}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"trace_sample": 0}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"trace_sample": "all"}}"#).is_err());
    }

    #[test]
    fn serve_slo_block_parses_and_validates() {
        let sc = RunConfig::from_json(
            r#"{"serve": {"slo": {"latency_ms": 25, "objective": 0.95}}}"#,
        )
        .unwrap()
        .serve
        .unwrap();
        let slo = sc.slo.unwrap();
        assert_eq!(slo.latency_ms, 25.0);
        assert_eq!(slo.objective, 0.95);
        // Partial blocks fill from the spec defaults.
        let sc = RunConfig::from_json(r#"{"serve": {"slo": {"latency_ms": 10}}}"#)
            .unwrap()
            .serve
            .unwrap();
        let slo = sc.slo.unwrap();
        assert_eq!(slo.latency_ms, 10.0);
        assert_eq!(slo.objective, SloSpec::default().objective);
        // Absent or null ⇒ no SLO accounting at all.
        assert!(RunConfig::from_json(r#"{"serve": {}}"#).unwrap().serve.unwrap().slo.is_none());
        assert!(RunConfig::from_json(r#"{"serve": {"slo": null}}"#)
            .unwrap()
            .serve
            .unwrap()
            .slo
            .is_none());
        // Invalid shapes and values rejected, not silently defaulted.
        assert!(RunConfig::from_json(r#"{"serve": {"slo": 50}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"slo": {"latency_ms": 0}}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"slo": {"objective": 1.0}}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"serve": {"slo": {"objective": "high"}}}"#).is_err());
    }

    #[test]
    fn lstm_and_resnet_workloads() {
        let cfg = RunConfig::from_json(r#"{"workload": {"kind": "lstm", "c": 128, "k": 128, "t": 8}}"#)
            .unwrap();
        assert_eq!(cfg.workload, Workload::Lstm { c: 128, k: 128, t: 8, layers: 1 });
        let cfg =
            RunConfig::from_json(r#"{"workload": {"kind": "resnet", "scale": 2}}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Resnet { scale: 2 });
    }

    #[test]
    fn rejects_invalid() {
        assert!(RunConfig::from_json(r#"{"backend": "cuda"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"workload": {"kind": "mlp", "sizes": [5]}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"batch": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"not json"#).is_err());
    }
}
