//! Run configuration: JSON config files for the launcher.
//!
//! A config names a workload (mlp / lstm / resnet), its shape, and the
//! execution backend (native BRGEMM primitives or compiled XLA artifacts)
//! — the coordinator's equivalent of a framework's model + run spec.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Which execution engine runs the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust BRGEMM primitives (the paper's C-kernel analogue).
    Native,
    /// AOT-compiled XLA artifacts via PJRT (the tensor-compiler analogue).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => bail!("unknown backend '{}' (native|xla)", other),
        }
    }
}

/// Workload family + shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    Mlp { sizes: Vec<usize> },
    Lstm { c: usize, k: usize, t: usize, layers: usize },
    Resnet { scale: usize },
}

/// A full run specification.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub workload: Workload,
    pub backend: Backend,
    pub batch: usize,
    pub steps: usize,
    pub lr: f64,
    pub workers: usize,
    pub nthreads: usize,
    pub seed: u64,
    /// Autotune the workload's layer shapes (persisting winners in the
    /// tuning cache) before the first training step, and build the model
    /// through the primitives' `tuned()` path.
    pub tune: bool,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            workload: Workload::Mlp { sizes: vec![64, 128, 10] },
            backend: Backend::Native,
            batch: 32,
            steps: 100,
            lr: 0.05,
            workers: 1,
            nthreads: 1,
            seed: 42,
            tune: false,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON document, e.g.
    /// `{"workload": {"kind": "mlp", "sizes": [64,128,10]}, "batch": 32,
    ///   "steps": 200, "lr": 0.05, "workers": 4, "backend": "native"}`.
    pub fn from_json(text: &str) -> Result<RunConfig> {
        let j = Json::parse(text).map_err(|e| anyhow!("config: {}", e))?;
        let mut cfg = RunConfig::default();
        if let Some(w) = j.get("workload") {
            let kind = w
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("workload.kind required"))?;
            cfg.workload = match kind {
                "mlp" => {
                    let sizes = w
                        .get("sizes")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("mlp needs sizes"))?
                        .iter()
                        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad size")))
                        .collect::<Result<Vec<_>>>()?;
                    if sizes.len() < 2 {
                        bail!("mlp sizes needs >= 2 entries");
                    }
                    Workload::Mlp { sizes }
                }
                "lstm" => Workload::Lstm {
                    c: get_usize(w, "c", 64)?,
                    k: get_usize(w, "k", 64)?,
                    t: get_usize(w, "t", 16)?,
                    layers: get_usize(w, "layers", 1)?,
                },
                "resnet" => Workload::Resnet { scale: get_usize(w, "scale", 4)? },
                other => bail!("unknown workload kind '{}'", other),
            };
        }
        if let Some(b) = j.get("backend").and_then(Json::as_str) {
            cfg.backend = Backend::parse(b)?;
        }
        cfg.batch = get_usize(&j, "batch", cfg.batch)?;
        cfg.steps = get_usize(&j, "steps", cfg.steps)?;
        cfg.workers = get_usize(&j, "workers", cfg.workers)?;
        cfg.nthreads = get_usize(&j, "nthreads", cfg.nthreads)?;
        cfg.seed = get_usize(&j, "seed", cfg.seed as usize)? as u64;
        if let Some(lr) = j.get("lr").and_then(Json::as_f64) {
            cfg.lr = lr;
        }
        if let Some(t) = j.get("tune").and_then(Json::as_bool) {
            cfg.tune = t;
        }
        if cfg.batch == 0 || cfg.workers == 0 || cfg.nthreads == 0 {
            bail!("batch/workers/nthreads must be positive");
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {}: {}", path, e))?;
        RunConfig::from_json(&text)
    }
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| anyhow!("{} must be a non-negative integer", key)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_json(
            r#"{"workload": {"kind": "mlp", "sizes": [32, 64, 10]},
                "backend": "xla", "batch": 16, "steps": 7, "lr": 0.1,
                "workers": 4, "nthreads": 2, "seed": 9}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload, Workload::Mlp { sizes: vec![32, 64, 10] });
        assert_eq!(cfg.backend, Backend::Xla);
        assert_eq!((cfg.batch, cfg.steps, cfg.workers, cfg.nthreads), (16, 7, 4, 2));
        assert!((cfg.lr - 0.1).abs() < 1e-12);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn defaults_apply() {
        let cfg = RunConfig::from_json(r#"{}"#).unwrap();
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(cfg.batch, 32);
        assert!(!cfg.tune, "tune-before-train defaults off");
    }

    #[test]
    fn tune_flag_parses() {
        let cfg = RunConfig::from_json(r#"{"tune": true}"#).unwrap();
        assert!(cfg.tune);
        let cfg = RunConfig::from_json(r#"{"tune": false}"#).unwrap();
        assert!(!cfg.tune);
    }

    #[test]
    fn lstm_and_resnet_workloads() {
        let cfg = RunConfig::from_json(r#"{"workload": {"kind": "lstm", "c": 128, "k": 128, "t": 8}}"#)
            .unwrap();
        assert_eq!(cfg.workload, Workload::Lstm { c: 128, k: 128, t: 8, layers: 1 });
        let cfg =
            RunConfig::from_json(r#"{"workload": {"kind": "resnet", "scale": 2}}"#).unwrap();
        assert_eq!(cfg.workload, Workload::Resnet { scale: 2 });
    }

    #[test]
    fn rejects_invalid() {
        assert!(RunConfig::from_json(r#"{"backend": "cuda"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"workload": {"kind": "mlp", "sizes": [5]}}"#).is_err());
        assert!(RunConfig::from_json(r#"{"batch": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"not json"#).is_err());
    }
}
