//! Shared model construction: one source of truth for layer configs.
//!
//! The training drivers ([`super::trainer::MlpModel`],
//! [`super::cnn::CnnModel`]) and the serving models
//! ([`crate::serve::InferenceModel`]) must agree *exactly* on how a
//! topology maps to layer configs — the chain-invariant reconciliation
//! (consumer `bc` = producer `bk`, one shared `bn` per FC chain) and the
//! FC-head blocking formula. Before the model-artifact subsystem, that
//! logic was duplicated between `coordinator/cnn.rs` and `serve/model.rs`
//! and only stayed byte-compatible by review; weight lifting (train →
//! artifact → serve) makes the agreement load-bearing, so it now lives
//! here, once.

use crate::coordinator::cnn::CnnSpec;
use crate::coordinator::rnn::RnnSpec;
use crate::primitives::conv::ConvConfig;
use crate::primitives::eltwise::Act;
use crate::primitives::fc::FcConfig;
use crate::primitives::lstm::LstmConfig;
use crate::util::num::largest_divisor_le as pick;

/// The FC layer configs of an MLP chain (`sizes = [d_in, h1, ...,
/// classes]`; hidden ReLU, linear head) with the no-inter-layer-reformat
/// invariant enforced: all layers share one `bn`, and each layer's input
/// block `bc` equals its producer's output block `bk`. With `tuned`, each
/// layer first consults the autotune cache and the reconciliation is then
/// re-applied (layer 0's `bn` wins for the chain; the shared feature
/// dimension guarantees every pinned block is a legal divisor).
pub fn mlp_chain_configs(
    sizes: &[usize],
    batch: usize,
    nthreads: usize,
    tuned: bool,
) -> Vec<FcConfig> {
    assert!(sizes.len() >= 2, "mlp needs at least input + output sizes");
    let bn = pick(batch, 24);
    let mut cfgs: Vec<FcConfig> = sizes
        .windows(2)
        .enumerate()
        .map(|(i, wdim)| {
            let (c, k) = (wdim[0], wdim[1]);
            let act = if i + 2 == sizes.len() { Act::Identity } else { Act::Relu };
            let cfg = FcConfig::new(batch, c, k, act)
                .with_blocking(bn, pick(c, 64), pick(k, 64))
                .with_threads(nthreads);
            if tuned {
                crate::autotune::tuned_fc_config(cfg)
            } else {
                cfg
            }
        })
        .collect();
    if tuned {
        // Reconcile: one bn everywhere, consumer bc = producer bk.
        let shared_bn = cfgs[0].bn;
        for i in 0..cfgs.len() {
            let bc = if i == 0 { cfgs[0].bc } else { cfgs[i - 1].bk };
            cfgs[i] = cfgs[i].with_blocking(shared_bn, bc, cfgs[i].bk);
        }
    }
    cfgs
}

/// The conv-stack configs of a [`CnnSpec`] in chain order with the chain
/// invariant enforced: where a (possibly tuned) consumer's `bc` disagrees
/// with its producer's `bk`, the consumer is re-blocked — the producer's
/// `bk` always divides the shared channel dimension, so the fix never
/// violates a divisibility constraint. Tuned kernel variants (`bq`, flat
/// strips, loop orders) survive the re-block.
pub fn conv_chain_configs(
    spec: &CnnSpec,
    batch: usize,
    nthreads: usize,
    tuned: bool,
) -> Vec<ConvConfig> {
    assert!(!spec.convs.is_empty(), "need at least one conv layer");
    let mut cfgs = spec.conv_configs(batch, nthreads);
    if tuned {
        for cfg in cfgs.iter_mut() {
            *cfg = crate::autotune::tuned_conv_config(*cfg);
        }
    }
    for i in 1..cfgs.len() {
        let prev_bk = cfgs[i - 1].bk;
        if cfgs[i].bc != prev_bk {
            cfgs[i] = cfgs[i].with_blocking(prev_bk, cfgs[i].bk, cfgs[i].bq);
        }
    }
    cfgs
}

/// The softmax head's FC config over `feat` input features — the one
/// blocking formula both the training drivers (CNN over pooled features,
/// RNN over the final hidden state) and the serving models use, so a
/// trained head lifts into any serving plan.
pub fn head_fc_config(
    batch: usize,
    feat: usize,
    classes: usize,
    nthreads: usize,
    tuned: bool,
) -> FcConfig {
    let cfg = FcConfig::new(batch, feat, classes, Act::Identity)
        .with_blocking(pick(batch, 24), pick(feat, 64), pick(classes, 64))
        .with_threads(nthreads);
    if tuned {
        crate::autotune::tuned_fc_config(cfg)
    } else {
        cfg
    }
}

/// The LSTM cell config of the sequence driver. The feature blocking
/// `(bc, bk)` depends only on `(c, k)` — never on the batch or sequence
/// length — which is what lets one packed weight copy back every serving
/// batch bucket and lets trained cell weights lift into any plan. With
/// `tuned`, the autotune cache is consulted (its shape key includes the
/// sequence length, so entries never cross `t`).
pub fn rnn_cell_config(spec: &RnnSpec, batch: usize, nthreads: usize, tuned: bool) -> LstmConfig {
    let cfg = LstmConfig::new(batch, spec.c, spec.k, spec.t).with_threads(nthreads);
    if tuned {
        crate::autotune::tuned_lstm_config(cfg)
    } else {
        cfg
    }
}

/// The per-layer cell configs of a stacked LSTM (`spec.layers` cells):
/// layer 0 maps `c -> k`, every deeper layer maps `k -> k` (its input is
/// the hidden sequence of the layer below). The depth-chain invariant —
/// consumer `bc` = producer `bk` — holds by construction: both sides of
/// every inter-layer seam block the same `k` with the same formula. Each
/// layer consults the autotune cache independently under `tuned` (the
/// cache key includes the layer's own `c`, so layer 0 and the deeper
/// layers never share an entry unless `c == k`).
pub fn rnn_stack_configs(
    spec: &RnnSpec,
    batch: usize,
    nthreads: usize,
    tuned: bool,
) -> Vec<LstmConfig> {
    assert!(spec.layers >= 1, "rnn needs at least one layer");
    (0..spec.layers)
        .map(|i| {
            let c_in = if i == 0 { spec.c } else { spec.k };
            let cfg = LstmConfig::new(batch, c_in, spec.k, spec.t).with_threads(nthreads);
            if tuned {
                crate::autotune::tuned_lstm_config(cfg)
            } else {
                cfg
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cnn::ConvSpec;

    #[test]
    fn mlp_chain_invariant_holds_untuned_and_batchwise() {
        for batch in [1usize, 2, 8, 24, 32] {
            let cfgs = mlp_chain_configs(&[18, 130, 5], batch, 1, false);
            assert_eq!(cfgs.len(), 2);
            for w in cfgs.windows(2) {
                assert_eq!(w[0].bk, w[1].bc, "consumer bc = producer bk");
                assert_eq!(w[0].bn, w[1].bn, "one bn per chain");
            }
            assert_eq!(cfgs[0].act, Act::Relu);
            assert_eq!(cfgs[1].act, Act::Identity, "linear head");
        }
    }

    #[test]
    fn conv_chain_invariant_holds() {
        let spec = CnnSpec {
            in_c: 6,
            in_h: 7,
            in_w: 7,
            convs: vec![
                ConvSpec { k: 10, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 1, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        };
        let cfgs = conv_chain_configs(&spec, 4, 1, false);
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].bk, cfgs[1].bc, "consumer bc = producer bk");
    }

    #[test]
    fn rnn_cell_feature_blocking_is_batch_and_t_independent() {
        let spec = crate::coordinator::rnn::RnnSpec { c: 24, k: 48, t: 6, classes: 4, layers: 1 };
        let a = rnn_cell_config(&spec, 32, 1, false);
        let b = rnn_cell_config(&spec, 1, 2, false);
        assert_eq!((a.bc, a.bk), (b.bc, b.bk), "feature blocking shared across batches");
        let longer = crate::coordinator::rnn::RnnSpec { t: 20, ..spec };
        let c = rnn_cell_config(&longer, 32, 1, false);
        assert_eq!((a.bc, a.bk), (c.bc, c.bk), "feature blocking shared across T");
    }

    #[test]
    fn rnn_stack_chains_hidden_width_and_keeps_depth_invariant() {
        let spec = crate::coordinator::rnn::RnnSpec { c: 24, k: 48, t: 6, classes: 4, layers: 3 };
        let cfgs = rnn_stack_configs(&spec, 16, 2, false);
        assert_eq!(cfgs.len(), 3);
        assert_eq!((cfgs[0].c, cfgs[0].k), (24, 48), "layer 0 maps c -> k");
        for cfg in &cfgs[1..] {
            assert_eq!((cfg.c, cfg.k), (48, 48), "deeper layers map k -> k");
        }
        for w in cfgs.windows(2) {
            assert_eq!(w[0].bk, w[1].bc, "depth seam: consumer bc = producer bk");
            assert_eq!(w[0].bn, w[1].bn, "one batch block across the stack");
            assert_eq!(w[0].t, w[1].t, "one unroll window across the stack");
        }
        // Layer 0 of the stack is exactly the single-cell formula — what
        // keeps pre-stack (layers=1) artifacts loadable bit-identically.
        let solo = rnn_cell_config(&spec, 16, 2, false);
        assert_eq!(
            (cfgs[0].bn, cfgs[0].bc, cfgs[0].bk),
            (solo.bn, solo.bc, solo.bk)
        );
    }

    #[test]
    fn head_formula_is_batch_block_only() {
        // Same feature blocking at every batch (what makes the packed head
        // weights shareable across batch buckets and liftable from a
        // trained model of any batch size).
        let a = head_fc_config(32, 256, 10, 1, false);
        let b = head_fc_config(2, 256, 10, 4, false);
        assert_eq!((a.bc, a.bk), (b.bc, b.bk));
        assert_eq!(a.act, Act::Identity);
    }
}
