//! The RNN training driver: end-to-end stacked-LSTM sequence
//! classification through the coordinator (paper §3.1, Fig. 6 / Fig. 10a
//! / Tab. 1 workload class — GNMT is a 4-layer stack of this cell).
//!
//! [`RnnModel`] is the sequence analogue of
//! [`MlpModel`](super::trainer::MlpModel) / [`CnnModel`](super::cnn::CnnModel):
//! `spec.layers` stacked [`LstmPrimitive`] cells unrolled over `[T][N][C]`
//! inputs (every per-step GEMM a BRGEMM call, threads synchronising per
//! time-step). Layer 0 maps `c -> k`; each deeper layer consumes the full
//! hidden sequence of the layer below (`k -> k`) — the workspace's
//! `[T][N][K]` hidden history is handed to the next cell as its input
//! with no reformat, exactly the "same BRGEMM loop nest, stacked" shape
//! the paper's GNMT run uses. An FC softmax head reads the **top layer's
//! final hidden state** `h_T`.
//!
//! Backpropagation-through-time chains *both* directions of the stack:
//! the head gradient enters the top cell at step `T`, each cell's fused
//! sweep ([`LstmPrimitive::backward`]) carries it back through time via
//! the recurrent `dh`/`ds` carries, and the cell's input gradient `dx`
//! (`[T][N][K]`) is exactly the upstream `dh_out` of the layer below —
//! depth chaining is one buffer handoff per seam. `T` is the truncation
//! window: the driver never backpropagates across batch boundaries.
//!
//! The model implements [`Model`], so
//! [`DataParallelTrainer`](super::trainer::DataParallelTrainer) and the
//! ring-allreduce path work over it unchanged (`grads_flat` /
//! `apply_sgd_from_flat` flatten every cell's gradients bottom-up, then
//! the head), and the model-artifact pipeline covers it: `export_weights`
//! emits one canonical [`LayerKind::Lstm`] layer per cell (unblocked
//! per-gate `W`/`R`/`b`, gate order i, g, f, o) plus the FC head —
//! `layers + 1` artifact layers, a pure index permutation, so export →
//! import round-trips bit-identically under any `{bn, bc, bk, threads}`.
//!
//! Inputs are [`ClassifyData`] rows of `dim = T·C` (one flattened
//! `[T][C]` sequence per sample — see
//! [`ClassifyData::synth_sequences`]); the driver re-views each batch as
//! time-major `[T][N][C]` for the bottom cell.

use crate::coordinator::build;
use crate::coordinator::data::ClassifyData;
use crate::coordinator::trainer::{eval_accuracy, softmax_xent, Model};
use crate::modelio::{LayerKind, LayerParams};
use crate::primitives::fc::FcPrimitive;
use crate::primitives::lstm::{LstmPrimitive, LstmWeights, LstmWorkspace, GATES};
use crate::telemetry::{self, Metrics};
use crate::tensor::layout;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

/// Shape of the RNN sequence-classification workload: per-step input
/// width `c`, hidden width `k`, sequence length (BPTT window) `t`, the
/// softmax width, and the number of stacked cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RnnSpec {
    pub c: usize,
    pub k: usize,
    pub t: usize,
    pub classes: usize,
    /// Stacked LSTM depth (GNMT uses 4). Layer 0 maps `c -> k`; every
    /// deeper layer maps `k -> k` over the hidden sequence below it.
    pub layers: usize,
}

impl RnnSpec {
    /// Flattened per-sample input width (`T·C`) — what the data pipeline
    /// produces per row.
    pub fn input_dim(&self) -> usize {
        self.t * self.c
    }
}

/// The FC softmax head's state (mirrors the CNN driver's head).
struct FcHead {
    prim: FcPrimitive,
    w: Vec<f32>, // packed [Kb][Cb][bc][bk]
    b: Vec<f32>, // [classes]
    y: Vec<f32>,
    dz: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

/// One cell of the stack: primitive + packed weights + workspace + the
/// gradient accumulators of the last backward (index-for-index with the
/// packed weight layouts).
struct CellState {
    prim: LstmPrimitive,
    weights: LstmWeights,
    ws: LstmWorkspace,
    dw: Vec<f32>,
    dr: Vec<f32>,
    db: Vec<f32>,
}

/// A stacked-LSTM sequence classifier built entirely from the BRGEMM
/// cell and FC primitives; same driver surface as `MlpModel`/`CnnModel`.
pub struct RnnModel {
    pub spec: RnnSpec,
    pub batch: usize,
    /// Bottom-up stack of `spec.layers` cells.
    cells: Vec<CellState>,
    /// Time-major input of the last forward (`[T][N][C]`), kept for the
    /// bottom cell's update pass.
    x_seq: Vec<f32>,
    /// The head's packed input (top-layer `h_T`), kept for its update pass.
    head_x: Vec<f32>,
    head: FcHead,
    /// Per-pass training breakdown — only fed while telemetry is enabled.
    metrics: Metrics,
}

impl RnnModel {
    pub fn new(spec: &RnnSpec, batch: usize, nthreads: usize, rng: &mut Rng) -> RnnModel {
        RnnModel::new_with(spec, batch, nthreads, false, rng)
    }

    /// Like [`RnnModel::new`], with `tuned` routing each cell through the
    /// autotuner's cached blockings (the cache key includes `t` and the
    /// layer's own input width) and the head through the FC tuning cache —
    /// the `{"tune": true}` run-config path.
    pub fn new_with(
        spec: &RnnSpec,
        batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> RnnModel {
        assert!(spec.classes >= 2, "need at least two classes");
        assert!(spec.c >= 1 && spec.k >= 1 && spec.t >= 1, "c/k/t must be >= 1");
        assert!(spec.layers >= 1, "rnn needs at least one layer");
        // Cell + head configs come from the shared construction module,
        // so the training model and the serving plans agree by
        // construction (weight lifting through artifacts depends on it).
        let cfgs = build::rnn_stack_configs(spec, batch, nthreads, tuned);
        let k = spec.k;
        let cells: Vec<CellState> = cfgs
            .into_iter()
            .map(|cfg| {
                // Uniform init scaled by the fan-in of each weight class
                // (layer 0 sees `c` inputs, deeper layers see `k`); the
                // forget-gate bias starts at +1 so early training does not
                // flush the cell state (standard LSTM practice). Gate
                // order i, g, f, o.
                let c_in = cfg.c;
                let wscale = (1.0 / c_in as f32).sqrt();
                let rscale = (1.0 / k as f32).sqrt();
                let w_plain: Vec<Vec<f32>> =
                    (0..GATES).map(|_| rng.vec_f32(k * c_in, -wscale, wscale)).collect();
                let r_plain: Vec<Vec<f32>> =
                    (0..GATES).map(|_| rng.vec_f32(k * k, -rscale, rscale)).collect();
                let b_plain: Vec<Vec<f32>> = (0..GATES)
                    .map(|z| if z == 2 { vec![1.0f32; k] } else { vec![0.0f32; k] })
                    .collect();
                let wref: Vec<&[f32]> = w_plain.iter().map(|v| v.as_slice()).collect();
                let rref: Vec<&[f32]> = r_plain.iter().map(|v| v.as_slice()).collect();
                let bref: Vec<&[f32]> = b_plain.iter().map(|v| v.as_slice()).collect();
                let weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
                CellState {
                    prim: LstmPrimitive::new(cfg),
                    ws: LstmWorkspace::new(&cfg),
                    // Zeroed so grads_flat is well-formed before the first
                    // backward (the allreduce path flattens unconditionally).
                    dw: vec![0.0; weights.w.len()],
                    dr: vec![0.0; weights.r.len()],
                    db: vec![0.0; weights.b.len()],
                    weights,
                }
            })
            .collect();

        // The RNN head is the shared softmax-head formula over the top
        // layer's final hidden state's `k` features.
        let hcfg = build::head_fc_config(batch, k, spec.classes, nthreads, tuned);
        let hprim = FcPrimitive::new(hcfg);
        let hscale = (2.0 / k as f32).sqrt();
        let hw_plain = rng.vec_f32(spec.classes * k, -hscale, hscale);
        let head = FcHead {
            w: layout::pack_weights_2d(&hw_plain, spec.classes, k, hcfg.bk, hcfg.bc),
            b: vec![0.0; spec.classes],
            y: vec![0.0; batch * spec.classes],
            dz: vec![0.0; batch * spec.classes],
            dw: vec![0.0; spec.classes * k],
            db: vec![0.0; spec.classes],
            prim: hprim,
        };

        RnnModel {
            spec: *spec,
            batch,
            cells,
            x_seq: vec![0.0; spec.t * batch * spec.c],
            head_x: Vec::new(),
            head,
            metrics: Metrics::new(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.cells
            .iter()
            .map(|c| c.weights.w.len() + c.weights.r.len() + c.weights.b.len())
            .sum::<usize>()
            + self.head.w.len()
            + self.head.b.len()
    }

    /// Forward from a plain `[batch][T·C]` input (one flattened `[T][C]`
    /// sequence per row); returns plain logits `[batch][classes]`.
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let (n, c, t, k) = (self.batch, self.spec.c, self.spec.t, self.spec.k);
        assert_eq!(x.len(), n * t * c, "input shape mismatch");
        // Rows are sample-major [N][T][C]; the cell wants time-major
        // [T][N][C] (a pure transpose — the sequence analogue of the
        // other drivers' activation packing).
        for ni in 0..n {
            for ti in 0..t {
                let src = &x[(ni * t + ti) * c..(ni * t + ti + 1) * c];
                let dst = (ti * n + ni) * c;
                self.x_seq[dst..dst + c].copy_from_slice(src);
            }
        }
        let nk = n * k;
        for li in 0..self.cells.len() {
            // Layer li's input: the raw sequence for the bottom cell, the
            // full [T][N][K] hidden history of the cell below otherwise
            // (workspace `h` holds the initial state at step 0, then the
            // T step outputs — skip the initial-state row).
            let (below, rest) = self.cells.split_at_mut(li);
            let x_in: &[f32] =
                if li == 0 { &self.x_seq } else { &below[li - 1].ws.h[nk..] };
            let CellState { prim, weights, ws, .. } = &mut rest[0];
            prim.forward(x_in, None, None, weights, ws);
        }
        let top = self.cells.last().unwrap();
        let h_last = top.ws.h_t(&top.prim.cfg, t - 1);
        let hcfg = self.head.prim.cfg;
        self.head_x = layout::pack_act_2d(h_last, n, k, hcfg.bn, hcfg.bc);
        self.head.prim.forward(&self.head_x, &self.head.w, &self.head.b, &mut self.head.y);
        layout::unpack_act_2d(&self.head.y, n, hcfg.k, hcfg.bn, hcfg.bk)
    }

    /// One SGD step; returns the mean cross-entropy loss. While telemetry
    /// is enabled, the per-pass breakdown (fwd / bwd incl. the loss / upd)
    /// lands in [`Model::metrics`]; disabled, the step pays one branch.
    pub fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        if !telemetry::enabled() {
            let logits = self.forward(x);
            let (loss, dlogits) = softmax_xent(&logits, labels, self.spec.classes);
            self.backward(&dlogits);
            self.apply_sgd(lr);
            return loss;
        }
        let t0 = Instant::now();
        let logits = self.forward(x);
        let t1 = Instant::now();
        let (loss, dlogits) = softmax_xent(&logits, labels, self.spec.classes);
        self.backward(&dlogits);
        let t2 = Instant::now();
        self.apply_sgd(lr);
        self.metrics.observe_secs("fwd", (t1 - t0).as_secs_f64());
        self.metrics.observe_secs("bwd", (t2 - t1).as_secs_f64());
        self.metrics.observe_secs("upd", t2.elapsed().as_secs_f64());
        self.metrics.inc("steps", 1);
        loss
    }

    /// Backward from plain dlogits: head update + backward-by-data gives
    /// the top layer's `dh_T`, which enters that cell's fused BPTT sweep
    /// as the upstream gradient of the final step (zero at every earlier
    /// step — the loss reads only the top `h_T`). Each cell's input
    /// gradient `dx` (`[T][N][K]`) is handed down as the *full* upstream
    /// `dh_out` of the layer below — the only external consumer of a
    /// non-top layer's hidden sequence is the cell above it, so depth
    /// chaining is one buffer swap per seam.
    pub fn backward(&mut self, dlogits: &[f32]) {
        let (n, t, k) = (self.batch, self.spec.t, self.spec.k);
        let hcfg = self.head.prim.cfg;
        assert_eq!(dlogits.len(), n * hcfg.k);
        // Linear head: dz = dlogits, packed.
        self.head.dz = layout::pack_act_2d(dlogits, n, hcfg.k, hcfg.bn, hcfg.bk);
        self.head.prim.update(&self.head_x, &self.head.dz, &mut self.head.dw, &mut self.head.db);
        let wt = layout::transpose_packed_2d(&self.head.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc);
        let mut dh_packed = vec![0.0f32; n * hcfg.c];
        self.head.prim.backward_data(&self.head.dz, &wt, &mut dh_packed);
        let dh_last = layout::unpack_act_2d(&dh_packed, n, hcfg.c, hcfg.bn, hcfg.bc);
        let nk = n * k;
        let mut dh_out = vec![0.0f32; t * nk];
        dh_out[(t - 1) * nk..].copy_from_slice(&dh_last);
        for li in (0..self.cells.len()).rev() {
            let (below, rest) = self.cells.split_at_mut(li);
            let x_in: &[f32] =
                if li == 0 { &self.x_seq } else { &below[li - 1].ws.h[nk..] };
            let cell = &mut rest[0];
            // Packed weight transposes for backward-by-data (amortised
            // across all T steps inside the sweep).
            let wt_cell = cell.weights.transposed();
            let (grads, _) = cell.prim.backward(x_in, &dh_out, &wt_cell, &cell.ws);
            cell.dw = grads.dw;
            cell.dr = grads.dr;
            cell.db = grads.db;
            if li > 0 {
                // dx is [T][N][K]: exactly the layer-below upstream grad.
                dh_out = grads.dx;
            }
        }
    }

    fn apply_sgd(&mut self, lr: f32) {
        for cell in self.cells.iter_mut() {
            for (w, g) in cell.weights.w.iter_mut().zip(&cell.dw) {
                *w -= lr * g;
            }
            for (r, g) in cell.weights.r.iter_mut().zip(&cell.dr) {
                *r -= lr * g;
            }
            for (b, g) in cell.weights.b.iter_mut().zip(&cell.db) {
                *b -= lr * g;
            }
        }
        for (w, g) in self.head.w.iter_mut().zip(&self.head.dw) {
            *w -= lr * g;
        }
        for (b, g) in self.head.b.iter_mut().zip(&self.head.db) {
            *b -= lr * g;
        }
    }

    /// Classification accuracy on plain data (partial final batches are
    /// padded and masked — see [`eval_accuracy`]).
    pub fn accuracy(&mut self, data: &ClassifyData, max_batches: usize) -> f64 {
        eval_accuracy(self, data, max_batches)
    }
}

impl Model for RnnModel {
    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        RnnModel::forward(self, x)
    }
    fn backward(&mut self, dlogits: &[f32]) {
        RnnModel::backward(self, dlogits)
    }
    fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        RnnModel::train_step(self, x, labels, lr)
    }
    fn grads_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for cell in &self.cells {
            out.extend_from_slice(&cell.dw);
            out.extend_from_slice(&cell.dr);
            out.extend_from_slice(&cell.db);
        }
        out.extend_from_slice(&self.head.dw);
        out.extend_from_slice(&self.head.db);
        out
    }
    fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32) {
        let mut off = 0;
        for cell in self.cells.iter_mut() {
            for (w, g) in cell.weights.w.iter_mut().zip(&flat[off..off + cell.dw.len()]) {
                *w -= lr * g;
            }
            off += cell.dw.len();
            for (r, g) in cell.weights.r.iter_mut().zip(&flat[off..off + cell.dr.len()]) {
                *r -= lr * g;
            }
            off += cell.dr.len();
            for (b, g) in cell.weights.b.iter_mut().zip(&flat[off..off + cell.db.len()]) {
                *b -= lr * g;
            }
            off += cell.db.len();
        }
        for (w, g) in self.head.w.iter_mut().zip(&flat[off..off + self.head.dw.len()]) {
            *w -= lr * g;
        }
        off += self.head.dw.len();
        for (b, g) in self.head.b.iter_mut().zip(&flat[off..off + self.head.db.len()]) {
            *b -= lr * g;
        }
        off += self.head.db.len();
        assert_eq!(off, flat.len(), "flat gradient length mismatch");
    }
    fn classes(&self) -> usize {
        self.spec.classes
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn param_count(&self) -> usize {
        RnnModel::param_count(self)
    }
    fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for cell in &self.cells {
            out.extend_from_slice(&cell.weights.w);
            out.extend_from_slice(&cell.weights.r);
            out.extend_from_slice(&cell.weights.b);
        }
        out.extend_from_slice(&self.head.w);
        out.extend_from_slice(&self.head.b);
        out
    }
    fn export_weights(&self) -> Vec<LayerParams> {
        // One canonical Lstm layer per cell (bottom-up), then the head —
        // `layers + 1` artifact layers. Canonical gate-major
        // concatenation per cell: [4][K][C_in] then [4][K][K] (the
        // LayerKind::Lstm artifact layout). Unpacking is a pure index
        // permutation.
        let mut out: Vec<LayerParams> = self
            .cells
            .iter()
            .map(|cell| {
                let cfg = cell.prim.cfg;
                let (k, c) = (cfg.k, cfg.c);
                let gw = k * c;
                let gr = k * k;
                let mut w = Vec::with_capacity(GATES * (gw + gr));
                for z in 0..GATES {
                    w.extend(layout::unpack_weights_2d(
                        &cell.weights.w[z * gw..(z + 1) * gw],
                        k,
                        c,
                        cfg.bk,
                        cfg.bc,
                    ));
                }
                for z in 0..GATES {
                    w.extend(layout::unpack_weights_2d(
                        &cell.weights.r[z * gr..(z + 1) * gr],
                        k,
                        k,
                        cfg.bk,
                        cfg.bk,
                    ));
                }
                LayerParams::lstm(k, c, w, cell.weights.b.clone())
            })
            .collect();
        let hcfg = self.head.prim.cfg;
        out.push(LayerParams::fc(
            hcfg.k,
            hcfg.c,
            layout::unpack_weights_2d(&self.head.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc),
            self.head.b.clone(),
        ));
        out
    }
    fn import_weights(&mut self, layers: &[LayerParams]) -> Result<()> {
        let want = self.cells.len() + 1;
        if layers.len() != want {
            bail!(
                "rnn has {} layers ({} stacked cells + head), artifact has {}",
                want,
                self.cells.len(),
                layers.len()
            );
        }
        for (li, cell) in self.cells.iter_mut().enumerate() {
            let cfg = cell.prim.cfg;
            let (k, c) = (cfg.k, cfg.c);
            layers[li].expect("rnn cell", LayerKind::Lstm, &[k, c])?;
            let (w_gates, r_gates) = layers[li].w.split_at(GATES * k * c);
            let wref: Vec<&[f32]> =
                (0..GATES).map(|z| &w_gates[z * k * c..(z + 1) * k * c]).collect();
            let rref: Vec<&[f32]> =
                (0..GATES).map(|z| &r_gates[z * k * k..(z + 1) * k * k]).collect();
            let bref: Vec<&[f32]> =
                (0..GATES).map(|z| &layers[li].b[z * k..(z + 1) * k]).collect();
            cell.weights = LstmWeights::pack(cfg, &wref, &rref, &bref);
        }
        let p = &layers[want - 1];
        let hcfg = self.head.prim.cfg;
        p.expect("rnn head", LayerKind::Fc, &[hcfg.k, hcfg.c])?;
        self.head.w = layout::pack_weights_2d(&p.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc);
        self.head.b = p.b.clone();
        Ok(())
    }
    fn metrics(&self) -> Option<&Metrics> {
        Some(&self.metrics)
    }
    fn metrics_mut(&mut self) -> Option<&mut Metrics> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::DataParallelTrainer;

    fn tiny_spec() -> RnnSpec {
        RnnSpec { c: 8, k: 16, t: 6, classes: 3, layers: 1 }
    }

    fn stacked_spec() -> RnnSpec {
        RnnSpec { c: 8, k: 16, t: 6, classes: 3, layers: 2 }
    }

    #[test]
    fn rnn_learns_synthetic_sequences() {
        let spec = tiny_spec();
        let mut rng = Rng::new(21);
        let data = ClassifyData::synth_sequences(256, spec.t, spec.c, spec.classes, 0.1, &mut rng);
        let mut model = RnnModel::new(&spec, 16, 1, &mut rng);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..120 {
            let (x, labels) = data.batch(step, 16);
            last = model.train_step(&x, &labels, 0.1);
            first.get_or_insert(last);
        }
        assert!(
            last < first.unwrap() * 0.5,
            "loss must at least halve: {} -> {}",
            first.unwrap(),
            last
        );
        let acc = model.accuracy(&data, 16);
        assert!(acc > 0.6, "accuracy {} not above chance enough", acc);
    }

    #[test]
    fn stacked_rnn_learns_and_exports_layers_plus_one() {
        // The honor-or-error contract made real: layers=2 trains two
        // genuinely distinct cells (the artifact has 3 layers, the second
        // cell is k -> k) and the stack still learns the workload.
        let spec = stacked_spec();
        let mut rng = Rng::new(22);
        let data = ClassifyData::synth_sequences(256, spec.t, spec.c, spec.classes, 0.1, &mut rng);
        let mut model = RnnModel::new(&spec, 16, 1, &mut rng);
        let exported = model.export_weights();
        assert_eq!(exported.len(), 3, "layers + 1 artifact layers");
        assert_eq!(exported[0].dims, vec![spec.k, spec.c], "layer 0: c -> k");
        assert_eq!(exported[1].dims, vec![spec.k, spec.k], "layer 1: k -> k");
        assert_eq!(exported[2].kind, LayerKind::Fc);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..120 {
            let (x, labels) = data.batch(step, 16);
            last = model.train_step(&x, &labels, 0.1);
            first.get_or_insert(last);
        }
        assert!(
            last < first.unwrap() * 0.5,
            "stacked loss must at least halve: {} -> {}",
            first.unwrap(),
            last
        );
        let acc = model.accuracy(&data, 16);
        assert!(acc > 0.6, "stacked accuracy {} not above chance enough", acc);
    }

    #[test]
    fn rnn_gradients_match_finite_difference() {
        // The assembled *stacked* driver backward (head chain + BPTT entry
        // at the top layer's step T + depth chaining through dx) against
        // central differences of the packed parameters of BOTH cells.
        // Gradients share the packed layouts, so index-for-index
        // comparison is exact.
        let spec = RnnSpec { c: 4, k: 4, t: 3, classes: 3, layers: 2 };
        let mut rng = Rng::new(31);
        let mut model = RnnModel::new(&spec, 2, 1, &mut rng);
        let x = rng.vec_f32(2 * spec.input_dim(), -1.0, 1.0);
        let labels = vec![0, 2];
        let logits = model.forward(&x);
        let (_, dlogits) = softmax_xent(&logits, &labels, spec.classes);
        model.backward(&dlogits);
        let hdw = model.head.dw.clone();
        let eps = 1e-3f32;
        let loss_of = |m: &mut RnnModel| {
            let l = m.forward(&x);
            softmax_xent(&l, &labels, spec.classes).0
        };
        for li in 0..2 {
            let dw = model.cells[li].dw.clone();
            let dr = model.cells[li].dr.clone();
            let db = model.cells[li].db.clone();
            for &idx in &[0usize, 7, 23, dw.len() - 1] {
                let orig = model.cells[li].weights.w[idx];
                model.cells[li].weights.w[idx] = orig + eps;
                let lp = loss_of(&mut model);
                model.cells[li].weights.w[idx] = orig - eps;
                let lm = loss_of(&mut model);
                model.cells[li].weights.w[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dw[idx]).abs() < 1e-2,
                    "cell {} dW[{}]: {} vs {}",
                    li,
                    idx,
                    num,
                    dw[idx]
                );
            }
            for &idx in &[0usize, 9, dr.len() - 1] {
                let orig = model.cells[li].weights.r[idx];
                model.cells[li].weights.r[idx] = orig + eps;
                let lp = loss_of(&mut model);
                model.cells[li].weights.r[idx] = orig - eps;
                let lm = loss_of(&mut model);
                model.cells[li].weights.r[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dr[idx]).abs() < 1e-2,
                    "cell {} dR[{}]: {} vs {}",
                    li,
                    idx,
                    num,
                    dr[idx]
                );
            }
            for &idx in &[0usize, 5, db.len() - 1] {
                let orig = model.cells[li].weights.b[idx];
                model.cells[li].weights.b[idx] = orig + eps;
                let lp = loss_of(&mut model);
                model.cells[li].weights.b[idx] = orig - eps;
                let lm = loss_of(&mut model);
                model.cells[li].weights.b[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - db[idx]).abs() < 1e-2,
                    "cell {} db[{}]: {} vs {}",
                    li,
                    idx,
                    num,
                    db[idx]
                );
            }
        }
        for &idx in &[0usize, hdw.len() - 1] {
            let orig = model.head.w[idx];
            model.head.w[idx] = orig + eps;
            let lp = loss_of(&mut model);
            model.head.w[idx] = orig - eps;
            let lm = loss_of(&mut model);
            model.head.w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - hdw[idx]).abs() < 1e-2, "head dW[{}]: {} vs {}", idx, num, hdw[idx]);
        }
    }

    #[test]
    fn export_import_roundtrip_bit_identical_across_blockings() {
        // Train a stacked model a few steps, export canonical params,
        // import into a model with a different batch (hence bn) and
        // thread count: packed params and forward outputs must be
        // bit-identical — blocking is a layout choice the artifact does
        // not bake in.
        let spec = stacked_spec();
        let mut rng = Rng::new(41);
        let data = ClassifyData::synth_sequences(64, spec.t, spec.c, spec.classes, 0.2, &mut rng);
        let mut src = RnnModel::new(&spec, 8, 1, &mut rng);
        for step in 0..10 {
            let (x, l) = data.batch(step, 8);
            src.train_step(&x, &l, 0.1);
        }
        let exported = src.export_weights();
        let mut dst = RnnModel::new(&spec, 4, 2, &mut Rng::new(999));
        dst.import_weights(&exported).unwrap();
        let back = dst.export_weights();
        assert_eq!(exported, back, "export -> import -> export must be bitwise identical");
        // Forward math agrees bit-for-bit row by row (same rows through
        // both batch shapes).
        let x4 = Rng::new(5).vec_f32(4 * spec.input_dim(), -1.0, 1.0);
        let y4 = dst.forward(&x4);
        let mut x8 = x4.clone();
        x8.extend(Rng::new(6).vec_f32(4 * spec.input_dim(), -1.0, 1.0));
        let y8 = src.forward(&x8);
        assert_eq!(&y8[..y4.len()], &y4[..], "same rows, same logits, any blocking");
    }

    #[test]
    fn import_rejects_shape_mismatch() {
        let spec = tiny_spec();
        let mut rng = Rng::new(51);
        let src = RnnModel::new(&spec, 4, 1, &mut rng);
        let other = RnnSpec { k: 8, ..spec };
        let mut dst = RnnModel::new(&other, 4, 1, &mut rng);
        let err = dst.import_weights(&src.export_weights()).unwrap_err();
        assert!(err.to_string().contains("expects lstm"), "{}", err);
        let mut one = src.export_weights();
        one.pop();
        let mut dst = RnnModel::new(&spec, 4, 1, &mut rng);
        assert!(dst.import_weights(&one).is_err(), "layer count");
        // Depth mismatch: a 1-layer export must not import into a 2-layer
        // stack (and vice versa) — layers is honored, never coerced.
        let mut deep = RnnModel::new(&stacked_spec(), 4, 1, &mut Rng::new(52));
        let err = deep.import_weights(&src.export_weights()).unwrap_err();
        assert!(err.to_string().contains("stacked cells"), "{}", err);
    }

    #[test]
    fn resume_equals_uninterrupted_training() {
        // K steps + export + import into a fresh model + K more steps
        // must land on exactly the parameters of 2K uninterrupted steps —
        // for the stacked model.
        let spec = stacked_spec();
        let spe = 6usize;
        let mut rng = Rng::new(61);
        let data = ClassifyData::synth_sequences(48, spec.t, spec.c, spec.classes, 0.2, &mut rng);

        let mut full = RnnModel::new(&spec, 8, 1, &mut Rng::new(77));
        for step in 0..2 * spe {
            let (x, l) = data.batch(step, 8);
            full.train_step(&x, &l, 0.1);
        }

        let mut half = RnnModel::new(&spec, 8, 1, &mut Rng::new(77));
        for step in 0..spe {
            let (x, l) = data.batch(step, 8);
            half.train_step(&x, &l, 0.1);
        }
        let snapshot = half.export_weights();
        drop(half);
        let mut resumed = RnnModel::new(&spec, 8, 1, &mut Rng::new(123)); // any init
        resumed.import_weights(&snapshot).unwrap();
        for step in spe..2 * spe {
            let (x, l) = data.batch(step, 8);
            resumed.train_step(&x, &l, 0.1);
        }
        assert_eq!(
            full.params_flat(),
            resumed.params_flat(),
            "resumed training must be bit-identical to the uninterrupted run"
        );
    }

    #[test]
    fn data_parallel_replicas_stay_consistent() {
        // The Model-trait contract the trainer depends on: identical-seed
        // replicas stay bit-identical under synchronous SGD with the real
        // ring-allreduce over grads_flat — including the stacked flatten
        // order (cells bottom-up, then head).
        let spec = stacked_spec();
        let mut rng = Rng::new(71);
        let data = ClassifyData::synth_sequences(64, spec.t, spec.c, spec.classes, 0.2, &mut rng);
        let workers: Vec<RnnModel> =
            (0..3).map(|_| RnnModel::new(&spec, 8, 1, &mut Rng::new(9))).collect();
        let mut dp = DataParallelTrainer::from_workers(workers, 0.1);
        for step in 0..3 {
            let shards: Vec<_> = (0..3).map(|w| data.batch(step * 3 + w, 8)).collect();
            let s = dp.step(&shards);
            assert!(s.loss.is_finite());
        }
        assert!(dp.replicas_consistent(), "replicas diverged under allreduce SGD");
    }
}
