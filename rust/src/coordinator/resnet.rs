//! ResNet-50 layer table (paper Table 2) and topology accounting.
//!
//! Each unique convolution shape appears `reps` times in the 53-layer
//! topology; the paper's *weighted efficiency* metric weights each layer's
//! flops/time by its repeat count — reproduced by [`weighted_gflops`].

use crate::primitives::conv::ConvConfig;

/// One row of Table 2 (+ repeat count in the full topology and the padding
/// ResNet-50 actually uses, which the paper omits from the table).
#[derive(Debug, Clone, Copy)]
pub struct ResnetLayer {
    pub id: usize,
    pub c: usize,
    pub k: usize,
    pub h: usize,
    pub w: usize,
    pub r: usize,
    pub s: usize,
    pub stride: usize,
    pub pad: usize,
    /// Occurrences in the 53-conv-layer ResNet-50 topology.
    pub reps: usize,
}

/// The 20 unique convolution shapes of ResNet-50 (paper Table 2), with
/// repeat counts summing to 53.
pub const RESNET50_LAYERS: [ResnetLayer; 20] = [
    ResnetLayer { id: 1, c: 3, k: 64, h: 224, w: 224, r: 7, s: 7, stride: 2, pad: 3, reps: 1 },
    ResnetLayer { id: 2, c: 64, k: 256, h: 56, w: 56, r: 1, s: 1, stride: 1, pad: 0, reps: 4 },
    ResnetLayer { id: 3, c: 64, k: 64, h: 56, w: 56, r: 1, s: 1, stride: 1, pad: 0, reps: 1 },
    ResnetLayer { id: 4, c: 64, k: 64, h: 56, w: 56, r: 3, s: 3, stride: 1, pad: 1, reps: 3 },
    ResnetLayer { id: 5, c: 256, k: 64, h: 56, w: 56, r: 1, s: 1, stride: 1, pad: 0, reps: 2 },
    ResnetLayer { id: 6, c: 256, k: 512, h: 56, w: 56, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 7, c: 256, k: 128, h: 56, w: 56, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 8, c: 128, k: 128, h: 28, w: 28, r: 3, s: 3, stride: 1, pad: 1, reps: 4 },
    ResnetLayer { id: 9, c: 128, k: 512, h: 28, w: 28, r: 1, s: 1, stride: 1, pad: 0, reps: 4 },
    ResnetLayer { id: 10, c: 512, k: 128, h: 28, w: 28, r: 1, s: 1, stride: 1, pad: 0, reps: 3 },
    ResnetLayer { id: 11, c: 512, k: 1024, h: 28, w: 28, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 12, c: 512, k: 256, h: 28, w: 28, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 13, c: 256, k: 256, h: 14, w: 14, r: 3, s: 3, stride: 1, pad: 1, reps: 6 },
    ResnetLayer { id: 14, c: 256, k: 1024, h: 14, w: 14, r: 1, s: 1, stride: 1, pad: 0, reps: 6 },
    ResnetLayer { id: 15, c: 1024, k: 256, h: 14, w: 14, r: 1, s: 1, stride: 1, pad: 0, reps: 5 },
    ResnetLayer { id: 16, c: 1024, k: 2048, h: 14, w: 14, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 17, c: 1024, k: 512, h: 14, w: 14, r: 1, s: 1, stride: 2, pad: 0, reps: 1 },
    ResnetLayer { id: 18, c: 512, k: 512, h: 7, w: 7, r: 3, s: 3, stride: 1, pad: 1, reps: 3 },
    ResnetLayer { id: 19, c: 512, k: 2048, h: 7, w: 7, r: 1, s: 1, stride: 1, pad: 0, reps: 3 },
    ResnetLayer { id: 20, c: 2048, k: 512, h: 7, w: 7, r: 1, s: 1, stride: 1, pad: 0, reps: 2 },
];

impl ResnetLayer {
    /// Convolution config at mini-batch `n`, optionally spatially scaled
    /// down by `scale` (the benches run the paper's shapes divided by 2 or
    /// 4 so a 1-core run finishes; channel dims — which drive the GEMM
    /// efficiency story — are kept exact).
    pub fn conv_config(&self, n: usize, scale: usize) -> ConvConfig {
        let h = (self.h / scale).max(self.r);
        let w = (self.w / scale).max(self.s);
        ConvConfig::new(n, self.c, self.k, h, w, self.r, self.s, self.stride, self.pad)
    }

    pub fn flops(&self, n: usize, scale: usize) -> f64 {
        self.conv_config(n, scale).flops()
    }

    pub fn label(&self) -> String {
        format!(
            "id{:02} {}x{} {}→{} {}x{}/{}",
            self.id, self.h, self.w, self.c, self.k, self.r, self.s, self.stride
        )
    }
}

/// A compact, *chainable* conv stack for the CNN training driver, drawn
/// from the stage-1 workhorse rows of the table: id 4 (the 3×3 64→64,
/// stride 1, pad 1) alternated with id 3 (the 1×1 64→64). Unlike arbitrary
/// table rows, consecutive entries compose (input channels = producer
/// output channels, spatial dims preserved), so the stack trains end to
/// end at any `depth`; spatial scaling is applied via
/// [`ResnetLayer::conv_config`]-style division by the driver.
pub fn mini_stack(depth: usize) -> Vec<ResnetLayer> {
    assert!(depth >= 1, "need at least one conv layer");
    (0..depth).map(|i| RESNET50_LAYERS[if i % 2 == 0 { 3 } else { 2 }]).collect()
}

/// Weighted GFLOPS over (layer, seconds) measurements, weights = reps
/// (the paper's topology-weighted efficiency).
pub fn weighted_gflops(measured: &[(ResnetLayer, f64, f64)]) -> f64 {
    // measured: (layer, flops, secs)
    let num: f64 = measured.iter().map(|(l, f, _)| l.reps as f64 * f).sum();
    let den: f64 = measured.iter().map(|(l, _, t)| l.reps as f64 * t).sum();
    num / den / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_paper_row_count_and_total() {
        assert_eq!(RESNET50_LAYERS.len(), 20);
        let total: usize = RESNET50_LAYERS.iter().map(|l| l.reps).sum();
        assert_eq!(total, 53, "ResNet-50 has 53 conv layers");
    }

    #[test]
    fn shapes_are_consistent() {
        for l in &RESNET50_LAYERS {
            let cfg = l.conv_config(1, 1);
            // output dims must be integral and positive
            assert!(cfg.p() > 0 && cfg.q() > 0, "layer {}", l.id);
            // 3x3 layers use pad 1, 7x7 pad 3, 1x1 pad 0
            match l.r {
                1 => assert_eq!(l.pad, 0),
                3 => assert_eq!(l.pad, 1),
                7 => assert_eq!(l.pad, 3),
                _ => panic!("unexpected filter size"),
            }
        }
    }

    #[test]
    fn scaling_preserves_channels() {
        let l = &RESNET50_LAYERS[3]; // 56x56 3x3
        let cfg = l.conv_config(4, 2);
        assert_eq!(cfg.c, l.c);
        assert_eq!(cfg.k, l.k);
        assert_eq!(cfg.h, 28);
    }

    #[test]
    fn mini_stack_chains() {
        let stack = mini_stack(4);
        assert_eq!(stack.len(), 4);
        for w in stack.windows(2) {
            assert_eq!(w[0].k, w[1].c, "consecutive layers must chain");
        }
        for l in &stack {
            // Stride-1 with pad = r/2 ⇒ spatial dims preserved layer to layer.
            assert_eq!(l.stride, 1);
            assert_eq!(l.pad, l.r / 2);
        }
    }

    #[test]
    fn weighted_gflops_weights_by_reps() {
        let a = RESNET50_LAYERS[1]; // reps 4
        let b = RESNET50_LAYERS[0]; // reps 1
        // layer a: 4 GFLOP in 1s ; layer b: 1 GFLOP in 1s
        let wg = weighted_gflops(&[(a, 1e9, 1.0), (b, 1e9, 1.0)]);
        // = (4*1e9 + 1*1e9) / (4*1 + 1*1) / 1e9 = 1.0
        assert!((wg - 1.0).abs() < 1e-9);
    }
}
