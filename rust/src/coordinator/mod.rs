//! The coordinator: the framework layer around the primitives and runtime.
//!
//! This is the GxM / Tensorflow-integration analogue of the paper's §4.2 —
//! everything above the kernels that a training system needs:
//!
//! * [`build`]   — shared model construction: the chain-invariant
//!   reconciliation and head-blocking formulas the training drivers *and*
//!   the serving models build from, so trained weights lift into serving
//!   plans byte-compatibly by construction.
//! * [`config`]  — run specifications (workload, backend, batch, workers).
//! * [`data`]    — synthetic data pipelines (WMT-like sequence corpus with
//!   the paper's length-bucketing load balancer; learnable classification
//!   data for the e2e drivers).
//! * [`trainer`] — training drivers over the native BRGEMM primitives
//!   (the [`trainer::Model`] surface + the MLP driver), including
//!   synchronous data-parallel training with a real ring-allreduce.
//! * [`cnn`]     — the CNN training driver: conv stacks (fwd bias+ReLU,
//!   backward-by-data, weight+bias update) with a pooling stage and the
//!   FC softmax head, end to end through the conv primitives.
//! * [`rnn`]     — the RNN training driver: the BRGEMM LSTM cell unrolled
//!   over `[T][N][C]` sequences with BPTT and an FC softmax head on the
//!   final hidden state — the paper's third workload class, end to end.
//! * [`dist`]    — the distributed simulator: collective algorithms +
//!   α-β network cost model reproducing the paper's multi-node scaling
//!   experiments (Fig. 10) on a single host.
//! * [`resnet`]  — the ResNet-50 layer table (paper Table 2) and
//!   weighted-efficiency accounting.
//!
//! The counter/timer registry lives in [`crate::telemetry`] (exact
//! parallel merge, JSON export), alongside the BRGEMM profiler, the span
//! tracer, and the health plane.

pub mod build;
pub mod cnn;
pub mod config;
pub mod data;
pub mod dist;
pub mod resnet;
pub mod rnn;
pub mod trainer;
