//! The CNN training driver: end-to-end convolution training through the
//! coordinator (paper §4, Algorithms 4–5).
//!
//! [`CnnModel`] is the conv analogue of [`MlpModel`](super::trainer::MlpModel):
//! a stack of [`ConvPrimitive`] layers — forward with fused bias+ReLU,
//! `backward_data` for the gradient chain, `update` for `dW` **and** `db` —
//! followed by an average-pool / flatten stage ([`AvgPool`]) and the FC
//! softmax-cross-entropy head. Every GEMM of every pass is a BRGEMM
//! primitive call, which is the paper's central claim exercised for CNN
//! *training*, not just inference.
//!
//! Activations flow between conv layers in blocked form: the chain
//! invariant (consumer `bc` = producer `bk`) makes the producer's output
//! `[N][Kb][P][Q][bk]` exactly the consumer's unpadded input, so the only
//! inter-layer reformat is the spatial border re-pad
//! ([`layout::repad_blocked`] forward, [`layout::crop_blocked`] backward).
//!
//! The model implements [`Model`], so
//! [`DataParallelTrainer`](super::trainer::DataParallelTrainer) and the
//! ring-allreduce path in [`super::dist`] work over it unchanged. With
//! `tuned`, layer construction routes through [`ConvPrimitive::tuned`]
//! (and the head through the FC tuning cache), feeding the autotuner's
//! cached winners a real conv training workload.

use crate::coordinator::build;
use crate::coordinator::data::ClassifyData;
use crate::coordinator::resnet;
use crate::coordinator::trainer::{eval_accuracy, softmax_xent, Model};
use crate::modelio::{LayerKind, LayerParams};
use crate::primitives::conv::{ConvConfig, ConvPrimitive};
use crate::primitives::eltwise::{act_backward, Act};
use crate::primitives::fc::FcPrimitive;
use crate::primitives::pool::{AvgPool, PoolConfig};
use crate::telemetry::{self, Metrics};
use crate::tensor::layout;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

/// Shape of one conv stage (plain dims; blocking is chosen internally and
/// possibly overridden by the tuning cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub k: usize,
    pub r: usize,
    pub s: usize,
    pub stride: usize,
    pub pad: usize,
}

/// A full CNN topology: input image shape, conv stack, pool stage, head.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CnnSpec {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub convs: Vec<ConvSpec>,
    /// Average-pool window after the last conv; `0` = global pool
    /// (ResNet-style: one feature per channel).
    pub pool_win: usize,
    /// Pool stride (ignored for global pooling).
    pub pool_stride: usize,
    pub classes: usize,
}

impl CnnSpec {
    /// A compact topology drawn from the ResNet-50 layer table
    /// ([`resnet::mini_stack`]): `depth` alternating 3×3 / 1×1 64-channel
    /// stage-1 convs at `56/scale` spatial resolution, global average
    /// pool, FC head. This is the `{"model": "cnn"}` run-config workload.
    pub fn resnet_mini(scale: usize, depth: usize, classes: usize) -> CnnSpec {
        let stack = resnet::mini_stack(depth);
        let hw = (56 / scale.max(1)).max(3);
        CnnSpec {
            in_c: stack[0].c,
            in_h: hw,
            in_w: hw,
            convs: stack
                .iter()
                .map(|l| ConvSpec { k: l.k, r: l.r, s: l.s, stride: l.stride, pad: l.pad })
                .collect(),
            pool_win: 0,
            pool_stride: 1,
            classes,
        }
    }

    /// Flattened input dimensionality (`C·H·W`) — what the synthetic data
    /// pipeline must produce per sample.
    pub fn input_dim(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// The default-blocking conv config of every layer, in chain order
    /// (input dims propagated through strides/padding). The tune-before-
    /// train path feeds exactly these shapes to the tuner, so its cache
    /// entries hit at model construction.
    pub fn conv_configs(&self, batch: usize, nthreads: usize) -> Vec<ConvConfig> {
        let (mut c, mut h, mut w) = (self.in_c, self.in_h, self.in_w);
        self.convs
            .iter()
            .map(|s| {
                let cfg = ConvConfig::new(batch, c, s.k, h, w, s.r, s.s, s.stride, s.pad)
                    .with_act(Act::Relu)
                    .with_threads(nthreads);
                c = s.k;
                h = cfg.p();
                w = cfg.q();
                cfg
            })
            .collect()
    }

    /// The pool stage's geometry over the last conv's output (the channel
    /// blocking is applied by the model, which matches it to the
    /// producer's `bk`).
    pub fn pool_config(&self, batch: usize, last: &ConvConfig) -> PoolConfig {
        if self.pool_win == 0 {
            PoolConfig::global(batch, last.k, last.p(), last.q())
        } else {
            PoolConfig::new(
                batch,
                last.k,
                last.p(),
                last.q(),
                self.pool_win,
                self.pool_stride.max(1),
            )
        }
    }

    /// The FC head's input width — last conv's channels × pooled spatial
    /// dims. Kept on the spec so the tune-before-train path tunes the
    /// exact head shape [`CnnModel::new_with`] constructs (global and
    /// windowed pooling alike).
    pub fn head_features(&self, batch: usize) -> usize {
        let last = *self.conv_configs(batch, 1).last().unwrap();
        let pcfg = self.pool_config(batch, &last);
        last.k * pcfg.p() * pcfg.q()
    }
}

/// One conv layer's state (packed weights + the buffers the training
/// passes exchange).
struct ConvLayer {
    prim: ConvPrimitive,
    w: Vec<f32>,  // packed [Kb][Cb][R][S][bc][bk]
    b: Vec<f32>,  // [K]
    /// Packed, padded input of this layer, kept for the update pass.
    x: Vec<f32>,
    /// Packed output (post bias+ReLU), kept for the ReLU backward.
    y: Vec<f32>,
    /// Pre-activation gradient (output geometry).
    dz: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

/// The FC softmax head's state.
struct FcHead {
    prim: FcPrimitive,
    w: Vec<f32>, // packed [Kb][Cb][bc][bk]
    b: Vec<f32>, // [classes]
    y: Vec<f32>,
    dz: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

/// A CNN classifier built entirely from the BRGEMM conv/pool/FC
/// primitives; same driver surface as `MlpModel`.
pub struct CnnModel {
    pub batch: usize,
    pub classes: usize,
    convs: Vec<ConvLayer>,
    pool: AvgPool,
    /// Pooled features, plain `[batch][feat]` (the pooled blocked layout
    /// flattened per sample — a fixed permutation the head learns under).
    pool_y: Vec<f32>,
    /// The head's packed input, kept for its update pass.
    head_x: Vec<f32>,
    head: FcHead,
    /// Per-pass training breakdown (incl. the pool stage) — only fed
    /// while telemetry is enabled.
    metrics: Metrics,
}

impl CnnModel {
    pub fn new(spec: &CnnSpec, batch: usize, nthreads: usize, rng: &mut Rng) -> CnnModel {
        CnnModel::new_with(spec, batch, nthreads, false, rng)
    }

    /// Like [`CnnModel::new`], with `tuned` routing every conv layer's
    /// construction through [`ConvPrimitive::tuned`] (and the head through
    /// the FC tuning cache). Where an independently tuned blocking breaks
    /// the chain invariant (consumer `bc` = producer `bk`), the consumer
    /// is re-blocked to restore it — the producer's `bk` always divides
    /// the shared channel dimension, so the fix never violates a
    /// divisibility constraint.
    pub fn new_with(
        spec: &CnnSpec,
        batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> CnnModel {
        assert!(!spec.convs.is_empty(), "need at least one conv layer");
        assert!(spec.classes >= 2, "need at least two classes");
        // Layer configs (tuning consultation + chain-invariant fix) come
        // from the shared construction module, so the training model and
        // the serving plans agree by construction — weight lifting through
        // artifacts depends on it.
        let cfgs = build::conv_chain_configs(spec, batch, nthreads, tuned);
        let convs: Vec<ConvLayer> = cfgs
            .into_iter()
            .map(|cfg| {
                let prim = ConvPrimitive::new(cfg);
                // He init on the plain layout, packed directly (the
                // blocked form is an internal detail).
                let scale = (2.0 / (cfg.c * cfg.r * cfg.s) as f32).sqrt();
                let w_plain = rng.vec_f32(cfg.k * cfg.c * cfg.r * cfg.s, -scale, scale);
                let w = layout::pack_conv_weights(
                    &w_plain, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc,
                );
                ConvLayer {
                    w,
                    b: vec![0.0; cfg.k],
                    x: Vec::new(),
                    y: vec![0.0; cfg.output_len()],
                    dz: vec![0.0; cfg.output_len()],
                    // Zeroed so grads_flat is well-formed before the first
                    // backward; each backward replaces them with the
                    // buffers `ConvPrimitive::update` returns.
                    dw: vec![0.0; cfg.weights_len()],
                    db: vec![0.0; cfg.k],
                    prim,
                }
            })
            .collect();

        // Pool stage over the last conv's output, sharing its channel
        // block so the blocked buffer is consumed in place.
        let last = convs.last().unwrap().prim.cfg;
        let pcfg = spec.pool_config(batch, &last).with_block(last.bk).with_threads(nthreads);
        let pool = AvgPool::new(pcfg);
        let feat = last.k * pcfg.p() * pcfg.q();

        let hcfg = build::head_fc_config(batch, feat, spec.classes, nthreads, tuned);
        let hprim = FcPrimitive::new(hcfg);
        let hscale = (2.0 / feat as f32).sqrt();
        let hw_plain = rng.vec_f32(spec.classes * feat, -hscale, hscale);
        let head = FcHead {
            w: layout::pack_weights_2d(&hw_plain, spec.classes, feat, hcfg.bk, hcfg.bc),
            b: vec![0.0; spec.classes],
            y: vec![0.0; batch * spec.classes],
            dz: vec![0.0; batch * spec.classes],
            dw: vec![0.0; spec.classes * feat],
            db: vec![0.0; spec.classes],
            prim: hprim,
        };

        CnnModel {
            batch,
            classes: spec.classes,
            convs,
            pool,
            pool_y: vec![0.0; pcfg.output_len()],
            head_x: Vec::new(),
            head,
            metrics: Metrics::new(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.convs.iter().map(|l| l.w.len() + l.b.len()).sum::<usize>()
            + self.head.w.len()
            + self.head.b.len()
    }

    /// Forward from a plain `[batch][C·H·W]` input (NCHW per sample);
    /// returns plain logits `[batch][classes]`.
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let n = self.batch;
        let cfg0 = self.convs[0].prim.cfg;
        assert_eq!(x.len(), n * cfg0.c * cfg0.h * cfg0.w, "input shape mismatch");
        let mut cur =
            layout::pack_conv_act(x, n, cfg0.c, cfg0.h, cfg0.w, cfg0.bc, cfg0.pad, cfg0.pad);
        for i in 0..self.convs.len() {
            let next_cfg =
                if i + 1 < self.convs.len() { Some(self.convs[i + 1].prim.cfg) } else { None };
            let l = &mut self.convs[i];
            l.x = cur;
            l.prim.forward(&l.x, &l.w, Some(&l.b), &mut l.y);
            cur = match next_cfg {
                // Chain invariant: the output [N][Kb][P][Q][bk] is exactly
                // the consumer's unpadded input — only the border re-pad
                // remains.
                Some(nc) => {
                    layout::repad_blocked(&l.y, n, nc.cb_ct(), nc.h, nc.w, nc.bc, nc.pad, nc.pad)
                }
                None => Vec::new(),
            };
        }
        let lastl = self.convs.last().unwrap();
        let t_pool = telemetry::enabled().then(Instant::now);
        self.pool.forward(&lastl.y, &mut self.pool_y);
        if let Some(t) = t_pool {
            self.metrics.observe_secs("pool", t.elapsed().as_secs_f64());
        }
        let hcfg = self.head.prim.cfg;
        self.head_x = layout::pack_act_2d(&self.pool_y, n, hcfg.c, hcfg.bn, hcfg.bc);
        self.head.prim.forward(&self.head_x, &self.head.w, &self.head.b, &mut self.head.y);
        layout::unpack_act_2d(&self.head.y, n, hcfg.k, hcfg.bn, hcfg.bk)
    }

    /// One SGD step; returns the mean cross-entropy loss. While telemetry
    /// is enabled, the per-pass breakdown (fwd / bwd incl. the loss / upd,
    /// plus the pool stage timed inside forward/backward) lands in
    /// [`Model::metrics`]; disabled, the step pays one branch.
    pub fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        if !telemetry::enabled() {
            let logits = self.forward(x);
            let (loss, dlogits) = softmax_xent(&logits, labels, self.classes);
            self.backward(&dlogits);
            self.apply_sgd(lr);
            return loss;
        }
        let t0 = Instant::now();
        let logits = self.forward(x);
        let t1 = Instant::now();
        let (loss, dlogits) = softmax_xent(&logits, labels, self.classes);
        self.backward(&dlogits);
        let t2 = Instant::now();
        self.apply_sgd(lr);
        self.metrics.observe_secs("fwd", (t1 - t0).as_secs_f64());
        self.metrics.observe_secs("bwd", (t2 - t1).as_secs_f64());
        self.metrics.observe_secs("upd", t2.elapsed().as_secs_f64());
        self.metrics.inc("steps", 1);
        loss
    }

    /// Backward from plain dlogits; fills every layer's dw/db.
    pub fn backward(&mut self, dlogits: &[f32]) {
        let n = self.batch;
        let hcfg = self.head.prim.cfg;
        assert_eq!(dlogits.len(), n * hcfg.k);
        // Linear head: dz = dlogits, packed.
        self.head.dz = layout::pack_act_2d(dlogits, n, hcfg.k, hcfg.bn, hcfg.bk);
        self.head.prim.update(&self.head_x, &self.head.dz, &mut self.head.dw, &mut self.head.db);
        let wt = layout::transpose_packed_2d(&self.head.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc);
        let mut dpool_packed = vec![0.0f32; n * hcfg.c];
        self.head.prim.backward_data(&self.head.dz, &wt, &mut dpool_packed);
        // Pool-output gradient, plain [n][feat] = the pooled blocked layout.
        let dpool = layout::unpack_act_2d(&dpool_packed, n, hcfg.c, hcfg.bn, hcfg.bc);
        // Through the pool into the last conv's output geometry.
        let t_pool = telemetry::enabled().then(Instant::now);
        let mut dy = self.pool.backward(&dpool);
        if let Some(t) = t_pool {
            self.metrics.observe_secs("pool", t.elapsed().as_secs_f64());
        }
        for i in (0..self.convs.len()).rev() {
            let l = &mut self.convs[i];
            // Chain through the fused ReLU: dz = dy ∘ relu'(y).
            act_backward(Act::Relu, &dy, &l.y, &mut l.dz);
            let (dw, db, _) = l.prim.update(&l.x, &l.dz);
            l.dw = dw;
            l.db = db;
            if i > 0 {
                let cfg = l.prim.cfg;
                let (dip, _) = l.prim.backward_data(&l.dz, &l.w);
                // dip has this layer's padded input geometry; cropping the
                // border yields the producing layer's output gradient
                // (pad 0 ⇒ the geometries coincide, move instead of copy).
                dy = if cfg.pad == 0 {
                    dip
                } else {
                    layout::crop_blocked(
                        &dip, n, cfg.cb_ct(), cfg.h, cfg.w, cfg.bc, cfg.pad, cfg.pad,
                    )
                };
            }
        }
    }

    fn apply_sgd(&mut self, lr: f32) {
        for l in &mut self.convs {
            for (w, g) in l.w.iter_mut().zip(&l.dw) {
                *w -= lr * g;
            }
            for (b, g) in l.b.iter_mut().zip(&l.db) {
                *b -= lr * g;
            }
        }
        for (w, g) in self.head.w.iter_mut().zip(&self.head.dw) {
            *w -= lr * g;
        }
        for (b, g) in self.head.b.iter_mut().zip(&self.head.db) {
            *b -= lr * g;
        }
    }

    /// Flatten all gradients (for allreduce): conv layers in order
    /// (dw then db each), then the head.
    pub fn grads_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.convs {
            out.extend_from_slice(&l.dw);
            out.extend_from_slice(&l.db);
        }
        out.extend_from_slice(&self.head.dw);
        out.extend_from_slice(&self.head.db);
        out
    }

    /// Apply SGD from an external (e.g. allreduced) flat gradient.
    pub fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32) {
        let mut off = 0;
        for l in &mut self.convs {
            for (w, g) in l.w.iter_mut().zip(&flat[off..off + l.dw.len()]) {
                *w -= lr * g;
            }
            off += l.dw.len();
            for (b, g) in l.b.iter_mut().zip(&flat[off..off + l.db.len()]) {
                *b -= lr * g;
            }
            off += l.db.len();
        }
        for (w, g) in self.head.w.iter_mut().zip(&flat[off..off + self.head.dw.len()]) {
            *w -= lr * g;
        }
        off += self.head.dw.len();
        for (b, g) in self.head.b.iter_mut().zip(&flat[off..off + self.head.db.len()]) {
            *b -= lr * g;
        }
        off += self.head.db.len();
        assert_eq!(off, flat.len(), "flat gradient length mismatch");
    }

    /// Classification accuracy on plain data (partial final batches are
    /// padded and masked — see [`eval_accuracy`]).
    pub fn accuracy(&mut self, data: &ClassifyData, max_batches: usize) -> f64 {
        eval_accuracy(self, data, max_batches)
    }
}

impl Model for CnnModel {
    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        CnnModel::forward(self, x)
    }
    fn backward(&mut self, dlogits: &[f32]) {
        CnnModel::backward(self, dlogits)
    }
    fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        CnnModel::train_step(self, x, labels, lr)
    }
    fn grads_flat(&self) -> Vec<f32> {
        CnnModel::grads_flat(self)
    }
    fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32) {
        CnnModel::apply_sgd_from_flat(self, flat, lr)
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn param_count(&self) -> usize {
        CnnModel::param_count(self)
    }
    fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.convs {
            out.extend_from_slice(&l.w);
            out.extend_from_slice(&l.b);
        }
        out.extend_from_slice(&self.head.w);
        out.extend_from_slice(&self.head.b);
        out
    }
    fn export_weights(&self) -> Vec<LayerParams> {
        let mut out: Vec<LayerParams> = self
            .convs
            .iter()
            .map(|l| {
                let cfg = l.prim.cfg;
                LayerParams::conv(
                    cfg.k,
                    cfg.c,
                    cfg.r,
                    cfg.s,
                    layout::unpack_conv_weights(&l.w, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc),
                    l.b.clone(),
                )
            })
            .collect();
        let hcfg = self.head.prim.cfg;
        out.push(LayerParams::fc(
            hcfg.k,
            hcfg.c,
            layout::unpack_weights_2d(&self.head.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc),
            self.head.b.clone(),
        ));
        out
    }
    fn import_weights(&mut self, layers: &[LayerParams]) -> Result<()> {
        if layers.len() != self.convs.len() + 1 {
            bail!(
                "cnn has {} layers (convs + head), artifact has {}",
                self.convs.len() + 1,
                layers.len()
            );
        }
        for (i, (l, p)) in self.convs.iter_mut().zip(layers).enumerate() {
            let cfg = l.prim.cfg;
            p.expect(
                &format!("cnn layer {}", i),
                LayerKind::Conv,
                &[cfg.k, cfg.c, cfg.r, cfg.s],
            )?;
            l.w = layout::pack_conv_weights(&p.w, cfg.k, cfg.c, cfg.r, cfg.s, cfg.bk, cfg.bc);
            l.b = p.b.clone();
        }
        let p = layers.last().unwrap();
        let hcfg = self.head.prim.cfg;
        p.expect("cnn head", LayerKind::Fc, &[hcfg.k, hcfg.c])?;
        self.head.w = layout::pack_weights_2d(&p.w, hcfg.k, hcfg.c, hcfg.bk, hcfg.bc);
        self.head.b = p.b.clone();
        Ok(())
    }
    fn metrics(&self) -> Option<&Metrics> {
        Some(&self.metrics)
    }
    fn metrics_mut(&mut self) -> Option<&mut Metrics> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::DataParallelTrainer;

    fn tiny_spec() -> CnnSpec {
        CnnSpec {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            convs: vec![
                ConvSpec { k: 3, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 1, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        }
    }

    /// A spec whose second layer downsamples (strided 1×1), exercising the
    /// strided backward-by-data path inside the training chain.
    fn strided_spec() -> CnnSpec {
        CnnSpec {
            in_c: 2,
            in_h: 6,
            in_w: 6,
            convs: vec![
                ConvSpec { k: 4, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 2, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        }
    }

    #[test]
    fn cnn_gradients_match_finite_difference() {
        for (si, spec) in [tiny_spec(), strided_spec()].into_iter().enumerate() {
            let batch = 2;
            let classes = spec.classes;
            let mut rng = Rng::new(5 + si as u64);
            let mut model = CnnModel::new(&spec, batch, 1, &mut rng);
            let x = rng.vec_f32(batch * spec.input_dim(), -1.0, 1.0);
            let labels = vec![0, 2];

            let logits = model.forward(&x);
            let (_, dlogits) = softmax_xent(&logits, &labels, classes);
            model.backward(&dlogits);
            let dw0 = model.convs[0].dw.clone();
            let db0 = model.convs[0].db.clone();
            let db1 = model.convs[1].db.clone();
            let hdw = model.head.dw.clone();

            let eps = 1e-3f32;
            let loss_of = |m: &mut CnnModel| {
                let l = m.forward(&x);
                softmax_xent(&l, &labels, classes).0
            };
            // First conv's weights (packed indices; gradients share the
            // packing, so index-for-index comparison is exact).
            for &idx in &[0usize, 7, 23, dw0.len() - 1] {
                let orig = model.convs[0].w[idx];
                model.convs[0].w[idx] = orig + eps;
                let lp = loss_of(&mut model);
                model.convs[0].w[idx] = orig - eps;
                let lm = loss_of(&mut model);
                model.convs[0].w[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - dw0[idx]).abs() < 1e-2,
                    "spec {} conv0 dw[{}]: {} vs {}",
                    si, idx, num, dw0[idx]
                );
            }
            // Conv biases of both layers — the headline bugfix: without the
            // db path these gradients would be silently absent.
            for (li, db) in [(0usize, &db0), (1usize, &db1)] {
                for idx in 0..db.len() {
                    let orig = model.convs[li].b[idx];
                    model.convs[li].b[idx] = orig + eps;
                    let lp = loss_of(&mut model);
                    model.convs[li].b[idx] = orig - eps;
                    let lm = loss_of(&mut model);
                    model.convs[li].b[idx] = orig;
                    let num = (lp - lm) / (2.0 * eps);
                    assert!(
                        (num - db[idx]).abs() < 1e-2,
                        "spec {} conv{} db[{}]: {} vs {}",
                        si, li, idx, num, db[idx]
                    );
                }
            }
            // Head weights.
            for &idx in &[0usize, hdw.len() / 2, hdw.len() - 1] {
                let orig = model.head.w[idx];
                model.head.w[idx] = orig + eps;
                let lp = loss_of(&mut model);
                model.head.w[idx] = orig - eps;
                let lm = loss_of(&mut model);
                model.head.w[idx] = orig;
                let num = (lp - lm) / (2.0 * eps);
                assert!(
                    (num - hdw[idx]).abs() < 1e-2,
                    "spec {} head dw[{}]: {} vs {}",
                    si, idx, num, hdw[idx]
                );
            }
        }
    }

    #[test]
    fn cnn_learns_separable_data() {
        let mut rng = Rng::new(11);
        let spec = CnnSpec {
            in_c: 3,
            in_h: 6,
            in_w: 6,
            convs: vec![ConvSpec { k: 8, r: 3, s: 3, stride: 1, pad: 1 }],
            pool_win: 3,
            pool_stride: 3,
            classes: 4,
        };
        let data = ClassifyData::synth(256, spec.input_dim(), 4, 0.1, &mut rng);
        let mut model = CnnModel::new(&spec, 16, 1, &mut rng);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..100 {
            let (x, labels) = data.batch(step, 16);
            last = model.train_step(&x, &labels, 0.1);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() * 0.5, "loss {} -> {}", first.unwrap(), last);
        let acc = model.accuracy(&data, 16);
        assert!(acc > 0.8, "accuracy {}", acc);
    }

    #[test]
    fn cnn_data_parallel_matches_single_worker_math() {
        // 2 CNN workers on shards A,B through the generic trainer + real
        // ring-allreduce must equal 1 worker on A∪B (same init, same total
        // batch) — the dist path works over CnnModel unchanged.
        let spec = tiny_spec();
        let mut rng = Rng::new(17);
        let data = ClassifyData::synth(128, spec.input_dim(), spec.classes, 0.2, &mut rng);
        let workers: Vec<CnnModel> =
            (0..2).map(|_| CnnModel::new(&spec, 8, 1, &mut Rng::new(99))).collect();
        let mut dp = DataParallelTrainer::from_workers(workers, 0.1);
        let (x0, l0) = data.batch(0, 8);
        let (x1, l1) = data.batch(1, 8);
        dp.step(&[(x0.clone(), l0.clone()), (x1.clone(), l1.clone())]);
        assert!(dp.replicas_consistent());

        let mut single = CnnModel::new(&spec, 16, 1, &mut Rng::new(99));
        let mut x = x0;
        x.extend(x1);
        let mut l = l0;
        l.extend(l1);
        single.train_step(&x, &l, 0.1);
        let a = dp.workers[0].params_flat();
        let b = single.params_flat();
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-5, "param[{}]: {} vs {}", i, a[i], b[i]);
        }
    }

    #[test]
    fn tuned_cnn_applies_cached_blocking_and_matches_math() {
        use crate::autotune::{cache, Candidate, TuneEntry, TuningCache};
        // Unique conv shape so no other test's cache entries collide.
        let spec = CnnSpec {
            in_c: 6,
            in_h: 7,
            in_w: 7,
            convs: vec![ConvSpec { k: 10, r: 3, s: 3, stride: 1, pad: 1 }],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        };
        let batch = 4;
        let ccfg = spec.conv_configs(batch, 1)[0];
        let cand = Candidate {
            bn: 1,
            bc: 3,
            bk: 5,
            bq: 7,
            flat_bq: 0,
            order: None,
            fwd_strided: false,
            upd_transpose: false,
        };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&cache::conv_key(&ccfg), TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });

        let x = Rng::new(3).vec_f32(batch * spec.input_dim(), -1.0, 1.0);
        let mut plain = CnnModel::new(&spec, batch, 1, &mut Rng::new(9));
        let mut tuned = CnnModel::new_with(&spec, batch, 1, true, &mut Rng::new(9));
        // The tuned path must route through the cached blocking...
        let tcfg = tuned.convs[0].prim.cfg;
        assert_eq!((tcfg.bc, tcfg.bk, tcfg.bq), (3, 5, 7));
        // ...while blocking stays a layout choice, not a math choice.
        let yp = plain.forward(&x);
        let yt = tuned.forward(&x);
        for i in 0..yp.len() {
            assert!((yp[i] - yt[i]).abs() < 1e-4, "[{}]: {} vs {}", i, yp[i], yt[i]);
        }
    }

    #[test]
    fn cnn_export_import_roundtrip_bit_identical() {
        // Same blocking formulas at any batch (default blockings are
        // batch-independent), so a trained CNN's canonical export imports
        // into a different-batch model with bit-identical packed params
        // and bit-identical forward outputs.
        let spec = tiny_spec();
        let mut rng = Rng::new(51);
        let data = ClassifyData::synth(64, spec.input_dim(), spec.classes, 0.2, &mut rng);
        let mut src = CnnModel::new(&spec, 4, 1, &mut rng);
        for step in 0..6 {
            let (x, l) = data.batch(step, 4);
            src.train_step(&x, &l, 0.05);
        }
        let exported = src.export_weights();
        assert_eq!(exported.len(), 3, "2 convs + head");
        let mut dst = CnnModel::new(&spec, 2, 2, &mut Rng::new(999));
        dst.import_weights(&exported).unwrap();
        assert_eq!(dst.export_weights(), exported, "roundtrip is bitwise");
        let x = Rng::new(52).vec_f32(2 * spec.input_dim(), -1.0, 1.0);
        let y2 = dst.forward(&x);
        let mut x4 = x.clone();
        x4.extend(Rng::new(53).vec_f32(2 * spec.input_dim(), -1.0, 1.0));
        let y4 = src.forward(&x4);
        assert_eq!(&y4[..y2.len()], &y2[..], "same rows, same logits across batch blockings");
        // Mismatched arch is rejected with a clear error.
        let other = CnnSpec { classes: 4, ..tiny_spec() };
        let mut wrong = CnnModel::new(&other, 2, 1, &mut Rng::new(1));
        assert!(wrong.import_weights(&exported).is_err());
    }

    #[test]
    fn resnet_mini_spec_trains_a_step() {
        // The `{"model": "cnn"}` default topology (scaled down hard) must
        // run a full train_step end to end: 3×3 and 1×1 table rows, global
        // pool, FC head.
        let spec = CnnSpec::resnet_mini(16, 2, 4); // 64ch 3x3+1x1 at 3x3 px
        assert_eq!(spec.in_c, 64);
        assert_eq!(spec.convs.len(), 2);
        let mut rng = Rng::new(21);
        let data = ClassifyData::synth(16, spec.input_dim(), 4, 0.2, &mut rng);
        let mut model = CnnModel::new(&spec, 4, 1, &mut rng);
        let (x, labels) = data.batch(0, 4);
        let l0 = model.train_step(&x, &labels, 0.05);
        let l1 = model.train_step(&x, &labels, 0.05);
        assert!(l0.is_finite() && l1.is_finite());
        assert!(l1 < l0, "repeated step on one batch must reduce loss: {} -> {}", l0, l1);
    }
}
