//! Distributed data-parallel training simulator (paper §4.2).
//!
//! The paper's multi-node runs (32× dual-socket SKX, Omnipath, MLSL) are
//! reproduced on this single-core host by separating the two ingredients
//! that shape the strong-scaling curves:
//!
//! 1. **Collective correctness** — a real chunked ring-allreduce runs over
//!    in-process workers (threads) and is property-tested against the sum
//!    oracle; the coordinator uses it to combine worker gradients in the
//!    e2e drivers.
//! 2. **Time model** — an α-β (latency-bandwidth) cost model of the ring
//!    allreduce plus measured single-socket compute time produces the
//!    simulated scaling curves of Fig. 10. The model is calibrated to
//!    Omnipath-class links (α = 1.5 µs, 100 Gb/s) like the paper's testbed.

use crate::util::pool::parallel_region;
use std::sync::{Barrier, Mutex};

/// α-β network model of one link.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte).
    pub beta: f64,
}

impl NetworkModel {
    /// Omnipath-class fabric: 1.5 µs latency, 100 Gb/s ≈ 12.5 GB/s.
    pub fn omnipath() -> NetworkModel {
        NetworkModel { alpha: 1.5e-6, beta: 1.0 / 12.5e9 }
    }

    /// Ring allreduce of `bytes` over `p` ranks: 2(p−1) steps, each sending
    /// `bytes/p`; total time `2(p−1)(α + (bytes/p)·β)`.
    pub fn ring_allreduce_secs(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        2.0 * (p - 1) as f64 * (self.alpha + (bytes as f64 / p as f64) * self.beta)
    }
}

/// A real chunked ring-allreduce over in-process workers.
///
/// Buffers are split into `p` chunks; in the reduce-scatter phase each rank
/// accumulates chunk `(rank - step)` from its ring predecessor, in the
/// allgather phase the reduced chunks circulate. The message schedule is
/// exactly the distributed algorithm's; "transport" is shared memory.
pub fn ring_allreduce(buffers: &mut [Vec<f32>]) {
    let p = buffers.len();
    if p <= 1 {
        return;
    }
    let len = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == len), "rank buffer length mismatch");
    // chunk c covers [bounds[c], bounds[c+1])
    let bounds: Vec<usize> = (0..=p).map(|c| c * len / p).collect();

    let shared: Vec<Mutex<&mut Vec<f32>>> = buffers.iter_mut().map(Mutex::new).collect();
    let barrier = Barrier::new(p);

    parallel_region(p, |rank| {
        let prev = (rank + p - 1) % p;
        // Reduce-scatter: after p-1 steps, rank owns the fully reduced
        // chunk (rank+1) mod p.
        for step in 0..p - 1 {
            let chunk = (rank + p - step) % p;
            let (lo, hi) = (bounds[chunk], bounds[chunk + 1]);
            let src: Vec<f32> = {
                let b = shared[prev].lock().unwrap();
                b[lo..hi].to_vec()
            };
            {
                let mut b = shared[rank].lock().unwrap();
                for (d, s) in b[lo..hi].iter_mut().zip(&src) {
                    *d += s;
                }
            }
            barrier.wait();
        }
        // Allgather: circulate the reduced chunks.
        for step in 0..p - 1 {
            let chunk = (rank + p - step + 1) % p;
            let (lo, hi) = (bounds[chunk], bounds[chunk + 1]);
            let src: Vec<f32> = {
                let b = shared[prev].lock().unwrap();
                b[lo..hi].to_vec()
            };
            {
                let mut b = shared[rank].lock().unwrap();
                b[lo..hi].copy_from_slice(&src);
            }
            barrier.wait();
        }
    });
}

/// Simulated strong scaling of synchronous data-parallel training.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub nodes: usize,
    pub compute_secs: f64,
    pub comm_secs: f64,
    /// Samples (or words) per second at this node count.
    pub throughput: f64,
    /// Parallel efficiency vs the smallest measured node count.
    pub efficiency: f64,
}

/// Build a strong-scaling curve: global batch `global_batch` is split over
/// `nodes`; per-step compute is `per_sample_secs · (global_batch / nodes)`
/// (+ an Amdahl floor `fixed_secs`), followed by an allreduce of
/// `grad_bytes`. `units_per_sample` converts samples to the reported unit
/// (words for GNMT, images for ResNet).
pub fn strong_scaling(
    net: &NetworkModel,
    node_counts: &[usize],
    global_batch: usize,
    per_sample_secs: f64,
    fixed_secs: f64,
    grad_bytes: usize,
    units_per_sample: f64,
) -> Vec<ScalingPoint> {
    let mut out = Vec::new();
    let mut base: Option<f64> = None; // throughput/node at smallest count
    for &p in node_counts {
        let local_batch = (global_batch + p - 1) / p;
        let compute = per_sample_secs * local_batch as f64 + fixed_secs;
        let comm = net.ring_allreduce_secs(grad_bytes, p);
        let step = compute + comm;
        let throughput = global_batch as f64 * units_per_sample / step;
        let per_node = throughput / p as f64;
        let eff = match base {
            None => {
                base = Some(per_node);
                1.0
            }
            Some(b) => per_node / b,
        };
        out.push(ScalingPoint { nodes: p, compute_secs: compute, comm_secs: comm, throughput, efficiency: eff });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn allreduce_equals_sum() {
        let mut rng = Rng::new(1);
        for p in [2usize, 3, 4, 7] {
            for len in [1usize, 5, 64, 1000] {
                let mut bufs: Vec<Vec<f32>> =
                    (0..p).map(|_| rng.vec_f32(len, -1.0, 1.0)).collect();
                let want: Vec<f32> = (0..len)
                    .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>())
                    .collect();
                ring_allreduce(&mut bufs);
                for b in &bufs {
                    for i in 0..len {
                        assert!(
                            (b[i] - want[i]).abs() < 1e-4,
                            "p={} len={} i={}: {} vs {}",
                            p, len, i, b[i], want[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let mut bufs = vec![vec![1.0, 2.0, 3.0]];
        ring_allreduce(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn property_allreduce_random() {
        Prop::new("ring allreduce = elementwise sum").cases(25).run(|g| {
            let p = g.usize(2..=6);
            let len = g.usize(1..=200);
            let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| g.vec_f32(len, -1.0, 1.0)).collect();
            let want: Vec<f32> =
                (0..len).map(|i| bufs.iter().map(|b| b[i]).sum::<f32>()).collect();
            ring_allreduce(&mut bufs);
            for (r, b) in bufs.iter().enumerate() {
                for i in 0..len {
                    if (b[i] - want[i]).abs() > 1e-3 {
                        return Err(format!("rank {} idx {}: {} vs {}", r, i, b[i], want[i]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn network_model_monotonic() {
        let net = NetworkModel::omnipath();
        assert_eq!(net.ring_allreduce_secs(1 << 20, 1), 0.0);
        let t2 = net.ring_allreduce_secs(1 << 20, 2);
        let t8 = net.ring_allreduce_secs(1 << 20, 8);
        assert!(t8 > t2, "more ranks, more steps");
        // bandwidth term dominates for large messages
        let big = net.ring_allreduce_secs(100 << 20, 4);
        let small = net.ring_allreduce_secs(1 << 10, 4);
        assert!(big > 100.0 * small);
    }

    #[test]
    fn strong_scaling_efficiency_improves_with_batch() {
        // The paper's observation: larger global batch ⇒ better strong
        // scaling (compute per node shrinks slower relative to comm).
        let net = NetworkModel::omnipath();
        let nodes = [1, 2, 4, 8, 16];
        let small = strong_scaling(&net, &nodes, 1344, 1e-4, 1e-3, 50 << 20, 20.0);
        let large = strong_scaling(&net, &nodes, 5376, 1e-4, 1e-3, 50 << 20, 20.0);
        let eff_small = small.last().unwrap().efficiency;
        let eff_large = large.last().unwrap().efficiency;
        assert!(
            eff_large > eff_small,
            "batch 5376 should scale better: {} vs {}",
            eff_large,
            eff_small
        );
        // Throughput must increase with nodes for the large batch.
        for w in large.windows(2) {
            assert!(w[1].throughput > w[0].throughput);
        }
    }
}
