//! Compatibility re-export: the metric registry moved to
//! [`crate::telemetry`], which unifies it with the per-primitive BRGEMM
//! profiler. Existing `coordinator::metrics::Metrics` paths keep working.

pub use crate::telemetry::{merge_online, Metrics};
