//! Metric collection for the coordinator: named counters and timers with a
//! JSON-lines export (consumed by EXPERIMENTS.md tooling and the CLI's
//! `--metrics-out`).

use crate::util::json::{obj, Json};
use crate::util::stats::Online;
use std::collections::BTreeMap;
use std::time::Instant;

/// A metric registry. Not thread-safe by design — each worker owns one and
/// they are merged at the end (the same pattern the primitives use for
/// outputs: no shared mutable state on the hot path).
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, Online>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_secs(&mut self, name: &str, secs: f64) {
        self.timers.entry(name.to_string()).or_insert_with(Online::new).push(secs);
    }

    /// Time a closure and record it.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe_secs(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn timer_mean(&self, name: &str) -> Option<f64> {
        self.timers.get(name).map(|o| o.mean())
    }

    /// Merge another registry into this one (post-run worker merge).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, o) in &other.timers {
            let mine = self.timers.entry(k.clone()).or_insert_with(Online::new);
            *mine = merge_online(mine, o);
        }
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let timers = Json::Obj(
            self.timers
                .iter()
                .map(|(k, o)| {
                    (
                        k.clone(),
                        obj([
                            ("n", o.n.into()),
                            ("mean_s", o.mean().into()),
                            ("std_s", o.std().into()),
                            ("min_s", o.min.into()),
                            ("max_s", o.max.into()),
                        ]),
                    )
                })
                .collect(),
        );
        obj([("counters", counters), ("timers", timers)])
    }
}

/// Chan et al. parallel-Welford merge (exact).
fn merge_online(a: &Online, b: &Online) -> Online {
    if b.n == 0 {
        return a.clone();
    }
    if a.n == 0 {
        return b.clone();
    }
    let (na, nb) = (a.n as f64, b.n as f64);
    let delta = b.mean() - a.mean();
    let mean = a.mean() + delta * nb / (na + nb);
    let m2 = a.std().powi(2) * (na - 1.0).max(0.0)
        + b.std().powi(2) * (nb - 1.0).max(0.0)
        + delta * delta * na * nb / (na + nb);
    Online::from_moments(a.n + b.n, mean, m2, a.min.min(b.min), a.max.max(b.max))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let mut m = Metrics::new();
        m.inc("requests", 2);
        m.inc("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe_secs("step", 0.1);
        m.observe_secs("step", 0.3);
        assert!((m.timer_mean("step").unwrap() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn time_records_and_returns() {
        let mut m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.timers.get("op").unwrap().n, 1);
    }

    #[test]
    fn merge_combines_exactly() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        for x in [1.0, 2.0, 3.0] {
            a.observe_secs("t", x);
        }
        for x in [4.0, 5.0] {
            b.observe_secs("t", x);
        }
        a.inc("c", 1);
        b.inc("c", 2);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        let mut whole = Metrics::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            whole.observe_secs("t", x);
        }
        let got = a.timers.get("t").unwrap();
        let want = whole.timers.get("t").unwrap();
        assert_eq!(got.n, want.n);
        assert!((got.mean() - want.mean()).abs() < 1e-12);
        assert!((got.std() - want.std()).abs() < 1e-9);
        assert_eq!(got.min, want.min);
        assert_eq!(got.max, want.max);
    }

    #[test]
    fn json_export_shape() {
        let mut m = Metrics::new();
        m.inc("x", 1);
        m.observe_secs("t", 0.5);
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get("x").unwrap().as_f64(), Some(1.0));
        assert!(j.get("timers").unwrap().get("t").unwrap().get("mean_s").is_some());
    }
}
