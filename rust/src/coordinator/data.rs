//! Synthetic data pipelines.
//!
//! The paper trains on WMT16 (GNMT) and ImageNet (ResNet-50); neither is
//! available here, so the coordinator generates synthetic equivalents that
//! preserve the *behaviour* the experiments depend on (DESIGN.md §5):
//!
//! * [`SeqCorpus`] — token sequences with a WMT-like right-skewed length
//!   distribution; what matters for Fig. 10a is the load-balance effect of
//!   grouping similar lengths (the paper's 1.5× bucketing win), which is a
//!   property of the length distribution, not the tokens.
//! * [`ClassifyData`] — Gaussian-cluster classification data that a small
//!   MLP/CNN can actually learn, so the e2e drivers produce a genuinely
//!   decreasing loss curve.

use crate::util::rng::Rng;

/// A synthetic batch-able sequence corpus.
#[derive(Debug, Clone)]
pub struct SeqCorpus {
    /// Lengths of each sequence (tokens).
    pub lengths: Vec<usize>,
}

impl SeqCorpus {
    /// Sample `n` sequences with a truncated log-normal length profile
    /// (mode ≈ `typical`, long tail up to `max_len`) — the shape of WMT
    /// sentence lengths.
    pub fn synth(n: usize, typical: usize, max_len: usize, rng: &mut Rng) -> SeqCorpus {
        let mu = (typical as f64).ln();
        let lengths = (0..n)
            .map(|_| {
                let l = (mu + 0.6 * rng.normal()).exp().round() as usize;
                l.clamp(2, max_len)
            })
            .collect();
        SeqCorpus { lengths }
    }

    /// Plain partitioning: consecutive ranges of the corpus per worker.
    pub fn partition_plain(&self, workers: usize, batch: usize) -> Vec<Vec<Vec<usize>>> {
        let per = self.lengths.len() / workers;
        (0..workers)
            .map(|w| {
                let slice = &self.lengths[w * per..(w + 1) * per];
                slice.chunks(batch).map(|c| c.to_vec()).collect()
            })
            .collect()
    }

    /// The paper's load-balance trick: sort by length, deal into batches of
    /// similar length, then round-robin batches across workers.
    pub fn partition_bucketed(&self, workers: usize, batch: usize) -> Vec<Vec<Vec<usize>>> {
        let mut sorted = self.lengths.clone();
        sorted.sort_unstable();
        let batches: Vec<Vec<usize>> =
            sorted.chunks(batch).map(|c| c.to_vec()).collect();
        let mut out = vec![Vec::new(); workers];
        for (i, b) in batches.into_iter().enumerate() {
            out[i % workers].push(b);
        }
        out
    }

    /// Per-step cost model: a time-step-synchronous LSTM batch costs
    /// `max(lengths)` (all lanes run until the longest sequence finishes);
    /// useful work is `sum(lengths)`. Returns (total_padded_steps,
    /// useful_steps) for one worker's batch list.
    pub fn padded_cost(batches: &[Vec<usize>]) -> (usize, usize) {
        let padded = batches.iter().map(|b| b.iter().max().copied().unwrap_or(0) * b.len()).sum();
        let useful = batches.iter().map(|b| b.iter().sum::<usize>()).sum();
        (padded, useful)
    }
}

/// Synthetic classification data: `classes` Gaussian clusters in
/// `dim`-dimensional space (separable ⇒ a small model can learn it).
#[derive(Debug, Clone)]
pub struct ClassifyData {
    pub dim: usize,
    pub classes: usize,
    pub x: Vec<f32>,      // [n][dim]
    pub labels: Vec<i32>, // [n]
}

impl ClassifyData {
    pub fn synth(n: usize, dim: usize, classes: usize, spread: f32, rng: &mut Rng) -> ClassifyData {
        // Random unit-ish centroids.
        let centroids: Vec<Vec<f32>> =
            (0..classes).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        let mut x = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(classes);
            labels.push(cls as i32);
            for d in 0..dim {
                x.push(centroids[cls][d] + spread * rng.normal() as f32);
            }
        }
        ClassifyData { dim, classes, x, labels }
    }

    /// Synthetic sequence-classification data for the RNN driver: each
    /// class is a smooth *trajectory* — a bounded random walk in `c`-dim
    /// feature space sampled once per class — and each sample is that
    /// trajectory plus per-element Gaussian noise. Unlike
    /// [`ClassifyData::synth`]'s iid clusters, consecutive steps are
    /// temporally correlated, so rows genuinely read as sequences. Rows
    /// are flattened `[t][c]` (dim = `t·c`), which keeps the whole
    /// batching / eval machinery unchanged; the RNN driver re-views each
    /// row as a length-`t` sequence.
    pub fn synth_sequences(
        n: usize,
        t: usize,
        c: usize,
        classes: usize,
        spread: f32,
        rng: &mut Rng,
    ) -> ClassifyData {
        assert!(t >= 1 && c >= 1 && classes >= 1);
        let mut trajectories: Vec<Vec<f32>> = Vec::with_capacity(classes);
        for _ in 0..classes {
            let mut traj = Vec::with_capacity(t * c);
            let mut cur = rng.vec_f32(c, -1.0, 1.0);
            for _ in 0..t {
                traj.extend_from_slice(&cur);
                for v in cur.iter_mut() {
                    *v = (*v + 0.4 * rng.normal() as f32).clamp(-1.5, 1.5);
                }
            }
            trajectories.push(traj);
        }
        let dim = t * c;
        let mut x = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(classes);
            labels.push(cls as i32);
            for d in 0..dim {
                x.push(trajectories[cls][d] + spread * rng.normal() as f32);
            }
        }
        ClassifyData { dim, classes, x, labels }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Batch `i` of size `batch` with **no wraparound**, for evaluation.
    /// Returns `(x, labels, valid)` where `valid` is how many of the
    /// `batch` rows are real samples (0 when `i·batch` is past the end of
    /// the data). Rows past the end are padding — copies of the last
    /// sample, so the batch stays well-formed for a fixed-batch model —
    /// and must be excluded from whatever statistic the caller computes.
    pub fn batch_trimmed(&self, i: usize, batch: usize) -> (Vec<f32>, Vec<i32>, usize) {
        let n = self.len();
        if n == 0 {
            return (vec![0.0; batch * self.dim], vec![0; batch], 0);
        }
        let start = i.saturating_mul(batch);
        let valid = n.saturating_sub(start).min(batch);
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ls = Vec::with_capacity(batch);
        for j in 0..batch {
            let idx = (start + j).min(n - 1);
            xs.extend_from_slice(&self.x[idx * self.dim..(idx + 1) * self.dim]);
            ls.push(self.labels[idx]);
        }
        (xs, ls, valid)
    }

    /// Batch `i` of size `batch` (wrapping).
    pub fn batch(&self, i: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let n = self.len();
        let mut xs = Vec::with_capacity(batch * self.dim);
        let mut ls = Vec::with_capacity(batch);
        for j in 0..batch {
            let idx = (i * batch + j) % n;
            xs.extend_from_slice(&self.x[idx * self.dim..(idx + 1) * self.dim]);
            ls.push(self.labels[idx]);
        }
        (xs, ls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_lengths_in_range_and_skewed() {
        let mut rng = Rng::new(1);
        let c = SeqCorpus::synth(10_000, 20, 100, &mut rng);
        assert!(c.lengths.iter().all(|&l| (2..=100).contains(&l)));
        let mean = c.lengths.iter().sum::<usize>() as f64 / c.lengths.len() as f64;
        let median = {
            let mut v = c.lengths.clone();
            v.sort_unstable();
            v[v.len() / 2] as f64
        };
        assert!(mean > median, "log-normal is right-skewed: mean {} median {}", mean, median);
    }

    #[test]
    fn bucketing_reduces_padding_waste() {
        let mut rng = Rng::new(2);
        let c = SeqCorpus::synth(4096, 20, 100, &mut rng);
        let plain = c.partition_plain(4, 32);
        let bucketed = c.partition_bucketed(4, 32);
        let (pp, pu): (usize, usize) = plain
            .iter()
            .map(|w| SeqCorpus::padded_cost(w))
            .fold((0, 0), |(a, b), (c, d)| (a + c, b + d));
        let (bp, bu): (usize, usize) = bucketed
            .iter()
            .map(|w| SeqCorpus::padded_cost(w))
            .fold((0, 0), |(a, b), (c, d)| (a + c, b + d));
        assert_eq!(pu, bu, "same useful work");
        let plain_eff = pu as f64 / pp as f64;
        let bucket_eff = bu as f64 / bp as f64;
        assert!(
            bucket_eff > plain_eff * 1.2,
            "bucketing should cut padding substantially: {} vs {}",
            bucket_eff,
            plain_eff
        );
    }

    #[test]
    fn partitions_cover_whole_corpus() {
        let mut rng = Rng::new(3);
        let c = SeqCorpus::synth(1024, 20, 80, &mut rng);
        for part in [c.partition_plain(4, 16), c.partition_bucketed(4, 16)] {
            let total: usize = part.iter().flat_map(|w| w.iter().map(|b| b.len())).sum();
            assert_eq!(total, 1024);
        }
    }

    #[test]
    fn classify_data_is_learnable_by_centroid_rule() {
        let mut rng = Rng::new(4);
        let d = ClassifyData::synth(512, 8, 4, 0.1, &mut rng);
        assert_eq!(d.len(), 512);
        // nearest-centroid accuracy should be near-perfect at low spread:
        // estimate centroids from the data itself.
        let mut centroids = vec![vec![0.0f64; 8]; 4];
        let mut counts = vec![0usize; 4];
        for i in 0..d.len() {
            let c = d.labels[i] as usize;
            counts[c] += 1;
            for j in 0..8 {
                centroids[c][j] += d.x[i * 8 + j] as f64;
            }
        }
        for c in 0..4 {
            for j in 0..8 {
                centroids[c][j] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.len() {
            let mut best = (f64::INFINITY, 0);
            for c in 0..4 {
                let dist: f64 = (0..8)
                    .map(|j| (d.x[i * 8 + j] as f64 - centroids[c][j]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct as f64 / d.len() as f64 > 0.95, "{}/512", correct);
    }

    #[test]
    fn sequence_data_is_deterministic_separable_and_temporally_correlated() {
        let (n, t, c, classes) = (256usize, 6usize, 4usize, 3usize);
        let a = ClassifyData::synth_sequences(n, t, c, classes, 0.1, &mut Rng::new(11));
        let b = ClassifyData::synth_sequences(n, t, c, classes, 0.1, &mut Rng::new(11));
        assert_eq!(a.x, b.x, "same seed, same data");
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.dim, t * c);
        assert_eq!(a.len(), n);
        // Nearest-trajectory rule (trajectories re-estimated from the data)
        // classifies near-perfectly at low spread — the workload is
        // genuinely learnable.
        let dim = a.dim;
        let mut cents = vec![vec![0.0f64; dim]; classes];
        let mut counts = vec![0usize; classes];
        for i in 0..n {
            let cls = a.labels[i] as usize;
            counts[cls] += 1;
            for d in 0..dim {
                cents[cls][d] += a.x[i * dim + d] as f64;
            }
        }
        for cls in 0..classes {
            for d in 0..dim {
                cents[cls][d] /= counts[cls].max(1) as f64;
            }
        }
        let mut correct = 0usize;
        for i in 0..n {
            let mut best = (f64::INFINITY, 0usize);
            for cls in 0..classes {
                let dist: f64 = (0..dim)
                    .map(|d| (a.x[i * dim + d] as f64 - cents[cls][d]).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, cls);
                }
            }
            correct += usize::from(best.1 == a.labels[i] as usize);
        }
        assert!(correct as f64 / n as f64 > 0.95, "{}/{}", correct, n);
        // Temporal correlation: consecutive steps are much closer than
        // the walk's endpoints (the trajectory is smooth), i.e. the rows
        // are sequences with a step-to-step structure, not iid noise in
        // t·c dimensions (where both gaps would be equal in expectation).
        let sq_gap = |i: usize, t0: usize, t1: usize| -> f64 {
            (0..c)
                .map(|ci| {
                    let x0 = a.x[i * dim + t0 * c + ci] as f64;
                    let x1 = a.x[i * dim + t1 * c + ci] as f64;
                    (x0 - x1).powi(2)
                })
                .sum()
        };
        let step_gap: f64 = (0..n)
            .map(|i| (0..t - 1).map(|ti| sq_gap(i, ti, ti + 1)).sum::<f64>() / (t - 1) as f64)
            .sum::<f64>()
            / n as f64;
        let end_gap: f64 = (0..n).map(|i| sq_gap(i, 0, t - 1)).sum::<f64>() / n as f64;
        assert!(
            end_gap > step_gap * 1.5,
            "random-walk smoothness: end-to-end gap {} should dominate step gap {}",
            end_gap,
            step_gap
        );
    }

    #[test]
    fn batch_trimmed_pads_and_reports_valid_rows() {
        let mut rng = Rng::new(6);
        let d = ClassifyData::synth(10, 4, 2, 0.1, &mut rng);
        // Full batch: all rows valid.
        let (x, l, valid) = d.batch_trimmed(0, 4);
        assert_eq!((x.len(), l.len(), valid), (16, 4, 4));
        assert_eq!(l[0], d.labels[0]);
        // Final partial batch: 10 = 2*4 + 2 → 2 valid, padding = last sample.
        let (x, l, valid) = d.batch_trimmed(2, 4);
        assert_eq!(valid, 2);
        assert_eq!(l[0], d.labels[8]);
        assert_eq!(l[3], d.labels[9], "padding repeats the last sample");
        assert_eq!(&x[3 * 4..4 * 4], &d.x[9 * 4..10 * 4]);
        // Past the end: zero valid rows.
        let (_, _, valid) = d.batch_trimmed(3, 4);
        assert_eq!(valid, 0);
    }

    #[test]
    fn batches_wrap() {
        let mut rng = Rng::new(5);
        let d = ClassifyData::synth(10, 4, 2, 0.1, &mut rng);
        let (x, l) = d.batch(3, 4); // indices 12..16 wrap to 2..6
        assert_eq!(x.len(), 16);
        assert_eq!(l.len(), 4);
        assert_eq!(l[0], d.labels[2]);
    }
}
