//! Training drivers over the native BRGEMM primitives.
//!
//! [`MlpModel`] is a complete MLP classifier (softmax cross-entropy) whose
//! every GEMM — forward, backward and update — is a BRGEMM primitive call;
//! the layer blockings are chosen so activations flow between layers in
//! blocked form with **no inter-layer reformat** (producer `bk` = consumer
//! `bc`). The [`Model`] trait is the driver-facing surface every trainable
//! model exposes (the CNN driver in [`super::cnn`] implements the same
//! contract), so [`DataParallelTrainer`] is generic: it replicates any
//! [`Model`] across simulated workers, shards batches, combines gradients
//! with the real [`super::dist::ring_allreduce`], and tracks both measured
//! compute time and modelled communication time (Fig. 10 methodology).

use crate::coordinator::build;
use crate::coordinator::data::ClassifyData;
use crate::coordinator::dist::{ring_allreduce, NetworkModel};
use crate::modelio::{LayerKind, LayerParams};
use crate::primitives::fc::FcPrimitive;
use crate::telemetry::health::{self, Health, HeartbeatGroup};
use crate::telemetry::trace::{self, SpanEvent, SpanKind, SpanRing, TraceGroup, Tracer};
use crate::telemetry::{self, Metrics};
use crate::tensor::layout::{
    pack_act_2d, pack_weights_2d, transpose_packed_2d, unpack_act_2d, unpack_weights_2d,
};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

/// The surface a trainable classifier exposes to the coordinator's
/// drivers: plain-layout logits out, plain dlogits in, flat gradient
/// exchange for the allreduce path. Implemented by [`MlpModel`] and the
/// CNN driver ([`super::cnn::CnnModel`]); [`DataParallelTrainer`] and
/// [`eval_accuracy`] work over any implementation unchanged.
pub trait Model {
    /// Forward from a plain `[batch][d_in]` input to plain
    /// `[batch][classes]` logits (stores whatever the backward pass needs).
    fn forward(&mut self, x: &[f32]) -> Vec<f32>;
    /// Backward from plain dlogits; fills the per-layer gradients.
    fn backward(&mut self, dlogits: &[f32]);
    /// One local SGD step (forward → softmax-xent → backward → in-place
    /// parameter update); returns the mean loss. In-place, so single-model
    /// training pays no flat-gradient copy.
    fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32;
    /// Flatten all gradients (for allreduce), in deterministic layer order.
    fn grads_flat(&self) -> Vec<f32>;
    /// Apply SGD from an external (e.g. allreduced) flat gradient, in the
    /// same order as [`Model::grads_flat`].
    fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32);
    /// Softmax width (output classes).
    fn classes(&self) -> usize;
    /// The model's fixed mini-batch (rows per forward call).
    fn batch_size(&self) -> usize;
    /// Total trainable parameter count (weights + biases).
    fn param_count(&self) -> usize;
    /// Flattened parameters in [`Model::grads_flat`] order, for
    /// replica-consistency checks.
    fn params_flat(&self) -> Vec<f32>;
    /// Canonical **unblocked** parameters in deterministic layer order
    /// (the model-artifact layer order — see
    /// [`crate::modelio::Arch::layer_shapes`]). Unpacking is a pure index
    /// permutation: export → [`Model::import_weights`] round-trips to
    /// bit-identical packed parameters under any blocking.
    fn export_weights(&self) -> Vec<LayerParams>;
    /// Restore parameters from canonical layer params, re-packing them
    /// into *this* model's blocking (which need not match the blocking
    /// the params were exported under). Errors on any shape mismatch.
    fn import_weights(&mut self, layers: &[LayerParams]) -> Result<()>;
    /// The model's per-pass metric registry (fwd/bwd/upd timers, step
    /// counters) — populated only while [`crate::telemetry`] is enabled.
    /// Defaults to `None` for models that keep no registry.
    fn metrics(&self) -> Option<&Metrics> {
        None
    }
    /// Mutable access to the registry, for drivers that add their own
    /// stage timers (eval, checkpoint) to a model's breakdown.
    fn metrics_mut(&mut self) -> Option<&mut Metrics> {
        None
    }
}

/// Classification accuracy of `model` over the first
/// `min(max_batches · batch, data.len())` samples. The final batch may be
/// partial (`len % batch != 0`): it is padded up to the model's fixed
/// batch via [`ClassifyData::batch_trimmed`] and the padded rows are
/// masked out of the count — no sample is dropped, double-counted, or
/// wrapped around.
pub fn eval_accuracy<M: Model>(model: &mut M, data: &ClassifyData, max_batches: usize) -> f64 {
    let batch = model.batch_size();
    let classes = model.classes();
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..max_batches {
        let (x, labels, valid) = data.batch_trimmed(i, batch);
        if valid == 0 {
            break;
        }
        let logits = model.forward(&x);
        for (j, &lab) in labels.iter().take(valid).enumerate() {
            let row = &logits[j * classes..(j + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == lab as usize);
        }
        total += valid;
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// One FC layer's state.
struct Layer {
    prim: FcPrimitive,
    w: Vec<f32>,    // packed [Kb][Cb][bc][bk]
    b: Vec<f32>,    // [K]
    /// Forward activations (packed) kept for the backward pass.
    y: Vec<f32>,
    dz: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

/// An MLP classifier built entirely from the BRGEMM FC primitive.
pub struct MlpModel {
    pub sizes: Vec<usize>,
    pub batch: usize,
    layers: Vec<Layer>,
    x_packed: Vec<f32>,
    /// Per-pass training breakdown — only fed while telemetry is enabled.
    metrics: Metrics,
}

impl MlpModel {
    /// `sizes = [d_in, h1, ..., d_out]`; hidden layers ReLU, linear head.
    pub fn new(sizes: &[usize], batch: usize, nthreads: usize, rng: &mut Rng) -> MlpModel {
        MlpModel::new_with(sizes, batch, nthreads, false, rng)
    }

    /// Like [`MlpModel::new`], with `tuned` consulting the autotuner's
    /// persistent cache for each layer shape. Tuned blockings are then
    /// *reconciled across layers* so the no-inter-layer-reformat invariant
    /// holds: all layers share one `bn`, and each layer's input block `bc`
    /// equals its producer's output block `bk` (the shared feature
    /// dimension guarantees both are divisors of it).
    pub fn new_with(
        sizes: &[usize],
        batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> MlpModel {
        // Layer configs come from the shared construction module, so the
        // training model and the serving plans agree by construction
        // (weight lifting through artifacts depends on it).
        let cfgs = build::mlp_chain_configs(sizes, batch, nthreads, tuned);
        let layers = cfgs
            .into_iter()
            .map(|cfg| {
                let (c, k) = (cfg.c, cfg.k);
                let prim = FcPrimitive::new(cfg);
                // He init, packed directly (blocked layout is an internal
                // detail; the plain-layout view only exists transiently).
                let scale = (2.0 / c as f32).sqrt();
                let w_plain = rng.vec_f32(k * c, -scale, scale);
                let w = crate::tensor::layout::pack_weights_2d(&w_plain, k, c, cfg.bk, cfg.bc);
                Layer {
                    prim,
                    w,
                    b: vec![0.0; k],
                    y: vec![0.0; batch * k],
                    dz: vec![0.0; batch * k],
                    dw: vec![0.0; k * c],
                    db: vec![0.0; k],
                }
            })
            .collect();
        MlpModel {
            sizes: sizes.to_vec(),
            batch,
            layers,
            x_packed: vec![0.0; batch * sizes[0]],
            metrics: Metrics::new(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward pass from a plain `[batch][d_in]` input; returns plain
    /// logits `[batch][d_out]`.
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let cfg0 = self.layers[0].prim.cfg;
        self.x_packed = pack_act_2d(x, self.batch, cfg0.c, cfg0.bn, cfg0.bc);
        for i in 0..self.layers.len() {
            // Split so we can read layer i-1's output while writing layer i.
            let (before, rest) = self.layers.split_at_mut(i);
            let l = &mut rest[0];
            let input: &[f32] = if i == 0 { &self.x_packed } else { &before[i - 1].y };
            l.prim.forward(input, &l.w, &l.b, &mut l.y);
        }
        let last = self.layers.last().unwrap();
        let cfg = last.prim.cfg;
        unpack_act_2d(&last.y, self.batch, cfg.k, cfg.bn, cfg.bk)
    }

    /// One SGD step; returns the mean cross-entropy loss. While telemetry
    /// is enabled, the per-pass breakdown (fwd / bwd incl. the loss / upd)
    /// lands in [`Model::metrics`]; disabled, the step pays one branch.
    pub fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        if !telemetry::enabled() {
            let logits = self.forward(x);
            let (loss, dlogits) = softmax_xent(&logits, labels, self.sizes[self.sizes.len() - 1]);
            self.backward(&dlogits);
            self.apply_sgd(lr);
            return loss;
        }
        let t0 = Instant::now();
        let logits = self.forward(x);
        let t1 = Instant::now();
        let (loss, dlogits) = softmax_xent(&logits, labels, self.sizes[self.sizes.len() - 1]);
        self.backward(&dlogits);
        let t2 = Instant::now();
        self.apply_sgd(lr);
        self.metrics.observe_secs("fwd", (t1 - t0).as_secs_f64());
        self.metrics.observe_secs("bwd", (t2 - t1).as_secs_f64());
        self.metrics.observe_secs("upd", t2.elapsed().as_secs_f64());
        self.metrics.inc("steps", 1);
        loss
    }

    /// Backward from plain dlogits; fills each layer's dw/db.
    pub fn backward(&mut self, dlogits: &[f32]) {
        let n_layers = self.layers.len();
        // Top layer dz = dlogits (linear head), packed.
        {
            let l = self.layers.last_mut().unwrap();
            let cfg = l.prim.cfg;
            l.dz = pack_act_2d(dlogits, self.batch, cfg.k, cfg.bn, cfg.bk);
        }
        for i in (0..n_layers).rev() {
            // Weight/bias gradients for layer i.
            let (before, rest) = self.layers.split_at_mut(i);
            let l = &mut rest[0];
            let input_owned;
            let input: &[f32] = if i == 0 {
                &self.x_packed
            } else {
                input_owned = std::mem::take(&mut before[i - 1].y);
                before[i - 1].y = input_owned; // keep ownership, borrow below
                &before[i - 1].y
            };
            l.prim.update(input, &l.dz, &mut l.dw, &mut l.db);
            if i > 0 {
                // Propagate: dx (pre-act of layer below's output space).
                let cfg = l.prim.cfg;
                let wt = transpose_packed_2d(&l.w, cfg.k, cfg.c, cfg.bk, cfg.bc);
                let mut dx = vec![0.0f32; self.batch * cfg.c];
                l.prim.backward_data(&l.dz, &wt, &mut dx);
                // Chain through the lower layer's activation.
                let low = &mut before[i - 1];
                low.prim.dz_from_dy(&dx, &low.y, &mut low.dz);
            }
        }
    }

    fn apply_sgd(&mut self, lr: f32) {
        for l in &mut self.layers {
            for (w, g) in l.w.iter_mut().zip(&l.dw) {
                *w -= lr * g;
            }
            for (b, g) in l.b.iter_mut().zip(&l.db) {
                *b -= lr * g;
            }
        }
    }

    /// Flatten all gradients (for allreduce), in deterministic layer order.
    pub fn grads_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.extend_from_slice(&l.dw);
            out.extend_from_slice(&l.db);
        }
        out
    }

    /// Apply SGD from an external (e.g. allreduced) flat gradient.
    pub fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32) {
        let mut off = 0;
        for l in &mut self.layers {
            for (w, g) in l.w.iter_mut().zip(&flat[off..off + l.dw.len()]) {
                *w -= lr * g;
            }
            off += l.dw.len();
            for (b, g) in l.b.iter_mut().zip(&flat[off..off + l.db.len()]) {
                *b -= lr * g;
            }
            off += l.db.len();
        }
    }

    /// Classification accuracy on plain data (partial final batches are
    /// padded and masked — see [`eval_accuracy`]).
    pub fn accuracy(&mut self, data: &ClassifyData, max_batches: usize) -> f64 {
        eval_accuracy(self, data, max_batches)
    }
}

impl Model for MlpModel {
    fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        MlpModel::forward(self, x)
    }
    fn backward(&mut self, dlogits: &[f32]) {
        MlpModel::backward(self, dlogits)
    }
    fn train_step(&mut self, x: &[f32], labels: &[i32], lr: f32) -> f32 {
        MlpModel::train_step(self, x, labels, lr)
    }
    fn grads_flat(&self) -> Vec<f32> {
        MlpModel::grads_flat(self)
    }
    fn apply_sgd_from_flat(&mut self, flat: &[f32], lr: f32) {
        MlpModel::apply_sgd_from_flat(self, flat, lr)
    }
    fn classes(&self) -> usize {
        *self.sizes.last().unwrap()
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn param_count(&self) -> usize {
        MlpModel::param_count(self)
    }
    fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.extend_from_slice(&l.w);
            out.extend_from_slice(&l.b);
        }
        out
    }
    fn export_weights(&self) -> Vec<LayerParams> {
        self.layers
            .iter()
            .map(|l| {
                let cfg = l.prim.cfg;
                LayerParams::fc(
                    cfg.k,
                    cfg.c,
                    unpack_weights_2d(&l.w, cfg.k, cfg.c, cfg.bk, cfg.bc),
                    l.b.clone(),
                )
            })
            .collect()
    }
    fn import_weights(&mut self, layers: &[LayerParams]) -> Result<()> {
        if layers.len() != self.layers.len() {
            bail!("mlp has {} layers, artifact has {}", self.layers.len(), layers.len());
        }
        for (i, (l, p)) in self.layers.iter_mut().zip(layers).enumerate() {
            let cfg = l.prim.cfg;
            p.expect(&format!("mlp layer {}", i), LayerKind::Fc, &[cfg.k, cfg.c])?;
            l.w = pack_weights_2d(&p.w, cfg.k, cfg.c, cfg.bk, cfg.bc);
            l.b = p.b.clone();
        }
        Ok(())
    }
    fn metrics(&self) -> Option<&Metrics> {
        Some(&self.metrics)
    }
    fn metrics_mut(&mut self) -> Option<&mut Metrics> {
        Some(&mut self.metrics)
    }
}

/// Mean softmax cross-entropy and its logits-gradient.
pub fn softmax_xent(logits: &[f32], labels: &[i32], classes: usize) -> (f32, Vec<f32>) {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes);
    let mut dlogits = vec![0.0f32; logits.len()];
    let mut loss = 0.0f64;
    for i in 0..n {
        let row = &logits[i * classes..(i + 1) * classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let sum: f32 = row.iter().map(|v| (v - max).exp()).sum();
        let log_z = max + sum.ln();
        let lab = labels[i] as usize;
        loss += (log_z - row[lab]) as f64;
        for c in 0..classes {
            let p = (row[c] - log_z).exp();
            dlogits[i * classes + c] = (p - f32::from(c == lab)) / n as f32;
        }
    }
    (loss as f32 / n as f32, dlogits)
}

/// Per-step record from the data-parallel trainer.
#[derive(Debug, Clone, Copy)]
pub struct DistStep {
    pub loss: f32,
    /// Max measured per-worker compute seconds (the synchronous step's
    /// critical path).
    pub compute_secs: f64,
    /// Modelled allreduce seconds for this gradient size and worker count.
    pub comm_secs: f64,
}

/// Synchronous data-parallel training over simulated workers. Generic
/// over the [`Model`] surface, so the MLP and CNN drivers (and any future
/// model) share one trainer and one ring-allreduce path.
pub struct DataParallelTrainer<M: Model = MlpModel> {
    pub workers: Vec<M>,
    pub net: NetworkModel,
    pub lr: f32,
    /// The trainer's own stage timers (allreduce, apply) — fed only while
    /// telemetry is enabled; see [`DataParallelTrainer::merged_metrics`].
    pub metrics: Metrics,
    /// Span-tracer handle, captured lazily on the first traced step (a
    /// fresh ring registration per step would leak rings). `None` until
    /// tracing is opted in via [`DataParallelTrainer::trace_steps`] *and*
    /// a tracer is installed; steps stay single-branch when tracing is off.
    trace: Option<(std::sync::Arc<Tracer>, std::sync::Arc<SpanRing>)>,
    /// Opt-in flag mirroring `ServeOpts::trace`: a trainer that was not
    /// asked to trace never writes into a tracer some other component
    /// installed. The CLI sets it alongside `--trace-out`.
    trace_opt_in: bool,
    /// Health-monitor handle, captured lazily on the first monitored step
    /// (same pattern as `trace`): the installed monitor plus this
    /// trainer's "train" heartbeat group, one counter per worker, bumped
    /// per step. `None` until opted in via
    /// [`DataParallelTrainer::monitor_health`] *and* a monitor is
    /// installed.
    hb: Option<(std::sync::Arc<Health>, std::sync::Arc<HeartbeatGroup>)>,
    /// Opt-in flag mirroring `trace_opt_in` for the health plane.
    health_opt_in: bool,
}

impl DataParallelTrainer<MlpModel> {
    /// All replicas start from identical parameters (same seed).
    pub fn new(
        sizes: &[usize],
        local_batch: usize,
        workers: usize,
        nthreads: usize,
        lr: f32,
        seed: u64,
    ) -> DataParallelTrainer<MlpModel> {
        DataParallelTrainer::new_with(sizes, local_batch, workers, nthreads, lr, seed, false)
    }

    /// Like [`DataParallelTrainer::new`], with `tuned` replicas built
    /// through the autotuner's cached blockings (every replica applies the
    /// same cache entries, so bit-identical synchronous SGD is preserved).
    #[allow(clippy::too_many_arguments)]
    pub fn new_with(
        sizes: &[usize],
        local_batch: usize,
        workers: usize,
        nthreads: usize,
        lr: f32,
        seed: u64,
        tuned: bool,
    ) -> DataParallelTrainer<MlpModel> {
        let models = (0..workers)
            .map(|_| {
                let mut rng = Rng::new(seed); // identical init across ranks
                MlpModel::new_with(sizes, local_batch, nthreads, tuned, &mut rng)
            })
            .collect();
        DataParallelTrainer::from_workers(models, lr)
    }
}

impl<M: Model> DataParallelTrainer<M> {
    /// Wrap pre-built replicas. Every replica must start from identical
    /// parameters (checked), or synchronous SGD silently diverges.
    pub fn from_workers(workers: Vec<M>, lr: f32) -> DataParallelTrainer<M> {
        assert!(!workers.is_empty(), "need at least one worker");
        let dp = DataParallelTrainer {
            workers,
            net: NetworkModel::omnipath(),
            lr,
            metrics: Metrics::new(),
            trace: None,
            trace_opt_in: false,
            hb: None,
            health_opt_in: false,
        };
        assert!(dp.replicas_consistent(), "replicas must start from identical parameters");
        dp
    }

    /// One synchronous step: worker `w` trains on `shards[w]`; gradients
    /// are ring-allreduced and every replica applies the mean gradient.
    pub fn step(&mut self, shards: &[(Vec<f32>, Vec<i32>)]) -> DistStep {
        let p = self.workers.len();
        assert_eq!(shards.len(), p);
        // Capture the installed tracer once per trainer; every step after
        // that pays one branch here when tracing is off.
        if self.trace_opt_in && trace::enabled() && self.trace.is_none() {
            self.trace = trace::current().map(|t| {
                let ring = t.ring();
                (t, ring)
            });
        }
        // Same lazy capture for the health monitor: the "train" heartbeat
        // group registers once, on the first monitored step.
        if self.health_opt_in && health::enabled() && self.hb.is_none() {
            self.hb = health::current().map(|h| {
                let g = h.register("train", p);
                (h, g)
            });
        }
        // Step ids advance on every step while a tracer is live, so 1-in-N
        // sampling picks a deterministic subsequence of steps.
        let mut group: Option<(u64, TraceGroup, Instant)> = match &self.trace {
            Some((t, _)) if trace::enabled() => {
                let sid = t.next_step_id();
                t.sampled(sid).then(|| (sid, TraceGroup::new(0), Instant::now()))
            }
            _ => None,
        };
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(p);
        let mut losses = Vec::with_capacity(p);
        let mut compute = 0.0f64;
        let mut compute_sum = 0.0f64;
        for (wi, (w, (x, labels))) in self.workers.iter_mut().zip(shards).enumerate() {
            let t0 = Instant::now();
            let logits = w.forward(x);
            let t1 = telemetry::enabled().then(Instant::now);
            let tf = group.as_ref().map(|_| Instant::now());
            let (loss, dlogits) = softmax_xent(&logits, labels, w.classes());
            w.backward(&dlogits);
            let tb = group.as_ref().map(|_| Instant::now());
            let worker_secs = t0.elapsed().as_secs_f64();
            compute = compute.max(worker_secs);
            compute_sum += worker_secs;
            if let Some((_, g)) = &self.hb {
                g.beat(wi);
            }
            if let Some(t1) = t1 {
                let bwd = t1.elapsed().as_secs_f64();
                if let Some(m) = w.metrics_mut() {
                    m.observe_secs("fwd", (t1 - t0).as_secs_f64());
                    m.observe_secs("bwd", bwd);
                }
            }
            if let (Some((sid, g, _)), Some(tf), Some(tb)) = (group.as_mut(), tf, tb) {
                let tr = &self.trace.as_ref().unwrap().0;
                let (fs, fd) = tr.span_us(t0, tf);
                g.push(SpanEvent {
                    kind: SpanKind::Fwd,
                    label: "",
                    trace_id: *sid,
                    tid: wi as u32,
                    start_us: fs,
                    dur_us: fd,
                    a: wi as u32,
                    b: 0,
                });
                let (bs, bd) = tr.span_us(tf, tb);
                g.push(SpanEvent {
                    kind: SpanKind::BwdData,
                    label: "",
                    trace_id: *sid,
                    tid: wi as u32,
                    start_us: bs,
                    dur_us: bd,
                    a: wi as u32,
                    b: 0,
                });
            }
            losses.push(loss);
            grads.push(w.grads_flat());
        }
        let grad_bytes = grads[0].len() * 4;
        let t_ar = telemetry::enabled().then(Instant::now);
        let tar0 = group.as_ref().map(|_| Instant::now());
        ring_allreduce(&mut grads);
        if let Some(t) = t_ar {
            self.metrics.observe_secs("allreduce", t.elapsed().as_secs_f64());
        }
        let t_up = telemetry::enabled().then(Instant::now);
        let tup0 = group.as_ref().map(|_| Instant::now());
        let scale = 1.0 / p as f32;
        for (w, g) in self.workers.iter_mut().zip(&grads) {
            let mean: Vec<f32> = g.iter().map(|v| v * scale).collect();
            w.apply_sgd_from_flat(&mean, self.lr);
        }
        if let Some(t) = t_up {
            self.metrics.observe_secs("upd", t.elapsed().as_secs_f64());
            self.metrics.inc("steps", 1);
            // Straggler accounting, per step: the slowest replica's
            // compute vs the mean across replicas. Their ratio (averaged
            // over the epoch) is the straggler index the `--metrics-out`
            // JSON reports.
            self.metrics.observe_secs("worker_step_max", compute);
            self.metrics.observe_secs("worker_step_mean", compute_sum / p as f64);
        }
        if let Some((sid, mut g, t_step0)) = group.take() {
            let (tr, ring) = self.trace.as_ref().unwrap();
            let tend = Instant::now();
            let (tar0, tup0) = (tar0.unwrap(), tup0.unwrap());
            // The worker-pool region: every replica's fwd+bwd, serialized
            // here, one simulated-rank lane each in the export.
            let (ps, pd) = tr.span_us(t_step0, tar0);
            g.push(SpanEvent {
                kind: SpanKind::Pool,
                label: "",
                trace_id: sid,
                tid: p as u32,
                start_us: ps,
                dur_us: pd,
                a: p as u32,
                b: 0,
            });
            let (ars, ard) = tr.span_us(tar0, tup0);
            g.push(SpanEvent {
                kind: SpanKind::Allreduce,
                label: "",
                trace_id: sid,
                tid: p as u32,
                start_us: ars,
                dur_us: ard,
                a: grad_bytes.min(u32::MAX as usize) as u32,
                b: p as u32,
            });
            let (us, ud) = tr.span_us(tup0, tend);
            g.push(SpanEvent {
                kind: SpanKind::Upd,
                label: "",
                trace_id: sid,
                tid: p as u32,
                start_us: us,
                dur_us: ud,
                a: p as u32,
                b: 0,
            });
            let (ss, sd) = tr.span_us(t_step0, tend);
            g.push(SpanEvent {
                kind: SpanKind::Step,
                label: "",
                trace_id: sid,
                tid: p as u32,
                start_us: ss,
                dur_us: sd,
                a: p as u32,
                b: 0,
            });
            ring.push(g);
        }
        DistStep {
            loss: losses.iter().sum::<f32>() / p as f32,
            compute_secs: compute,
            comm_secs: self.net.ring_allreduce_secs(grad_bytes, p),
        }
    }

    /// Opt this trainer into recording per-step spans when a tracer is
    /// installed (`--trace-out` sets it). Off by default so an untraced
    /// run never touches the global tracer.
    pub fn trace_steps(&mut self, on: bool) {
        self.trace_opt_in = on;
        if !on {
            self.trace = None;
        }
    }

    /// Opt this trainer into the health plane: when a monitor is
    /// installed, every worker beats a "train" heartbeat once per step,
    /// so a replica that wedges mid-epoch degrades the health state with
    /// its index in the reason. Off by default, like [`Self::trace_steps`].
    pub fn monitor_health(&mut self, on: bool) {
        self.health_opt_in = on;
        if !on {
            self.retire_health();
        }
    }

    /// Take this trainer's workers out of stall detection (training is
    /// ending on purpose). Idempotent.
    pub fn retire_health(&mut self) {
        if let Some((_, g)) = self.hb.take() {
            g.retire();
        }
    }

    /// Epoch straggler index: mean over steps of (slowest replica compute
    /// / mean replica compute). 1.0 = perfectly balanced; grows as one
    /// replica lags the pack. `None` until a telemetry-enabled step ran.
    pub fn straggler_index(&self) -> Option<f64> {
        let max = self.metrics.timer_mean("worker_step_max")?;
        let mean = self.metrics.timer_mean("worker_step_mean")?;
        (mean > 0.0).then(|| max / mean)
    }

    /// Share of step time spent waiting in the allreduce, averaged over
    /// the epoch: allreduce / (slowest compute + allreduce + update).
    /// `None` until a telemetry-enabled step ran.
    pub fn allreduce_share(&self) -> Option<f64> {
        let ar = self.metrics.timer_mean("allreduce")?;
        let comp = self.metrics.timer_mean("worker_step_max")?;
        let upd = self.metrics.timer_mean("upd").unwrap_or(0.0);
        let total = comp + ar + upd;
        (total > 0.0).then(|| ar / total)
    }

    /// The trainer's registry merged with every worker's, via the exact
    /// parallel-Welford merge — per-worker fwd/bwd timer moments combine
    /// as if one registry had observed every sample.
    pub fn merged_metrics(&self) -> Metrics {
        let mut out = self.metrics.clone();
        for w in &self.workers {
            if let Some(m) = w.metrics() {
                out.merge(m);
            }
        }
        out
    }

    /// Replicas must stay bit-identical under synchronous SGD; used as a
    /// consistency check by tests and the e2e drivers.
    pub fn replicas_consistent(&self) -> bool {
        let r0 = self.workers[0].params_flat();
        self.workers.iter().skip(1).all(|w| w.params_flat() == r0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::eltwise::Act;

    #[test]
    fn softmax_xent_matches_hand_computation() {
        // two samples, two classes, logits [0, ln3] → p = [0.25, 0.75]
        let l3 = 3.0f32.ln();
        let logits = vec![0.0, l3, 0.0, l3];
        let labels = vec![1, 0];
        let (loss, d) = softmax_xent(&logits, &labels, 2);
        let want = (-(0.75f32.ln()) - (0.25f32.ln())) / 2.0;
        assert!((loss - want).abs() < 1e-6);
        // dlogits = (p - onehot)/n
        assert!((d[0] - 0.25 / 2.0).abs() < 1e-6);
        assert!((d[1] - (0.75 - 1.0) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn mlp_learns_separable_data() {
        let mut rng = Rng::new(11);
        let data = ClassifyData::synth(256, 16, 4, 0.15, &mut rng);
        let mut model = MlpModel::new(&[16, 32, 4], 32, 1, &mut rng);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..60 {
            let (x, labels) = data.batch(step, 32);
            last = model.train_step(&x, &labels, 0.1);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap() * 0.5, "loss {} -> {}", first.unwrap(), last);
        let acc = model.accuracy(&data, 8);
        assert!(acc > 0.9, "accuracy {}", acc);
    }

    #[test]
    fn accuracy_handles_partial_final_batch() {
        // 36 % 8 = 4: the old wrapping evaluation re-counted the first 4
        // samples; pad-and-mask must count each of the 36 exactly once.
        let mut rng = Rng::new(23);
        let data = ClassifyData::synth(36, 8, 3, 0.15, &mut rng);
        // Same init seed ⇒ identical weights regardless of model batch, so
        // the batch-1 model is a per-sample oracle for the batch-8 model.
        let mut m8 = MlpModel::new(&[8, 16, 3], 8, 1, &mut Rng::new(7));
        let mut m1 = MlpModel::new(&[8, 16, 3], 1, 1, &mut Rng::new(7));
        let mut correct = 0usize;
        for i in 0..data.len() {
            let (x, l) = data.batch(i, 1);
            let logits = m1.forward(&x);
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == l[0] as usize);
        }
        let want = correct as f64 / data.len() as f64;
        // 5 batches of 8 cover the 36 samples only via a partial final batch.
        let got = m8.accuracy(&data, 5);
        assert!((got - want).abs() < 1e-9, "partial batch: {} vs {}", got, want);
        // More batches than data must not wrap around and change the answer.
        let again = m8.accuracy(&data, 100);
        assert!((again - got).abs() < 1e-9, "no wraparound: {} vs {}", again, got);
    }

    #[test]
    fn mlp_gradients_match_finite_difference() {
        let mut rng = Rng::new(13);
        let mut model = MlpModel::new(&[6, 8, 3], 4, 1, &mut rng);
        let x = rng.vec_f32(4 * 6, -1.0, 1.0);
        let labels = vec![0, 2, 1, 1];
        let logits = model.forward(&x);
        let (_, dlogits) = softmax_xent(&logits, &labels, 3);
        model.backward(&dlogits);
        let dw0 = model.layers[0].dw.clone();
        let eps = 1e-3;
        for idx in [0usize, 5, 17, 40] {
            let orig = model.layers[0].w[idx];
            model.layers[0].w[idx] = orig + eps;
            let lp = {
                let l = model.forward(&x);
                softmax_xent(&l, &labels, 3).0
            };
            model.layers[0].w[idx] = orig - eps;
            let lm = {
                let l = model.forward(&x);
                softmax_xent(&l, &labels, 3).0
            };
            model.layers[0].w[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - dw0[idx]).abs() < 1e-2,
                "dw[{}]: {} vs {}",
                idx, num, dw0[idx]
            );
        }
    }

    #[test]
    fn tuned_model_matches_untuned_math() {
        use crate::autotune::{cache, Candidate, TuneEntry, TuningCache};
        use crate::primitives::fc::FcConfig;
        // Unique layer shapes so no other test's cache entries collide.
        let sizes = [22usize, 33, 11];
        let batch = 8;
        // Cache a non-default blocking for the first layer.
        let cfg0 = FcConfig::new(batch, 22, 33, Act::Relu);
        let cand = Candidate {
            bn: 4,
            bc: 11,
            bk: 11,
            bq: 1,
            flat_bq: 0,
            order: None,
            fwd_strided: true,
            upd_transpose: false,
        };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&cache::fc_key(&cfg0), TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });

        let x = Rng::new(55).vec_f32(batch * sizes[0], -1.0, 1.0);
        let mut plain = MlpModel::new(&sizes, batch, 1, &mut Rng::new(91));
        let mut tuned = MlpModel::new_with(&sizes, batch, 1, true, &mut Rng::new(91));
        // The tuned path must apply the cached blocking (reconciled bn)...
        assert_eq!(tuned.layers[0].prim.cfg.bc, 11);
        assert!(tuned.layers[0].prim.cfg.fwd_strided);
        // ...and the chain invariant bk(i) == bc(i+1) must hold.
        assert_eq!(tuned.layers[0].prim.cfg.bk, tuned.layers[1].prim.cfg.bc);
        assert_eq!(tuned.layers[0].prim.cfg.bn, tuned.layers[1].prim.cfg.bn);
        // Blocking is a layout choice, not a math choice: same forward.
        let yp = plain.forward(&x);
        let yt = tuned.forward(&x);
        for i in 0..yp.len() {
            assert!((yp[i] - yt[i]).abs() < 1e-4, "[{}]: {} vs {}", i, yp[i], yt[i]);
        }
    }

    #[test]
    fn data_parallel_matches_single_worker_math() {
        // 2 workers on shards A,B with allreduced mean gradient must equal
        // 1 worker on A∪B (same total batch, same init).
        let mut rng = Rng::new(17);
        let data = ClassifyData::synth(128, 8, 2, 0.2, &mut rng);
        let mut dp = DataParallelTrainer::new(&[8, 16, 2], 16, 2, 1, 0.1, 99);
        let (x0, l0) = data.batch(0, 16);
        let (x1, l1) = data.batch(1, 16);
        dp.step(&[(x0.clone(), l0.clone()), (x1.clone(), l1.clone())]);
        assert!(dp.replicas_consistent());

        let mut single = {
            let mut rng = Rng::new(99);
            MlpModel::new(&[8, 16, 2], 32, 1, &mut rng)
        };
        let mut x = x0;
        x.extend(x1);
        let mut l = l0;
        l.extend(l1);
        single.train_step(&x, &l, 0.1);
        // Compare first-layer weights.
        let a = &dp.workers[0].layers[0].w;
        let b = &single.layers[0].w;
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-5, "w[{}]: {} vs {}", i, a[i], b[i]);
        }
    }

    #[test]
    fn export_import_roundtrip_bit_identical_across_blockings() {
        // Train a few steps so the weights are non-trivial, export the
        // canonical params, import into a model built with a *different*
        // batch (hence different bn) and thread count: packed params and
        // forward outputs must be bit-identical — blocking is a layout
        // choice the artifact does not bake in.
        let mut rng = Rng::new(31);
        let data = ClassifyData::synth(128, 12, 3, 0.2, &mut rng);
        let mut src = MlpModel::new(&[12, 130, 3], 8, 1, &mut rng);
        for step in 0..10 {
            let (x, l) = data.batch(step, 8);
            src.train_step(&x, &l, 0.1);
        }
        let exported = src.export_weights();
        // Different batch (bn 4 vs 8) and thread count.
        let mut dst = MlpModel::new(&[12, 130, 3], 4, 2, &mut Rng::new(999));
        dst.import_weights(&exported).unwrap();
        // Round-trip equality in canonical space is bitwise.
        let back = dst.export_weights();
        assert_eq!(exported, back, "export -> import -> export must be bitwise identical");
        // And the forward math agrees bit-for-bit row by row.
        let x = Rng::new(5).vec_f32(4 * 12, -1.0, 1.0);
        let y4 = dst.forward(&x);
        let mut x8 = x.clone();
        x8.extend(Rng::new(6).vec_f32(4 * 12, -1.0, 1.0));
        let y8 = src.forward(&x8);
        assert_eq!(&y8[..y4.len()], &y4[..], "same rows, same logits, any blocking");
    }

    #[test]
    fn import_rejects_shape_mismatch() {
        let mut rng = Rng::new(33);
        let src = MlpModel::new(&[6, 8, 3], 4, 1, &mut rng);
        let mut dst = MlpModel::new(&[6, 10, 3], 4, 1, &mut rng);
        let err = dst.import_weights(&src.export_weights()).unwrap_err();
        assert!(err.to_string().contains("expects fc"), "{}", err);
        let mut dst = MlpModel::new(&[6, 8, 3, 3], 4, 1, &mut rng);
        assert!(dst.import_weights(&src.export_weights()).is_err(), "layer count");
    }

    #[test]
    fn resume_equals_uninterrupted_training() {
        // K steps + export + import into a fresh model + K more steps must
        // land on exactly the parameters of 2K uninterrupted steps: the
        // artifact round-trip is bitwise and the data schedule is a pure
        // function of the step index.
        let spe = 8usize; // "steps per epoch"
        let mut rng = Rng::new(41);
        let data = ClassifyData::synth(64, 10, 3, 0.2, &mut rng);
        let sizes = [10usize, 16, 3];

        let mut full = MlpModel::new(&sizes, 8, 1, &mut Rng::new(77));
        for step in 0..2 * spe {
            let (x, l) = data.batch(step, 8);
            full.train_step(&x, &l, 0.1);
        }

        let mut half = MlpModel::new(&sizes, 8, 1, &mut Rng::new(77));
        for step in 0..spe {
            let (x, l) = data.batch(step, 8);
            half.train_step(&x, &l, 0.1);
        }
        let snapshot = half.export_weights();
        drop(half); // the "interrupted" process is gone
        let mut resumed = MlpModel::new(&sizes, 8, 1, &mut Rng::new(123)); // any init
        resumed.import_weights(&snapshot).unwrap();
        for step in spe..2 * spe {
            let (x, l) = data.batch(step, 8);
            resumed.train_step(&x, &l, 0.1);
        }
        assert_eq!(
            full.params_flat(),
            resumed.params_flat(),
            "resumed training must be bit-identical to the uninterrupted run"
        );
    }

    #[test]
    fn instrumented_training_is_bit_identical() {
        // The whole point of the gated instrumentation: enabling the
        // profiler AND the span tracer AND the health monitor must change
        // timing side channels only. Same seed, same data, same steps —
        // the final parameters must match bitwise with and without them.
        let _g = telemetry::test_lock();
        let run = |instrument: bool| {
            if instrument {
                telemetry::install();
                trace::install(1, 64);
                health::install(crate::telemetry::health::HealthThresholds::default());
            } else {
                telemetry::uninstall();
                trace::uninstall();
                health::uninstall();
            }
            let mut rng = Rng::new(7);
            let data = ClassifyData::synth(64, 8, 3, 0.2, &mut rng);
            let mut m = MlpModel::new(&[8, 16, 3], 8, 1, &mut Rng::new(42));
            for step in 0..6 {
                let (x, l) = data.batch(step, 8);
                m.train_step(&x, &l, 0.1);
            }
            // The data-parallel path is where per-step trace spans land.
            let mut dp = DataParallelTrainer::new(&[8, 16, 3], 8, 2, 1, 0.05, 21);
            dp.trace_steps(instrument);
            dp.monitor_health(instrument);
            let shards: Vec<_> = (0..2).map(|i| data.batch(i, 8)).collect();
            for _ in 0..4 {
                dp.step(&shards);
            }
            if instrument {
                // Every worker beat once per step.
                let snap = health::current().unwrap().evaluate();
                let train = snap.groups.iter().find(|g| g.name == "train").unwrap();
                assert_eq!(train.beats, vec![4, 4]);
                let drained = trace::current().unwrap().drain();
                assert!(
                    drained.groups.iter().any(|g| g.find(SpanKind::Step).is_some()),
                    "traced steps must land Step spans"
                );
                assert!(
                    drained.groups.iter().any(|g| g.find(SpanKind::Fwd).is_some()
                        && g.find(SpanKind::BwdData).is_some()
                        && g.find(SpanKind::Allreduce).is_some()
                        && g.find(SpanKind::Upd).is_some()),
                    "per-worker pass spans must land too"
                );
            }
            telemetry::uninstall();
            trace::uninstall();
            health::uninstall();
            let mut out = m.params_flat();
            out.extend(dp.workers[0].params_flat());
            out
        };
        assert_eq!(run(true), run(false), "instrumentation must not change the math");
    }

    #[test]
    fn straggler_index_and_allreduce_share_are_gated_and_sane() {
        let _g = telemetry::test_lock();
        let mut rng = Rng::new(23);
        let data = ClassifyData::synth(64, 8, 2, 0.2, &mut rng);
        let shards: Vec<_> = (0..2).map(|i| data.batch(i, 8)).collect();
        // Disabled: no straggler timers land, both derivations are None.
        telemetry::uninstall();
        let mut dp = DataParallelTrainer::new(&[8, 8, 2], 8, 2, 1, 0.05, 1);
        dp.step(&shards);
        assert!(dp.straggler_index().is_none());
        assert!(dp.allreduce_share().is_none());
        // Enabled: the index is >= 1 by construction (max >= mean) and
        // the allreduce share is a proper fraction.
        telemetry::install();
        let mut dp = DataParallelTrainer::new(&[8, 8, 2], 8, 2, 1, 0.05, 1);
        for _ in 0..3 {
            dp.step(&shards);
        }
        let si = dp.straggler_index().unwrap();
        assert!(si >= 1.0, "straggler index {} must be >= 1", si);
        let share = dp.allreduce_share().unwrap();
        assert!((0.0..=1.0).contains(&share), "allreduce share {} in [0,1]", share);
        // The merged view carries the raw timers for --metrics-out.
        let merged = dp.merged_metrics();
        assert!(merged.timer_mean("worker_step_max").is_some());
        assert!(merged.timer_mean("worker_step_mean").is_some());
        telemetry::uninstall();
    }

    #[test]
    fn train_step_breakdown_is_gated_and_recorded() {
        let _g = telemetry::test_lock();
        let mut rng = Rng::new(3);
        let data = ClassifyData::synth(32, 8, 2, 0.2, &mut rng);
        // Disabled: no timers land.
        telemetry::uninstall();
        let mut m = MlpModel::new(&[8, 8, 2], 8, 1, &mut Rng::new(1));
        let (x, l) = data.batch(0, 8);
        m.train_step(&x, &l, 0.1);
        assert_eq!(Model::metrics(&m).unwrap().counter("steps"), 0);
        // Enabled: fwd/bwd/upd timers and the step counter land.
        telemetry::install();
        let mut m = MlpModel::new(&[8, 8, 2], 8, 1, &mut Rng::new(1));
        for step in 0..3 {
            let (x, l) = data.batch(step, 8);
            m.train_step(&x, &l, 0.1);
        }
        let metrics = Model::metrics(&m).unwrap();
        assert_eq!(metrics.counter("steps"), 3);
        for pass in ["fwd", "bwd", "upd"] {
            assert!(metrics.timer_mean(pass).unwrap() >= 0.0, "{} timer present", pass);
        }
        telemetry::uninstall();
    }

    #[test]
    fn data_parallel_merges_worker_breakdowns() {
        let _g = telemetry::test_lock();
        telemetry::install();
        let mut rng = Rng::new(19);
        let data = ClassifyData::synth(64, 8, 2, 0.2, &mut rng);
        let mut dp = DataParallelTrainer::new(&[8, 8, 2], 8, 2, 1, 0.05, 1);
        let shards: Vec<_> = (0..2).map(|i| data.batch(i, 8)).collect();
        dp.step(&shards);
        dp.step(&shards);
        let merged = dp.merged_metrics();
        assert_eq!(merged.counter("steps"), 2);
        // 2 workers x 2 steps = 4 fwd samples in the merged view.
        assert!((merged.to_json().get("timers").unwrap().get("fwd").unwrap())
            .get("n")
            .unwrap()
            .as_f64()
            == Some(4.0));
        assert!(merged.timer_mean("allreduce").is_some());
        telemetry::uninstall();
    }

    #[test]
    fn dist_step_reports_costs() {
        let mut rng = Rng::new(19);
        let data = ClassifyData::synth(64, 8, 2, 0.2, &mut rng);
        let mut dp = DataParallelTrainer::new(&[8, 8, 2], 8, 3, 1, 0.05, 1);
        let shards: Vec<_> = (0..3).map(|i| data.batch(i, 8)).collect();
        let s = dp.step(&shards);
        assert!(s.compute_secs > 0.0);
        assert!(s.comm_secs > 0.0);
        assert!(s.loss.is_finite());
    }
}
