//! brgemm-dl launcher: the L3 command-line entry point.
//!
//! Subcommands:
//!   info        — platform, measured peak, artifact inventory
//!   run         — execute a training (or, with a "serve" section,
//!                 serving) run from a JSON config
//!   serve       — dynamic-batching inference serving under a synthetic
//!                 open-loop load (see examples/serve.json)
//!   primitive   — run one DL primitive and report GFLOPS/efficiency
//!   tune        — autotune a primitive's blockings, persist the winner
//!   perfcheck   — validate --metrics-out files, compare bench JSON
//!                 against a committed baseline (advisory in ci.sh)
//!   xla         — execute one AOT artifact with synthetic inputs

use anyhow::{anyhow, bail, Result};
use brgemm_dl::autotune::{tuner, TuneOpts, TuningCache};
use brgemm_dl::cli::{usage, Args, Command, OptSpec};
use brgemm_dl::coordinator::build::rnn_stack_configs;
use brgemm_dl::coordinator::cnn::{CnnModel, CnnSpec};
use brgemm_dl::coordinator::config::{
    Backend, CheckpointConfig, RunConfig, ServeConfig, Workload,
};
use brgemm_dl::coordinator::data::ClassifyData;
use brgemm_dl::coordinator::rnn::{RnnModel, RnnSpec};
use brgemm_dl::coordinator::trainer::{eval_accuracy, DataParallelTrainer, MlpModel, Model};
use brgemm_dl::modelio::{Arch, ModelArtifact, TrainMeta};
use brgemm_dl::perfmodel;
use brgemm_dl::primitives::conv::{ConvConfig, ConvPrimitive};
use brgemm_dl::primitives::eltwise::Act;
use brgemm_dl::primitives::fc::{FcConfig, FcPrimitive};
use brgemm_dl::primitives::lstm::{LstmConfig, LstmPrimitive, LstmWeights, LstmWorkspace};
use brgemm_dl::runtime::{DType, HostTensor, Runtime};
use brgemm_dl::serve::{
    drive_open_loop_every, seq_request_source, AdminServer, InferenceModel, LoadSpec,
    ModelWatcher, NetSpec, Response, ServeOpts, Server, SloSpec,
};
use brgemm_dl::telemetry;
use brgemm_dl::telemetry::health::{self, HealthThresholds};
use brgemm_dl::telemetry::trace;
use brgemm_dl::tensor::layout;
use brgemm_dl::util::json::{obj, Json};
use brgemm_dl::util::logger;
use brgemm_dl::util::rng::Rng;
use brgemm_dl::{log_info, log_warn};
use std::path::Path;
use std::time::{Duration, Instant};

fn commands() -> Vec<Command> {
    vec![
        Command {
            name: "info",
            about: "platform, measured peak FLOPS, artifact inventory",
            opts: vec![],
        },
        Command {
            name: "run",
            about: "run a JSON config: training, or serving when it has a \
                    'serve' section (examples/serve.json)",
            opts: vec![
                OptSpec { name: "config", help: "config file path", takes_value: true, default: None },
                OptSpec { name: "steps", help: "override step count", takes_value: true, default: None },
                OptSpec { name: "epochs", help: "override epoch count (epoch = one pass over the training set)", takes_value: true, default: None },
                OptSpec { name: "resume", help: "resume training from a model artifact (see examples/checkpoint.json)", takes_value: true, default: None },
                OptSpec { name: "metrics-out", help: "write run metrics as JSON lines: per-epoch pass breakdown + per-primitive BRGEMM profile", takes_value: true, default: None },
                OptSpec { name: "trace-out", help: "write a Chrome trace-event JSON of per-step fwd/bwd/allreduce/update spans (data-parallel runs; open in Perfetto)", takes_value: true, default: None },
            ],
        },
        Command {
            name: "serve",
            about: "dynamic-batching inference serving under synthetic open-loop load \
                    (run-config form: examples/serve.json)",
            // No OptSpec defaults here: Args::parse would materialise them
            // into the flag map, shadowing the single runtime source of
            // serving defaults (ServeConfig::default()) and defeating the
            // --config conflict detection below. Defaults are documented
            // in the help strings instead.
            opts: vec![
                OptSpec { name: "config", help: "JSON run config with a 'serve' section (excludes the other flags)", takes_value: true, default: None },
                OptSpec { name: "model", help: "mlp|cnn|rnn topology [default: mlp]", takes_value: true, default: None },
                OptSpec { name: "layers", help: "with --model rnn: stacked LSTM depth [default: 1]", takes_value: true, default: None },
                OptSpec { name: "seq-len-typical", help: "rnn: mixed-length load with this typical request length (GNMT-style lognormal, bucketed by length) [default: off = full-T requests]", takes_value: true, default: None },
                OptSpec { name: "model-path", help: "serve trained weights from this model artifact (topology comes from the artifact)", takes_value: true, default: None },
                OptSpec { name: "min-accuracy", help: "with --model-path: replay the training distribution and fail below this accuracy fraction", takes_value: true, default: None },
                OptSpec { name: "watch-model", help: "with --model-path: poll the artifact file and hot-reload it on change", takes_value: false, default: None },
                OptSpec { name: "watch-poll-ms", help: "with --watch-model: poll cadence in milliseconds [default: 50]", takes_value: true, default: None },
                OptSpec { name: "wait-fill-us", help: "batching delay: wait up to this many us for a bucket to fill [default: 0 = greedy]", takes_value: true, default: None },
                OptSpec { name: "rate", help: "mean arrival rate, req/s [default: 2000]", takes_value: true, default: None },
                OptSpec { name: "requests", help: "total requests to generate [default: 512]", takes_value: true, default: None },
                OptSpec { name: "max-batch", help: "top batch bucket (ladder 1/2/4/..) [default: 8]", takes_value: true, default: None },
                OptSpec { name: "serve-workers", help: "serving worker threads [default: 2]", takes_value: true, default: None },
                OptSpec { name: "nthreads", help: "threads per primitive call [default: 1]", takes_value: true, default: None },
                OptSpec { name: "seed", help: "load + weight seed [default: 42]", takes_value: true, default: None },
                OptSpec { name: "tune", help: "build bucket plans via the tuning cache", takes_value: false, default: None },
                OptSpec { name: "json", help: "also print the report as one JSON row", takes_value: false, default: None },
                OptSpec { name: "metrics-out", help: "write the final report + per-primitive BRGEMM profile as JSON", takes_value: true, default: None },
                OptSpec { name: "metrics-every", help: "log a point-in-time serving snapshot every this many seconds", takes_value: true, default: None },
                OptSpec { name: "trace-out", help: "write a Chrome trace-event JSON of request/batch/layer spans (open in Perfetto)", takes_value: true, default: None },
                OptSpec { name: "trace-sample", help: "with tracing on: record 1 in N requests, keyed off the request id [default: 1 = all]", takes_value: true, default: None },
                OptSpec { name: "admin-sock", help: "listen on this Unix socket for line-delimited JSON admin commands (stats|trace|reload|drain|health|metrics)", takes_value: true, default: None },
                OptSpec { name: "slo-latency-ms", help: "latency SLO deadline stamped on every request, milliseconds [default: off]", takes_value: true, default: None },
                OptSpec { name: "slo-objective", help: "with --slo-latency-ms: target attainment fraction in (0,1) [default: 0.99]", takes_value: true, default: None },
            ],
        },
        Command {
            name: "admin",
            about: "send one command to a running server's --admin-sock endpoint",
            opts: vec![
                OptSpec { name: "sock", help: "Unix socket path the server listens on", takes_value: true, default: None },
                OptSpec { name: "cmd", help: "command line to send: stats | drain | health | metrics | a JSON object like {\"cmd\":\"reload\",\"path\":\"m.bin\"}", takes_value: true, default: None },
                OptSpec { name: "wait-ready", help: "poll the socket's health command until the server reports ready (exit 0) or --timeout expires (exit 1)", takes_value: false, default: None },
                OptSpec { name: "timeout", help: "with --wait-ready: give up after this many seconds [default: 10]", takes_value: true, default: None },
            ],
        },
        Command {
            name: "primitive",
            about: "run one primitive (fc|lstm|conv) and report GFLOPS",
            opts: vec![
                OptSpec { name: "op", help: "fc|lstm|conv", takes_value: true, default: Some("fc") },
                OptSpec { name: "n", help: "mini-batch", takes_value: true, default: Some("32") },
                OptSpec { name: "c", help: "input features/channels", takes_value: true, default: Some("256") },
                OptSpec { name: "k", help: "output features/channels", takes_value: true, default: Some("256") },
                OptSpec { name: "t", help: "LSTM sequence length", takes_value: true, default: Some("16") },
                OptSpec { name: "hw", help: "conv spatial size", takes_value: true, default: Some("28") },
                OptSpec { name: "r", help: "conv filter size", takes_value: true, default: Some("3") },
                OptSpec { name: "iters", help: "timing iterations", takes_value: true, default: Some("10") },
            ],
        },
        Command {
            name: "tune",
            about: "autotune blockings for one primitive (conv|fc|lstm), persist winners",
            opts: vec![
                OptSpec { name: "primitive", help: "conv|fc|lstm", takes_value: true, default: Some("conv") },
                OptSpec { name: "n", help: "mini-batch", takes_value: true, default: Some("1") },
                OptSpec { name: "c", help: "input features/channels", takes_value: true, default: Some("64") },
                OptSpec { name: "k", help: "output features/channels", takes_value: true, default: Some("64") },
                OptSpec { name: "hw", help: "conv spatial size", takes_value: true, default: Some("56") },
                OptSpec { name: "r", help: "conv filter size (pad = r/2)", takes_value: true, default: Some("1") },
                OptSpec { name: "stride", help: "conv stride", takes_value: true, default: Some("1") },
                OptSpec { name: "t", help: "LSTM sequence length", takes_value: true, default: Some("8") },
                OptSpec { name: "threads", help: "thread count to tune for", takes_value: true, default: Some("1") },
                OptSpec { name: "top", help: "candidates measured after model pruning (default: 12, or 24 with --full)", takes_value: true, default: None },
                OptSpec { name: "cache", help: "tuning-cache path (default: $BRGEMM_TUNE_CACHE or tuning_cache.json)", takes_value: true, default: None },
                OptSpec { name: "train", help: "FC: rank by fwd+upd (enables upd variants)", takes_value: false, default: None },
                OptSpec { name: "full", help: "thorough measurement protocol", takes_value: false, default: None },
            ],
        },
        Command {
            name: "perfcheck",
            about: "validate --metrics-out files; compare bench JSON against a baseline",
            opts: vec![
                OptSpec { name: "metrics", help: "JSON-lines metrics file: every line must parse (see --require)", takes_value: true, default: None },
                OptSpec { name: "require", help: "comma-separated keys that must appear in --metrics with a nonzero/non-empty value", takes_value: true, default: None },
                OptSpec { name: "baseline", help: "committed baseline JSON (BENCH_*.json at the repo root; history docs compare their newest entry)", takes_value: true, default: None },
                OptSpec { name: "current", help: "freshly measured JSON (bench_results/*.json)", takes_value: true, default: None },
                OptSpec { name: "tolerance", help: "allowed fractional change vs baseline: throughput drop or latency rise [default: 0.5]; widened to 3x MAD where the baseline row records a <key>_mad sibling", takes_value: true, default: None },
                OptSpec { name: "trace", help: "Chrome trace-event JSON (--trace-out file): must parse with nonzero complete spans", takes_value: true, default: None },
                OptSpec { name: "min-span-cats", help: "with --trace: require at least this many distinct span categories [default: 2]", takes_value: true, default: None },
            ],
        },
        Command {
            name: "xla",
            about: "execute one AOT artifact with synthetic inputs",
            opts: vec![
                OptSpec { name: "entry", help: "artifact name", takes_value: true, default: Some("brgemm_demo") },
                OptSpec { name: "iters", help: "timing iterations", takes_value: true, default: Some("5") },
                OptSpec { name: "artifacts", help: "artifact dir", takes_value: true, default: Some("artifacts") },
            ],
        },
    ]
}

fn main() {
    logger::init(None);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmds = commands();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!(
            "{}",
            usage("brgemm-dl", "DL primitives via a single building block (BRGEMM)", &cmds)
        );
        return;
    }
    let args = match Args::parse(&argv, &cmds) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {}", e);
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("admin") => cmd_admin(&args),
        Some("primitive") => cmd_primitive(&args),
        Some("tune") => cmd_tune(&args),
        Some("perfcheck") => cmd_perfcheck(&args),
        Some("xla") => cmd_xla(&args),
        _ => {
            print!("{}", usage("brgemm-dl", "DL primitives via a single building block", &cmds));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn cmd_info() -> Result<()> {
    println!("brgemm-dl — High-Performance Deep Learning via a Single Building Block");
    println!(
        "host peak (measured 1-core FMA roofline): {:.1} GFLOPS",
        perfmodel::host_peak_gflops()
    );
    println!(
        "paper platform: {} = {:.0} GFLOPS / {} cores",
        perfmodel::SKX_PAPER.name,
        perfmodel::SKX_PAPER.peak_gflops_f32,
        perfmodel::SKX_PAPER.cores
    );
    match Runtime::cpu(Path::new("artifacts")) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts ({}):", rt.manifest.entries.len());
            for e in &rt.manifest.entries {
                println!("  {:<28} {:>10.1} MFLOP  {}", e.name, e.flops / 1e6, e.desc);
            }
        }
        Err(e) => log_warn!("no artifacts: {:#} (run `make artifacts`)", e),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = match args.str("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    if let Some(steps) = args.usize("steps").map_err(|e| anyhow!("{}", e))? {
        cfg.steps = steps;
        cfg.epochs = None; // an explicit step count overrides an epoch schedule
    }
    if let Some(epochs) = args.usize("epochs").map_err(|e| anyhow!("{}", e))? {
        if epochs == 0 {
            bail!("--epochs must be >= 1");
        }
        cfg.epochs = Some(epochs);
    }
    if let Some(path) = args.str("metrics-out") {
        if path.is_empty() {
            bail!("--metrics-out needs a non-empty file path");
        }
        cfg.metrics_out = Some(path.to_string());
    }
    if let Some(path) = args.str("trace-out") {
        if path.is_empty() {
            bail!("--trace-out needs a non-empty file path");
        }
        cfg.trace_out = Some(path.to_string());
    }
    let resume = match args.str("resume") {
        Some(path) => {
            let art = ModelArtifact::load(path)?;
            log_info!(
                "resuming from {}: {} — epoch {}, step {}, acc {:.1}%",
                path,
                art.arch.describe(),
                art.meta.epoch,
                art.meta.step,
                art.meta.accuracy * 100.0
            );
            Some(art)
        }
        None => None,
    };
    log_info!("run config: {:?}", cfg);
    if let Some(sc) = cfg.serve.clone() {
        if resume.is_some() {
            bail!("--resume is a training flag; serving reads --model-path / serve.model_path");
        }
        return run_serve(&cfg, sc, args.flag("json"));
    }
    match (cfg.workload.clone(), cfg.backend) {
        (Workload::Mlp { sizes }, Backend::Native) => run_mlp_native(&cfg, &sizes, resume),
        (Workload::Mlp { .. }, Backend::Xla) => run_mlp_xla(&cfg),
        (Workload::Cnn { scale, depth, classes }, Backend::Native) => {
            run_cnn_native(&cfg, scale, depth, classes, resume)
        }
        (Workload::Rnn { c, k, t, classes, layers }, Backend::Native) => {
            run_rnn_native(&cfg, RnnSpec { c, k, t, classes, layers }, resume)
        }
        (w, b) => bail!("workload {:?} on backend {:?} not wired in the CLI (see examples/)", w, b),
    }
}

/// The synthetic training dataset of an architecture — one definition
/// shared by the training drivers and the serve-side accuracy replay, so
/// a trained artifact's stored seed regenerates exactly the distribution
/// it learned (the two paths can never drift).
fn synth_dataset(arch: &Arch, seed: u64) -> ClassifyData {
    let mut rng = Rng::new(seed);
    match arch {
        Arch::Mlp { sizes } => {
            ClassifyData::synth(4096, sizes[0], *sizes.last().unwrap(), 0.2, &mut rng)
        }
        Arch::Cnn(spec) => {
            ClassifyData::synth(1024, spec.input_dim(), spec.classes, 0.3, &mut rng)
        }
        Arch::Rnn(spec) => {
            ClassifyData::synth_sequences(2048, spec.t, spec.c, spec.classes, 0.2, &mut rng)
        }
    }
}

/// Serving driver shared by `run` (config `"serve"` section) and the
/// `serve` subcommand: build the forward-only bucket-plan model — from a
/// trained artifact when `model_path` is set, else from the workload
/// topology with He init — drive the deterministic open-loop load through
/// the batcher + worker pool, and print the latency/throughput report.
/// With `min_accuracy`, the load replays the training distribution and
/// the run fails unless the served responses classify it well enough —
/// the end-to-end proof that trained weights flow through serving.
fn run_serve(cfg: &RunConfig, sc: ServeConfig, emit_json: bool) -> Result<()> {
    // Install before the model is built: the bucket plans' primitives
    // register their profiler slots at construction time.
    let profiler = cfg.metrics_out.as_ref().map(|_| telemetry::install());
    // The span tracer turns on when anything can observe it: a
    // --trace-out file, or a live admin socket (its `trace` command
    // drains the same rings).
    let tracing = cfg.trace_out.is_some() || sc.admin_sock.is_some();
    let tracer = tracing.then(|| trace::install(sc.trace_sample, trace::DEFAULT_RING_CAP));
    if tracing {
        log_info!(
            "tracing: sampling 1 in {} request(s), ring capacity {} group(s) per worker",
            sc.trace_sample,
            trace::DEFAULT_RING_CAP
        );
    }
    // The health monitor turns on when something can observe it: the
    // admin socket's `health` command (and `admin --wait-ready`).
    let monitored = sc.admin_sock.is_some();
    if monitored {
        health::install(HealthThresholds::default());
    }
    // The resource plane turns on when something can observe it: a
    // --metrics-out report or an admin socket (`stats`/`metrics` attach
    // the resource block).
    let resourced = cfg.metrics_out.is_some() || sc.admin_sock.is_some();
    if resourced {
        telemetry::resource::install();
    }
    let artifact = match &sc.model_path {
        Some(path) => {
            let art = ModelArtifact::load(path)?;
            log_info!(
                "serving artifact {}: {} — epoch {}, step {}, trained acc {:.1}%",
                path,
                art.arch.describe(),
                art.meta.epoch,
                art.meta.step,
                art.meta.accuracy * 100.0
            );
            Some(art)
        }
        None => None,
    };
    let (spec, model) = match &artifact {
        Some(art) => {
            // The artifact is authoritative for the topology.
            let model = InferenceModel::from_artifact(art, sc.max_batch, cfg.nthreads, cfg.tune)?;
            (NetSpec::from_arch(&art.arch), model)
        }
        None => {
            let spec = match &cfg.workload {
                Workload::Mlp { sizes } => NetSpec::Mlp { sizes: sizes.clone() },
                Workload::Cnn { scale, depth, classes } => {
                    NetSpec::Cnn(CnnSpec::resnet_mini(*scale, *depth, *classes))
                }
                Workload::Rnn { c, k, t, classes, layers } => NetSpec::Rnn(RnnSpec {
                    c: *c,
                    k: *k,
                    t: *t,
                    classes: *classes,
                    layers: *layers,
                }),
                w => bail!("workload {:?} not servable (mlp|cnn|rnn)", w),
            };
            let mut rng = Rng::new(cfg.seed);
            let model =
                InferenceModel::from_spec(&spec, sc.max_batch, cfg.nthreads, cfg.tune, &mut rng);
            (spec, model)
        }
    };
    log_info!(
        "serving {}: input dim {}, {} classes, buckets {:?}, {} weight allocations \
         for {} layers, {} workers, fill window {} us",
        match &spec {
            NetSpec::Mlp { .. } => "mlp",
            NetSpec::Cnn(_) => "cnn",
            NetSpec::Rnn(_) => "rnn",
        },
        model.input_dim(),
        model.classes(),
        model.buckets(),
        model.weight_alloc_ids().len(),
        model.layer_count(),
        sc.workers,
        sc.wait_for_fill_us
    );
    if let Some(slo) = &sc.slo {
        log_info!(
            "slo: {} ms deadline at {:.2}% attainment objective",
            slo.latency_ms,
            slo.objective * 100.0
        );
    }
    let opts = ServeOpts {
        max_batch: sc.max_batch,
        workers: sc.workers,
        wait_for_fill_us: sc.wait_for_fill_us,
        trace: tracing,
        slo: sc.slo,
        health: monitored,
    };
    // `--watch-model`: the validated config guarantees a model path, and
    // run_serve loaded the artifact above — it becomes the watcher's
    // change-detection baseline, so a checkpoint landing while the bucket
    // plans were being built is applied on the first poll.
    let watch: Option<(&str, &ModelArtifact)> = if sc.watch_model {
        sc.model_path.as_deref().zip(artifact.as_ref())
    } else {
        None
    };
    let report = if let Some(min_acc) = sc.min_accuracy {
        let art = artifact.as_ref().expect("validated: min_accuracy requires model_path");
        if sc.seq_len_typical.is_some() {
            log_warn!(
                "min_accuracy replays the training distribution at its full sequence \
                 length; seq_len_typical is ignored for this run"
            );
        }
        let (report, accuracy) = serve_eval_load(model, opts, &sc, art, watch)?;
        log_info!(
            "serve accuracy over the training distribution: {:.1}% (threshold {:.1}%)",
            accuracy * 100.0,
            min_acc * 100.0
        );
        if accuracy < min_acc {
            bail!(
                "served accuracy {:.3} below the required {:.3} — trained weights are not \
                 flowing through serving",
                accuracy,
                min_acc
            );
        }
        report
    } else {
        let load = LoadSpec { requests: sc.requests, rate_rps: sc.rate, seed: cfg.seed };
        let (report, responses) = match sc.seq_len_typical {
            Some(typical) => {
                let step = model.seq_step_dim().ok_or_else(|| {
                    anyhow!(
                        "serve.seq_len_typical needs a sequence (rnn) model; this model \
                         takes fixed {}-float requests",
                        model.input_dim()
                    )
                })?;
                let t = model.seq_max_len().expect("sequence model has a max length");
                if typical > t {
                    bail!(
                        "serve.seq_len_typical {} exceeds the model's sequence capacity T={}",
                        typical,
                        t
                    );
                }
                log_info!(
                    "mixed-length load: lengths ~ lognormal around {} (clamped to [2, {}]), \
                     routed through length buckets {:?}",
                    typical,
                    t,
                    model.len_buckets()
                );
                open_loop_watched(
                    model,
                    opts,
                    &load,
                    watch,
                    sc.admin_sock.as_deref(),
                    sc.metrics_every,
                    sc.watch_poll_ms,
                    seq_request_source(step, typical, t),
                )?
            }
            None => {
                let dim = model.input_dim();
                open_loop_watched(
                    model,
                    opts,
                    &load,
                    watch,
                    sc.admin_sock.as_deref(),
                    sc.metrics_every,
                    sc.watch_poll_ms,
                    move |rng, _i| rng.vec_f32(dim, -1.0, 1.0),
                )?
            }
        };
        if responses.len() != sc.requests {
            // An admin `drain` legitimately ends the run early: the load
            // generator stops at the first rejected submit and every
            // accepted request was still answered.
            if sc.admin_sock.is_some() && responses.len() < sc.requests {
                log_info!(
                    "served {} of {} requests (admin drain ended the run early)",
                    responses.len(),
                    sc.requests
                );
            } else {
                bail!("served {} of {} requests", responses.len(), sc.requests);
            }
        }
        report
    };
    print!("{}", report.render());
    if emit_json {
        println!("{}", report.to_json().to_string_compact());
    }
    if let Some(t) = tracer {
        // Whatever an admin `trace` command already drained is gone by
        // design (the rings hand out each group once); this exports the
        // remainder.
        if let Some(path) = &cfg.trace_out {
            let drained = t.drain();
            log_info!(
                "trace: {} span group(s) captured, {} dropped by ring overflow",
                drained.groups.len(),
                drained.dropped_groups
            );
            std::fs::write(path, format!("{}\n", drained.to_chrome().to_string_compact()))
                .map_err(|e| anyhow!("writing {}: {}", path, e))?;
            log_info!("chrome trace written to {} (open in Perfetto / chrome://tracing)", path);
        }
        trace::uninstall();
    }
    if let (Some(path), Some(prof)) = (&cfg.metrics_out, profiler) {
        let mut doc = report.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("primitives".to_string(), prof.snapshot());
        }
        std::fs::write(path, format!("{}\n", doc.to_string_compact()))
            .map_err(|e| anyhow!("writing {}: {}", path, e))?;
        log_info!("serve metrics written to {}", path);
        telemetry::uninstall();
    }
    if monitored {
        health::uninstall();
    }
    if resourced {
        telemetry::resource::uninstall();
    }
    Ok(())
}

/// Start the server, optionally attach the `--watch-model` file poller
/// and the `--admin-sock` control endpoint, pace the open-loop load, and
/// drain — the one open-loop entry both serving paths (synthetic noise
/// and the accuracy replay) go through.
fn open_loop_watched(
    model: InferenceModel,
    opts: ServeOpts,
    load: &LoadSpec,
    watch: Option<(&str, &ModelArtifact)>,
    admin_sock: Option<&str>,
    metrics_every: Option<f64>,
    watch_poll_ms: u64,
    make_input: impl FnMut(&mut Rng, usize) -> Vec<f32>,
) -> Result<(brgemm_dl::serve::ServeReport, Vec<Response>)> {
    let (server, rx) = Server::start(model, opts);
    let admin = match admin_sock {
        Some(path) => {
            let a = AdminServer::start(path, server.admin_handle())?;
            log_info!(
                "admin: listening on {} (stats | trace | reload | drain | health | metrics)",
                path
            );
            Some(a)
        }
        None => None,
    };
    let watcher = watch.map(|(p, loaded)| {
        log_info!("watch-model: polling {} every {} ms for changes", p, watch_poll_ms);
        ModelWatcher::spawn(
            server.reload_handle(),
            p,
            Duration::from_millis(watch_poll_ms),
            Some(loaded),
        )
    });
    let out = drive_open_loop_every(server, rx, load, metrics_every, make_input);
    if let Some(w) = watcher {
        let applied = w.stop();
        log_info!("watch-model: {} reload(s) applied during the run", applied);
    }
    if let Some(a) = admin {
        // Drain linger: the server just shut down, so the health monitor
        // reports Draining — keep the socket answering briefly so a
        // concurrent `admin health` poller (CI's drain walk) observes the
        // transition before the endpoint disappears.
        if health::enabled() {
            std::thread::sleep(Duration::from_millis(600));
        }
        a.stop();
    }
    Ok(out)
}

/// Accuracy-replay load: pace the artifact's own training distribution
/// (regenerated from its stored seed) through the server open-loop, then
/// score the responses against the labels. Request ids are submission
/// order, so responses pair with labels by id. The pacing machinery is
/// [`open_loop_watched`] — the same loop as the synthetic load, fed
/// dataset rows instead of noise.
fn serve_eval_load(
    model: InferenceModel,
    opts: ServeOpts,
    sc: &ServeConfig,
    art: &ModelArtifact,
    watch: Option<(&str, &ModelArtifact)>,
) -> Result<(brgemm_dl::serve::ServeReport, f64)> {
    let data = synth_dataset(&art.arch, art.meta.seed);
    let n = sc.requests.min(data.len());
    if n < sc.requests {
        log_info!(
            "eval load capped at {} requests (the training set size); {} were configured",
            n,
            sc.requests
        );
    }
    let load = LoadSpec { requests: n, rate_rps: sc.rate, seed: art.meta.seed };
    let (report, responses) = open_loop_watched(
        model,
        opts,
        &load,
        watch,
        sc.admin_sock.as_deref(),
        sc.metrics_every,
        sc.watch_poll_ms,
        |_rng, i| data.batch(i, 1).0,
    )?;
    if responses.len() != n {
        bail!("served {} of {} eval requests", responses.len(), n);
    }
    let mut correct = 0usize;
    for r in &responses {
        let (_, labels) = data.batch(r.id as usize, 1);
        let pred = r
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        correct += usize::from(pred == labels[0] as usize);
    }
    Ok((report, correct as f64 / n as f64))
}

fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(path) = args.str("config") {
        // The config file is authoritative: reject flags it would silently
        // override (only --json composes with --config).
        let conflicting: Vec<&str> =
            ["model", "layers", "seq-len-typical", "model-path", "min-accuracy", "watch-model",
             "watch-poll-ms", "wait-fill-us", "rate", "requests", "max-batch", "serve-workers",
             "nthreads", "seed", "tune", "metrics-out", "metrics-every", "trace-out",
             "trace-sample", "admin-sock", "slo-latency-ms", "slo-objective"]
            .into_iter()
            .filter(|&k| args.str(k).is_some())
            .collect();
        if !conflicting.is_empty() {
            bail!(
                "--config conflicts with --{}: edit the config file or drop --config",
                conflicting.join(", --")
            );
        }
        let cfg = RunConfig::from_file(path)?;
        let sc = cfg
            .serve
            .clone()
            .ok_or_else(|| anyhow!("config {} has no \"serve\" section", path))?;
        return run_serve(&cfg, sc, args.flag("json"));
    }
    if args.str("model-path").is_some() && args.str("model").is_some() {
        bail!("--model-path serves the artifact's own topology; drop --model");
    }
    let mut cfg = RunConfig::default();
    let layers = args.usize_or("layers", 1).map_err(|e| anyhow!("{}", e))?;
    if layers == 0 {
        bail!("--layers must be >= 1 (stacked LSTM depth)");
    }
    cfg.workload = match args.str_or("model", "mlp") {
        "mlp" => Workload::Mlp { sizes: vec![64, 128, 10] },
        "cnn" => Workload::Cnn { scale: 8, depth: 2, classes: 8 },
        "rnn" => Workload::Rnn { c: 16, k: 32, t: 8, classes: 4, layers },
        other => bail!("unknown model '{}' (mlp|cnn|rnn)", other),
    };
    if args.str("layers").is_some() && !matches!(cfg.workload, Workload::Rnn { .. }) {
        bail!("--layers applies to --model rnn (stacked LSTM depth)");
    }
    cfg.nthreads = args.usize_or("nthreads", 1).map_err(|e| anyhow!("{}", e))?;
    cfg.seed = args.usize_or("seed", 42).map_err(|e| anyhow!("{}", e))? as u64;
    cfg.tune = args.flag("tune");
    // Runtime fallbacks come from ServeConfig::default() — the one source
    // of serving defaults, shared with the run-config parser.
    let d = ServeConfig::default();
    let sc = ServeConfig {
        rate: args.f64_or("rate", d.rate).map_err(|e| anyhow!("{}", e))?,
        requests: args.usize_or("requests", d.requests).map_err(|e| anyhow!("{}", e))?,
        max_batch: args.usize_or("max-batch", d.max_batch).map_err(|e| anyhow!("{}", e))?,
        workers: args.usize_or("serve-workers", d.workers).map_err(|e| anyhow!("{}", e))?,
        wait_for_fill_us: args.usize_or("wait-fill-us", 0).map_err(|e| anyhow!("{}", e))?
            as u64,
        model_path: args.str("model-path").map(String::from),
        min_accuracy: args.f64("min-accuracy").map_err(|e| anyhow!("{}", e))?,
        watch_model: args.flag("watch-model"),
        watch_poll_ms: args
            .usize_or("watch-poll-ms", d.watch_poll_ms as usize)
            .map_err(|e| anyhow!("{}", e))? as u64,
        seq_len_typical: args.usize("seq-len-typical").map_err(|e| anyhow!("{}", e))?,
        metrics_every: args.f64("metrics-every").map_err(|e| anyhow!("{}", e))?,
        admin_sock: args.str("admin-sock").map(String::from),
        trace_sample: args
            .usize_or("trace-sample", d.trace_sample as usize)
            .map_err(|e| anyhow!("{}", e))? as u64,
        slo: match args.f64("slo-latency-ms").map_err(|e| anyhow!("{}", e))? {
            Some(latency_ms) => Some(SloSpec {
                latency_ms,
                objective: args
                    .f64_or("slo-objective", SloSpec::default().objective)
                    .map_err(|e| anyhow!("{}", e))?,
            }),
            None => {
                if args.str("slo-objective").is_some() {
                    bail!("--slo-objective needs --slo-latency-ms (the deadline to attain)");
                }
                None
            }
        },
    };
    sc.validate()?;
    cfg.metrics_out = args.str("metrics-out").map(String::from);
    cfg.trace_out = args.str("trace-out").map(String::from);
    run_serve(&cfg, sc, args.flag("json"))
}

/// One-shot admin client: send a single command line to a running
/// server's `--admin-sock` endpoint and print the JSON reply. Bare
/// `stats` / `drain` / `trace` are wrapped into the JSON form; anything
/// containing `{` is sent verbatim. Exit status follows the reply's
/// `ok` field, so shell scripts can gate on it directly.
fn cmd_admin(args: &Args) -> Result<()> {
    let sock = args.str("sock").ok_or_else(|| anyhow!("admin needs --sock <path>"))?;
    if args.flag("wait-ready") {
        let timeout = args.f64_or("timeout", 10.0).map_err(|e| anyhow!("{}", e))?;
        return admin_wait_ready(sock, timeout);
    }
    let cmd = args.str("cmd").ok_or_else(|| anyhow!("admin needs --cmd <command>"))?;
    let line = if cmd.contains('{') {
        cmd.to_string()
    } else {
        obj([("cmd", cmd.into())]).to_string_compact()
    };
    let reply = brgemm_dl::serve::admin::send_command(sock, &line)?;
    let parsed = Json::parse(&reply).ok();
    // A `metrics` reply carries the whole Prometheus exposition as one
    // JSON-escaped string: print the decoded text, not the JSON line, so
    // the output pipes straight into a scraper or promtool.
    match parsed.as_ref().and_then(|j| j.get("metrics")).and_then(Json::as_str) {
        Some(text) => print!("{}", text),
        None => println!("{}", reply),
    }
    let ok = parsed
        .and_then(|j| j.get("ok").and_then(Json::as_bool))
        .unwrap_or(false);
    if !ok {
        bail!("admin command failed (reply above)");
    }
    Ok(())
}

/// `admin --wait-ready`: poll the socket's `health` command until the
/// server reports `ready` (exit 0) or the timeout expires (exit 1). A
/// socket that is not up yet (missing file, connection refused) counts
/// as not-ready, so this can gate on a server that is still starting.
fn admin_wait_ready(sock: &str, timeout_secs: f64) -> Result<()> {
    if !(timeout_secs > 0.0) || !timeout_secs.is_finite() {
        bail!("--timeout must be a positive, finite number of seconds");
    }
    let deadline = std::time::Instant::now() + Duration::from_secs_f64(timeout_secs);
    loop {
        if let Ok(reply) = brgemm_dl::serve::admin::send_command(sock, "{\"cmd\":\"health\"}") {
            let state = Json::parse(&reply).ok().and_then(|j| {
                j.get("health")
                    .and_then(|h| h.get("state"))
                    .and_then(Json::as_str)
                    .map(String::from)
            });
            if state.as_deref() == Some("ready") {
                println!("{}", reply);
                return Ok(());
            }
        }
        if std::time::Instant::now() >= deadline {
            bail!("server did not report ready within {:.1}s", timeout_secs);
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The training schedule derived from a config: epoch = one pass over
/// the synthetic training set; an `epochs` config runs that many passes,
/// otherwise the raw `steps` count applies. A data-parallel step
/// consumes `workers` shards of `batch` samples, so the per-step sample
/// count scales with the worker count.
struct Schedule {
    steps_per_epoch: usize,
    total_steps: usize,
}

fn schedule_of(cfg: &RunConfig, data: &ClassifyData) -> Schedule {
    let samples_per_step = cfg.batch * cfg.workers;
    let steps_per_epoch = (data.len() / samples_per_step).max(1);
    let total_steps = match cfg.epochs {
        Some(e) => e * steps_per_epoch,
        None => cfg.steps,
    };
    Schedule { steps_per_epoch, total_steps }
}

/// Snapshot `model` into a checkpoint artifact (canonical weights +
/// training metadata, atomically replacing the file at `ck.path`).
#[allow(clippy::too_many_arguments)]
fn save_checkpoint<M: Model>(
    ck: &CheckpointConfig,
    arch: &Arch,
    cfg: &RunConfig,
    model: &mut M,
    data: &ClassifyData,
    epoch: usize,
    step: usize,
    loss: f32,
    train_rng: &Rng,
) -> Result<()> {
    let accuracy = eval_accuracy(model, data, 16);
    let meta = TrainMeta {
        epoch: epoch as u64,
        step: step as u64,
        seed: cfg.seed,
        rng: train_rng.state(),
        loss,
        accuracy,
    };
    let art = ModelArtifact::new(arch.clone(), meta, model.export_weights());
    let path = art.save(&ck.path)?;
    log_info!(
        "checkpoint: epoch {} step {} loss {:.4} acc {:.1}% -> {}",
        epoch,
        step,
        loss,
        accuracy * 100.0,
        path.display()
    );
    Ok(())
}

/// Shared native training driver over any [`Model`]: multi-worker
/// synchronous data-parallel (real ring-allreduce, modelled comm time) or
/// single-model SGD, with step logging, per-epoch checkpointing, resume
/// from a model artifact, and a final accuracy report. `build` constructs
/// one replica from a seeded RNG; every replica is built from the same
/// seed so synchronous SGD starts bit-identical. A resumed run restores
/// every replica's parameters from the artifact and continues at the
/// stored step — bit-identical to a run that never stopped, because the
/// data schedule is a pure function of the step index.
fn drive_native<M: Model>(
    cfg: &RunConfig,
    data: &ClassifyData,
    arch: &Arch,
    resume: Option<&ModelArtifact>,
    build: impl Fn(&mut Rng) -> M,
) -> Result<()> {
    let sched = schedule_of(cfg, data);
    let spe = sched.steps_per_epoch;
    let total = sched.total_steps;
    let ckpt = cfg.checkpoint.as_ref();
    // --metrics-out: enable telemetry before any replica is built (the
    // primitives register their profiler slots at construction), then
    // stream one JSON line per epoch plus a final per-primitive profile.
    let profiler = cfg.metrics_out.as_ref().map(|_| telemetry::install());
    // --metrics-out also turns on the resource plane: every epoch line
    // (and the final line) carries a `resource` block with RSS / faults /
    // CPU / allocator accounting.
    let resourced = cfg.metrics_out.is_some();
    if resourced {
        telemetry::resource::install();
    }
    // --trace-out: per-step fwd/bwd/allreduce/update spans come from the
    // data-parallel trainer; every step is recorded (steps are few and
    // coarse next to serve requests, so sampling buys nothing here).
    let tracer = cfg.trace_out.as_ref().map(|_| trace::install(1, trace::DEFAULT_RING_CAP));
    if tracer.is_some() && cfg.workers <= 1 {
        log_warn!(
            "--trace-out: step spans are recorded by the data-parallel path; this \
             single-worker run will produce an empty trace (set \"workers\": 2+)"
        );
    }
    let mut sink = match &cfg.metrics_out {
        Some(path) => Some(std::io::BufWriter::new(
            std::fs::File::create(path).map_err(|e| anyhow!("creating {}: {}", path, e))?,
        )),
        None => None,
    };
    let mut train_rng = Rng::new(cfg.seed);
    let mut start_step = 0usize;
    if let Some(art) = resume {
        if art.arch != *arch {
            bail!(
                "resume artifact is {}, run config builds {}",
                art.arch.describe(),
                arch.describe()
            );
        }
        if art.meta.seed != cfg.seed {
            bail!(
                "resume artifact was trained with seed {}, run config has seed {} — the \
                 synthetic dataset and schedule are seed-derived, so resuming on a \
                 different seed would silently train a different task; set \"seed\": {}",
                art.meta.seed,
                cfg.seed,
                art.meta.seed
            );
        }
        start_step = art.meta.step as usize;
        train_rng = Rng::from_state(art.meta.rng);
        if start_step >= total {
            log_info!(
                "artifact is already at step {} of {} — nothing to train \
                 (raise --epochs/--steps to continue)",
                start_step,
                total
            );
        }
    }
    let at_epoch_end = |model: &mut M, step: usize, loss: f32, rng: &Rng| -> Result<()> {
        let done = step + 1;
        if done % spe != 0 {
            return Ok(());
        }
        let epoch = done / spe;
        if let Some(ck) = ckpt {
            if epoch % ck.every_epochs == 0 {
                save_checkpoint(ck, arch, cfg, model, data, epoch, done, loss, rng)?;
            }
        }
        Ok(())
    };
    if cfg.workers > 1 {
        // Every replica must start bit-identical, so each is built from a
        // fresh seed-rng — except worker 0 on a fresh run, which consumes
        // `train_rng` (it starts equal to `Rng::new(cfg.seed)`, so the
        // init is identical) to advance the checkpointed training stream
        // past initialisation. On resume the stream position comes from
        // the artifact, so init draws from throwaway rngs instead.
        let mut workers: Vec<M> = (0..cfg.workers)
            .map(|i| {
                if i == 0 && resume.is_none() {
                    build(&mut train_rng)
                } else {
                    build(&mut Rng::new(cfg.seed))
                }
            })
            .collect();
        if let Some(art) = resume {
            for w in workers.iter_mut() {
                w.import_weights(&art.layers)?;
            }
        }
        let mut dp = DataParallelTrainer::from_workers(workers, cfg.lr as f32);
        dp.trace_steps(tracer.is_some());
        log_info!("model params: {} × {} replicas", dp.workers[0].param_count(), cfg.workers);
        for step in start_step..total {
            let shards: Vec<_> = (0..cfg.workers)
                .map(|w| data.batch(step * cfg.workers + w, cfg.batch))
                .collect();
            let s = dp.step(&shards);
            if step % 10 == 0 || step + 1 == total {
                log_info!(
                    "step {:4} loss {:.4} compute {:.1}ms comm(model) {:.2}ms",
                    step,
                    s.loss,
                    s.compute_secs * 1e3,
                    s.comm_secs * 1e3
                );
            }
            at_epoch_end(&mut dp.workers[0], step, s.loss, &train_rng)?;
            if let Some(w) = sink.as_mut() {
                if (step + 1) % spe == 0 {
                    let mut row = obj([
                        ("epoch", ((step + 1) / spe).into()),
                        ("step", (step + 1).into()),
                        ("loss", (s.loss as f64).into()),
                        ("metrics", dp.merged_metrics().to_json()),
                    ]);
                    // Per-epoch straggler view: slowest-vs-mean replica
                    // compute and the allreduce's share of step time.
                    if let (Json::Obj(fields), Some(si), Some(ar)) =
                        (&mut row, dp.straggler_index(), dp.allreduce_share())
                    {
                        fields.insert("straggler_index".to_string(), si.into());
                        fields.insert("allreduce_share".to_string(), ar.into());
                    }
                    attach_resource(&mut row);
                    write_metrics_line(w, &row)?;
                }
            }
        }
        if !dp.replicas_consistent() {
            bail!("replicas diverged");
        }
        log_info!("replicas consistent after {} steps", total.saturating_sub(start_step));
        let t_eval = telemetry::enabled().then(Instant::now);
        let acc = eval_accuracy(&mut dp.workers[0], data, 16);
        if let Some(t) = t_eval {
            dp.metrics.observe_secs("eval", t.elapsed().as_secs_f64());
        }
        log_info!("final accuracy {:.1}% (worker 0)", acc * 100.0);
        if let Some(w) = sink.as_mut() {
            let mut row = obj([
                ("final_accuracy", acc.into()),
                ("metrics", dp.merged_metrics().to_json()),
            ]);
            if let (Json::Obj(fields), Some(si), Some(ar)) =
                (&mut row, dp.straggler_index(), dp.allreduce_share())
            {
                fields.insert("straggler_index".to_string(), si.into());
                fields.insert("allreduce_share".to_string(), ar.into());
            }
            attach_resource(&mut row);
            write_metrics_line(w, &row)?;
        }
    } else {
        // Fresh run: init consumes the checkpointed training stream, so
        // TrainMeta.rng records the post-init position. Resume: the
        // position was restored from the artifact above; init uses a
        // throwaway rng (its draws are overwritten by the import).
        let mut model = if resume.is_none() {
            build(&mut train_rng)
        } else {
            build(&mut Rng::new(cfg.seed))
        };
        if let Some(art) = resume {
            model.import_weights(&art.layers)?;
        }
        log_info!("model params: {}", model.param_count());
        for step in start_step..total {
            let (x, labels) = data.batch(step, cfg.batch);
            let loss = model.train_step(&x, &labels, cfg.lr as f32);
            if step % 10 == 0 || step + 1 == total {
                log_info!("step {:4} loss {:.4}", step, loss);
            }
            at_epoch_end(&mut model, step, loss, &train_rng)?;
            if let Some(w) = sink.as_mut() {
                if (step + 1) % spe == 0 {
                    let mut row = obj([
                        ("epoch", ((step + 1) / spe).into()),
                        ("step", (step + 1).into()),
                        ("loss", (loss as f64).into()),
                        (
                            "metrics",
                            model.metrics().map(|m| m.to_json()).unwrap_or(Json::Null),
                        ),
                    ]);
                    attach_resource(&mut row);
                    write_metrics_line(w, &row)?;
                }
            }
        }
        let t_eval = telemetry::enabled().then(Instant::now);
        let acc = eval_accuracy(&mut model, data, 16);
        if let (Some(t), Some(m)) = (t_eval, model.metrics_mut()) {
            m.observe_secs("eval", t.elapsed().as_secs_f64());
        }
        log_info!("final accuracy {:.1}%", acc * 100.0);
        if let Some(w) = sink.as_mut() {
            let mut row = obj([
                ("final_accuracy", acc.into()),
                ("metrics", model.metrics().map(|m| m.to_json()).unwrap_or(Json::Null)),
            ]);
            attach_resource(&mut row);
            write_metrics_line(w, &row)?;
        }
    }
    if let Some(t) = tracer {
        if let Some(path) = &cfg.trace_out {
            let drained = t.drain();
            log_info!(
                "trace: {} step group(s) captured, {} dropped by ring overflow",
                drained.groups.len(),
                drained.dropped_groups
            );
            std::fs::write(path, format!("{}\n", drained.to_chrome().to_string_compact()))
                .map_err(|e| anyhow!("writing {}: {}", path, e))?;
            log_info!("chrome trace written to {} (open in Perfetto / chrome://tracing)", path);
        }
        trace::uninstall();
    }
    if let (Some(mut w), Some(prof)) = (sink, profiler) {
        write_metrics_line(&mut w, &obj([("primitives", prof.snapshot())]))?;
        use std::io::Write;
        w.flush().map_err(|e| anyhow!("flushing metrics: {}", e))?;
        log_info!(
            "metrics written to {}\n{}",
            cfg.metrics_out.as_deref().unwrap_or_default(),
            prof.render()
        );
        telemetry::uninstall();
    }
    if resourced {
        telemetry::resource::uninstall();
    }
    Ok(())
}

/// One compact JSON line into the `--metrics-out` stream.
fn write_metrics_line(w: &mut impl std::io::Write, j: &Json) -> Result<()> {
    writeln!(w, "{}", j.to_string_compact()).map_err(|e| anyhow!("writing metrics: {}", e))
}

/// Attach the resource plane's snapshot to a metrics row. No-op when the
/// plane is off (the block's absence, not a null, marks "plane off").
fn attach_resource(row: &mut Json) {
    if let (Json::Obj(fields), Some(snap)) = (&mut *row, telemetry::resource::snapshot()) {
        fields.insert("resource".to_string(), snap.to_json());
    }
}

fn run_mlp_native(cfg: &RunConfig, sizes: &[usize], resume: Option<ModelArtifact>) -> Result<()> {
    if cfg.tune {
        tune_mlp_layers(cfg, sizes);
    }
    let arch = Arch::Mlp { sizes: sizes.to_vec() };
    let data = synth_dataset(&arch, cfg.seed);
    drive_native(cfg, &data, &arch, resume.as_ref(), |rng| {
        MlpModel::new_with(sizes, cfg.batch, cfg.nthreads, cfg.tune, rng)
    })
}

/// Tune-before-train: tune every FC layer shape of the MLP (quick
/// protocol), persist winners into the global tuning cache, and save it so
/// later runs skip straight to the cached blockings.
fn tune_mlp_layers(cfg: &RunConfig, sizes: &[usize]) {
    use brgemm_dl::primitives::eltwise::Act;
    use brgemm_dl::primitives::fc::FcConfig;
    let topts = TuneOpts::quick().with_train(true);
    let mut cache = TuningCache::global().lock().unwrap();
    for (i, wdim) in sizes.windows(2).enumerate() {
        let act = if i + 2 == sizes.len() { Act::Identity } else { Act::Relu };
        let fcfg = FcConfig::new(cfg.batch, wdim[0], wdim[1], act).with_threads(cfg.nthreads);
        let rep = tuner::tune_fc_cached(&fcfg, &topts, &mut cache);
        log_info!(
            "tuned fc layer {} ({}x{}->{}): {} at {:.2} GF/s ({:.2}x default)",
            i,
            cfg.batch,
            wdim[0],
            wdim[1],
            rep.best().cand.label(rep.kind),
            rep.best().gflops,
            rep.speedup_vs_default()
        );
    }
    match cache.save() {
        Ok(path) => log_info!("tuning cache saved to {}", path.display()),
        Err(e) => log_warn!("could not save tuning cache: {}", e),
    }
}

/// Native CNN training: the conv stack + pool + FC head driver, trained
/// end to end through the BRGEMM primitives (single- or multi-worker).
fn run_cnn_native(
    cfg: &RunConfig,
    scale: usize,
    depth: usize,
    classes: usize,
    resume: Option<ModelArtifact>,
) -> Result<()> {
    let spec = CnnSpec::resnet_mini(scale, depth, classes);
    if cfg.tune {
        tune_cnn_layers(cfg, &spec);
    }
    let arch = Arch::Cnn(spec.clone());
    let data = synth_dataset(&arch, cfg.seed);
    log_info!(
        "cnn: {} conv layers at {}x{}x{}",
        spec.convs.len(),
        spec.in_c,
        spec.in_h,
        spec.in_w
    );
    drive_native(cfg, &data, &arch, resume.as_ref(), |rng| {
        CnnModel::new_with(&spec, cfg.batch, cfg.nthreads, cfg.tune, rng)
    })
}

/// Tune-before-train for the CNN: tune every conv layer shape (quick
/// protocol) plus the FC head, persist winners in the global tuning cache
/// so `CnnModel::new_with(.., tuned: true, ..)` — which routes layer
/// construction through `ConvPrimitive::tuned` — hits them.
fn tune_cnn_layers(cfg: &RunConfig, spec: &CnnSpec) {
    let topts = TuneOpts::quick();
    let mut cache = TuningCache::global().lock().unwrap();
    for (i, ccfg) in spec.conv_configs(cfg.batch, cfg.nthreads).iter().enumerate() {
        let rep = tuner::tune_conv_cached(ccfg, &topts, &mut cache);
        log_info!(
            "tuned conv layer {} ({}x{} {}->{} {}x{}/{}): {} at {:.2} GF/s ({:.2}x default)",
            i,
            ccfg.h,
            ccfg.w,
            ccfg.c,
            ccfg.k,
            ccfg.r,
            ccfg.s,
            ccfg.stride,
            rep.best().cand.label(rep.kind),
            rep.best().gflops,
            rep.speedup_vs_default()
        );
    }
    // Head: the exact shape the model constructs (last conv's channels ×
    // pooled spatial dims — see CnnSpec::head_features), tuned with the
    // update pass enabled, like the MLP path.
    let feat = spec.head_features(cfg.batch);
    let fcfg =
        FcConfig::new(cfg.batch, feat, spec.classes, Act::Identity).with_threads(cfg.nthreads);
    let rep = tuner::tune_fc_cached(&fcfg, &topts.with_train(true), &mut cache);
    log_info!(
        "tuned fc head ({}x{}->{}): {} at {:.2} GF/s ({:.2}x default)",
        cfg.batch,
        feat,
        spec.classes,
        rep.best().cand.label(rep.kind),
        rep.best().gflops,
        rep.speedup_vs_default()
    );
    match cache.save() {
        Ok(path) => log_info!("tuning cache saved to {}", path.display()),
        Err(e) => log_warn!("could not save tuning cache: {}", e),
    }
}

/// Native RNN training: the LSTM sequence-classifier driver (cell
/// unrolled with BPTT + FC softmax head on the final hidden state),
/// trained end to end through the BRGEMM primitives.
fn run_rnn_native(cfg: &RunConfig, spec: RnnSpec, resume: Option<ModelArtifact>) -> Result<()> {
    if cfg.tune {
        tune_rnn_layers(cfg, &spec);
    }
    let arch = Arch::Rnn(spec);
    let data = synth_dataset(&arch, cfg.seed);
    log_info!(
        "rnn: {} stacked lstm cell(s), c{} -> k{} over T={} steps, {} classes",
        spec.layers,
        spec.c,
        spec.k,
        spec.t,
        spec.classes
    );
    drive_native(cfg, &data, &arch, resume.as_ref(), |rng| {
        RnnModel::new_with(&spec, cfg.batch, cfg.nthreads, cfg.tune, rng)
    })
}

/// Tune-before-train for the RNN: tune every LSTM cell shape of the
/// stack (layer 0 maps `c -> k`, deeper layers `k -> k`; the cache key
/// includes each layer's own input width and the sequence length) plus
/// the FC head, persisting winners so
/// `RnnModel::new_with(.., tuned: true, ..)` hits them.
fn tune_rnn_layers(cfg: &RunConfig, spec: &RnnSpec) {
    let topts = TuneOpts::quick();
    let mut cache = TuningCache::global().lock().unwrap();
    // `tuned: false`: these are the raw shapes to tune, not cache lookups.
    for (i, lcfg) in rnn_stack_configs(spec, cfg.batch, cfg.nthreads, false).iter().enumerate() {
        let rep = tuner::tune_lstm_cached(lcfg, &topts, &mut cache);
        log_info!(
            "tuned lstm layer {} ({}x{}->{} T{}): {} at {:.2} GF/s ({:.2}x default)",
            i,
            cfg.batch,
            lcfg.c,
            lcfg.k,
            spec.t,
            rep.best().cand.label(rep.kind),
            rep.best().gflops,
            rep.speedup_vs_default()
        );
    }
    let fcfg = FcConfig::new(cfg.batch, spec.k, spec.classes, Act::Identity)
        .with_threads(cfg.nthreads);
    let rep = tuner::tune_fc_cached(&fcfg, &topts.with_train(true), &mut cache);
    log_info!(
        "tuned fc head ({}x{}->{}): {} at {:.2} GF/s ({:.2}x default)",
        cfg.batch,
        spec.k,
        spec.classes,
        rep.best().cand.label(rep.kind),
        rep.best().gflops,
        rep.speedup_vs_default()
    );
    match cache.save() {
        Ok(path) => log_info!("tuning cache saved to {}", path.display()),
        Err(e) => log_warn!("could not save tuning cache: {}", e),
    }
}

fn run_mlp_xla(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::cpu(Path::new("artifacts"))?;
    let meta = rt.manifest.get("mlp_train_step")?.clone();
    let mut rng = Rng::new(cfg.seed);
    let mut tensors = synth_inputs(&meta.inputs, &mut rng);
    for step in 0..cfg.steps {
        let (outs, stats) = rt.execute("mlp_train_step", &tensors)?;
        let loss = outs.last().unwrap().as_f32()?[0];
        for (i, out) in outs[..outs.len() - 1].iter().enumerate() {
            tensors[i] = out.clone();
        }
        if step % 10 == 0 || step + 1 == cfg.steps {
            log_info!("step {:4} loss {:.4} ({:.1} ms)", step, loss, stats.secs * 1e3);
        }
    }
    Ok(())
}

fn synth_inputs(metas: &[brgemm_dl::runtime::TensorMeta], rng: &mut Rng) -> Vec<HostTensor> {
    metas
        .iter()
        .map(|t| match t.dtype {
            DType::F32 => HostTensor::f32(rng.vec_f32(t.element_count(), -0.1, 0.1), &t.shape),
            DType::I32 => HostTensor::i32(
                (0..t.element_count()).map(|_| rng.below(10) as i32).collect(),
                &t.shape,
            ),
        })
        .collect()
}

fn cmd_primitive(args: &Args) -> Result<()> {
    let op = args.str("op").unwrap_or("fc");
    let n = args.usize_or("n", 32).map_err(|e| anyhow!("{}", e))?;
    let c = args.usize_or("c", 256).map_err(|e| anyhow!("{}", e))?;
    let k = args.usize_or("k", 256).map_err(|e| anyhow!("{}", e))?;
    let iters = args.usize_or("iters", 10).map_err(|e| anyhow!("{}", e))?;
    let peak = perfmodel::host_peak_gflops();
    let mut rng = Rng::new(1);
    match op {
        "fc" => {
            let cfg = FcConfig::new(n, c, k, Act::Relu);
            let prim = FcPrimitive::new(cfg);
            let x = rng.vec_f32(n * c, -1.0, 1.0);
            let w = rng.vec_f32(k * c, -0.5, 0.5);
            let bias = rng.vec_f32(k, -0.1, 0.1);
            let xp = layout::pack_act_2d(&x, n, c, cfg.bn, cfg.bc);
            let wp = layout::pack_weights_2d(&w, k, c, cfg.bk, cfg.bc);
            let mut y = vec![0.0; n * k];
            prim.forward(&xp, &wp, &bias, &mut y); // warmup
            let t0 = Instant::now();
            for _ in 0..iters {
                prim.forward(&xp, &wp, &bias, &mut y);
            }
            report("fc fwd", cfg.flops() * iters as f64, t0.elapsed().as_secs_f64(), peak);
        }
        "lstm" => {
            let t = args.usize_or("t", 16).map_err(|e| anyhow!("{}", e))?;
            let cfg = LstmConfig::new(n, c, k, t);
            let prim = LstmPrimitive::new(cfg);
            let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * c, -0.3, 0.3)).collect();
            let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k * k, -0.3, 0.3)).collect();
            let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(k, -0.1, 0.1)).collect();
            let wr: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
            let rr: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
            let br: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
            let weights = LstmWeights::pack(cfg, &wr, &rr, &br);
            let x = rng.vec_f32(t * n * c, -1.0, 1.0);
            let mut ws = LstmWorkspace::new(&cfg);
            prim.forward(&x, None, None, &weights, &mut ws);
            let t0 = Instant::now();
            for _ in 0..iters {
                prim.forward(&x, None, None, &weights, &mut ws);
            }
            report("lstm fwd", cfg.fwd_flops() * iters as f64, t0.elapsed().as_secs_f64(), peak);
        }
        "conv" => {
            let hw = args.usize_or("hw", 28).map_err(|e| anyhow!("{}", e))?;
            let r = args.usize_or("r", 3).map_err(|e| anyhow!("{}", e))?;
            let pad = if r > 1 { r / 2 } else { 0 };
            let cfg = ConvConfig::new(n, c, k, hw, hw, r, r, 1, pad);
            let prim = ConvPrimitive::new(cfg);
            let x = rng.vec_f32(n * c * hw * hw, -1.0, 1.0);
            let w = rng.vec_f32(k * c * r * r, -0.3, 0.3);
            let xp = layout::pack_conv_act(&x, n, c, hw, hw, cfg.bc, pad, pad);
            let wp = layout::pack_conv_weights(&w, k, c, r, r, cfg.bk, cfg.bc);
            let mut y = vec![0.0; cfg.output_len()];
            prim.forward(&xp, &wp, None, &mut y);
            let t0 = Instant::now();
            for _ in 0..iters {
                prim.forward(&xp, &wp, None, &mut y);
            }
            report("conv fwd", cfg.flops() * iters as f64, t0.elapsed().as_secs_f64(), peak);
        }
        other => bail!("unknown primitive '{}'", other),
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let primitive = args.str_or("primitive", "conv");
    let n = args.usize_or("n", 1).map_err(|e| anyhow!("{}", e))?;
    let c = args.usize_or("c", 64).map_err(|e| anyhow!("{}", e))?;
    let k = args.usize_or("k", 64).map_err(|e| anyhow!("{}", e))?;
    let threads = args.usize_or("threads", 1).map_err(|e| anyhow!("{}", e))?;
    let base = if args.flag("full") { TuneOpts::full() } else { TuneOpts::quick() };
    let mut topts = base.with_train(args.flag("train"));
    if let Some(top) = args.usize("top").map_err(|e| anyhow!("{}", e))? {
        topts = topts.with_top_k(top);
    }
    let custom_cache_path = args.str("cache").map(|p| p.to_string());
    let mut cache = match &custom_cache_path {
        Some(p) => TuningCache::at(p),
        None => TuningCache::load_default(),
    };

    // Calibrate before the tuner runs: its cost model ranks candidates
    // against `host_platform()`, which prefers these measured constants.
    let (cal, hit) = perfmodel::calibrate::ensure();
    let cal_path = perfmodel::calibrate::default_path();
    if hit {
        println!(
            "calibration: loaded from {} (peak {:.1} GFLOPS, stream {:.1} GB/s)",
            cal_path.display(),
            cal.peak_gflops,
            cal.stream_gbs
        );
    } else {
        println!(
            "calibration: probed and saved to {} (peak {:.1} GFLOPS, stream {:.1} GB/s)",
            cal_path.display(),
            cal.peak_gflops,
            cal.stream_gbs
        );
    }

    let rep = match primitive {
        "conv" => {
            let hw = args.usize_or("hw", 56).map_err(|e| anyhow!("{}", e))?;
            let r = args.usize_or("r", 1).map_err(|e| anyhow!("{}", e))?;
            let stride = args.usize_or("stride", 1).map_err(|e| anyhow!("{}", e))?;
            let pad = if r > 1 { r / 2 } else { 0 };
            let cfg = ConvConfig::new(n, c, k, hw, hw, r, r, stride, pad).with_threads(threads);
            tuner::tune_conv_cached(&cfg, &topts, &mut cache)
        }
        "fc" => {
            let cfg = FcConfig::new(n, c, k, Act::Relu).with_threads(threads);
            tuner::tune_fc_cached(&cfg, &topts, &mut cache)
        }
        "lstm" => {
            let t = args.usize_or("t", 8).map_err(|e| anyhow!("{}", e))?;
            let cfg = LstmConfig::new(n, c, k, t).with_threads(threads);
            tuner::tune_lstm_cached(&cfg, &topts, &mut cache)
        }
        other => bail!("unknown primitive '{}' (conv|fc|lstm)", other),
    };

    print!("{}", rep.render());
    let path = cache.save().map_err(|e| anyhow!("saving tuning cache: {}", e))?;
    println!(
        "cached winner under key '{}' in {} ({} entries total)",
        rep.key.id(),
        path.display(),
        cache.len()
    );
    match custom_cache_path {
        None => println!(
            "ConvPrimitive::tuned / FcPrimitive::tuned / LstmPrimitive::tuned load this \
             cache automatically for matching shape + ISA + thread count"
        ),
        // The tuned() constructors only consult the default location.
        Some(p) => println!(
            "note: the tuned() constructors read $BRGEMM_TUNE_CACHE or ./tuning_cache.json — \
             set BRGEMM_TUNE_CACHE={} for them to load this cache",
            p
        ),
    }
    Ok(())
}

/// Throughput-like keys (higher is better) compared by
/// `perfcheck --baseline/--current`. `useful_wps` is the serve bench's
/// useful-words-per-second rate (padding excluded); `slo_attainment`
/// and `error_budget_remaining` are the serve SLO plane's fractions —
/// attainment falling or the budget draining faster is the regression.
/// Counters and timestamps are ignored — only sustained-rate numbers
/// are meaningful across runs.
const PERF_KEYS: [&str; 7] = [
    "gflops",
    "kwps",
    "imgs_per_s",
    "throughput_rps",
    "useful_wps",
    "slo_attainment",
    "error_budget_remaining",
];

/// Latency-like keys (**lower** is better), compared with the same
/// tolerance in the opposite direction: a *rise* beyond the allowed
/// fraction is the regression. `queue_wait_ms` is the per-bucket
/// queue-wait leaf of the serve report's bucket table;
/// `queue_depth_max` is the high-water queue depth — a backlog metric,
/// so growth is the bad direction exactly like a latency.
/// `straggler_index` is the data-parallel trainer's slowest-vs-mean
/// replica ratio (1.0 = perfectly balanced) — drift upward means one
/// replica is holding the ring back.
const LAT_KEYS: [&str; 6] =
    ["p50_ms", "p95_ms", "p99_ms", "queue_wait_ms", "queue_depth_max", "straggler_index"];

/// `perfcheck` — CI's observability gate. Two independent modes that can
/// be combined in one invocation:
///
/// * `--metrics <file> [--require k1,k2]`: the file must be non-empty
///   JSON lines, and each required key must occur somewhere in it with a
///   nonzero number / non-empty container.
/// * `--baseline <json> --current <json> [--tolerance f]`: every perf
///   leaf present in both documents at the same path must stay within
///   the tolerance fraction of baseline — throughput keys
///   ([`PERF_KEYS`]) may not drop below `base * (1 - tol)`, latency keys
///   ([`LAT_KEYS`]) may not rise above `base * (1 + tol)`. Exit status
///   is the verdict; ci.sh runs this advisorily.
fn cmd_perfcheck(args: &Args) -> Result<()> {
    let did_metrics = match args.str("metrics") {
        Some(path) => {
            check_metrics_file(path, args.str("require").unwrap_or(""))?;
            true
        }
        None => false,
    };
    let did_trace = match args.str("trace") {
        Some(path) => {
            let min_cats = args.usize_or("min-span-cats", 2).map_err(|e| anyhow!("{}", e))?;
            check_trace_file(path, min_cats)?;
            true
        }
        None => false,
    };
    match (args.str("baseline"), args.str("current")) {
        (Some(b), Some(c)) => {
            let tol = args.f64_or("tolerance", 0.5).map_err(|e| anyhow!("{}", e))?;
            if !(0.0..1.0).contains(&tol) {
                bail!("--tolerance must be in [0, 1)");
            }
            compare_perf(b, c, tol)
        }
        (None, None) if did_metrics || did_trace => Ok(()),
        (None, None) => bail!("perfcheck needs --metrics, --trace, and/or --baseline/--current"),
        _ => bail!("--baseline and --current must be given together"),
    }
}

/// Validate a `--trace-out` document: it must parse as a Chrome
/// trace-event JSON with a nonzero number of complete (`"ph":"X"`) span
/// events covering at least `min_cats` distinct categories — the proof
/// that the tracer actually recorded more than one stage of the
/// pipeline, not just one span kind in a loop.
fn check_trace_file(path: &str, min_cats: usize) -> Result<()> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {}: {}", path, e))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("{}: {:?}", path, e))?;
    let (spans, cats) = trace_span_summary(&doc)
        .ok_or_else(|| anyhow!("{}: no traceEvents array (not a --trace-out document)", path))?;
    if spans == 0 {
        bail!("{}: traceEvents has no complete ('X') span events", path);
    }
    if cats.len() < min_cats {
        bail!(
            "{}: only {} span categor{} ({}); {} required",
            path,
            cats.len(),
            if cats.len() == 1 { "y" } else { "ies" },
            cats.join(", "),
            min_cats
        );
    }
    println!(
        "perfcheck {}: {} span(s) across {} categories ({})",
        path,
        spans,
        cats.len(),
        cats.join(", ")
    );
    Ok(())
}

/// `(complete-span count, sorted distinct categories)` of a Chrome
/// trace-event document, or `None` when it has no `traceEvents` array.
/// Flow arrows (`ph` "s"/"f") are deliberately not counted as spans.
fn trace_span_summary(doc: &Json) -> Option<(usize, Vec<String>)> {
    let events = doc.get("traceEvents").and_then(Json::as_arr)?;
    let mut spans = 0usize;
    let mut cats = std::collections::BTreeSet::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) == Some("X") {
            spans += 1;
            if let Some(c) = e.get("cat").and_then(Json::as_str) {
                cats.insert(c.to_string());
            }
        }
    }
    Some((spans, cats.into_iter().collect()))
}

fn check_metrics_file(path: &str, require: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {}: {}", path, e))?;
    let mut docs: Vec<Json> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        docs.push(
            Json::parse(line).map_err(|e| anyhow!("{} line {}: {:?}", path, i + 1, e))?,
        );
    }
    if docs.is_empty() {
        bail!("{} has no JSON lines", path);
    }
    for key in require.split(',').map(str::trim).filter(|k| !k.is_empty()) {
        let mut vals: Vec<&Json> = Vec::new();
        for d in &docs {
            collect_key(d, key, &mut vals);
        }
        if vals.is_empty() {
            bail!("{}: required key '{}' not found", path, key);
        }
        let ok = vals.iter().any(|v| match v {
            Json::Num(x) => *x > 0.0,
            Json::Null => false,
            Json::Arr(a) => !a.is_empty(),
            Json::Obj(o) => !o.is_empty(),
            _ => true,
        });
        if !ok {
            bail!("{}: key '{}' present but every occurrence is zero/empty", path, key);
        }
        println!("perfcheck {}: '{}' ok ({} occurrence(s))", path, key, vals.len());
    }
    println!("perfcheck {}: {} JSON line(s) parse", path, docs.len());
    Ok(())
}

/// Collect every value stored under `key` anywhere in the document.
fn collect_key<'a>(j: &'a Json, key: &str, out: &mut Vec<&'a Json>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                if k == key {
                    out.push(v);
                }
                collect_key(v, key, out);
            }
        }
        Json::Arr(a) => {
            for v in a {
                collect_key(v, key, out);
            }
        }
        _ => {}
    }
}

/// Collect `(path, value)` for every numeric leaf whose key is in
/// `keys`; paths use object keys and array indices, so two structurally
/// equal documents pair up exactly.
fn collect_perf(j: &Json, keys: &[&str], path: &mut String, out: &mut Vec<(String, f64)>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let len = path.len();
                path.push('/');
                path.push_str(k);
                if let Json::Num(x) = v {
                    if keys.contains(&k.as_str()) {
                        out.push((path.clone(), *x));
                    }
                }
                collect_perf(v, keys, path, out);
                path.truncate(len);
            }
        }
        Json::Arr(a) => {
            for (i, v) in a.iter().enumerate() {
                let len = path.len();
                path.push_str(&format!("/{}", i));
                collect_perf(v, keys, path, out);
                path.truncate(len);
            }
        }
        _ => {}
    }
}

/// Widening factor on a baseline row's measured noise: a delta only
/// counts as a regression once it exceeds `max(base·tol, MAD_K·mad)`.
/// 3×MAD is the usual robust-outlier cut (≈2σ for Gaussian noise).
const MAD_K: f64 = 3.0;

/// Direction-aware comparison of every shared perf leaf: throughput keys
/// ([`PERF_KEYS`]) regress by *dropping* below `base - allow`, latency
/// keys ([`LAT_KEYS`]) regress by *rising* above `base + allow`, where
/// `allow = max(base·tol, MAD_K · mad)` and `mad` comes from the
/// baseline's sibling `<key>_mad` leaf when the bench recorded one (rows
/// emitting `{median, mad, iters}`). Without a mad sibling this is
/// exactly the old fixed-fraction gate. Zero/negative baselines are
/// skipped — there is no meaningful fraction of nothing. Returns the
/// number of compared points plus one message per regression.
fn perf_deltas(b: &Json, c: &Json, tol: f64) -> (usize, Vec<String>) {
    let mut compared = 0usize;
    let mut regressions: Vec<String> = Vec::new();
    for (keys, lower_is_better) in [(&PERF_KEYS[..], false), (&LAT_KEYS[..], true)] {
        let mut bvals: Vec<(String, f64)> = Vec::new();
        let mut cvals: Vec<(String, f64)> = Vec::new();
        collect_perf(b, keys, &mut String::new(), &mut bvals);
        collect_perf(c, keys, &mut String::new(), &mut cvals);
        // Noise siblings: a `<key>_mad` leaf sits next to its `<key>`
        // leaf, so its path is the metric's path + "_mad".
        let mad_keys: Vec<String> = keys.iter().map(|k| format!("{}_mad", k)).collect();
        let mad_refs: Vec<&str> = mad_keys.iter().map(String::as_str).collect();
        let mut mvals: Vec<(String, f64)> = Vec::new();
        collect_perf(b, &mad_refs, &mut String::new(), &mut mvals);
        let mmap: std::collections::BTreeMap<String, f64> = mvals.into_iter().collect();
        let cmap: std::collections::BTreeMap<String, f64> = cvals.into_iter().collect();
        for (path, bv) in &bvals {
            if let Some(cv) = cmap.get(path) {
                compared += 1;
                if *bv <= 0.0 {
                    continue;
                }
                let mad = mmap.get(&format!("{}_mad", path)).copied().unwrap_or(0.0);
                let allow = (bv * tol).max(MAD_K * mad.max(0.0));
                let bad =
                    if lower_is_better { *cv > *bv + allow } else { *cv < *bv - allow };
                if bad {
                    regressions.push(format!(
                        "REGRESSION {}: {:.3} vs baseline {:.3} (allowed {} {:.3} = \
                         max({:.0}% of base, {}x MAD {:.3}))",
                        path,
                        cv,
                        bv,
                        if lower_is_better { "rise" } else { "drop" },
                        allow,
                        tol * 100.0,
                        MAD_K,
                        mad
                    ));
                }
            }
        }
    }
    (compared, regressions)
}

/// A BENCH baseline file maintained by `scripts/refresh_baselines.sh` is
/// `{note, history: [entry, ...]}` with provenance-stamped entries
/// appended over time; comparisons always run against the *newest*
/// entry. A flat document (no `history` array) is its own entry.
fn latest_entry(doc: &Json) -> &Json {
    doc.get("history").and_then(Json::as_arr).and_then(|h| h.last()).unwrap_or(doc)
}

fn compare_perf(baseline: &str, current: &str, tol: f64) -> Result<()> {
    let load = |p: &str| -> Result<Json> {
        let s = std::fs::read_to_string(p).map_err(|e| anyhow!("reading {}: {}", p, e))?;
        Json::parse(&s).map_err(|e| anyhow!("{}: {:?}", p, e))
    };
    let (b, c) = (load(baseline)?, load(current)?);
    let (compared, regressions) = perf_deltas(latest_entry(&b), latest_entry(&c), tol);
    for r in &regressions {
        println!("{}", r);
    }
    if compared == 0 {
        bail!(
            "no comparable perf keys ({} / {}) shared between {} and {}",
            PERF_KEYS.join("/"),
            LAT_KEYS.join("/"),
            baseline,
            current
        );
    }
    if !regressions.is_empty() {
        bail!(
            "{} of {} perf point(s) regressed beyond {:.0}% of baseline {}",
            regressions.len(),
            compared,
            tol * 100.0,
            baseline
        );
    }
    println!(
        "perfcheck: {} perf point(s) within {:.0}% of baseline {}",
        compared,
        tol * 100.0,
        baseline
    );
    Ok(())
}

fn report(what: &str, flops: f64, secs: f64, peak: f64) {
    let gf = telemetry::achieved_gflops(flops, secs);
    println!(
        "{}: {:.1} GFLOPS ({:.1}% of measured 1-core peak {:.1})",
        what,
        gf,
        100.0 * gf / peak,
        peak
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn throughput_drop_is_a_regression_and_rise_is_not() {
        let base = j(r#"{"throughput_rps": 100.0, "gflops": 50.0}"#);
        let worse = j(r#"{"throughput_rps": 40.0, "gflops": 50.0}"#);
        let (compared, regs) = perf_deltas(&base, &worse, 0.5);
        assert_eq!(compared, 2);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/throughput_rps") && regs[0].contains("drop"));
        // 10x better throughput is never a "regression".
        let better = j(r#"{"throughput_rps": 1000.0, "gflops": 500.0}"#);
        assert!(perf_deltas(&base, &better, 0.5).1.is_empty());
    }

    #[test]
    fn latency_rise_is_a_regression_and_drop_is_not() {
        let base = j(r#"{"p95_ms": 10.0, "p99_ms": 20.0, "throughput_rps": 100.0}"#);
        // p99 triples: beyond a 50% allowed rise. p95 halves: fine —
        // lower latency is the good direction.
        let cur = j(r#"{"p95_ms": 5.0, "p99_ms": 60.0, "throughput_rps": 100.0}"#);
        let (compared, regs) = perf_deltas(&base, &cur, 0.5);
        assert_eq!(compared, 3);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/p99_ms") && regs[0].contains("rise"));
        // Within tolerance on both axes: clean.
        let ok = j(r#"{"p95_ms": 12.0, "p99_ms": 25.0, "throughput_rps": 80.0}"#);
        assert!(perf_deltas(&base, &ok, 0.5).1.is_empty());
    }

    #[test]
    fn perf_leaves_pair_by_path_through_arrays_and_zero_baselines_skip() {
        // Rows pair by index, so appended rows in current are ignored and
        // a reordered baseline would not cross-compare.
        let base = j(r#"{"rows": [{"kwps": 5.0}, {"kwps": 0.0}]}"#);
        let cur = j(r#"{"rows": [{"kwps": 1.0}, {"kwps": 7.0}, {"useful_wps": 3.0}]}"#);
        let (compared, regs) = perf_deltas(&base, &cur, 0.5);
        // Both kwps paths exist in both docs; the zero baseline is
        // counted but never regresses.
        assert_eq!(compared, 2);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/rows/0/kwps"));
    }

    #[test]
    fn queue_wait_and_useful_wps_leaves_are_compared() {
        let base = j(r#"{"buckets": [{"queue_wait_ms": 2.0}], "useful_wps": 100.0}"#);
        let cur = j(r#"{"buckets": [{"queue_wait_ms": 9.0}], "useful_wps": 20.0}"#);
        let (compared, regs) = perf_deltas(&base, &cur, 0.5);
        assert_eq!(compared, 2);
        assert_eq!(regs.len(), 2, "{:?}", regs);
    }

    #[test]
    fn queue_depth_growth_is_a_regression_and_shrink_is_not() {
        // queue_depth_max is a backlog high-water mark: lower is better,
        // like a latency — a deeper queue at the same load is the
        // regression, a shallower one never is.
        let base = j(r#"{"queue_depth_max": 10.0, "p99_ms": 5.0}"#);
        let worse = j(r#"{"queue_depth_max": 40.0, "p99_ms": 5.0}"#);
        let (compared, regs) = perf_deltas(&base, &worse, 0.5);
        assert_eq!(compared, 2);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/queue_depth_max") && regs[0].contains("rise"));
        let better = j(r#"{"queue_depth_max": 2.0, "p99_ms": 4.0}"#);
        assert!(perf_deltas(&base, &better, 0.5).1.is_empty());
    }

    #[test]
    fn slo_attainment_and_budget_are_higher_is_better() {
        // Attainment dropping from 0.99 to 0.40 and the error budget
        // draining from 0.8 to 0.1 both regress; improvement never does.
        let base = j(r#"{"slo": {"slo_attainment": 0.99, "error_budget_remaining": 0.8}}"#);
        let worse = j(r#"{"slo": {"slo_attainment": 0.40, "error_budget_remaining": 0.1}}"#);
        let (compared, regs) = perf_deltas(&base, &worse, 0.5);
        assert_eq!(compared, 2);
        assert_eq!(regs.len(), 2, "{:?}", regs);
        assert!(regs.iter().any(|r| r.contains("/slo_attainment") && r.contains("drop")));
        assert!(regs.iter().any(|r| r.contains("/error_budget_remaining")));
        let better = j(r#"{"slo": {"slo_attainment": 1.0, "error_budget_remaining": 1.0}}"#);
        assert!(perf_deltas(&base, &better, 0.5).1.is_empty());
    }

    #[test]
    fn straggler_index_growth_is_a_regression_and_shrink_is_not() {
        // 1.0 is perfect balance; the index can only regress by rising.
        let base = j(r#"{"metrics": {}, "straggler_index": 1.05}"#);
        let worse = j(r#"{"metrics": {}, "straggler_index": 2.4}"#);
        let (compared, regs) = perf_deltas(&base, &worse, 0.5);
        assert_eq!(compared, 1);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/straggler_index") && regs[0].contains("rise"));
        let better = j(r#"{"metrics": {}, "straggler_index": 1.0}"#);
        assert!(perf_deltas(&base, &better, 0.5).1.is_empty());
    }

    #[test]
    fn mad_sibling_widens_the_allowance() {
        // Fixed 10% tolerance would flag 100 → 85; a recorded MAD of 6
        // widens the allowance to 3·6 = 18, so the dip is noise.
        let base = j(r#"{"rows": [{"kwps": 100.0, "kwps_mad": 6.0, "iters": 5}]}"#);
        let dip = j(r#"{"rows": [{"kwps": 85.0, "kwps_mad": 5.0, "iters": 5}]}"#);
        let (compared, regs) = perf_deltas(&base, &dip, 0.1);
        assert_eq!(compared, 1);
        assert!(regs.is_empty(), "{:?}", regs);
        // A synthetically slowed row falls past 3·MAD too: regression.
        let slowed = j(r#"{"rows": [{"kwps": 60.0, "kwps_mad": 5.0, "iters": 5}]}"#);
        let (_, regs) = perf_deltas(&base, &slowed, 0.1);
        assert_eq!(regs.len(), 1, "{:?}", regs);
        assert!(regs[0].contains("/rows/0/kwps") && regs[0].contains("MAD"));
        // Without a mad sibling, the old fixed-fraction gate applies.
        let nomad = j(r#"{"rows": [{"kwps": 100.0}]}"#);
        let (_, regs) = perf_deltas(&nomad, &dip, 0.1);
        assert_eq!(regs.len(), 1, "no sibling → 10% gate flags 85: {:?}", regs);
    }

    #[test]
    fn mad_widens_latency_allowance_symmetrically() {
        let base = j(r#"{"p99_ms": 10.0, "p99_ms_mad": 2.0}"#);
        // +50% rise but within 3·MAD = 6: noise.
        let noisy = j(r#"{"p99_ms": 15.0}"#);
        assert!(perf_deltas(&base, &noisy, 0.1).1.is_empty());
        // Beyond base + 3·MAD: regression.
        let worse = j(r#"{"p99_ms": 17.0}"#);
        assert_eq!(perf_deltas(&base, &worse, 0.1).1.len(), 1);
    }

    #[test]
    fn identical_run_never_regresses_regardless_of_mad() {
        let doc = j(r#"{"rows": [{"throughput_rps": 42.0, "throughput_rps_mad": 0.0}]}"#);
        let (compared, regs) = perf_deltas(&doc, &doc, 0.1);
        assert_eq!(compared, 1);
        assert!(regs.is_empty(), "self-compare must pass: {:?}", regs);
    }

    #[test]
    fn latest_entry_selects_newest_history_entry_or_flat_doc() {
        let hist = j(
            r#"{"note": "n", "history": [
                {"rev": "old", "rows": [{"kwps": 10.0}]},
                {"rev": "new", "rows": [{"kwps": 20.0}]}
            ]}"#,
        );
        let latest = latest_entry(&hist);
        assert_eq!(latest.get("rev").and_then(Json::as_str), Some("new"));
        // Newest-vs-newest self compare through the unwrap.
        assert!(perf_deltas(latest_entry(&hist), latest_entry(&hist), 0.1).1.is_empty());
        let flat = j(r#"{"rows": [{"kwps": 5.0}]}"#);
        assert!(std::ptr::eq(latest_entry(&flat), &flat), "flat doc is its own entry");
        // An empty history array degrades to the flat doc (no panic).
        let empty = j(r#"{"history": []}"#);
        assert!(std::ptr::eq(latest_entry(&empty), &empty));
    }

    #[test]
    fn trace_summary_counts_complete_spans_and_distinct_categories() {
        // Flow arrows (ph "s"/"f") must not count as spans; categories
        // come only from complete events.
        let doc = j(
            r#"{"traceEvents": [
                {"ph": "X", "cat": "serve.request", "name": "request"},
                {"ph": "X", "cat": "serve.batch", "name": "batch"},
                {"ph": "X", "cat": "serve.batch", "name": "batch"},
                {"ph": "s", "cat": "flow", "name": "served_in"},
                {"ph": "f", "cat": "flow", "name": "served_in"}
            ], "dropped_groups": 0}"#,
        );
        let (spans, cats) = trace_span_summary(&doc).unwrap();
        assert_eq!(spans, 3);
        assert_eq!(cats, vec!["serve.batch".to_string(), "serve.request".to_string()]);
        // Not a trace document at all.
        assert!(trace_span_summary(&j(r#"{"rows": []}"#)).is_none());
        // Empty traceEvents parses but carries zero spans.
        assert_eq!(trace_span_summary(&j(r#"{"traceEvents": []}"#)).unwrap().0, 0);
    }
}

fn cmd_xla(args: &Args) -> Result<()> {
    let entry = args.str("entry").unwrap_or("brgemm_demo");
    let iters = args.usize_or("iters", 5).map_err(|e| anyhow!("{}", e))?;
    let dir = args.str("artifacts").unwrap_or("artifacts");
    let rt = Runtime::cpu(Path::new(dir))?;
    let meta = rt.manifest.get(entry)?.clone();
    println!("{}: {}", entry, meta.desc);
    let mut rng = Rng::new(3);
    let inputs = synth_inputs(&meta.inputs, &mut rng);
    rt.warmup(&[entry])?;
    let (_, first) = rt.execute(entry, &inputs)?;
    let t0 = Instant::now();
    for _ in 0..iters {
        rt.execute(entry, &inputs)?;
    }
    let secs = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "first {:.2} ms, steady {:.2} ms/iter, {:.2} GFLOPS",
        first.secs * 1e3,
        secs * 1e3,
        meta.flops / secs / 1e9
    );
    Ok(())
}
