//! The tuner: prune a [`TuningSpace`](crate::autotune::space::TuningSpace)
//! with the analytic cost model, measure the shortlist through
//! [`crate::util::bench::measure`], rank empirically, and (optionally)
//! persist the winner in a [`TuningCache`].
//!
//! The config-default candidate is always force-included in the measured
//! shortlist, so the ranked table directly answers "did tuning beat the
//! seed blocking?" and the cached winner is by construction never slower
//! than the default (up to measurement noise).

use crate::autotune::cache::{conv_key, fc_key, lstm_key, TuneEntry, TuneKey, TuningCache};
use crate::autotune::costmodel::CostModel;
use crate::autotune::space::{self, Candidate, PrimKind, TuningSpace};
use crate::primitives::conv::{ConvConfig, ConvPrimitive};
use crate::primitives::fc::{FcConfig, FcPrimitive};
use crate::primitives::lstm::{LstmConfig, LstmPrimitive, LstmWeights, LstmWorkspace};
use crate::tensor::layout;
use crate::util::bench::{black_box, measure, Opts};
use crate::util::rng::Rng;

/// Tuning-run options.
#[derive(Debug, Clone, Copy)]
pub struct TuneOpts {
    /// How many model-ranked candidates get empirically measured.
    pub top_k: usize,
    /// Measurement protocol per candidate.
    pub bench: Opts,
    /// For FC: also measure the weight-update pass and rank by the summed
    /// time (enables the `upd_transpose` axis).
    pub train: bool,
}

impl TuneOpts {
    /// Fast default: enough repetitions to rank clearly separated
    /// candidates, bounded wall-clock per candidate.
    pub fn quick() -> TuneOpts {
        TuneOpts { top_k: 12, bench: Opts::quick(), train: false }
    }

    /// Thorough protocol for real tuning runs.
    pub fn full() -> TuneOpts {
        TuneOpts { top_k: 24, bench: Opts::full(), train: false }
    }

    pub fn with_train(mut self, train: bool) -> TuneOpts {
        self.train = train;
        self
    }

    pub fn with_top_k(mut self, k: usize) -> TuneOpts {
        self.top_k = k.max(1);
        self
    }
}

/// One measured candidate in the final ranking.
#[derive(Debug, Clone, Copy)]
pub struct Ranked {
    pub cand: Candidate,
    /// Analytic estimate (seconds) that earned it a shortlist slot.
    pub model_secs: f64,
    /// Measured best-of-N seconds.
    pub measured_secs: f64,
    pub gflops: f64,
}

/// Result of one tuning run.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub key: TuneKey,
    pub kind: PrimKind,
    /// Flops of the measured pass (forward; + update when `train`).
    pub flops: f64,
    /// Size of the generated space before the model cut.
    pub space_size: usize,
    /// Measured candidates, best (highest GFLOPS) first.
    pub ranked: Vec<Ranked>,
    /// Measured GFLOPS of the config-default candidate.
    pub default_gflops: f64,
}

impl TuneReport {
    /// The winner (the ranking is never empty: the default candidate is
    /// always measured).
    pub fn best(&self) -> &Ranked {
        &self.ranked[0]
    }

    /// Winner speedup over the config-default blocking.
    pub fn speedup_vs_default(&self) -> f64 {
        if self.default_gflops > 0.0 {
            self.best().gflops / self.default_gflops
        } else {
            1.0
        }
    }

    /// Cache entry for the winner.
    pub fn best_entry(&self) -> TuneEntry {
        let b = self.best();
        TuneEntry {
            cand: b.cand,
            gflops: b.gflops,
            model_gflops: if b.model_secs > 0.0 { self.flops / b.model_secs / 1e9 } else { 0.0 },
        }
    }

    /// Paper-style ranked candidate table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "\n== tuned {} | {} | {} of {} candidates measured ==\n",
            self.key.primitive,
            self.key.shape,
            self.ranked.len(),
            self.space_size
        ));
        out.push_str(&format!(
            "{:<4} {:<34} {:>12} {:>12} {:>10}\n",
            "rank", "candidate", "model GF/s", "meas GF/s", "vs-default"
        ));
        for (i, r) in self.ranked.iter().enumerate() {
            let model_gf = if r.model_secs > 0.0 { self.flops / r.model_secs / 1e9 } else { 0.0 };
            let rel = if self.default_gflops > 0.0 { r.gflops / self.default_gflops } else { 1.0 };
            out.push_str(&format!(
                "{:<4} {:<34} {:>12.2} {:>12.2} {:>9.2}x\n",
                i + 1,
                r.cand.label(self.kind),
                model_gf,
                r.gflops,
                rel
            ));
        }
        out.push_str(&format!(
            "winner: {}  ({:.2} GF/s, {:.2}x default)\n",
            self.best().cand.label(self.kind),
            self.best().gflops,
            self.speedup_vs_default()
        ));
        out
    }
}

/// Model-rank the space and return the measurement shortlist (always
/// containing the default candidate).
fn shortlist(
    space: &TuningSpace,
    topts: &TuneOpts,
    mut model_secs: impl FnMut(&Candidate) -> f64,
) -> Vec<(Candidate, f64)> {
    let mut scored: Vec<(Candidate, f64)> =
        space.candidates.iter().map(|c| (*c, model_secs(c))).collect();
    scored.sort_by(|a, b| a.1.total_cmp(&b.1));
    let mut short: Vec<(Candidate, f64)> = scored.iter().take(topts.top_k).copied().collect();
    if !short.iter().any(|(c, _)| *c == space.default) {
        let d = scored.iter().find(|(c, _)| *c == space.default).copied();
        short.push(d.unwrap_or((space.default, 0.0)));
    }
    short
}

fn rank(
    kind: PrimKind,
    key: TuneKey,
    flops: f64,
    space_size: usize,
    default: Candidate,
    mut measured: Vec<Ranked>,
) -> TuneReport {
    measured.sort_by(|a, b| b.gflops.total_cmp(&a.gflops));
    let default_gflops =
        measured.iter().find(|r| r.cand == default).map(|r| r.gflops).unwrap_or(0.0);
    TuneReport { key, kind, flops, space_size, ranked: measured, default_gflops }
}

/// Tune a convolution shape (forward pass).
pub fn tune_conv(cfg: &ConvConfig, topts: &TuneOpts) -> TuneReport {
    let space = space::conv_space(cfg);
    let model = CostModel::host();
    let short = shortlist(&space, topts, |c| model.conv_fwd(&space::apply_conv(*cfg, c)).secs());

    let mut rng = Rng::new(0xC0_FFEE);
    let x = rng.vec_f32(cfg.n * cfg.c * cfg.h * cfg.w, -1.0, 1.0);
    let w = rng.vec_f32(cfg.weights_len(), -0.3, 0.3);
    let flops = cfg.flops();

    let measured = short
        .into_iter()
        .map(|(cand, model_secs)| {
            let ccfg = space::apply_conv(*cfg, &cand);
            let prim = ConvPrimitive::new(ccfg);
            let xp = layout::pack_conv_act(&x, ccfg.n, ccfg.c, ccfg.h, ccfg.w, ccfg.bc, ccfg.pad, ccfg.pad);
            let wp = layout::pack_conv_weights(&w, ccfg.k, ccfg.c, ccfg.r, ccfg.s, ccfg.bk, ccfg.bc);
            let mut y = vec![0.0f32; ccfg.output_len()];
            let s = measure(topts.bench, || {
                prim.forward(&xp, &wp, None, &mut y);
                black_box(&y);
            });
            Ranked { cand, model_secs, measured_secs: s.min, gflops: flops / s.min / 1e9 }
        })
        .collect();
    rank(PrimKind::Conv, conv_key(cfg), flops, space.candidates.len(), space.default, measured)
}

/// Tune an FC shape (forward; + weight update when `opts.train`).
pub fn tune_fc(cfg: &FcConfig, topts: &TuneOpts) -> TuneReport {
    let space = space::fc_space(cfg, topts.train);
    let model = CostModel::host();
    let short = shortlist(&space, topts, |c| {
        let ccfg = space::apply_fc(*cfg, c);
        let mut secs = model.fc_fwd(&ccfg).secs();
        if topts.train {
            secs += model.fc_upd(&ccfg).secs();
        }
        secs
    });

    let mut rng = Rng::new(0xF0_0D);
    let x = rng.vec_f32(cfg.n * cfg.c, -1.0, 1.0);
    let w = rng.vec_f32(cfg.k * cfg.c, -0.5, 0.5);
    let bias = rng.vec_f32(cfg.k, -0.1, 0.1);
    let fwd_flops = cfg.flops();
    let flops = if topts.train { 2.0 * fwd_flops } else { fwd_flops };

    let measured = short
        .into_iter()
        .map(|(cand, model_secs)| {
            let ccfg = space::apply_fc(*cfg, &cand);
            let prim = FcPrimitive::new(ccfg);
            let xp = layout::pack_act_2d(&x, ccfg.n, ccfg.c, ccfg.bn, ccfg.bc);
            let wp = layout::pack_weights_2d(&w, ccfg.k, ccfg.c, ccfg.bk, ccfg.bc);
            let mut y = vec![0.0f32; ccfg.n * ccfg.k];
            let s = if topts.train {
                let dz = rng.vec_f32(ccfg.n * ccfg.k, -1.0, 1.0);
                let mut dw = vec![0.0f32; ccfg.k * ccfg.c];
                let mut db = vec![0.0f32; ccfg.k];
                measure(topts.bench, || {
                    prim.forward(&xp, &wp, &bias, &mut y);
                    prim.update(&xp, &dz, &mut dw, &mut db);
                    black_box(&y);
                    black_box(&dw);
                })
            } else {
                measure(topts.bench, || {
                    prim.forward(&xp, &wp, &bias, &mut y);
                    black_box(&y);
                })
            };
            Ranked { cand, model_secs, measured_secs: s.min, gflops: flops / s.min / 1e9 }
        })
        .collect();
    rank(PrimKind::Fc, fc_key(cfg), flops, space.candidates.len(), space.default, measured)
}

/// Tune an LSTM cell shape (forward pass over the configured sequence).
pub fn tune_lstm(cfg: &LstmConfig, topts: &TuneOpts) -> TuneReport {
    let space = space::lstm_space(cfg);
    let model = CostModel::host();
    let short = shortlist(&space, topts, |c| model.lstm_fwd(&space::apply_lstm(*cfg, c)).secs());

    let mut rng = Rng::new(0x15_73);
    let w: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(cfg.k * cfg.c, -0.3, 0.3)).collect();
    let r: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(cfg.k * cfg.k, -0.3, 0.3)).collect();
    let b: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_f32(cfg.k, -0.1, 0.1)).collect();
    let x = rng.vec_f32(cfg.t * cfg.n * cfg.c, -1.0, 1.0);
    let flops = cfg.fwd_flops();

    let measured = short
        .into_iter()
        .map(|(cand, model_secs)| {
            let ccfg = space::apply_lstm(*cfg, &cand);
            let prim = LstmPrimitive::new(ccfg);
            let wr: Vec<&[f32]> = w.iter().map(|v| v.as_slice()).collect();
            let rr: Vec<&[f32]> = r.iter().map(|v| v.as_slice()).collect();
            let br: Vec<&[f32]> = b.iter().map(|v| v.as_slice()).collect();
            let weights = LstmWeights::pack(ccfg, &wr, &rr, &br);
            let mut ws = LstmWorkspace::new(&ccfg);
            let s = measure(topts.bench, || {
                prim.forward(&x, None, None, &weights, &mut ws);
                black_box(&ws.h);
            });
            Ranked { cand, model_secs, measured_secs: s.min, gflops: flops / s.min / 1e9 }
        })
        .collect();
    rank(PrimKind::Lstm, lstm_key(cfg), flops, space.candidates.len(), space.default, measured)
}

/// Tune and persist the winner into `cache` (caller saves to disk).
pub fn tune_conv_cached(cfg: &ConvConfig, topts: &TuneOpts, cache: &mut TuningCache) -> TuneReport {
    let rep = tune_conv(cfg, topts);
    cache.put(&rep.key, rep.best_entry());
    rep
}

/// Tune and persist the winner into `cache` (caller saves to disk).
pub fn tune_fc_cached(cfg: &FcConfig, topts: &TuneOpts, cache: &mut TuningCache) -> TuneReport {
    let rep = tune_fc(cfg, topts);
    cache.put(&rep.key, rep.best_entry());
    rep
}

/// Tune and persist the winner into `cache` (caller saves to disk).
pub fn tune_lstm_cached(cfg: &LstmConfig, topts: &TuneOpts, cache: &mut TuningCache) -> TuneReport {
    let rep = tune_lstm(cfg, topts);
    cache.put(&rep.key, rep.best_entry());
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::eltwise::Act;

    fn tiny_opts() -> TuneOpts {
        TuneOpts {
            top_k: 4,
            bench: Opts { warmup_iters: 1, min_iters: 2, max_iters: 4, max_seconds: 0.05 },
            train: false,
        }
    }

    #[test]
    fn conv_tuning_ranks_and_includes_default() {
        let cfg = ConvConfig::new(1, 8, 8, 8, 8, 1, 1, 1, 0);
        let rep = tune_conv(&cfg, &tiny_opts());
        assert!(!rep.ranked.is_empty());
        assert!(rep.ranked.iter().any(|r| r.cand == space::conv_space(&cfg).default));
        assert!(rep.default_gflops > 0.0, "default candidate must be measured");
        // Ranking is sorted best-first.
        for w in rep.ranked.windows(2) {
            assert!(w[0].gflops >= w[1].gflops);
        }
        // Every measured candidate satisfies divisibility.
        for r in &rep.ranked {
            assert_eq!(cfg.c % r.cand.bc, 0);
            assert_eq!(cfg.k % r.cand.bk, 0);
            assert_eq!(cfg.q() % r.cand.bq, 0);
        }
        let table = rep.render();
        assert!(table.contains("winner:") && table.contains("vs-default"), "{}", table);
    }

    #[test]
    fn fc_tuning_with_cache_persists_winner() {
        let cfg = FcConfig::new(8, 16, 16, Act::Relu);
        let mut cache = TuningCache::empty();
        let rep = tune_fc_cached(&cfg, &tiny_opts().with_train(true), &mut cache);
        let hit = cache.get(&rep.key).expect("winner must be cached");
        assert_eq!(hit.cand, rep.best().cand);
        assert!(hit.gflops > 0.0);
    }

    #[test]
    fn lstm_tuning_runs() {
        let cfg = LstmConfig::new(4, 8, 8, 2);
        let rep = tune_lstm(&cfg, &tiny_opts());
        assert!(!rep.ranked.is_empty());
        assert!(rep.best().gflops > 0.0);
    }
}
