//! Tuning spaces: the candidate blockings / loop orders / kernel variants
//! the tuner considers for one problem shape.
//!
//! Candidates are generated from the shape under two hard constraints:
//!
//! * **divisibility** — every block factor divides its dimension (the
//!   packed layouts require it; enforced here *and* re-checked by the
//!   config `validate()` when a candidate is applied), and
//! * **footprint** — the per-call BRGEMM tile set (A strip + B panel + C
//!   accumulator block) must fit in L2; candidates that can never be
//!   cache-resident are not worth measuring.
//!
//! The spaces stay deliberately small (tens of candidates, not thousands):
//! block factors are drawn from divisors nearest the microkernel-friendly
//! targets rather than from all divisors, mirroring how PolyDL-style
//! systems sample the transformation space before the cost model ranks it.

use crate::perfmodel::CacheModel;
use crate::primitives::conv::{ConvConfig, FlatSpatial};
use crate::primitives::fc::FcConfig;
use crate::primitives::lstm::LstmConfig;
use crate::primitives::partition::Strategy;

pub use crate::util::num::largest_divisor_le;

/// Divisors of `dim` nearest (from below) to each target, deduplicated and
/// ascending — the per-dimension candidate set.
pub fn divisors_near(dim: usize, targets: &[usize]) -> Vec<usize> {
    let mut out: Vec<usize> = targets.iter().map(|&t| largest_divisor_le(dim, t)).collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// Which primitive a space / cache entry belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimKind {
    Conv,
    Fc,
    Lstm,
}

impl PrimKind {
    pub fn name(self) -> &'static str {
        match self {
            PrimKind::Conv => "conv",
            PrimKind::Fc => "fc",
            PrimKind::Lstm => "lstm",
        }
    }

    pub fn parse(s: &str) -> Option<PrimKind> {
        match s {
            "conv" => Some(PrimKind::Conv),
            "fc" => Some(PrimKind::Fc),
            "lstm" => Some(PrimKind::Lstm),
            _ => None,
        }
    }
}

/// One point of a tuning space. A single struct covers all primitives;
/// fields that do not apply are held at their neutral value (`bn`/`bq` = 1
/// resp. unused, `flat_bq` = 0, flags = false).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Mini-batch block (FC / LSTM).
    pub bn: usize,
    /// Input-feature block.
    pub bc: usize,
    /// Output-feature block.
    pub bk: usize,
    /// Output-pixel strip (conv).
    pub bq: usize,
    /// Spatially-collapsed pixel strip for eligible 1×1 convs; 0 = the
    /// collapse is disabled for this candidate.
    pub flat_bq: usize,
    /// Forward loop order / thread partition; `None` = shape heuristic.
    pub order: Option<Strategy>,
    /// FC forward through the strided BRGEMM variant.
    pub fwd_strided: bool,
    /// FC weight update through a physical transpose instead of the
    /// in-place `a_kstride` read.
    pub upd_transpose: bool,
}

impl Candidate {
    fn neutral() -> Candidate {
        Candidate {
            bn: 1,
            bc: 1,
            bk: 1,
            bq: 1,
            flat_bq: 0,
            order: None,
            fwd_strided: false,
            upd_transpose: false,
        }
    }

    /// Compact human-readable form for tables and logs.
    pub fn label(&self, kind: PrimKind) -> String {
        let mut s = match kind {
            PrimKind::Conv => format!("bc{} bk{} bq{}", self.bc, self.bk, self.bq),
            PrimKind::Fc | PrimKind::Lstm => {
                format!("bn{} bc{} bk{}", self.bn, self.bc, self.bk)
            }
        };
        if self.flat_bq > 0 {
            s.push_str(&format!(" flat{}", self.flat_bq));
        }
        if let Some(o) = self.order {
            s.push_str(match o {
                Strategy::MinibatchFirst => " ord=mb",
                Strategy::FeatureFirst => " ord=feat",
                Strategy::Flat => " ord=flat",
            });
        }
        if self.fwd_strided {
            s.push_str(" strided");
        }
        if self.upd_transpose {
            s.push_str(" updT");
        }
        s
    }
}

/// Serialise a loop-order choice for the JSON cache.
pub fn order_name(o: Option<Strategy>) -> &'static str {
    match o {
        None => "auto",
        Some(Strategy::MinibatchFirst) => "minibatch",
        Some(Strategy::FeatureFirst) => "feature",
        Some(Strategy::Flat) => "flat",
    }
}

/// Inverse of [`order_name`]; unknown strings fall back to `auto`.
pub fn order_parse(s: &str) -> Option<Strategy> {
    match s {
        "minibatch" => Some(Strategy::MinibatchFirst),
        "feature" => Some(Strategy::FeatureFirst),
        "flat" => Some(Strategy::Flat),
        _ => None,
    }
}

/// A generated candidate set for one problem shape.
#[derive(Debug, Clone)]
pub struct TuningSpace {
    pub kind: PrimKind,
    /// The candidate reproducing the config-default blocking (always a
    /// member of `candidates`, so "tuned" can never regress below it
    /// without the regression being visible in the ranked table).
    pub default: Candidate,
    pub candidates: Vec<Candidate>,
}

/// Per-call BRGEMM tile footprint in bytes: one A strip, one B panel and
/// the C accumulator block of a single k-step through the chain.
pub fn tile_footprint_bytes(m: usize, n: usize, k: usize) -> usize {
    (m * k + k * n + m * n) * 4
}

/// The candidate reproducing `cfg`'s *current* behaviour — including its
/// flat mode and loop-order override, so the tuner's "vs-default" baseline
/// is what this exact config would run, not a hardcoded assumption.
fn default_conv_candidate(cfg: &ConvConfig) -> Candidate {
    let pq = cfg.p() * cfg.q();
    let flat_bq = if conv_flat_legal(cfg) {
        match cfg.flat {
            FlatSpatial::Off => 0,
            FlatSpatial::Strip(s) => largest_divisor_le(pq, s.max(1)),
            FlatSpatial::Auto => largest_divisor_le(pq, 64),
        }
    } else {
        0
    };
    Candidate {
        bc: cfg.bc,
        bk: cfg.bk,
        bq: cfg.bq,
        flat_bq,
        order: cfg.par_strategy,
        ..Candidate::neutral()
    }
}

fn conv_flat_legal(cfg: &ConvConfig) -> bool {
    cfg.r == 1 && cfg.s == 1 && cfg.stride == 1 && cfg.pad == 0
}

/// Candidate blockings for a convolution shape.
pub fn conv_space(cfg: &ConvConfig) -> TuningSpace {
    let caches = CacheModel::host_default();
    let q = cfg.q();
    let pq = cfg.p() * q;
    let bcs = divisors_near(cfg.c, &[16, 32, 64, 128]);
    let bks = divisors_near(cfg.k, &[16, 32, 64, 128]);
    let bqs = divisors_near(q, &[8, 14, 28, 64, q]);
    let flats: Vec<usize> =
        if conv_flat_legal(cfg) { divisors_near(pq, &[32, 64, 128]) } else { Vec::new() };
    let orders: &[Option<Strategy>] = &[None, Some(Strategy::FeatureFirst)];

    let mut candidates = Vec::new();
    for &bc in &bcs {
        for &bk in &bks {
            for &order in orders {
                // Tap-loop candidates: explore the bq strip axis.
                // Footprint: the kernel works on (bq×bc)·(bc×bk) tiles.
                for &bq in &bqs {
                    if tile_footprint_bytes(bq, bk, bc) > caches.l2_bytes {
                        continue;
                    }
                    candidates.push(Candidate { bc, bk, bq, order, ..Candidate::neutral() });
                }
                // Spatially-collapsed candidates: the flat path never reads
                // `bq`, so it is pinned to the config default — otherwise
                // every flat strip would appear |bqs| times with identical
                // behaviour and crowd the measurement shortlist with ties.
                for &flat_bq in &flats {
                    if tile_footprint_bytes(flat_bq, bk, bc) > caches.l2_bytes {
                        continue;
                    }
                    candidates.push(Candidate {
                        bc,
                        bk,
                        bq: cfg.bq,
                        flat_bq,
                        order,
                        ..Candidate::neutral()
                    });
                }
            }
        }
    }
    let default = default_conv_candidate(cfg);
    if !candidates.contains(&default) {
        candidates.push(default);
    }
    TuningSpace { kind: PrimKind::Conv, default, candidates }
}

/// Apply a conv candidate to a config (blocking, flat mode, loop order).
pub fn apply_conv(cfg: ConvConfig, cand: &Candidate) -> ConvConfig {
    let mut cfg = cfg.with_blocking(cand.bc, cand.bk, cand.bq);
    cfg.flat = if cand.flat_bq > 0 { FlatSpatial::Strip(cand.flat_bq) } else { FlatSpatial::Off };
    cfg.par_strategy = cand.order;
    cfg
}

fn default_fc_candidate(cfg: &FcConfig) -> Candidate {
    Candidate {
        bn: cfg.bn,
        bc: cfg.bc,
        bk: cfg.bk,
        order: cfg.par_strategy,
        fwd_strided: cfg.fwd_strided,
        upd_transpose: cfg.upd_transpose,
        ..Candidate::neutral()
    }
}

/// Candidate blockings for an FC shape. With `train` the weight-update
/// variant axis (`upd_transpose`) is included; for inference-only tuning
/// it would only duplicate forward measurements.
pub fn fc_space(cfg: &FcConfig, train: bool) -> TuningSpace {
    let caches = CacheModel::host_default();
    let bns = divisors_near(cfg.n, &[8, 16, 24, 32, 64]);
    let bcs = divisors_near(cfg.c, &[16, 32, 64, 128]);
    let bks = divisors_near(cfg.k, &[16, 32, 64, 128]);
    let upds: &[bool] = if train { &[false, true] } else { &[false] };
    let mut candidates = Vec::new();
    for &bn in &bns {
        for &bc in &bcs {
            for &bk in &bks {
                if tile_footprint_bytes(bn, bk, bc) > caches.l2_bytes {
                    continue;
                }
                for &fwd_strided in &[false, true] {
                    for &upd_transpose in upds {
                        candidates.push(Candidate {
                            bn,
                            bc,
                            bk,
                            fwd_strided,
                            upd_transpose,
                            ..Candidate::neutral()
                        });
                    }
                }
            }
        }
    }
    let default = default_fc_candidate(cfg);
    if !candidates.contains(&default) {
        candidates.push(default);
    }
    TuningSpace { kind: PrimKind::Fc, default, candidates }
}

/// Apply an FC candidate to a config.
pub fn apply_fc(cfg: FcConfig, cand: &Candidate) -> FcConfig {
    let mut cfg = cfg
        .with_blocking(cand.bn, cand.bc, cand.bk)
        .with_fwd_strided(cand.fwd_strided)
        .with_upd_transpose(cand.upd_transpose);
    cfg.par_strategy = cand.order;
    cfg
}

fn default_lstm_candidate(cfg: &LstmConfig) -> Candidate {
    Candidate { bn: cfg.bn, bc: cfg.bc, bk: cfg.bk, ..Candidate::neutral() }
}

/// Candidate blockings for an LSTM cell shape (the W·x and R·h chains
/// share `bn`/`bk`; `bc` only shapes the W·x chain).
pub fn lstm_space(cfg: &LstmConfig) -> TuningSpace {
    let caches = CacheModel::host_default();
    let bns = divisors_near(cfg.n, &[8, 16, 24, 32]);
    let bcs = divisors_near(cfg.c, &[16, 32, 64]);
    let bks = divisors_near(cfg.k, &[16, 32, 64]);
    let mut candidates = Vec::new();
    for &bn in &bns {
        for &bc in &bcs {
            for &bk in &bks {
                // Both chains must fit: W·x tiles (bn×bc→bk) and R·h
                // tiles (bn×bk→bk).
                if tile_footprint_bytes(bn, bk, bc.max(bk)) > caches.l2_bytes {
                    continue;
                }
                candidates.push(Candidate { bn, bc, bk, ..Candidate::neutral() });
            }
        }
    }
    let default = default_lstm_candidate(cfg);
    if !candidates.contains(&default) {
        candidates.push(default);
    }
    TuningSpace { kind: PrimKind::Lstm, default, candidates }
}

/// Apply an LSTM candidate to a config.
pub fn apply_lstm(cfg: LstmConfig, cand: &Candidate) -> LstmConfig {
    cfg.with_blocking(cand.bn, cand.bc, cand.bk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::eltwise::Act;
    use crate::util::prop::Prop;

    #[test]
    fn divisor_helpers() {
        assert_eq!(largest_divisor_le(64, 48), 32);
        assert_eq!(largest_divisor_le(64, 64), 64);
        assert_eq!(largest_divisor_le(64, 1000), 64);
        assert_eq!(largest_divisor_le(7, 4), 1);
        assert_eq!(divisors_near(56, &[8, 14, 28, 64, 56]), vec![8, 14, 28, 56]);
    }

    #[test]
    fn conv_space_contains_default_and_is_bounded() {
        let cfg = ConvConfig::new(1, 64, 256, 56, 56, 1, 1, 1, 0);
        let space = conv_space(&cfg);
        assert!(space.candidates.contains(&space.default));
        assert!(!space.candidates.is_empty());
        assert!(space.candidates.len() < 2000, "space exploded: {}", space.candidates.len());
        // 1×1/s1/p0 must offer both flat and non-flat candidates.
        assert!(space.candidates.iter().any(|c| c.flat_bq > 0));
        assert!(space.candidates.iter().any(|c| c.flat_bq == 0));
    }

    #[test]
    fn non_1x1_space_has_no_flat_candidates() {
        let cfg = ConvConfig::new(1, 64, 64, 28, 28, 3, 3, 1, 1);
        let space = conv_space(&cfg);
        assert!(space.candidates.iter().all(|c| c.flat_bq == 0));
    }

    #[test]
    fn applying_candidates_round_trips_exactly() {
        // Candidates are exact divisors, so with_blocking's rounding must
        // be the identity when applying them.
        let cfg = ConvConfig::new(2, 48, 96, 14, 14, 3, 3, 1, 1);
        for cand in &conv_space(&cfg).candidates {
            let applied = apply_conv(cfg, cand);
            assert_eq!((applied.bc, applied.bk, applied.bq), (cand.bc, cand.bk, cand.bq));
        }
        let fcfg = FcConfig::new(24, 48, 96, Act::Relu);
        for cand in &fc_space(&fcfg, true).candidates {
            let applied = apply_fc(fcfg, cand);
            assert_eq!((applied.bn, applied.bc, applied.bk), (cand.bn, cand.bc, cand.bk));
            assert_eq!(applied.fwd_strided, cand.fwd_strided);
            assert_eq!(applied.upd_transpose, cand.upd_transpose);
        }
    }

    #[test]
    fn order_names_round_trip() {
        for o in [
            None,
            Some(Strategy::MinibatchFirst),
            Some(Strategy::FeatureFirst),
            Some(Strategy::Flat),
        ] {
            assert_eq!(order_parse(order_name(o)), o);
        }
        assert_eq!(order_parse("garbage"), None);
    }

    #[test]
    fn property_every_candidate_satisfies_divisibility() {
        Prop::new("tuning-space candidates divide their dimensions").cases(40).run(|g| {
            // Random conv shape.
            let c = g.usize(1..=16) * g.usize(1..=8);
            let k = g.usize(1..=16) * g.usize(1..=8);
            let r = *g.choose(&[1usize, 3]);
            let pad = if r == 1 { 0 } else { 1 };
            let h = g.usize(r.max(4)..=30);
            let w = g.usize(r.max(4)..=30);
            let cfg = ConvConfig::new(g.usize(1..=4), c, k, h, w, r, r, 1, pad);
            let space = conv_space(&cfg);
            for cand in &space.candidates {
                if cfg.c % cand.bc != 0 || cfg.k % cand.bk != 0 || cfg.q() % cand.bq != 0 {
                    return Err(format!("conv cand {:?} violates divisibility for {:?}", cand, cfg));
                }
                if cand.flat_bq > 0 && (cfg.p() * cfg.q()) % cand.flat_bq != 0 {
                    return Err(format!("conv cand {:?}: flat strip ∤ P·Q", cand));
                }
            }
            // Random FC shape.
            let n = g.usize(1..=8) * g.usize(1..=8);
            let fcfg = FcConfig::new(n, c, k, Act::Relu);
            for cand in &fc_space(&fcfg, g.bool()).candidates {
                if fcfg.n % cand.bn != 0 || fcfg.c % cand.bc != 0 || fcfg.k % cand.bk != 0 {
                    return Err(format!("fc cand {:?} violates divisibility", cand));
                }
            }
            // Random LSTM shape.
            let lcfg = LstmConfig::new(n, c, k, 2);
            for cand in &lstm_space(&lcfg).candidates {
                if lcfg.n % cand.bn != 0 || lcfg.c % cand.bc != 0 || lcfg.k % cand.bk != 0 {
                    return Err(format!("lstm cand {:?} violates divisibility", cand));
                }
            }
            Ok(())
        });
    }
}
