//! Analytic cost model: scores a candidate *before* anything is measured,
//! so the tuner only spends wall-clock on the shortlist.
//!
//! The model combines the two effects that dominate blocking choices on
//! this kernel (and that PolyDL/PolyScientist-style systems model the same
//! way):
//!
//! 1. **Microkernel fill** — the register tile is `MR` rows × a whole
//!    number of vectors; a blocking whose output block is, say, 7×17 wastes
//!    lanes in the masked tail vector and rows in the remainder tile. This
//!    scales the attainable compute roof.
//! 2. **Roofline traffic** — per-call operand bytes vs. the bandwidth
//!    roof, with one reuse refinement: when a work-group's B panel (the
//!    weights of one output-feature block) fits in L2, its traffic is
//!    charged once per group instead of once per call.
//!
//! The output is an estimated execution time; candidates are ranked
//! ascending. The estimate does not need to be *accurate* — it needs to be
//! *monotone enough* that the true winner survives the shortlist cut,
//! which the `abl02_autotune` bench checks empirically.

use crate::brgemm::Isa;
use crate::perfmodel::{host_platform, CacheModel, PlatformModel};
use crate::primitives::conv::ConvConfig;
use crate::primitives::fc::FcConfig;
use crate::primitives::lstm::LstmConfig;

/// Cost estimate for one candidate configuration.
#[derive(Debug, Clone, Copy)]
pub struct Cost {
    pub flops: f64,
    /// Modelled DRAM traffic in bytes.
    pub bytes: f64,
    /// Compute-roof seconds after the microkernel-fill derating.
    pub flop_secs: f64,
    /// Bandwidth-roof seconds.
    pub mem_secs: f64,
}

impl Cost {
    /// Roofline: the binding roof is the estimate.
    pub fn secs(&self) -> f64 {
        self.flop_secs.max(self.mem_secs)
    }

    pub fn model_gflops(&self) -> f64 {
        self.flops / self.secs() / 1e9
    }
}

/// The model: a platform (peak + bandwidth), a cache hierarchy and the ISA
/// whose register-tile geometry derates partially-filled tiles.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub platform: PlatformModel,
    pub caches: CacheModel,
    pub isa: Isa,
}

impl CostModel {
    /// Model of this host: measured peak, default cache sizes, detected ISA.
    pub fn host() -> CostModel {
        CostModel { platform: host_platform(), caches: CacheModel::host_default(), isa: Isa::detect() }
    }

    /// Fixed-platform model (deterministic; used by tests and by callers
    /// that must not pay the peak-probe).
    pub fn with_platform(platform: PlatformModel, isa: Isa) -> CostModel {
        CostModel { platform, caches: CacheModel::host_default(), isa }
    }

    /// Fraction of the register tile a `(m × n)` output block keeps busy:
    /// lanes in the last (masked) vector and rows in the remainder tile.
    pub fn kernel_fill(&self, m: usize, n: usize) -> f64 {
        let (mr, lanes) = self.isa.microkernel_tile();
        let eff_n = n as f64 / (lanes * n.div_ceil(lanes)) as f64;
        let eff_m = m as f64 / (mr * m.div_ceil(mr)) as f64;
        eff_m * eff_n
    }

    /// Cost of a BRGEMM loop nest: `calls` kernel invocations, each a
    /// batch-`batch` chain of `(m×k)·(k×n)` products. `b_group_calls` is
    /// how many consecutive calls share the same B chain (weight reuse);
    /// if that chain fits in L2 its traffic is charged once per group.
    #[allow(clippy::too_many_arguments)]
    pub fn nest(&self, calls: f64, batch: f64, m: f64, n: f64, k: f64, b_group_calls: f64) -> Cost {
        let flops = 2.0 * calls * batch * m * n * k;
        let fill = self.kernel_fill(m as usize, n as usize).max(1e-3);
        let flop_secs = flops / (self.platform.peak_gflops_f32 * 1e9 * fill);

        let a_call = batch * m * k * 4.0;
        let b_chain = batch * k * n * 4.0;
        let c_call = m * n * 4.0 * 2.0; // written + (potentially) re-read
        let b_bytes = if b_chain <= (self.caches.l2_bytes / 2) as f64 && b_group_calls > 1.0 {
            calls / b_group_calls * b_chain
        } else {
            calls * b_chain
        };
        let bytes = calls * (a_call + c_call) + b_bytes;
        let mem_secs = bytes / (self.platform.stream_gbs * 1e9);
        Cost { flops, bytes, flop_secs, mem_secs }
    }

    /// Forward-pass cost of a convolution config (the pass the tuner
    /// measures; bwd/upd share the blocking, so ranking by fwd is the
    /// same proxy the paper's hand-tuning used).
    pub fn conv_fwd(&self, cfg: &ConvConfig) -> Cost {
        let (p, q) = (cfg.p(), cfg.q());
        let cb = cfg.cb_ct() as f64;
        let kb = cfg.kb_ct() as f64;
        let flat = cfg.r == 1
            && cfg.s == 1
            && cfg.stride == 1
            && cfg.pad == 0
            && !matches!(cfg.flat, crate::primitives::conv::FlatSpatial::Off);
        if flat {
            let pq = (p * q) as f64;
            let strip = match cfg.flat {
                crate::primitives::conv::FlatSpatial::Strip(s) => {
                    crate::autotune::space::largest_divisor_le(p * q, s.max(1)) as f64
                }
                _ => crate::autotune::space::largest_divisor_le(p * q, 64) as f64,
            };
            let calls = cfg.n as f64 * kb * (pq / strip);
            // One (n, kb) group shares the kb weight chain across pq/strip calls.
            self.nest(calls, cb, strip, cfg.bk as f64, cfg.bc as f64, pq / strip)
        } else {
            let calls = cfg.n as f64 * kb * p as f64 * (q as f64 / cfg.bq as f64);
            let batch = cfg.r as f64 * cfg.s as f64 * cb;
            let group = p as f64 * q as f64 / cfg.bq as f64;
            self.nest(calls, batch, cfg.bq as f64, cfg.bk as f64, cfg.bc as f64, group)
        }
    }

    /// Forward-pass cost of an FC config.
    pub fn fc_fwd(&self, cfg: &FcConfig) -> Cost {
        let (nb, cb, kb) = (cfg.nb() as f64, cfg.cb() as f64, cfg.kb() as f64);
        // MinibatchFirst iterates the batch innermost → nb calls share one
        // weight-column chain.
        self.nest(nb * kb, cb, cfg.bn as f64, cfg.bk as f64, cfg.bc as f64, nb)
    }

    /// Weight-update cost of an FC config, including the physical
    /// transpose's copy traffic when that variant is selected.
    pub fn fc_upd(&self, cfg: &FcConfig) -> Cost {
        let (nb, cb, kb) = (cfg.nb() as f64, cfg.cb() as f64, cfg.kb() as f64);
        let mut cost = self.nest(kb * cb, nb, cfg.bc as f64, cfg.bk as f64, cfg.bn as f64, cb);
        if cfg.upd_transpose {
            // X is rewritten once per call: read + write of N·C floats.
            let copy_bytes = 2.0 * (cfg.n * cfg.c * 4) as f64;
            cost.bytes += copy_bytes;
            cost.mem_secs += copy_bytes / (self.platform.stream_gbs * 1e9);
        } else {
            // The in-place a_kstride walk touches one cache line per k-step
            // once bc*4 exceeds a line: derate the A traffic accordingly.
            let line = self.caches.line_bytes as f64;
            let astride_bytes = (cfg.bc * 4) as f64;
            if astride_bytes > line {
                let waste = (astride_bytes / line).min(16.0);
                let extra = (kb * cb) * nb * (cfg.bc * cfg.bn) as f64 * 4.0 * (waste - 1.0);
                cost.bytes += extra;
                cost.mem_secs += extra / (self.platform.stream_gbs * 1e9);
            }
        }
        cost
    }

    /// Forward-pass cost of one LSTM cell sweep: per time-step, the W·x
    /// chain (k = bc·Cb) and the R·h chain (k = bk·Kb), for 4 gates.
    pub fn lstm_fwd(&self, cfg: &LstmConfig) -> Cost {
        let (nb, cb, kb) = (cfg.nb() as f64, cfg.cb() as f64, cfg.kb() as f64);
        let gates = crate::primitives::lstm::GATES as f64;
        let t = cfg.t as f64;
        let wx = self.nest(t * gates * nb * kb, cb, cfg.bn as f64, cfg.bk as f64, cfg.bc as f64, nb);
        let rh = self.nest(t * gates * nb * kb, kb, cfg.bn as f64, cfg.bk as f64, cfg.bk as f64, nb);
        Cost {
            flops: wx.flops + rh.flops,
            bytes: wx.bytes + rh.bytes,
            flop_secs: wx.flop_secs + rh.flop_secs,
            mem_secs: wx.mem_secs + rh.mem_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::eltwise::Act;

    fn model() -> CostModel {
        // Fixed platform so tests are deterministic and probe-free.
        let p = PlatformModel { name: "test", peak_gflops_f32: 100.0, cores: 1, stream_gbs: 10.0 };
        CostModel::with_platform(p, Isa::Avx512)
    }

    #[test]
    fn kernel_fill_prefers_tile_multiples() {
        let m = model();
        assert!((m.kernel_fill(6, 64) - 1.0).abs() < 1e-12, "full tile fills completely");
        assert!(m.kernel_fill(7, 64) < m.kernel_fill(6, 64), "remainder row derates");
        assert!(m.kernel_fill(6, 17) < m.kernel_fill(6, 16), "masked tail lane derates");
        assert!(m.kernel_fill(1, 1) > 0.0);
    }

    #[test]
    fn conv_cost_is_positive_and_flops_exact() {
        let m = model();
        let cfg = ConvConfig::new(1, 64, 64, 28, 28, 3, 3, 1, 1);
        let c = m.conv_fwd(&cfg);
        assert!(c.secs() > 0.0 && c.bytes > 0.0);
        assert!((c.flops - cfg.flops()).abs() / cfg.flops() < 1e-9, "model flops must match");
    }

    #[test]
    fn cost_penalises_tiny_feature_blocks() {
        // bk = 4 wastes 12 of 16 lanes; the model must rank it worse than
        // the lane-filling bk = 64 at identical flops.
        let m = model();
        let good = ConvConfig::new(1, 64, 64, 28, 28, 3, 3, 1, 1).with_blocking(64, 64, 28);
        let bad = good.with_blocking(64, 4, 28);
        assert!(m.conv_fwd(&bad).secs() > m.conv_fwd(&good).secs());
    }

    #[test]
    fn fc_upd_transpose_charges_copy_traffic() {
        let m = model();
        let cfg = FcConfig::new(64, 256, 256, Act::Relu);
        let inplace = m.fc_upd(&cfg);
        let transposed = m.fc_upd(&cfg.with_upd_transpose(true));
        assert!(transposed.bytes > 0.0 && inplace.bytes > 0.0);
        // Both variants charge *something* beyond the bare GEMM traffic;
        // which wins is shape-dependent — just require finite, distinct
        // accounting.
        assert!((transposed.bytes - inplace.bytes).abs() > 0.0);
    }

    #[test]
    fn lstm_cost_scales_with_sequence_length() {
        let m = model();
        let short = m.lstm_fwd(&LstmConfig::new(16, 64, 64, 2));
        let long = m.lstm_fwd(&LstmConfig::new(16, 64, 64, 8));
        assert!(long.secs() > 3.0 * short.secs());
    }
}
