//! Autotuner: cost-model-guided loop/blocking search with a persistent
//! tuning cache.
//!
//! The paper's closing argument is that once BRGEMM is the sole optimized
//! kernel, "DL library-development degenerates to mere (potentially
//! automatic) tuning of loops" around it. This subsystem is that automatic
//! tuning, in the PolyDL / PolyScientist shape (arXiv 2006.02230,
//! 2002.02145): an analytic model prunes the transformation space, and
//! empirical measurement picks the winner among the survivors.
//!
//! Pipeline, one module per stage:
//!
//! * [`space`] — generate the candidate set for a problem shape: block
//!   factors (`bc`/`bk`/`bn`/`bq`), loop orders, and BRGEMM variants
//!   (address-list vs. strided forward, in-place `a_kstride` vs. physical
//!   transpose update, spatial collapse strips), under divisibility and
//!   cache-footprint constraints.
//! * [`costmodel`] — score candidates analytically on [`crate::perfmodel`]
//!   primitives (microkernel register-tile fill × roofline traffic with an
//!   L2 weight-reuse refinement) so only a shortlist is ever measured.
//! * [`tuner`] — measure the shortlist through [`crate::util::bench`],
//!   rank empirically, report a candidate table.
//! * [`cache`] — persist winners as JSON keyed by problem shape + ISA +
//!   thread count; loaded process-wide once, consulted by the `tuned()`
//!   constructors ([`ConvPrimitive::tuned`](crate::primitives::conv::ConvPrimitive::tuned),
//!   [`FcPrimitive::tuned`](crate::primitives::fc::FcPrimitive::tuned),
//!   [`LstmPrimitive::tuned`](crate::primitives::lstm::LstmPrimitive::tuned)).
//!
//! End-to-end entry points: the `tune` CLI subcommand populates the cache;
//! `RunConfig { tune: true }` tunes a training run's layer shapes before
//! the first step; the `abl02_autotune` bench quantifies tuned vs. default
//! blockings on ResNet-50 layer shapes.

pub mod cache;
pub mod costmodel;
pub mod space;
pub mod tuner;

pub use cache::{TuneEntry, TuneKey, TuningCache};
pub use costmodel::{Cost, CostModel};
pub use space::{Candidate, PrimKind, TuningSpace};
pub use tuner::{TuneOpts, TuneReport};

use crate::primitives::conv::ConvConfig;
use crate::primitives::fc::FcConfig;
use crate::primitives::lstm::LstmConfig;

/// Apply the globally cached winner for this conv shape, if any.
/// Exact-key lookup means a hit always satisfies the shape's divisibility
/// constraints; a miss returns the config unchanged.
pub fn tuned_conv_config(cfg: ConvConfig) -> ConvConfig {
    let key = cache::conv_key(&cfg);
    let hit = TuningCache::global().lock().unwrap().get(&key).map(|e| e.cand);
    match hit {
        Some(cand) => space::apply_conv(cfg, &cand),
        None => cfg,
    }
}

/// Apply the globally cached winner for this FC shape, if any.
pub fn tuned_fc_config(cfg: FcConfig) -> FcConfig {
    let key = cache::fc_key(&cfg);
    let hit = TuningCache::global().lock().unwrap().get(&key).map(|e| e.cand);
    match hit {
        Some(cand) => space::apply_fc(cfg, &cand),
        None => cfg,
    }
}

/// Apply the globally cached winner for this LSTM cell shape, if any.
pub fn tuned_lstm_config(cfg: LstmConfig) -> LstmConfig {
    let key = cache::lstm_key(&cfg);
    let hit = TuningCache::global().lock().unwrap().get(&key).map(|e| e.cand);
    match hit {
        Some(cand) => space::apply_lstm(cfg, &cand),
        None => cfg,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::conv::ConvPrimitive;
    use crate::primitives::eltwise::Act;
    use crate::primitives::fc::FcPrimitive;

    // These tests share the process-global cache with each other (tests
    // run concurrently), so each uses a shape no other test touches.

    #[test]
    fn tuned_constructors_are_identity_on_cache_miss() {
        let cfg = ConvConfig::new(1, 10, 10, 9, 9, 3, 3, 1, 1);
        // Force a miss: the global cache may have loaded a tuning_cache.json
        // from the working directory.
        TuningCache::global().lock().unwrap().remove(&cache::conv_key(&cfg));
        let tuned = tuned_conv_config(cfg);
        assert_eq!((tuned.bc, tuned.bk, tuned.bq), (cfg.bc, cfg.bk, cfg.bq));
        let prim = ConvPrimitive::tuned(cfg); // must construct fine
        assert_eq!(prim.cfg.bc, cfg.bc);
    }

    #[test]
    fn tuned_constructor_applies_cached_entry() {
        let cfg = ConvConfig::new(1, 20, 20, 11, 11, 3, 3, 1, 1); // unique shape
        let key = cache::conv_key(&cfg);
        let cand = Candidate { bc: 10, bk: 5, bq: 11, ..cache_neutral() };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&key, TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });
        let prim = ConvPrimitive::tuned(cfg);
        assert_eq!((prim.cfg.bc, prim.cfg.bk, prim.cfg.bq), (10, 5, 11));
    }

    #[test]
    fn tuned_fc_applies_variants() {
        let cfg = FcConfig::new(14, 21, 35, Act::Relu); // unique shape
        let key = cache::fc_key(&cfg);
        let cand =
            Candidate { bn: 7, bc: 21, bk: 35, fwd_strided: true, ..cache_neutral() };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&key, TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });
        let tuned = tuned_fc_config(cfg);
        assert_eq!((tuned.bn, tuned.bc, tuned.bk), (7, 21, 35));
        assert!(tuned.fwd_strided);
        // And the primitive constructs + runs with it.
        let prim = FcPrimitive::tuned(cfg);
        assert!(prim.cfg.fwd_strided);
    }

    #[test]
    fn lstm_cache_entry_is_keyed_by_sequence_length() {
        use crate::primitives::lstm::LstmPrimitive;
        // Unique (n, c, k) so no other test's entries collide. Cache a
        // winner for T=5: it must apply at T=5 and be invisible at T=9 —
        // the satellite regression for the T-less key bug.
        let cfg5 = LstmConfig::new(6, 18, 12, 5);
        let cfg9 = LstmConfig::new(6, 18, 12, 9);
        let cand = Candidate { bn: 3, bc: 9, bk: 6, ..cache_neutral() };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&cache::lstm_key(&cfg5), TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });
        TuningCache::global().lock().unwrap().remove(&cache::lstm_key(&cfg9));
        let hit = tuned_lstm_config(cfg5);
        assert_eq!((hit.bn, hit.bc, hit.bk), (3, 9, 6), "same T applies the winner");
        let miss = tuned_lstm_config(cfg9);
        assert_eq!(
            (miss.bn, miss.bc, miss.bk),
            (cfg9.bn, cfg9.bc, cfg9.bk),
            "a different T must be a cache miss, not a cross-T hit"
        );
        // And the tuned constructor builds fine either way.
        let prim = LstmPrimitive::tuned(cfg5);
        assert_eq!((prim.cfg.bn, prim.cfg.bc, prim.cfg.bk), (3, 9, 6));
    }

    fn cache_neutral() -> Candidate {
        Candidate {
            bn: 1,
            bc: 1,
            bk: 1,
            bq: 1,
            flat_bq: 0,
            order: None,
            fwd_strided: false,
            upd_transpose: false,
        }
    }
}
