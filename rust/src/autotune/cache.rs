//! Persistent tuning cache: maps (primitive, problem shape, ISA, thread
//! count) → the winning [`Candidate`] of a past tuning run, stored as JSON
//! (via [`crate::util::json`]) so results survive across processes.
//!
//! Lookup is exact-key: a cache entry only ever applies to the identical
//! shape it was tuned for, on the same ISA, at the same thread count — so
//! applying an entry can never violate a divisibility constraint (the
//! `with_blocking` rounding is a belt-and-braces no-op on hits).
//!
//! The process-wide [`TuningCache::global`] instance is what the
//! `tuned()` primitive constructors consult; it is loaded once from
//! [`TuningCache::default_path`] (`$BRGEMM_TUNE_CACHE` or
//! `tuning_cache.json`).

use crate::autotune::space::{order_name, order_parse, Candidate};
use crate::brgemm::Isa;
use crate::primitives::conv::ConvConfig;
use crate::primitives::fc::FcConfig;
use crate::primitives::lstm::LstmConfig;
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

/// Cache-key components; [`TuneKey::id`] is the canonical string form.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuneKey {
    pub primitive: String,
    pub shape: String,
    pub isa: String,
    pub nthreads: usize,
}

impl TuneKey {
    pub fn id(&self) -> String {
        format!("{}|{}|isa={}|t={}", self.primitive, self.shape, self.isa, self.nthreads)
    }
}

/// Key for a convolution shape (detected ISA).
pub fn conv_key(cfg: &ConvConfig) -> TuneKey {
    TuneKey {
        primitive: "conv".to_string(),
        shape: format!(
            "n{} c{} k{} h{} w{} r{} s{} st{} p{}",
            cfg.n, cfg.c, cfg.k, cfg.h, cfg.w, cfg.r, cfg.s, cfg.stride, cfg.pad
        ),
        isa: Isa::detect().name().to_string(),
        nthreads: cfg.nthreads,
    }
}

/// Key for an FC shape. The activation is irrelevant to blocking choice
/// and is deliberately excluded.
pub fn fc_key(cfg: &FcConfig) -> TuneKey {
    TuneKey {
        primitive: "fc".to_string(),
        shape: format!("n{} c{} k{}", cfg.n, cfg.c, cfg.k),
        isa: Isa::detect().name().to_string(),
        nthreads: cfg.nthreads,
    }
}

/// Key for an LSTM cell shape. The sequence length **is** part of the
/// key: tuning measures a full `t`-step recurrence (per-step thread
/// synchronisation, state-tensor footprint and the h/s reuse window all
/// scale with `t`), so a blocking ranked at one sequence length must
/// never be applied to a workload that differs only in `t`. (Cache files
/// written before this fix carry `t`-less keys; the schema-version bump
/// to v3 drops them wholesale on load rather than leaving permanently
/// unreachable entries behind.)
pub fn lstm_key(cfg: &LstmConfig) -> TuneKey {
    TuneKey {
        primitive: "lstm".to_string(),
        shape: format!("n{} c{} k{} t{}", cfg.n, cfg.c, cfg.k, cfg.t),
        isa: Isa::detect().name().to_string(),
        nthreads: cfg.nthreads,
    }
}

/// A cached tuning winner.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneEntry {
    pub cand: Candidate,
    /// Measured GFLOPS of the winner when it was tuned.
    pub gflops: f64,
    /// The analytic model's GFLOPS estimate at tuning time (kept so cache
    /// files document how far off the model was).
    pub model_gflops: f64,
}

impl TuneEntry {
    pub fn to_json(&self) -> Json {
        obj([
            ("bn", self.cand.bn.into()),
            ("bc", self.cand.bc.into()),
            ("bk", self.cand.bk.into()),
            ("bq", self.cand.bq.into()),
            ("flat_bq", self.cand.flat_bq.into()),
            ("order", order_name(self.cand.order).into()),
            ("fwd_strided", self.cand.fwd_strided.into()),
            ("upd_transpose", self.cand.upd_transpose.into()),
            ("gflops", self.gflops.into()),
            ("model_gflops", self.model_gflops.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TuneEntry> {
        let get = |k: &str| j.get(k).and_then(Json::as_usize);
        Some(TuneEntry {
            cand: Candidate {
                bn: get("bn")?.max(1),
                bc: get("bc")?.max(1),
                bk: get("bk")?.max(1),
                bq: get("bq")?.max(1),
                flat_bq: get("flat_bq").unwrap_or(0),
                order: j.get("order").and_then(Json::as_str).and_then(order_parse),
                fwd_strided: j.get("fwd_strided").and_then(Json::as_bool).unwrap_or(false),
                upd_transpose: j.get("upd_transpose").and_then(Json::as_bool).unwrap_or(false),
            },
            gflops: j.get("gflops").and_then(Json::as_f64).unwrap_or(0.0),
            model_gflops: j.get("model_gflops").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

/// Schema version of the cache file. Bump whenever the candidate encoding
/// or the **key semantics** change shape: entries written by an older
/// binary are **ignored on load** (and rewritten at the current version on
/// the next `save`), so stale cached blockings can never be applied to a
/// reshaped tuning space — and key-scheme changes cannot leave permanently
/// unreachable dead entries in the file. History: v1 = PR-1 encoding,
/// unchecked on load; v2 = same encoding, version-checked (conv
/// training-driver era); v3 = LSTM keys gained the sequence length
/// (`t{}`), orphaning every v2 `lstm|…` entry.
const FORMAT_VERSION: usize = 3;

/// The cache: a keyed map of winners plus the file it persists to.
#[derive(Debug)]
pub struct TuningCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, TuneEntry>,
}

impl TuningCache {
    /// In-memory cache with no backing file (`save` is a no-op error).
    pub fn empty() -> TuningCache {
        TuningCache { path: None, entries: BTreeMap::new() }
    }

    /// Cache backed by `path`; loads existing contents if the file exists.
    /// Unreadable or malformed files are treated as empty (a tuning cache
    /// is always regenerable), with a warning on stderr.
    pub fn at(path: impl Into<PathBuf>) -> TuningCache {
        let path = path.into();
        let entries = match std::fs::read_to_string(&path) {
            Err(e) => {
                if e.kind() != std::io::ErrorKind::NotFound {
                    // A cache that exists but cannot be read must not be
                    // silently treated as empty: a later save() would
                    // replace it and drop every previously tuned winner.
                    crate::log_warn!(
                        "tuning cache {} unreadable ({}); starting empty — a save will overwrite it",
                        path.display(),
                        e
                    );
                }
                BTreeMap::new()
            }
            Ok(text) => match Self::entries_from_json_text(&text) {
                Ok(e) => e,
                Err(why) => {
                    crate::log_warn!(
                        "ignoring malformed tuning cache {}: {}",
                        path.display(),
                        why
                    );
                    BTreeMap::new()
                }
            },
        };
        TuningCache { path: Some(path), entries }
    }

    /// `$BRGEMM_TUNE_CACHE` or `tuning_cache.json` in the working dir.
    pub fn default_path() -> PathBuf {
        std::env::var("BRGEMM_TUNE_CACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("tuning_cache.json"))
    }

    pub fn load_default() -> TuningCache {
        TuningCache::at(TuningCache::default_path())
    }

    /// The process-wide cache consulted by the `tuned()` constructors.
    pub fn global() -> &'static Mutex<TuningCache> {
        static GLOBAL: OnceLock<Mutex<TuningCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Mutex::new(TuningCache::load_default()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get(&self, key: &TuneKey) -> Option<&TuneEntry> {
        self.entries.get(&key.id())
    }

    pub fn put(&mut self, key: &TuneKey, entry: TuneEntry) {
        self.entries.insert(key.id(), entry);
    }

    /// Drop an entry (used to invalidate a shape, and by tests to
    /// guarantee a miss regardless of any cache file in the working dir).
    pub fn remove(&mut self, key: &TuneKey) -> Option<TuneEntry> {
        self.entries.remove(&key.id())
    }

    pub fn to_json(&self) -> Json {
        let entries: BTreeMap<String, Json> =
            self.entries.iter().map(|(k, e)| (k.clone(), e.to_json())).collect();
        obj([("version", FORMAT_VERSION.into()), ("entries", Json::Obj(entries))])
    }

    fn entries_from_json_text(text: &str) -> Result<BTreeMap<String, TuneEntry>, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != FORMAT_VERSION {
            return Err(format!(
                "schema v{} (this binary writes v{}); ignoring stale entries — the next save \
                 rewrites the file at the current version",
                version, FORMAT_VERSION
            ));
        }
        let entries = j
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| "missing 'entries' object".to_string())?;
        let mut out = BTreeMap::new();
        for (k, v) in entries {
            match TuneEntry::from_json(v) {
                Some(e) => {
                    out.insert(k.clone(), e);
                }
                None => return Err(format!("malformed entry '{}'", k)),
            }
        }
        Ok(out)
    }

    /// Write to the backing file (via a temp file + rename, so a crashed
    /// writer never leaves a torn cache). Returns the path written.
    pub fn save(&self) -> std::io::Result<PathBuf> {
        let path = self.path.clone().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "cache has no backing file")
        })?;
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_string_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::eltwise::Act;
    use crate::primitives::partition::Strategy;

    fn sample_entry() -> TuneEntry {
        TuneEntry {
            cand: Candidate {
                bn: 24,
                bc: 64,
                bk: 32,
                bq: 28,
                flat_bq: 64,
                order: Some(Strategy::FeatureFirst),
                fwd_strided: true,
                upd_transpose: false,
            },
            gflops: 123.4,
            model_gflops: 150.0,
        }
    }

    #[test]
    fn entry_round_trips_through_json() {
        let e = sample_entry();
        let j = e.to_json().to_string_compact();
        let back = TuneEntry::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn cache_round_trips_through_file() {
        let dir = std::env::temp_dir().join("brgemm_dl_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache_roundtrip.json");
        std::fs::remove_file(&path).ok();

        let key = TuneKey {
            primitive: "conv".into(),
            shape: "n1 c64 k64 h56 w56 r1 s1 st1 p0".into(),
            isa: "avx512".into(),
            nthreads: 1,
        };
        let mut cache = TuningCache::at(&path);
        assert!(cache.is_empty(), "fresh cache starts empty");
        cache.put(&key, sample_entry());
        let written = cache.save().unwrap();
        assert_eq!(written, path);

        let reloaded = TuningCache::at(&path);
        assert_eq!(reloaded.len(), 1);
        assert_eq!(reloaded.get(&key).unwrap(), &sample_entry());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_hit_requires_exact_key() {
        let mut cache = TuningCache::empty();
        let cfg = ConvConfig::new(1, 64, 64, 56, 56, 1, 1, 1, 0);
        let key = conv_key(&cfg);
        cache.put(&key, sample_entry());
        assert!(cache.get(&key).is_some(), "same shape hits");
        // Different thread count → miss.
        assert!(cache.get(&conv_key(&cfg.with_threads(2))).is_none());
        // Different shape → miss.
        assert!(cache.get(&conv_key(&ConvConfig::new(1, 64, 64, 28, 28, 1, 1, 1, 0))).is_none());
        // Different primitive with a same-ish shape string → miss.
        let fkey = fc_key(&FcConfig::new(1, 64, 64, Act::Relu));
        assert!(cache.get(&fkey).is_none());
    }

    #[test]
    fn lstm_key_includes_sequence_length() {
        // Regression: two workloads differing only in T must not share a
        // cached blocking (T scales the per-step sync and state footprint
        // the measurement was taken under).
        let a = lstm_key(&LstmConfig::new(16, 64, 64, 4));
        let b = lstm_key(&LstmConfig::new(16, 64, 64, 32));
        assert_ne!(a.id(), b.id(), "sequence length must participate in the key");
        // Same shape including T still hits.
        let c = lstm_key(&LstmConfig::new(16, 64, 64, 4));
        assert_eq!(a.id(), c.id());
        let mut cache = TuningCache::empty();
        cache.put(&a, sample_entry());
        assert!(cache.get(&a).is_some());
        assert!(cache.get(&b).is_none(), "a T=4 winner must miss at T=32");
    }

    #[test]
    fn malformed_cache_files_are_tolerated() {
        let dir = std::env::temp_dir().join("brgemm_dl_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache_malformed.json");
        std::fs::write(&path, "this is not json").unwrap();
        let cache = TuningCache::at(&path);
        assert!(cache.is_empty(), "garbage file must load as empty, not panic");
        std::fs::write(&path, r#"{"version":1}"#).unwrap();
        assert!(TuningCache::at(&path).is_empty(), "missing entries key tolerated");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stale_schema_version_is_ignored_and_rewritten() {
        let dir = std::env::temp_dir().join("brgemm_dl_tune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache_stale_version.json");
        // A v1 file (what pre-schema-check binaries wrote) holding a
        // perfectly well-formed entry: it must load as empty, because the
        // tuning space it was ranked against may since have been reshaped.
        let entry_json = sample_entry().to_json().to_string_compact();
        std::fs::write(
            &path,
            format!(r#"{{"version":1,"entries":{{"conv|stale|isa=scalar|t=1":{}}}}}"#, entry_json),
        )
        .unwrap();
        let mut cache = TuningCache::at(&path);
        assert!(cache.is_empty(), "stale-version entries must not survive into this binary");
        // Same for a file with no version field at all.
        std::fs::write(
            &path,
            format!(r#"{{"entries":{{"conv|stale|isa=scalar|t=1":{}}}}}"#, entry_json),
        )
        .unwrap();
        assert!(TuningCache::at(&path).is_empty(), "unversioned entries ignored");
        // A save rewrites the file at the current schema version, after
        // which entries round-trip again.
        let key = TuneKey {
            primitive: "conv".into(),
            shape: "fresh".into(),
            isa: "scalar".into(),
            nthreads: 1,
        };
        cache.put(&key, sample_entry());
        cache.save().unwrap();
        let reloaded = TuningCache::at(&path);
        assert_eq!(reloaded.len(), 1);
        assert_eq!(reloaded.get(&key).unwrap(), &sample_entry());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_cache_save_errors_cleanly() {
        let mut cache = TuningCache::empty();
        cache.put(
            &TuneKey { primitive: "fc".into(), shape: "x".into(), isa: "scalar".into(), nthreads: 1 },
            sample_entry(),
        );
        assert!(cache.save().is_err(), "no backing file → explicit error");
    }
}
