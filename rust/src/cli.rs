//! Command-line argument parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value` and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Specification of one option, for validation + usage text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Whether the option takes a value (`--key v`); false = boolean flag.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Declarative command spec.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Args {
    /// Parse raw argv (excluding the program name). If `commands` is
    /// non-empty, the first non-flag token must be one of them.
    pub fn parse(argv: &[String], commands: &[Command]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();

        if !commands.is_empty() {
            match it.peek() {
                Some(tok) if !tok.starts_with('-') => {
                    let name = it.next().unwrap();
                    if !commands.iter().any(|c| c.name == *name) {
                        return Err(CliError(format!(
                            "unknown command '{}'; expected one of: {}",
                            name,
                            commands.iter().map(|c| c.name).collect::<Vec<_>>().join(", ")
                        )));
                    }
                    out.subcommand = Some(name.clone());
                }
                _ => {}
            }
        }

        let spec: Option<&Command> = out
            .subcommand
            .as_ref()
            .and_then(|s| commands.iter().find(|c| c.name == *s));

        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let takes_value = spec
                    .map(|s| {
                        s.opts
                            .iter()
                            .find(|o| o.name == key)
                            .map(|o| o.takes_value)
                            // Unknown options default to value-taking if a
                            // value is inline, else flag.
                            .unwrap_or(inline_val.is_some())
                    })
                    .unwrap_or(inline_val.is_some() || matches!(it.peek(), Some(v) if !v.starts_with("--")));
                let val = if let Some(v) = inline_val {
                    v
                } else if takes_value {
                    it.next()
                        .ok_or_else(|| CliError(format!("--{} expects a value", key)))?
                        .clone()
                } else {
                    "true".to_string()
                };
                out.flags.insert(key, val);
            } else {
                out.positional.push(tok.clone());
            }
        }

        // Apply declared defaults.
        if let Some(s) = spec {
            for o in &s.opts {
                if let Some(d) = o.default {
                    out.flags.entry(o.name.to_string()).or_insert_with(|| d.to_string());
                }
            }
        }
        Ok(out)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// `--key v` with a default fallback (string options).
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.str(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize(&self, key: &str) -> Result<Option<usize>, CliError> {
        self.parse_opt(key)
    }

    pub fn f64(&self, key: &str) -> Result<Option<f64>, CliError> {
        self.parse_opt(key)
    }

    fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, CliError> {
        match self.str(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{}: cannot parse '{}'", key, v))),
        }
    }

    /// `--key v` with a required default fallback.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.usize(key)?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.f64(key)?.unwrap_or(default))
    }
}

/// Render usage text for a command set.
pub fn usage(prog: &str, about: &str, commands: &[Command]) -> String {
    let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", prog, about, prog);
    for c in commands {
        s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
    }
    s.push_str("\nOPTIONS (per command):\n");
    for c in commands {
        if c.opts.is_empty() {
            continue;
        }
        s.push_str(&format!("  {}:\n", c.name));
        for o in &c.opts {
            let v = if o.takes_value { " <v>" } else { "" };
            let d = o.default.map(|d| format!(" [default: {}]", d)).unwrap_or_default();
            s.push_str(&format!("    --{}{:<12} {}{}\n", o.name, v, o.help, d));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmds() -> Vec<Command> {
        vec![Command {
            name: "bench",
            about: "run benches",
            opts: vec![
                OptSpec { name: "iters", help: "iterations", takes_value: true, default: Some("10") },
                OptSpec { name: "quick", help: "quick mode", takes_value: false, default: None },
            ],
        }]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse(&sv(&["bench", "--iters", "32", "--quick", "extra"]), &cmds()).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.usize("iters").unwrap(), Some(32));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::parse(&sv(&["bench", "--iters=7"]), &cmds()).unwrap();
        assert_eq!(a.usize("iters").unwrap(), Some(7));
        let b = Args::parse(&sv(&["bench"]), &cmds()).unwrap();
        assert_eq!(b.usize("iters").unwrap(), Some(10), "default applies");
        assert!(!b.flag("quick"));
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(Args::parse(&sv(&["nope"]), &cmds()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["bench", "--iters"]), &cmds()).is_err());
    }

    #[test]
    fn bad_numeric_value() {
        let a = Args::parse(&sv(&["bench", "--iters", "xyz"]), &cmds()).unwrap();
        assert!(a.usize("iters").is_err());
    }

    #[test]
    fn str_or_falls_back() {
        let a = Args::parse(&sv(&["bench"]), &cmds()).unwrap();
        assert_eq!(a.str_or("missing", "dflt"), "dflt");
        assert_eq!(a.str_or("iters", "dflt"), "10", "declared default wins over fallback");
    }

    #[test]
    fn usage_mentions_commands_and_opts() {
        let u = usage("brgemm-dl", "demo", &cmds());
        assert!(u.contains("bench") && u.contains("--iters"));
    }
}
