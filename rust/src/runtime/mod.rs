//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path.
//!
//! The flow (see /opt/xla-example/load_hlo/): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation happens once per entry and is
//! cached; the hot path is `execute` on the cached executable. Python never
//! runs here — artifacts are produced offline by `make artifacts`.

pub mod manifest;

pub use manifest::{ArtifactMeta, DType, Manifest, TensorMeta};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// A host-side tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(d, _) => xla::Literal::vec1(d),
            HostTensor::I32(d, _) => xla::Literal::vec1(d),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported output element type {:?}", other),
        }
    }
}

/// Stats of one executed call (fed into the coordinator's metrics).
#[derive(Debug, Clone, Copy)]
pub struct ExecStats {
    pub secs: f64,
    pub flops: f64,
}

impl ExecStats {
    pub fn gflops(&self) -> f64 {
        self.flops / self.secs / 1e9
    }
}

/// The PJRT runtime: client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn cpu(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached executable for) one artifact.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.get(name)?;
        let path = meta
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", meta.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling artifact {}: {e:?}", name))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Validate inputs against the manifest, execute, unwrap the output
    /// tuple. Returns outputs + timing.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<(Vec<HostTensor>, ExecStats)> {
        let meta = self.manifest.get(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            bail!("{}: expected {} inputs, got {}", name, meta.inputs.len(), inputs.len());
        }
        for (i, (inp, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if inp.shape() != want.shape.as_slice() || inp.dtype() != want.dtype {
                bail!(
                    "{}: input {} mismatch: got {:?}/{:?}, manifest says {:?}/{:?}",
                    name, i, inp.shape(), inp.dtype(), want.shape, want.dtype
                );
            }
        }
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", name))?;
        let secs = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple().map_err(|e| anyhow!("untupling {}: {e:?}", name))?;
        let outputs: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        if outputs.len() != meta.outputs.len() {
            bail!("{}: manifest promises {} outputs, got {}", name, meta.outputs.len(), outputs.len());
        }
        Ok((outputs, ExecStats { secs, flops: meta.flops }))
    }

    /// Warm the cache for a set of entries (used by the coordinator at
    /// startup so compile time never lands on the request path).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n).with_context(|| format!("warming {}", n))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.as_f32().is_ok());
        let i = HostTensor::i32(vec![1, 2], &[2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_len_mismatch_panics() {
        HostTensor::f32(vec![0.0; 5], &[2, 3]);
    }
}
