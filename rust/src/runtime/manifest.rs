//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json` + one HLO text file per entry) and the
//! Rust runtime that loads them.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Element type of a tensor in the manifest (the subset we exchange).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{}'", other),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorMeta> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor meta missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape element")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorMeta { shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub flops: f64,
    pub desc: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?} (run `make artifacts` first)", path))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string();
                let file = dir.join(
                    e.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("missing file"))?,
                );
                let parse_tensors = |key: &str| -> Result<Vec<TensorMeta>> {
                    e.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("entry {} missing {}", name, key))?
                        .iter()
                        .map(TensorMeta::from_json)
                        .collect()
                };
                Ok(ArtifactMeta {
                    inputs: parse_tensors("inputs")?,
                    outputs: parse_tensors("outputs")?,
                    flops: e.get("flops").and_then(Json::as_f64).unwrap_or(0.0),
                    desc: e.get("desc").and_then(Json::as_str).unwrap_or("").to_string(),
                    name,
                    file,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("artifact '{}' not in manifest ({} entries)", name, self.entries.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "entries": [
        {"name": "demo", "file": "demo.hlo.txt",
         "inputs": [{"shape": [4, 8, 32], "dtype": "float32"},
                    {"shape": [64], "dtype": "int32"}],
         "outputs": [{"shape": [8, 64], "dtype": "float32"}],
         "flops": 131072, "desc": "demo entry"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("demo").unwrap();
        assert_eq!(e.inputs[0].shape, vec![4, 8, 32]);
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.inputs[0].element_count(), 1024);
        assert_eq!(e.outputs[0].shape, vec![8, 64]);
        assert_eq!(e.flops, 131072.0);
        assert_eq!(e.file, Path::new("/tmp/a").join("demo.hlo.txt"));
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("float32", "float64");
        assert!(Manifest::parse(Path::new("."), &bad).is_err());
    }
}
