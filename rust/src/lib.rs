//! # brgemm-dl — Deep Learning via a Single Building Block
//!
//! A reproduction of *"High-Performance Deep Learning via a Single Building
//! Block"* (Georganas et al., 2019): the **batch-reduce GEMM (BRGEMM)**
//! kernel, and LSTM / CNN / MLP training + inference primitives expressed as
//! nothing more than loop tuning around that single kernel.
//!
//! The crate is organised as the L3 (request-path) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`brgemm`] — the single building block: the batch-reduce GEMM kernel
//!   (address-list / offset / stride variants, α/β scaling, fused eltwise
//!   epilogues) with architecture-dispatched microkernels, plus the plain
//!   and batched GEMM baselines the paper compares against.
//! * [`tensor`] — blocked tensor layouts (the paper's `[Kb][Cb][bc][bk]`
//!   weight and `[N][Cb][H][W][bc]` activation formats) and reformat ops.
//! * [`primitives`] — the DL primitives built on BRGEMM: fully-connected,
//!   LSTM cell, and direct convolution, each with forward, backward-by-data
//!   and weight-update passes, plus the coarse-grained baselines
//!   (large-GEMM cell, im2col + batched GEMM, small-GEMM loop nests).
//! * [`runtime`] — PJRT client wrapper that loads AOT-compiled HLO
//!   artifacts produced by the python (JAX + Pallas) build path.
//! * [`coordinator`] — the framework layer: model/config system, training
//!   driver, synthetic data pipelines, and the distributed data-parallel
//!   simulator (ring-allreduce with a network cost model) used for the
//!   paper's multi-node experiments.
//! * [`perfmodel`] — roofline probes and efficiency accounting so results
//!   can be reported as %-of-peak like the paper does.
//! * [`autotune`] — the "automatic tuning of loops" the paper's thesis
//!   promises: per-primitive tuning spaces (blockings, loop orders, BRGEMM
//!   variants), an analytic cost model that prunes them, an empirical
//!   tuner that ranks the survivors, and a persistent JSON tuning cache
//!   the primitives' `tuned()` constructors load automatically.
//! * [`modelio`] — the model-artifact subsystem: a versioned, checksummed
//!   binary format holding the arch descriptor plus **canonical
//!   unblocked** weights (re-packed on load for whatever blocking the
//!   tuner picks) and training metadata — the persistence layer that
//!   turns trainer, tuner and server into one train → checkpoint → serve
//!   pipeline (checkpoint/resume in the coordinator, `--model-path` and
//!   hot weight reload in serving).
//! * [`telemetry`] — the observability layer: metric registries (counters
//!   + timers with an exact parallel-Welford merge, exported as JSON lines
//!   by `run --metrics-out`) and a gated per-primitive BRGEMM profiler
//!   (per-pass kernel-invocation/flop/byte/time counters with
//!   efficiency-vs-roofline, branch-only on the hot path when disabled).
//! * [`serve`] — the inference-serving subsystem: a request queue +
//!   dynamic batcher coalescing single-sample requests into pow-2 batch
//!   buckets, a worker pool running forward-only MLP/CNN/RNN plans built
//!   per bucket through `tuned()`, all buckets sharing one `Arc`-backed
//!   packed-weight copy per layer, with latency/throughput/batch-fill
//!   accounting, a deterministic open-loop load generator, and an
//!   artifact-file watcher for hot reload of trainer checkpoints.
//! * [`util`] — self-contained substrates (JSON, RNG, stats, thread pool,
//!   bench harness, property testing) — the crates.io registry is not
//!   available in this environment, so these are built in-tree.

/// The process allocator is the resource plane's counting wrapper around
/// [`std::alloc::System`] (see [`telemetry::resource`]). Declared here so
/// one declaration covers the binary, tests and benches; when the plane is
/// off the wrapper costs one relaxed load and a branch per call and
/// forwards verbatim, so allocation behaviour — and therefore every
/// computed result — is bit-identical either way.
#[global_allocator]
static GLOBAL_ALLOC: telemetry::resource::CountingAlloc = telemetry::resource::CountingAlloc;

pub mod autotune;
pub mod brgemm;
pub mod cli;
pub mod coordinator;
pub mod modelio;
pub mod perfmodel;
pub mod primitives;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod util;
