//! Deterministic open-loop load generation.
//!
//! Arrivals are Poisson-ish: exponential inter-arrival gaps drawn from
//! [`crate::util::rng`] (inverse-CDF transform), so the *schedule and
//! request contents* are exactly reproducible from the seed — only the
//! measured latencies vary with the host. Open loop means the generator
//! never waits for responses: if the servers falls behind, the queue
//! grows and the batcher rides up the bucket ladder, which is precisely
//! the regime dynamic batching exists for.

use crate::serve::batcher::{Response, ServeOpts, Server};
use crate::serve::metrics::ServeReport;
use crate::serve::model::InferenceModel;
use crate::util::rng::Rng;
use std::time::Duration;

/// An open-loop workload: `requests` arrivals at `rate_rps` on average.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    pub requests: usize,
    pub rate_rps: f64,
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec { requests: 512, rate_rps: 2000.0, seed: 42 }
    }
}

/// One exponential inter-arrival gap (seconds) at `rate_rps`.
pub fn poisson_gap_secs(rng: &mut Rng, rate_rps: f64) -> f64 {
    assert!(rate_rps > 0.0);
    // Inverse CDF; f64() < 1.0 so the log argument is in (0, 1].
    -(1.0 - rng.f64()).ln() / rate_rps
}

/// One GNMT-style request length: clamped log-normal around
/// `ln(typical_len)` with σ = 0.6 — the same length law
/// [`crate::coordinator::data::SeqCorpus::synth`] uses for training
/// corpora, so the serving arrival mix matches what the model trained on.
pub fn seq_request_len(rng: &mut Rng, typical_len: usize, max_len: usize) -> usize {
    assert!(max_len >= 2 && typical_len >= 1 && typical_len <= max_len);
    let mu = (typical_len as f64).ln();
    ((mu + 0.6 * rng.normal()).exp().round() as i64).clamp(2, max_len as i64) as usize
}

/// A `make_input` source for sequence models: each arrival is a
/// flattened `[len][step_dim]` sequence whose length is drawn by
/// [`seq_request_len`] and whose contents are uniform noise from the
/// same stream — schedule, lengths, *and* contents all reproduce from
/// the load seed. Feed to [`run_open_loop_with`] /
/// [`drive_open_loop_every`].
pub fn seq_request_source(
    step_dim: usize,
    typical_len: usize,
    max_len: usize,
) -> impl FnMut(&mut Rng, usize) -> Vec<f32> {
    move |rng, _i| {
        let len = seq_request_len(rng, typical_len, max_len);
        rng.vec_f32(len * step_dim, -1.0, 1.0)
    }
}

/// Drive `model` with `load` through a [`Server`]: spawn the pool, pace
/// the arrivals, drain on shutdown, and return the report plus every
/// response (collected concurrently, so an unbounded backlog never sits
/// in the channel at drain time). Request rows are uniform noise drawn
/// from the same stream as the arrival gaps, so schedule *and* contents
/// are reproducible from the seed.
pub fn run_open_loop(
    model: InferenceModel,
    opts: ServeOpts,
    load: &LoadSpec,
) -> (ServeReport, Vec<Response>) {
    let dim = model.input_dim();
    run_open_loop_with(model, opts, load, move |rng, _i| rng.vec_f32(dim, -1.0, 1.0))
}

/// [`run_open_loop`] with a caller-supplied request source: `make_input`
/// produces arrival `i`'s row (handed the load RNG, which has just drawn
/// that arrival's gap). The `serve --min-accuracy` path uses this to
/// replay a labelled dataset through the server; the pacing, stall-guard
/// and drain logic live here once for both.
pub fn run_open_loop_with(
    model: InferenceModel,
    opts: ServeOpts,
    load: &LoadSpec,
    make_input: impl FnMut(&mut Rng, usize) -> Vec<f32>,
) -> (ServeReport, Vec<Response>) {
    let (server, rx) = Server::start(model, opts);
    drive_open_loop(server, rx, load, make_input)
}

/// Pace `load` into an **already-started** server and drain it — the
/// split lets a caller attach side channels (e.g. the `--watch-model`
/// file watcher, via [`Server::reload_handle`]) between starting the pool
/// and applying load.
pub fn drive_open_loop(
    server: Server,
    rx: std::sync::mpsc::Receiver<Response>,
    load: &LoadSpec,
    make_input: impl FnMut(&mut Rng, usize) -> Vec<f32>,
) -> (ServeReport, Vec<Response>) {
    drive_open_loop_every(server, rx, load, None, make_input)
}

/// [`drive_open_loop`] with an optional periodic snapshot: every
/// `every` seconds (checked at arrival granularity) the server's
/// point-in-time [`ServeReport`] is logged as one compact JSON line —
/// the `serve --metrics-every` flag lands here.
pub fn drive_open_loop_every(
    server: Server,
    rx: std::sync::mpsc::Receiver<Response>,
    load: &LoadSpec,
    every: Option<f64>,
    mut make_input: impl FnMut(&mut Rng, usize) -> Vec<f32>,
) -> (ServeReport, Vec<Response>) {
    let collector = std::thread::spawn(move || {
        let mut out = Vec::new();
        while let Ok(r) = rx.recv() {
            out.push(r);
        }
        out
    });
    let mut rng = Rng::new(load.seed);
    // Absolute schedule: arrival i fires at start + Σ gaps, so sleep
    // overshoot / submit cost do not accumulate and the delivered rate
    // tracks `rate_rps` even when gaps are shorter than the sleep
    // granularity (a late generator submits immediately and catches up).
    let start = std::time::Instant::now();
    let mut due = 0.0f64;
    // Stall guard: cap a single draw at 10× the mean gap. P(Exp > 10/λ)
    // = e⁻¹⁰, so the delivered rate is unbiased at any configured rate
    // (a fixed-seconds cap would silently inflate low rates).
    let gap_cap = 10.0 / load.rate_rps;
    let mut next_snapshot = every.map(|e| {
        assert!(e > 0.0, "--metrics-every must be positive");
        e
    });
    for i in 0..load.requests {
        due += poisson_gap_secs(&mut rng, load.rate_rps).min(gap_cap);
        let now = start.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(Duration::from_secs_f64(due - now));
        }
        if server.try_submit(make_input(&mut rng, i)).is_none() {
            // The admin plane drained the server mid-run: stop generating
            // load; every request accepted so far still gets its response.
            break;
        }
        if let Some(at) = next_snapshot {
            let elapsed = start.elapsed().as_secs_f64();
            if elapsed >= at {
                let snap = server.stats_snapshot();
                crate::log_info!("serve snapshot: {}", snap.to_json().to_string_compact());
                // Skip past missed ticks instead of bursting to catch up.
                let e = every.unwrap();
                next_snapshot = Some(at + (((elapsed - at) / e).floor() + 1.0) * e);
            }
        }
    }
    let report = server.shutdown();
    let responses = collector.join().expect("response collector panicked");
    (report, responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaps_are_deterministic_and_mean_matches_rate() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let ga: Vec<f64> = (0..5000).map(|_| poisson_gap_secs(&mut a, 100.0)).collect();
        let gb: Vec<f64> = (0..5000).map(|_| poisson_gap_secs(&mut b, 100.0)).collect();
        assert_eq!(ga, gb, "same seed, same schedule");
        assert!(ga.iter().all(|&g| g >= 0.0));
        let mean = ga.iter().sum::<f64>() / ga.len() as f64;
        // Exponential(λ=100) has mean 0.01 s; 5000 samples pin it well.
        assert!((mean - 0.01).abs() < 0.002, "mean gap {}", mean);
    }

    #[test]
    fn seq_lengths_are_deterministic_and_clamped() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let la: Vec<usize> = (0..2000).map(|_| seq_request_len(&mut a, 8, 24)).collect();
        let lb: Vec<usize> = (0..2000).map(|_| seq_request_len(&mut b, 8, 24)).collect();
        assert_eq!(la, lb, "same seed, same length mix");
        assert!(la.iter().all(|&l| (2..=24).contains(&l)));
        // The mode sits near the typical length and the mix is genuinely
        // mixed — both shorter and longer than typical appear.
        assert!(la.iter().any(|&l| l < 8) && la.iter().any(|&l| l > 8));
        let mean = la.iter().sum::<usize>() as f64 / la.len() as f64;
        assert!(mean > 4.0 && mean < 16.0, "mean length {}", mean);
    }

    #[test]
    fn mixed_length_open_loop_serves_every_request() {
        use crate::coordinator::rnn::RnnSpec;
        let spec = RnnSpec { c: 4, k: 8, t: 8, classes: 3, layers: 2 };
        let model = InferenceModel::new_rnn(&spec, 4, 1, false, &mut Rng::new(15));
        let load = LoadSpec { requests: 40, rate_rps: 50_000.0, seed: 5 };
        let (report, responses) = run_open_loop_with(
            model,
            ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() },
            &load,
            seq_request_source(spec.c, 4, spec.t),
        );
        assert_eq!(report.requests, 40);
        assert_eq!(responses.len(), 40);
        assert!(!report.len_buckets.is_empty(), "length split recorded");
        let split: usize = report.len_buckets.iter().map(|&(_, _, n, _)| n).sum();
        assert_eq!(split, 40, "every request accounted to a length bucket");
        assert!(responses.iter().all(|r| r.logits.len() == 3 && r.len_bucket >= 2));
        assert!(responses.iter().flat_map(|r| &r.logits).all(|v| v.is_finite()));
    }

    #[test]
    fn open_loop_serves_every_request() {
        let model = InferenceModel::new_mlp(&[8, 10, 3], 4, 1, false, &mut Rng::new(13));
        let load = LoadSpec { requests: 60, rate_rps: 50_000.0, seed: 3 };
        let (report, responses) =
            run_open_loop(model, ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() }, &load);
        assert_eq!(report.requests, 60);
        assert_eq!(responses.len(), 60);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
        let served: f64 = report
            .batch_fill
            .iter()
            .map(|&(b, n, fill)| fill * (b * n) as f64)
            .sum();
        assert!((served - 60.0).abs() < 1e-6);
        // Every response row has the right width and finite values.
        assert!(responses.iter().all(|r| r.logits.len() == 3));
        assert!(responses.iter().flat_map(|r| &r.logits).all(|v| v.is_finite()));
    }
}
