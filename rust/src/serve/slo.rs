//! SLO plane: per-request deadlines, attainment accounting, violation
//! attribution, and SRE-style burn-rate / error-budget tracking.
//!
//! PR 6/8 gave the serve path *measurements* (stage split, histograms,
//! traces); this module turns them into an *objective*: every request is
//! stamped with a deadline at submit (default from
//! [`SloSpec::latency_ms`], per-request override allowed), classified
//! met/violated when it is answered, and every violation is **attributed**
//! to the stage that dominated it — queue wait (batcher backlog), compute
//! (the bucket plan's forward pass), or reload stall (blocked on the
//! weight-generation swap of a hot reload). Attainment is accounted
//! run-wide, per batch bucket and per length bucket, plus two SRE-style
//! rolling windows:
//!
//! * **burn rate** — the windowed violation rate divided by the budget
//!   rate `1 - objective`. Burn 1.0 = spending the error budget exactly
//!   at the sustainable pace; 10 = ten times too fast. The short window
//!   reacts in seconds (paging signal), the long window smooths over the
//!   full ring (ticket signal) — the classic multi-window alert pair.
//! * **error budget remaining** — `1 - violations / (total · (1 -
//!   objective))`: the fraction of the run's violation allowance still
//!   unspent (negative = the run has already blown its objective).
//!
//! Everything here is pure accounting over numbers the batcher already
//! measures: no clocks are read and no locks are taken beyond the stats
//! mutex the serve metrics already hold, so the disabled path stays the
//! one branch the observability planes promise (`ServeOpts.slo = None`),
//! and enabling it cannot change the math (covered by the serve
//! bit-identity test).

use crate::util::json::{obj, Json};
use std::collections::BTreeMap;
use std::time::Instant;

/// A latency service-level objective: "`objective` of requests answer
/// within `latency_ms`". The serve config spells it
/// `{"serve": {"slo": {"latency_ms": 50, "objective": 0.99}}}`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Deadline stamped on every request at submit (milliseconds).
    pub latency_ms: f64,
    /// Target attainment fraction in (0, 1): the budget rate is
    /// `1 - objective`.
    pub objective: f64,
}

impl Default for SloSpec {
    fn default() -> SloSpec {
        SloSpec { latency_ms: 50.0, objective: 0.99 }
    }
}

impl SloSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(self.latency_ms > 0.0) || !self.latency_ms.is_finite() {
            anyhow::bail!("slo.latency_ms must be a positive, finite number of milliseconds");
        }
        if !(self.objective > 0.0 && self.objective < 1.0) {
            anyhow::bail!("slo.objective must be a fraction in (0, 1), e.g. 0.99");
        }
        Ok(())
    }

    /// The default per-request deadline in seconds.
    pub fn deadline_secs(&self) -> f64 {
        self.latency_ms * 1e-3
    }

    /// The budget rate `1 - objective`, floored away from zero so burn
    /// rates stay finite.
    pub fn budget_rate(&self) -> f64 {
        (1.0 - self.objective).max(1e-12)
    }
}

/// The stage a violation is attributed to: whichever of the request's
/// measured components dominated its latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloCause {
    /// Enqueue → dequeue dominated: the batcher backlog, not the model.
    QueueWait,
    /// The bucket plan's forward pass dominated.
    Compute,
    /// The wait to pin a weight generation dominated: a hot reload's
    /// swap blocked the worker.
    ReloadStall,
}

impl SloCause {
    pub fn name(self) -> &'static str {
        match self {
            SloCause::QueueWait => "queue_wait",
            SloCause::Compute => "compute",
            SloCause::ReloadStall => "reload_stall",
        }
    }
}

/// One request's verdict: met, or violated with the dominant stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloOutcome {
    pub met: bool,
    pub cause: Option<SloCause>,
}

/// Classify one answered request against its deadline (all arguments in
/// seconds). A violation is attributed to the *largest* measured
/// component; ties resolve queue-wait over compute over reload-stall,
/// the order in which an operator can actually intervene (add workers /
/// shrink the model / reschedule reloads).
pub fn classify(
    deadline_secs: f64,
    latency_secs: f64,
    queue_wait_secs: f64,
    compute_secs: f64,
    reload_stall_secs: f64,
) -> SloOutcome {
    if latency_secs <= deadline_secs {
        return SloOutcome { met: true, cause: None };
    }
    let cause = if queue_wait_secs >= compute_secs && queue_wait_secs >= reload_stall_secs {
        SloCause::QueueWait
    } else if compute_secs >= reload_stall_secs {
        SloCause::Compute
    } else {
        SloCause::ReloadStall
    };
    SloOutcome { met: false, cause: Some(cause) }
}

/// Burn-window geometry: 1-second slots over a 60-slot ring. The short
/// window (5 s) is the fast page-worthy signal, the long window is the
/// whole ring (60 s). Runs shorter than one slot land everything in slot
/// zero, so both windows degrade gracefully to the run-wide rate.
const SLOT_SECS: f64 = 1.0;
const RING_SLOTS: usize = 60;
const SHORT_WINDOW_SLOTS: usize = 5;

/// A fixed ring of per-slot (total, violated) counters. O(1) memory in
/// the request count, like the serve histograms.
#[derive(Debug, Clone)]
struct BurnRing {
    slots: Vec<(u64, u64)>,
    /// Highest absolute slot index ever written (slots advance with the
    /// run clock; the ring position is `slot % RING_SLOTS`).
    head: u64,
}

impl BurnRing {
    fn new() -> BurnRing {
        BurnRing { slots: vec![(0, 0); RING_SLOTS], head: 0 }
    }

    /// Record one request into the slot for `elapsed_secs` since the
    /// stats epoch, zeroing any slots the clock skipped past.
    fn record(&mut self, elapsed_secs: f64, met: bool) {
        let slot = (elapsed_secs.max(0.0) / SLOT_SECS) as u64;
        if slot > self.head {
            // Clear everything between the old head and the new slot —
            // those seconds saw no traffic and must read as zero.
            let gap = (slot - self.head).min(RING_SLOTS as u64);
            for d in 1..=gap {
                self.slots[((self.head + d) % RING_SLOTS as u64) as usize] = (0, 0);
            }
            self.head = slot;
        }
        // Late-arriving records older than the ring are folded into the
        // oldest live slot rather than resurrecting an expired one.
        let slot = slot.max(self.head.saturating_sub(RING_SLOTS as u64 - 1));
        let s = &mut self.slots[(slot % RING_SLOTS as u64) as usize];
        s.0 += 1;
        s.1 += u64::from(!met);
    }

    /// Violation fraction over the most recent `window` slots, or `None`
    /// when the window saw no traffic.
    fn violation_rate(&self, window: usize) -> Option<f64> {
        let window = window.min(RING_SLOTS) as u64;
        let (mut total, mut viol) = (0u64, 0u64);
        for d in 0..window.min(self.head + 1) {
            let s = self.slots[((self.head - d) % RING_SLOTS as u64) as usize];
            total += s.0;
            viol += s.1;
        }
        (total > 0).then(|| viol as f64 / total as f64)
    }
}

/// Run-wide SLO accounting, owned by `ServeStats` under its existing
/// mutex. The clock epoch is the stats' construction (server start).
#[derive(Debug, Clone)]
pub struct SloStats {
    spec: SloSpec,
    started: Instant,
    total: u64,
    met: u64,
    /// Violations by cause, indexed [queue_wait, compute, reload_stall].
    viol: [u64; 3],
    /// Per batch bucket: (total, met).
    per_bucket: BTreeMap<usize, (u64, u64)>,
    /// Per length bucket: (total, met). Fixed-length models never record
    /// here (len bucket 0 is the batcher's "not a sequence" sentinel).
    per_len_bucket: BTreeMap<usize, (u64, u64)>,
    ring: BurnRing,
}

impl SloStats {
    pub fn new(spec: SloSpec) -> SloStats {
        SloStats {
            spec,
            started: Instant::now(),
            total: 0,
            met: 0,
            viol: [0; 3],
            per_bucket: BTreeMap::new(),
            per_len_bucket: BTreeMap::new(),
            ring: BurnRing::new(),
        }
    }

    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }

    /// Account one answered request (called by the batcher worker under
    /// the stats lock, right after `record_batch`).
    pub fn record(&mut self, bucket: usize, len_bucket: usize, outcome: SloOutcome) {
        let elapsed = self.started.elapsed().as_secs_f64();
        self.record_at(elapsed, bucket, len_bucket, outcome);
    }

    /// Clock-injected form of [`record`](Self::record) — the unit tests
    /// drive the burn windows deterministically through this.
    fn record_at(&mut self, elapsed_secs: f64, bucket: usize, len_bucket: usize, o: SloOutcome) {
        self.total += 1;
        if o.met {
            self.met += 1;
        } else {
            let idx = match o.cause.unwrap_or(SloCause::Compute) {
                SloCause::QueueWait => 0,
                SloCause::Compute => 1,
                SloCause::ReloadStall => 2,
            };
            self.viol[idx] += 1;
        }
        let b = self.per_bucket.entry(bucket).or_insert((0, 0));
        b.0 += 1;
        b.1 += u64::from(o.met);
        if len_bucket > 0 {
            let lb = self.per_len_bucket.entry(len_bucket).or_insert((0, 0));
            lb.0 += 1;
            lb.1 += u64::from(o.met);
        }
        self.ring.record(elapsed_secs, o.met);
    }

    /// Short-window burn rate alone — the health plane's per-batch feed,
    /// cheaper than building a full [`summary`](Self::summary).
    pub fn burn_rate_short(&self) -> f64 {
        self.ring
            .violation_rate(SHORT_WINDOW_SLOTS)
            .map_or(0.0, |r| r / self.spec.budget_rate())
    }

    /// The exported summary (lands in `ServeReport.slo`).
    pub fn summary(&self) -> SloSummary {
        let violations = self.total - self.met;
        let attainment = if self.total == 0 {
            1.0
        } else {
            self.met as f64 / self.total as f64
        };
        let budget = self.spec.budget_rate();
        let burn = |w: usize| self.ring.violation_rate(w).map_or(0.0, |r| r / budget);
        let error_budget_remaining = if self.total == 0 {
            1.0
        } else {
            1.0 - violations as f64 / (self.total as f64 * budget)
        };
        SloSummary {
            latency_ms: self.spec.latency_ms,
            objective: self.spec.objective,
            total: self.total,
            met: self.met,
            attainment,
            viol_queue_wait: self.viol[0],
            viol_compute: self.viol[1],
            viol_reload: self.viol[2],
            burn_rate_short: burn(SHORT_WINDOW_SLOTS),
            burn_rate_long: burn(RING_SLOTS),
            error_budget_remaining,
            per_bucket: self.per_bucket.iter().map(|(&b, &(t, m))| (b, t, m)).collect(),
            per_len_bucket: self.per_len_bucket.iter().map(|(&b, &(t, m))| (b, t, m)).collect(),
        }
    }
}

/// Point-in-time SLO summary: the render/JSON-facing flattening of
/// [`SloStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct SloSummary {
    pub latency_ms: f64,
    pub objective: f64,
    pub total: u64,
    pub met: u64,
    pub attainment: f64,
    pub viol_queue_wait: u64,
    pub viol_compute: u64,
    pub viol_reload: u64,
    pub burn_rate_short: f64,
    pub burn_rate_long: f64,
    pub error_budget_remaining: f64,
    /// `(batch bucket, total, met)` rows.
    pub per_bucket: Vec<(usize, u64, u64)>,
    /// `(length bucket, total, met)` rows (sequence models only).
    pub per_len_bucket: Vec<(usize, u64, u64)>,
}

impl SloSummary {
    pub fn violations(&self) -> u64 {
        self.total - self.met
    }

    /// JSON export. Key names `slo_attainment` / `error_budget_remaining`
    /// are the ones `perfcheck --require` and the perf comparator know.
    pub fn to_json(&self) -> Json {
        let bucket_rows = |rows: &[(usize, u64, u64)], key: &str| {
            Json::Arr(
                rows.iter()
                    .map(|&(b, t, m)| {
                        obj([
                            (key, b.into()),
                            ("requests", (t as f64).into()),
                            ("met", (m as f64).into()),
                            (
                                "slo_attainment",
                                (if t == 0 { 1.0 } else { m as f64 / t as f64 }).into(),
                            ),
                        ])
                    })
                    .collect(),
            )
        };
        obj([
            ("latency_ms", self.latency_ms.into()),
            ("objective", self.objective.into()),
            ("requests", (self.total as f64).into()),
            ("met", (self.met as f64).into()),
            ("slo_attainment", self.attainment.into()),
            ("violations", (self.violations() as f64).into()),
            ("viol_queue_wait", (self.viol_queue_wait as f64).into()),
            ("viol_compute", (self.viol_compute as f64).into()),
            ("viol_reload", (self.viol_reload as f64).into()),
            ("burn_rate_short", self.burn_rate_short.into()),
            ("burn_rate_long", self.burn_rate_long.into()),
            ("error_budget_remaining", self.error_budget_remaining.into()),
            ("slo_buckets", bucket_rows(&self.per_bucket, "bucket")),
            ("slo_len_buckets", bucket_rows(&self.per_len_bucket, "len_bucket")),
        ])
    }

    /// Append the human-readable block to a serve report rendering.
    pub fn render_into(&self, out: &mut String) {
        use std::fmt::Write;
        let _ = writeln!(
            out,
            "  slo: {:.1} ms @ {:.2}% — attainment {:.2}% ({} of {} met)",
            self.latency_ms,
            self.objective * 100.0,
            self.attainment * 100.0,
            self.met,
            self.total
        );
        let _ = writeln!(
            out,
            "    violations {} (queue_wait {}, compute {}, reload_stall {})",
            self.violations(),
            self.viol_queue_wait,
            self.viol_compute,
            self.viol_reload
        );
        let _ = writeln!(
            out,
            "    burn rate {:.2} (short) / {:.2} (long), error budget remaining {:.1}%",
            self.burn_rate_short,
            self.burn_rate_long,
            self.error_budget_remaining * 100.0
        );
        for &(b, t, m) in &self.per_bucket {
            let _ = writeln!(
                out,
                "    bucket {:>4}: {:.2}% attained ({} of {})",
                b,
                if t == 0 { 100.0 } else { 100.0 * m as f64 / t as f64 },
                m,
                t
            );
        }
        for &(b, t, m) in &self.per_len_bucket {
            let _ = writeln!(
                out,
                "    len bucket {:>4}: {:.2}% attained ({} of {})",
                b,
                if t == 0 { 100.0 } else { 100.0 * m as f64 / t as f64 },
                m,
                t
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validates() {
        assert!(SloSpec::default().validate().is_ok());
        assert!(SloSpec { latency_ms: 0.0, objective: 0.99 }.validate().is_err());
        assert!(SloSpec { latency_ms: -5.0, objective: 0.99 }.validate().is_err());
        assert!(SloSpec { latency_ms: f64::NAN, objective: 0.99 }.validate().is_err());
        assert!(SloSpec { latency_ms: 10.0, objective: 0.0 }.validate().is_err());
        assert!(SloSpec { latency_ms: 10.0, objective: 1.0 }.validate().is_err());
    }

    #[test]
    fn classify_meets_and_attributes_dominant_stage() {
        // Under deadline: met, no cause.
        let o = classify(0.050, 0.010, 0.002, 0.008, 0.0);
        assert!(o.met && o.cause.is_none());
        // Deadline is inclusive.
        assert!(classify(0.050, 0.050, 0.0, 0.050, 0.0).met);
        // Queue wait dominates.
        let o = classify(0.010, 0.030, 0.025, 0.005, 0.0);
        assert_eq!(o.cause, Some(SloCause::QueueWait));
        // Compute dominates.
        let o = classify(0.010, 0.030, 0.005, 0.025, 0.0);
        assert_eq!(o.cause, Some(SloCause::Compute));
        // Reload stall dominates: the weight-pin wait outweighed both.
        let o = classify(0.010, 0.030, 0.002, 0.003, 0.025);
        assert_eq!(o.cause, Some(SloCause::ReloadStall));
        // Ties resolve queue_wait > compute > reload_stall.
        let o = classify(0.010, 0.030, 0.015, 0.015, 0.015);
        assert_eq!(o.cause, Some(SloCause::QueueWait));
        let o = classify(0.010, 0.030, 0.001, 0.015, 0.015);
        assert_eq!(o.cause, Some(SloCause::Compute));
    }

    fn met() -> SloOutcome {
        SloOutcome { met: true, cause: None }
    }

    fn viol(cause: SloCause) -> SloOutcome {
        SloOutcome { met: false, cause: Some(cause) }
    }

    #[test]
    fn attainment_and_budget_account_run_wide_and_per_bucket() {
        let mut s = SloStats::new(SloSpec { latency_ms: 10.0, objective: 0.9 });
        // 8 met + 2 violated = 80% attainment against a 90% objective:
        // the 10% budget allows 1 violation in 10; 2 spend it twice over.
        for i in 0..8 {
            s.record_at(0.1 * i as f64, 4, 0, met());
        }
        s.record_at(0.85, 4, 0, viol(SloCause::QueueWait));
        s.record_at(0.9, 8, 0, viol(SloCause::Compute));
        let sum = s.summary();
        assert_eq!((sum.total, sum.met), (10, 8));
        assert!((sum.attainment - 0.8).abs() < 1e-12);
        assert_eq!((sum.viol_queue_wait, sum.viol_compute, sum.viol_reload), (1, 1, 0));
        // error budget: 1 - 2 / (10 * 0.1) = -1.0 (blown twice over).
        assert!((sum.error_budget_remaining - (-1.0)).abs() < 1e-9);
        // Everything within one slot: both windows see rate 0.2, burn
        // 0.2 / 0.1 = 2.
        assert!((sum.burn_rate_short - 2.0).abs() < 1e-9);
        assert!((sum.burn_rate_long - 2.0).abs() < 1e-9);
        // Bucket split: bucket 4 took 9 (8 met), bucket 8 took 1 (0 met).
        assert_eq!(sum.per_bucket, vec![(4, 9, 8), (8, 1, 0)]);
        assert!(sum.per_len_bucket.is_empty(), "len bucket 0 is the sentinel");
    }

    #[test]
    fn short_window_recovers_while_long_window_remembers() {
        let mut s = SloStats::new(SloSpec { latency_ms: 10.0, objective: 0.9 });
        // Second 0: a burst of violations.
        for _ in 0..10 {
            s.record_at(0.5, 2, 0, viol(SloCause::Compute));
        }
        // Seconds 10..20: clean traffic, one request per second.
        for t in 10..20 {
            s.record_at(t as f64 + 0.5, 2, 0, met());
        }
        let sum = s.summary();
        // The short (5 s) window only sees the clean tail: burn 0.
        assert_eq!(sum.burn_rate_short, 0.0);
        // The long window still covers the burst: 10 violations in 20
        // requests = rate 0.5, burn 5.
        assert!((sum.burn_rate_long - 5.0).abs() < 1e-9);
        // Run-wide attainment counts everything.
        assert!((sum.attainment - 0.5).abs() < 1e-12);
    }

    #[test]
    fn burn_ring_expires_slots_beyond_the_long_window() {
        let mut s = SloStats::new(SloSpec { latency_ms: 10.0, objective: 0.9 });
        for _ in 0..10 {
            s.record_at(0.5, 2, 0, viol(SloCause::QueueWait));
        }
        // 2 ring-lengths later: the burst has aged out of both windows.
        s.record_at(2.0 * super::RING_SLOTS as f64 * super::SLOT_SECS, 2, 0, met());
        let sum = s.summary();
        assert_eq!(sum.burn_rate_short, 0.0);
        assert_eq!(sum.burn_rate_long, 0.0);
        // ...but the run-wide counters never forget.
        assert_eq!(sum.violations(), 10);
    }

    #[test]
    fn empty_stats_report_full_budget() {
        let s = SloStats::new(SloSpec::default());
        let sum = s.summary();
        assert_eq!(sum.total, 0);
        assert_eq!(sum.attainment, 1.0);
        assert_eq!(sum.error_budget_remaining, 1.0);
        assert_eq!((sum.burn_rate_short, sum.burn_rate_long), (0.0, 0.0));
    }

    #[test]
    fn len_buckets_account_sequence_traffic() {
        let mut s = SloStats::new(SloSpec::default());
        s.record_at(0.0, 2, 4, met());
        s.record_at(0.0, 2, 8, viol(SloCause::Compute));
        s.record_at(0.0, 2, 8, met());
        let sum = s.summary();
        assert_eq!(sum.per_len_bucket, vec![(4, 1, 1), (8, 2, 1)]);
    }

    #[test]
    fn summary_json_carries_the_perfcheck_keys() {
        let mut s = SloStats::new(SloSpec::default());
        s.record_at(0.0, 2, 0, met());
        let j = s.summary().to_json();
        assert!(j.get("slo_attainment").is_some());
        assert!(j.get("error_budget_remaining").is_some());
        assert!(j.get("viol_queue_wait").is_some());
        assert!(j.get("viol_compute").is_some());
        assert!(j.get("viol_reload").is_some());
        assert!(j.get("burn_rate_short").is_some());
        let text = j.to_string_compact();
        assert!(text.contains("\"slo_attainment\":1"));
    }
}
