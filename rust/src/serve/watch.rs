//! `serve --watch-model`: file-polling auto-reload for a long-running
//! server.
//!
//! [`crate::serve::Server::reload`] has been API-level since the model
//! artifact subsystem landed; this module closes the loop for a server
//! that outlives its operator. A [`ModelWatcher`] thread polls the
//! artifact file's **header signature** (payload length + CRC — content
//! derived, so a rewrite is caught even on filesystems with coarse mtime
//! granularity) and, on change, loads + validates the artifact and
//! applies it through a [`ReloadHandle`] — the exact same atomic
//! weight-generation swap as an API reload, so in-flight batches still
//! finish on the weights they pinned and every applied swap lands in the
//! serve metrics (`ServeReport::reloads`).
//!
//! Trainer checkpoints are written atomically (temp file + rename), so a
//! poll never observes a half-written artifact: it sees either the old
//! file or the new one. A load or validation failure (torn copy from a
//! non-atomic writer, schema mismatch, different arch) is logged and
//! skipped — the server keeps answering on its current weights, and the
//! next signature change is tried afresh.

use crate::modelio::ModelArtifact;
use crate::serve::batcher::ReloadHandle;
use crate::{log_info, log_warn};
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// The polling reload thread. Spawn with [`ModelWatcher::spawn`]; stop
/// (and join) with [`ModelWatcher::stop`].
pub struct ModelWatcher {
    stop: Arc<AtomicBool>,
    applied: Arc<AtomicU64>,
    handle: JoinHandle<()>,
}

impl ModelWatcher {
    /// Watch `path` every `poll` interval, applying changed artifacts
    /// through `reload`. Change detection compares the artifact file's
    /// **header signature** (magic + schema version + payload length +
    /// CRC — see [`file_sig`]), which is content-derived: a rewrite is
    /// detected even when the filesystem's mtime granularity would
    /// swallow it. `loaded` is the artifact the server was built from —
    /// its re-encoded header is the baseline, so a checkpoint written
    /// *between* the server's load and this spawn is picked up on the
    /// first poll instead of silently becoming the baseline. With
    /// `loaded: None` the baseline is whatever is on disk at spawn.
    pub fn spawn(
        reload: ReloadHandle,
        path: impl Into<PathBuf>,
        poll: Duration,
        loaded: Option<&ModelArtifact>,
    ) -> ModelWatcher {
        let path = path.into();
        let stop = Arc::new(AtomicBool::new(false));
        let applied = Arc::new(AtomicU64::new(0));
        let stop_flag = Arc::clone(&stop);
        let applied_ctr = Arc::clone(&applied);
        // `save` writes exactly `encode()`'s bytes, so the loaded
        // artifact's re-encoded header equals the on-disk header iff the
        // file is still the one the server loaded.
        let baseline = loaded.map(|art| art.encode()[..SIG_LEN].to_vec());
        let handle = std::thread::spawn(move || {
            let mut last = baseline.or_else(|| file_sig(&path));
            while !stop_flag.load(Ordering::SeqCst) {
                std::thread::sleep(poll);
                let cur = file_sig(&path);
                if cur.is_none() || cur == last {
                    // Missing file: keep serving the current weights and
                    // keep the old baseline, so the file *reappearing*
                    // with new contents (next atomic rename) is picked up.
                    continue;
                }
                last = cur;
                match ModelArtifact::load(&path) {
                    Ok(art) => match reload.reload(&art) {
                        Ok(()) => {
                            applied_ctr.fetch_add(1, Ordering::SeqCst);
                            log_info!(
                                "watch-model: reloaded {} ({}, epoch {}, acc {:.1}%)",
                                path.display(),
                                art.arch.describe(),
                                art.meta.epoch,
                                art.meta.accuracy * 100.0
                            );
                        }
                        Err(e) => {
                            log_warn!("watch-model: reload of {} rejected: {:#}", path.display(), e)
                        }
                    },
                    Err(e) => log_warn!("watch-model: {:#}", e),
                }
            }
        });
        ModelWatcher { stop, applied, handle }
    }

    /// Reloads this watcher has successfully applied so far.
    pub fn reloads_applied(&self) -> u64 {
        self.applied.load(Ordering::SeqCst)
    }

    /// Stop polling and join the thread; returns the number of reloads
    /// the watcher applied over its lifetime.
    pub fn stop(self) -> u64 {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.join().expect("model watcher panicked");
        self.applied.load(Ordering::SeqCst)
    }
}

/// Artifact-header length: magic (8) + schema version (4) + payload
/// length (8) + payload CRC-32 (4) — see [`crate::modelio`]. The CRC
/// makes the signature content-derived.
const SIG_LEN: usize = 24;

/// The first [`SIG_LEN`] bytes of the file (fewer if the file is
/// shorter), or `None` if it cannot be opened. Two artifact files have
/// equal signatures iff their payload length and checksum agree —
/// change detection that is immune to coarse filesystem mtimes.
fn file_sig(path: &Path) -> Option<Vec<u8>> {
    let mut buf = Vec::with_capacity(SIG_LEN);
    std::fs::File::open(path)
        .ok()?
        .take(SIG_LEN as u64)
        .read_to_end(&mut buf)
        .ok()?;
    Some(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::{MlpModel, Model};
    use crate::modelio::{Arch, TrainMeta};
    use crate::serve::batcher::{Response, ServeOpts, Server};
    use crate::serve::model::InferenceModel;
    use crate::util::rng::Rng;
    use std::time::Instant;

    fn artifact_for_seed(sizes: &[usize], seed: u64) -> ModelArtifact {
        let model = MlpModel::new(sizes, 4, 1, &mut Rng::new(seed));
        ModelArtifact::new(
            Arch::Mlp { sizes: sizes.to_vec() },
            TrainMeta::fresh(seed),
            model.export_weights(),
        )
    }

    #[test]
    fn watcher_applies_new_artifact_and_metrics_count_it() {
        let dir = std::env::temp_dir().join("brgemm_watch_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        let sizes = [6usize, 10, 3];
        let art1 = artifact_for_seed(&sizes, 1);
        art1.save(&path).unwrap();

        let model = InferenceModel::from_artifact(&art1, 4, 1, false).unwrap();
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 1, ..ServeOpts::default() },
        );
        let watcher = ModelWatcher::spawn(
            server.reload_handle(),
            &path,
            Duration::from_millis(2),
            Some(&art1),
        );
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(watcher.reloads_applied(), 0, "the loaded artifact must not trigger");

        // A new artifact lands via the trainer's atomic rename; detection
        // is by header signature (length + CRC), not mtime, so no
        // granularity games are needed.
        let art2 = artifact_for_seed(&sizes, 2);
        art2.save(&path).unwrap();
        let t0 = Instant::now();
        while watcher.reloads_applied() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(watcher.reloads_applied() >= 1, "watcher never picked up the new artifact");

        // Requests submitted after the reload answer with the new weights.
        let x = Rng::new(3).vec_f32(6, -1.0, 1.0);
        let id = server.submit(x.clone());
        let report = server.shutdown();
        let applied = watcher.stop();
        assert!(report.reloads >= applied, "watch reloads land in the serve metrics");
        assert!(applied >= 1);
        let responses: Vec<Response> = rx.iter().collect();
        let r = responses.iter().find(|r| r.id == id).expect("response delivered");
        let new_oracle = InferenceModel::from_artifact(&art2, 4, 1, false).unwrap();
        assert_eq!(
            r.logits,
            new_oracle.forward(1, &x),
            "post-reload responses come from the watched artifact"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_landing_before_spawn_is_not_missed() {
        // Regression: the baseline is the artifact the server *loaded*,
        // not whatever is on disk at spawn — a checkpoint written in the
        // window between the server's load and the watcher's spawn must
        // be applied on the first poll, not silently become the baseline.
        let dir = std::env::temp_dir().join("brgemm_watch_model_window_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        let sizes = [6usize, 10, 3];
        let art1 = artifact_for_seed(&sizes, 1);
        art1.save(&path).unwrap();
        let model = InferenceModel::from_artifact(&art1, 4, 1, false).unwrap();
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 1, ..ServeOpts::default() },
        );
        // The trainer checkpoints *before* the watcher is up.
        let art2 = artifact_for_seed(&sizes, 2);
        art2.save(&path).unwrap();
        let watcher = ModelWatcher::spawn(
            server.reload_handle(),
            &path,
            Duration::from_millis(2),
            Some(&art1),
        );
        let t0 = Instant::now();
        while watcher.reloads_applied() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(watcher.reloads_applied(), 1, "pre-spawn checkpoint must be applied");
        let _ = server.shutdown();
        watcher.stop();
        drop(rx);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn watcher_skips_bad_files_and_recovers() {
        // A corrupt write must be logged + skipped (server keeps its
        // weights), and a later good artifact must still be applied.
        let dir = std::env::temp_dir().join("brgemm_watch_model_bad_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        let sizes = [6usize, 10, 3];
        let art1 = artifact_for_seed(&sizes, 1);
        art1.save(&path).unwrap();
        let model = InferenceModel::from_artifact(&art1, 4, 1, false).unwrap();
        let (server, rx) = Server::start(
            model,
            ServeOpts { max_batch: 4, workers: 1, ..ServeOpts::default() },
        );
        let watcher = ModelWatcher::spawn(
            server.reload_handle(),
            &path,
            Duration::from_millis(2),
            Some(&art1),
        );
        std::fs::write(&path, b"not an artifact").unwrap();
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(watcher.reloads_applied(), 0, "garbage must not be applied");
        // Recovery: a good artifact replaces the garbage — detected by
        // signature change regardless of how close the writes landed.
        let art2 = artifact_for_seed(&sizes, 2);
        art2.save(&path).unwrap();
        let t0 = Instant::now();
        while watcher.reloads_applied() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(watcher.reloads_applied(), 1, "recovery artifact applied");
        let _ = server.shutdown();
        watcher.stop();
        drop(rx);
        std::fs::remove_dir_all(&dir).ok();
    }
}
