//! Inference serving: dynamic batching over shared-weight BRGEMM plans.
//!
//! The paper's thesis is that one tuned batch-reduce GEMM kernel plus
//! cheap loops around it covers every DL workload. Training exercised
//! that claim in the coordinator; this subsystem applies it to *serving*,
//! where the mini-batch is a **runtime** axis instead of a config
//! constant: single-sample requests arrive on an open loop, a dynamic
//! batcher coalesces them into pow-2 batch buckets (pad-to-bucket, masked
//! outputs), and a worker pool executes forward-only inference through
//! per-bucket BRGEMM execution plans.
//!
//! The enabling refactor lives in the primitive layer: packed weights are
//! split out of `FcPrimitive`/`ConvPrimitive` execution state into
//! [`Arc`](std::sync::Arc)-shared structs
//! ([`FcSharedWeights`](crate::primitives::fc::FcSharedWeights),
//! [`ConvSharedWeights`](crate::primitives::conv::ConvSharedWeights)), so
//! **one packed weight copy per layer** backs every bucket's plan — the
//! packed layouts depend only on the feature blocking, never on the
//! mini-batch. Each bucket's plan is constructed through the primitives'
//! `tuned()` path, so the autotune cache is consulted per bucket shape.
//!
//! Weights come from He init or — the production path — from a trained
//! [`ModelArtifact`](crate::modelio::ModelArtifact) (`serve --model-path`,
//! [`InferenceModel::from_artifact`]), and a running server hot-swaps a
//! new artifact atomically ([`Server::reload`]): in-flight batches finish
//! on the generation they pinned at batch start, the swap count lands in
//! the serve metrics.
//!
//! Modules:
//!
//! * [`model`]   — [`InferenceModel`]: the bucket-plan set over one shared
//!   weight allocation per layer; forward-only MLP / CNN execution with
//!   per-worker scratch reuse ([`ServeScratch`] — no per-request
//!   allocation on the steady-state path) and atomic weight-generation
//!   swap for hot reload.
//! * [`batcher`] — [`Server`]: request queue, dynamic batcher (greedy, or
//!   delayed by the [`ServeOpts::wait_for_fill_us`] fill window), worker
//!   pool, drain-on-shutdown semantics, hot reload entry point.
//! * [`metrics`] — per-request latency (p50/p95/p99), throughput, queue
//!   depth, the batch-fill histogram, and the reload counter, with JSON
//!   export and Prometheus text exposition (`admin metrics`).
//! * [`slo`]     — the SLO plane: per-request deadlines stamped at
//!   submit, met/violated classification with queue-vs-compute-vs-reload
//!   attribution, run-wide and per-bucket attainment, multi-window burn
//!   rate and error-budget accounting ([`ServeOpts::slo`]).
//! * [`loadgen`] — deterministic open-loop load generator (Poisson
//!   arrivals from [`crate::util::rng`]); [`loadgen::seq_request_source`]
//!   draws GNMT-style mixed-length sequence requests from the same seed.
//! * [`watch`]   — `--watch-model`: a file-polling thread that applies
//!   a changed artifact file through the hot-reload path, so a
//!   long-running server tracks a concurrent trainer's checkpoints.
//! * [`admin`]   — `--admin-sock`: a Unix-domain-socket control endpoint
//!   speaking line-delimited JSON (`stats` / `trace` / `reload` /
//!   `drain` / `health` / `metrics`) over an [`AdminHandle`] — the
//!   push-style superset of the poll-only watcher, one thread per
//!   connection so liveness polls answer during a blocking drain.
//!
//! Forward-only plans cover all three of the paper's workload classes —
//! MLP, CNN, and RNN (a stack of LSTM cells + classifier head,
//! [`crate::primitives::lstm::LstmSharedWeights`] per layer). Sequence
//! requests additionally carry a **runtime length** axis: the batcher
//! rounds each request up to a pow-2 *length bucket*, queues per length
//! bucket, and the model runs the stacked recurrence as a `t_run =
//! len_bucket` prefix of its full-capacity plans — gathering each row's
//! final hidden state at its true length, so co-batched variable-length
//! rows are bit-identical to solo batch-1 runs.
//!
//! Entry points: the `serve` CLI subcommand / `{"serve": {...}}`
//! run-config (see `examples/serve.json`; `serve --model-path <artifact>`
//! serves trained weights) and the `serve_load` bench.

pub mod admin;
pub mod batcher;
pub mod loadgen;
pub mod metrics;
pub mod model;
pub mod slo;
pub mod watch;

pub use admin::AdminServer;
pub use batcher::{AdminHandle, ReloadHandle, Response, ServeOpts, Server};
pub use loadgen::{
    drive_open_loop, drive_open_loop_every, run_open_loop, run_open_loop_with, seq_request_len,
    seq_request_source, LoadSpec,
};
pub use metrics::{ServeReport, ServeStats, ServerInfo};
pub use slo::{SloSpec, SloSummary};
pub use model::{InferenceModel, NetSpec, ServeScratch};
pub use watch::ModelWatcher;
