//! Inference serving: dynamic batching over shared-weight BRGEMM plans.
//!
//! The paper's thesis is that one tuned batch-reduce GEMM kernel plus
//! cheap loops around it covers every DL workload. Training exercised
//! that claim in the coordinator; this subsystem applies it to *serving*,
//! where the mini-batch is a **runtime** axis instead of a config
//! constant: single-sample requests arrive on an open loop, a dynamic
//! batcher coalesces them into pow-2 batch buckets (pad-to-bucket, masked
//! outputs), and a worker pool executes forward-only inference through
//! per-bucket BRGEMM execution plans.
//!
//! The enabling refactor lives in the primitive layer: packed weights are
//! split out of `FcPrimitive`/`ConvPrimitive` execution state into
//! [`Arc`](std::sync::Arc)-shared structs
//! ([`FcSharedWeights`](crate::primitives::fc::FcSharedWeights),
//! [`ConvSharedWeights`](crate::primitives::conv::ConvSharedWeights)), so
//! **one packed weight copy per layer** backs every bucket's plan — the
//! packed layouts depend only on the feature blocking, never on the
//! mini-batch. Each bucket's plan is constructed through the primitives'
//! `tuned()` path, so the autotune cache is consulted per bucket shape.
//!
//! Modules:
//!
//! * [`model`]   — [`InferenceModel`]: the bucket-plan set over one shared
//!   weight allocation per layer; forward-only MLP / CNN execution.
//! * [`batcher`] — [`Server`]: request queue, dynamic batcher, worker
//!   pool, drain-on-shutdown semantics.
//! * [`metrics`] — per-request latency (p50/p95/p99), throughput, queue
//!   depth, and the batch-fill histogram, with JSON export.
//! * [`loadgen`] — deterministic open-loop load generator (Poisson
//!   arrivals from [`crate::util::rng`]).
//!
//! Entry points: the `serve` CLI subcommand / `{"serve": {...}}`
//! run-config (see `examples/serve.json`) and the `serve_load` bench.

pub mod batcher;
pub mod loadgen;
pub mod metrics;
pub mod model;

pub use batcher::{Response, ServeOpts, Server};
pub use loadgen::{run_open_loop, LoadSpec};
pub use metrics::{ServeReport, ServeStats};
pub use model::{InferenceModel, NetSpec};
