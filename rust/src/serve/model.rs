//! Forward-only inference models with per-bucket execution plans over one
//! shared weight allocation per layer.
//!
//! Serving makes the mini-batch a runtime axis: the dynamic batcher may
//! hand a worker 1, 2, 4, … up to `max_batch` samples. Each bucket size
//! gets its own execution plan — the primitives' configs (and therefore
//! their BRGEMM descriptors and thread partitions) are built per bucket,
//! routed through the `tuned()` constructors so the autotune cache is
//! keyed per bucket shape. What the plans **share** is the packed
//! weights: [`FcSharedWeights`] / [`ConvSharedWeights`] are allocated
//! exactly once per layer and every plan executes against the same
//! [`Arc`](std::sync::Arc)-backed buffers.
//!
//! The feature blocking `(bc, bk)` is pinned across buckets (the packed
//! layout depends on it), so per-element accumulation order is identical
//! at every bucket size — a co-batched request's logits are bit-identical
//! to running it solo at batch 1, which is what makes pad-to-bucket
//! masking safe (and is asserted by the batcher tests).

use crate::coordinator::cnn::CnnSpec;
use crate::primitives::conv::{ConvConfig, ConvPrimitive, ConvSharedWeights};
use crate::primitives::eltwise::Act;
use crate::primitives::fc::{FcConfig, FcPrimitive, FcSharedWeights};
use crate::primitives::pool::AvgPool;
use crate::tensor::layout;
use crate::util::num::largest_divisor_le as pick;
use crate::util::rng::Rng;

/// Which network a serving model executes.
#[derive(Debug, Clone)]
pub enum NetSpec {
    /// `sizes = [d_in, h1, ..., classes]`; hidden ReLU, linear head.
    Mlp { sizes: Vec<usize> },
    /// Conv stack + pool + FC head (the training driver's topology).
    Cnn(CnnSpec),
}

impl NetSpec {
    pub fn input_dim(&self) -> usize {
        match self {
            NetSpec::Mlp { sizes } => sizes[0],
            NetSpec::Cnn(spec) => spec.input_dim(),
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            NetSpec::Mlp { sizes } => *sizes.last().unwrap(),
            NetSpec::Cnn(spec) => spec.classes,
        }
    }
}

/// The batch buckets for a maximum batch: powers of two up to `max`, plus
/// `max` itself when it is not a power of two (so a full queue can always
/// be taken whole).
pub fn bucket_sizes(max_batch: usize) -> Vec<usize> {
    assert!(max_batch >= 1);
    let mut out = Vec::new();
    let mut b = 1;
    while b < max_batch {
        out.push(b);
        b *= 2;
    }
    out.push(max_batch);
    out
}

/// One bucket's executable pipeline (primitives only — weights live in
/// the shared structs on [`InferenceModel`]).
enum PlanKind {
    Mlp { fcs: Vec<FcPrimitive> },
    Cnn { convs: Vec<ConvPrimitive>, pool: AvgPool, head: FcPrimitive },
}

struct Plan {
    batch: usize,
    kind: PlanKind,
}

/// A forward-only model: per-bucket plans over one shared weight copy per
/// layer. `Send + Sync` (all state is plain config + `Arc` buffers), so
/// the worker pool shares it behind one `Arc`.
pub struct InferenceModel {
    spec: NetSpec,
    buckets: Vec<usize>,
    /// MLP layer weights, or (for CNN) the single FC head entry.
    fc_weights: Vec<FcSharedWeights>,
    /// CNN conv-stack weights (empty for MLP).
    conv_weights: Vec<ConvSharedWeights>,
    plans: Vec<Plan>,
}

impl InferenceModel {
    /// Build an MLP serving model with He-initialised weights. With
    /// `tuned`, each bucket's layer configs consult the autotune cache
    /// (the per-bucket shape is the cache key); the feature blocking is
    /// then pinned back to the shared packed layout, so a tuning hit can
    /// re-block the batch axis and kernel variants but never fork the
    /// weight copy.
    pub fn new_mlp(
        sizes: &[usize],
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        assert!(sizes.len() >= 2, "mlp needs at least input + output sizes");
        let buckets = bucket_sizes(max_batch);
        // Canonical feature blocking (chain invariant bc_i = bk_{i-1}
        // holds by construction: both are pick(shared dim, 64)).
        let canon: Vec<FcConfig> = sizes
            .windows(2)
            .enumerate()
            .map(|(i, wd)| {
                let act = if i + 2 == sizes.len() { Act::Identity } else { Act::Relu };
                FcConfig::new(max_batch, wd[0], wd[1], act)
                    .with_blocking(pick(max_batch, 24), pick(wd[0], 64), pick(wd[1], 64))
            })
            .collect();
        // One packed weight allocation per layer, shared by every plan.
        let fc_weights: Vec<FcSharedWeights> = canon
            .iter()
            .map(|cfg| {
                let scale = (2.0 / cfg.c as f32).sqrt();
                let w_plain = rng.vec_f32(cfg.k * cfg.c, -scale, scale);
                let bias = rng.vec_f32(cfg.k, -0.1, 0.1);
                FcSharedWeights::pack(cfg, &w_plain, &bias)
            })
            .collect();
        let plans = buckets
            .iter()
            .map(|&b| {
                // One bn for the whole chain: blocked activations flow
                // between layers with no repack, so every layer of a
                // bucket's plan must agree on the batch block (the same
                // reconciliation MlpModel applies). With tuning, layer 0's
                // cached bn wins for the chain.
                let mut shared_bn = pick(b, 24);
                if tuned {
                    let cfg0 = FcConfig::new(b, canon[0].c, canon[0].k, canon[0].act)
                        .with_blocking(shared_bn, canon[0].bc, canon[0].bk)
                        .with_threads(nthreads);
                    shared_bn = crate::autotune::tuned_fc_config(cfg0).bn;
                }
                let fcs = canon
                    .iter()
                    .zip(&fc_weights)
                    .map(|(base, w)| {
                        let mut cfg = FcConfig::new(b, base.c, base.k, base.act)
                            .with_blocking(shared_bn, base.bc, base.bk)
                            .with_threads(nthreads);
                        if tuned {
                            // Per-bucket cache key; keep the tuned kernel
                            // variants, pin bn to the chain's shared value
                            // and the feature blocks to the shared packed
                            // layout.
                            let t = crate::autotune::tuned_fc_config(cfg);
                            cfg = t.with_blocking(shared_bn, base.bc, base.bk);
                        }
                        assert!(w.matches(&cfg), "bucket plan must match shared weights");
                        FcPrimitive::new(cfg)
                    })
                    .collect();
                Plan { batch: b, kind: PlanKind::Mlp { fcs } }
            })
            .collect();
        InferenceModel {
            spec: NetSpec::Mlp { sizes: sizes.to_vec() },
            buckets,
            fc_weights,
            conv_weights: Vec::new(),
            plans,
        }
    }

    /// Build a CNN serving model (conv stack + pool + FC head) with
    /// He-initialised weights; same sharing/tuning contract as
    /// [`Self::new_mlp`].
    pub fn new_cnn(
        spec: &CnnSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        assert!(!spec.convs.is_empty(), "need at least one conv layer");
        let buckets = bucket_sizes(max_batch);
        // Canonical conv configs with the chain invariant enforced
        // (consumer bc = producer bk), exactly like the training driver.
        let mut canon: Vec<ConvConfig> = spec.conv_configs(max_batch, nthreads);
        for i in 1..canon.len() {
            let prev_bk = canon[i - 1].bk;
            if canon[i].bc != prev_bk {
                canon[i] = canon[i].with_blocking(prev_bk, canon[i].bk, canon[i].bq);
            }
        }
        let conv_weights: Vec<ConvSharedWeights> = canon
            .iter()
            .map(|cfg| {
                let scale = (2.0 / (cfg.c * cfg.r * cfg.s) as f32).sqrt();
                let w_plain = rng.vec_f32(cfg.weights_len(), -scale, scale);
                let bias = rng.vec_f32(cfg.k, -0.1, 0.1);
                ConvSharedWeights::pack(cfg, &w_plain, &bias)
            })
            .collect();
        let last = *canon.last().unwrap();
        let pcfg0 = spec.pool_config(max_batch, &last).with_block(last.bk);
        let feat = last.k * pcfg0.p() * pcfg0.q();
        let head_canon = FcConfig::new(max_batch, feat, spec.classes, Act::Identity)
            .with_blocking(pick(max_batch, 24), pick(feat, 64), pick(spec.classes, 64));
        let head_weights = {
            let scale = (2.0 / feat as f32).sqrt();
            let w_plain = rng.vec_f32(spec.classes * feat, -scale, scale);
            let bias = rng.vec_f32(spec.classes, -0.1, 0.1);
            FcSharedWeights::pack(&head_canon, &w_plain, &bias)
        };
        let plans = buckets
            .iter()
            .map(|&b| {
                let convs: Vec<ConvPrimitive> = spec
                    .conv_configs(b, nthreads)
                    .into_iter()
                    .zip(&canon)
                    .zip(&conv_weights)
                    .map(|((cfg, base), w)| {
                        let mut cfg = cfg;
                        if tuned {
                            cfg = crate::autotune::tuned_conv_config(cfg);
                        }
                        // Pin the feature blocks to the shared packed
                        // layout (keeps any tuned bq / flat / loop order).
                        if cfg.bc != base.bc || cfg.bk != base.bk {
                            cfg = cfg.with_blocking(base.bc, base.bk, cfg.bq);
                        }
                        assert!(w.matches(&cfg), "bucket plan must match shared weights");
                        ConvPrimitive::new(cfg)
                    })
                    .collect();
                let blast = convs.last().unwrap().cfg;
                let pool = AvgPool::new(
                    spec.pool_config(b, &blast).with_block(blast.bk).with_threads(nthreads),
                );
                let mut hcfg = FcConfig::new(b, feat, spec.classes, Act::Identity)
                    .with_blocking(pick(b, 24), head_canon.bc, head_canon.bk)
                    .with_threads(nthreads);
                if tuned {
                    let t = crate::autotune::tuned_fc_config(hcfg);
                    hcfg = t.with_blocking(t.bn, head_canon.bc, head_canon.bk);
                }
                assert!(head_weights.matches(&hcfg));
                Plan {
                    batch: b,
                    kind: PlanKind::Cnn { convs, pool, head: FcPrimitive::new(hcfg) },
                }
            })
            .collect();
        InferenceModel {
            spec: NetSpec::Cnn(spec.clone()),
            buckets,
            fc_weights: vec![head_weights],
            conv_weights,
            plans,
        }
    }

    /// Build from a [`NetSpec`] (the run-config dispatch point).
    pub fn from_spec(
        spec: &NetSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        match spec {
            NetSpec::Mlp { sizes } => {
                InferenceModel::new_mlp(sizes, max_batch, nthreads, tuned, rng)
            }
            NetSpec::Cnn(c) => InferenceModel::new_cnn(c, max_batch, nthreads, tuned, rng),
        }
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn input_dim(&self) -> usize {
        self.spec.input_dim()
    }

    pub fn classes(&self) -> usize {
        self.spec.classes()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    pub fn max_batch(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits `k` requests (`1 <= k <= max_batch`).
    pub fn bucket_for(&self, k: usize) -> usize {
        assert!(k >= 1 && k <= self.max_batch(), "batch {} outside buckets", k);
        *self.buckets.iter().find(|&&b| b >= k).unwrap()
    }

    /// Distinct packed-weight allocations backing this model — one per
    /// layer, *regardless of the number of batch buckets* (the acceptance
    /// invariant; plans hold no weight storage at all).
    pub fn weight_alloc_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self
            .conv_weights
            .iter()
            .map(|w| w.alloc_id())
            .chain(self.fc_weights.iter().map(|w| w.alloc_id()))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Number of weight-bearing layers (conv stack + FC layers).
    pub fn layer_count(&self) -> usize {
        self.conv_weights.len() + self.fc_weights.len()
    }

    /// Forward `bucket` samples (plain `[bucket][input_dim]`, padded rows
    /// included) through the bucket's plan; returns plain
    /// `[bucket][classes]` logits. `&self` — safe to call concurrently
    /// from many workers.
    pub fn forward(&self, bucket: usize, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), bucket * self.input_dim(), "input shape mismatch");
        let plan = self
            .plans
            .iter()
            .find(|p| p.batch == bucket)
            .unwrap_or_else(|| panic!("no plan for bucket {}", bucket));
        match &plan.kind {
            PlanKind::Mlp { fcs } => {
                let cfg0 = fcs[0].cfg;
                let mut cur = layout::pack_act_2d(x, bucket, cfg0.c, cfg0.bn, cfg0.bc);
                for (fc, w) in fcs.iter().zip(&self.fc_weights) {
                    let mut y = vec![0.0f32; bucket * fc.cfg.k];
                    fc.forward_shared(&cur, w, &mut y);
                    cur = y;
                }
                let lcfg = fcs.last().unwrap().cfg;
                layout::unpack_act_2d(&cur, bucket, lcfg.k, lcfg.bn, lcfg.bk)
            }
            PlanKind::Cnn { convs, pool, head } => {
                let cfg0 = convs[0].cfg;
                let mut cur = layout::pack_conv_act(
                    x, bucket, cfg0.c, cfg0.h, cfg0.w, cfg0.bc, cfg0.pad, cfg0.pad,
                );
                for (i, (prim, w)) in convs.iter().zip(&self.conv_weights).enumerate() {
                    let mut y = vec![0.0f32; prim.cfg.output_len()];
                    prim.forward_shared(&cur, w, &mut y);
                    cur = match convs.get(i + 1) {
                        // Chain invariant: the output is the consumer's
                        // unpadded input; only the border re-pad remains.
                        Some(next) => {
                            let nc = next.cfg;
                            layout::repad_blocked(
                                &y, bucket, nc.cb_ct(), nc.h, nc.w, nc.bc, nc.pad, nc.pad,
                            )
                        }
                        None => y,
                    };
                }
                let mut pool_y = vec![0.0f32; pool.cfg.output_len()];
                pool.forward(&cur, &mut pool_y);
                let hcfg = head.cfg;
                let head_x = layout::pack_act_2d(&pool_y, bucket, hcfg.c, hcfg.bn, hcfg.bc);
                let mut head_y = vec![0.0f32; bucket * hcfg.k];
                head.forward_shared(&head_x, &self.fc_weights[0], &mut head_y);
                layout::unpack_act_2d(&head_y, bucket, hcfg.k, hcfg.bn, hcfg.bk)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cnn::ConvSpec;

    fn tiny_cnn() -> CnnSpec {
        CnnSpec {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            convs: vec![
                ConvSpec { k: 3, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 1, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        }
    }

    #[test]
    fn bucket_ladder_shapes() {
        assert_eq!(bucket_sizes(1), vec![1]);
        assert_eq!(bucket_sizes(8), vec![1, 2, 4, 8]);
        assert_eq!(bucket_sizes(6), vec![1, 2, 4, 6]);
        let m = InferenceModel::new_mlp(&[6, 8, 3], 6, 1, false, &mut Rng::new(1));
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(3), 4);
        assert_eq!(m.bucket_for(5), 6);
        assert_eq!(m.bucket_for(6), 6);
    }

    #[test]
    fn packed_weights_allocated_once_per_layer() {
        // The acceptance invariant: however many buckets exist, each
        // layer's packed weights are one allocation shared by every plan.
        let mlp = InferenceModel::new_mlp(&[12, 16, 8, 4], 16, 1, false, &mut Rng::new(2));
        assert_eq!(mlp.buckets().len(), 5, "1/2/4/8/16");
        assert_eq!(mlp.layer_count(), 3);
        assert_eq!(mlp.weight_alloc_ids().len(), 3, "3 layers -> 3 allocations, not 15");

        let cnn = InferenceModel::new_cnn(&tiny_cnn(), 8, 1, false, &mut Rng::new(3));
        assert_eq!(cnn.layer_count(), 3, "2 convs + head");
        assert_eq!(cnn.weight_alloc_ids().len(), 3, "3 layers -> 3 allocations, not 12");
    }

    #[test]
    fn co_batched_rows_bit_identical_to_solo_mlp() {
        let model = InferenceModel::new_mlp(&[10, 12, 5], 8, 1, false, &mut Rng::new(7));
        let mut rng = Rng::new(8);
        let dim = model.input_dim();
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        // 3 real rows padded into the 4-bucket.
        let mut x = vec![0.0f32; 4 * dim];
        for (i, s) in samples.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(s);
        }
        let batched = model.forward(4, &x);
        let classes = model.classes();
        for (i, s) in samples.iter().enumerate() {
            let solo = model.forward(1, s);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "row {} must be bit-identical to its solo batch-1 run",
                i
            );
        }
    }

    #[test]
    fn co_batched_rows_bit_identical_to_solo_cnn() {
        let model = InferenceModel::new_cnn(&tiny_cnn(), 4, 1, false, &mut Rng::new(11));
        let mut rng = Rng::new(12);
        let dim = model.input_dim();
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        let mut x = vec![0.0f32; 4 * dim];
        for (i, s) in samples.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(s);
        }
        let batched = model.forward(4, &x);
        let classes = model.classes();
        for (i, s) in samples.iter().enumerate() {
            let solo = model.forward(1, s);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "cnn row {} must be bit-identical to its solo batch-1 run",
                i
            );
        }
    }

    #[test]
    fn tuned_bucket_plans_share_weights_and_match_untuned_math() {
        use crate::autotune::{cache, Candidate, TuneEntry, TuningCache};
        // Seed the cache for the bucket-2 layer-0 shape only, with a
        // candidate whose batch and feature blocks disagree with the
        // defaults: the plan must adopt the tuned bn for the *whole chain*
        // (blocked activations flow between layers with no repack) while
        // pinning bc/bk back to the shared packing. Layer 1 has Cb > 1
        // (130 features, bc 26), so a bn mismatch between the layers
        // would scramble the layout and fail the math check below.
        let sizes = [18usize, 130, 5];
        let cfg_b2 = FcConfig::new(2, 18, 130, Act::Relu);
        let cand = Candidate {
            bn: 1,
            bc: 9,
            bk: 13,
            bq: 1,
            flat_bq: 0,
            order: None,
            fwd_strided: true,
            upd_transpose: false,
        };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&cache::fc_key(&cfg_b2), TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });
        let plain = InferenceModel::new_mlp(&sizes, 4, 1, false, &mut Rng::new(21));
        let tuned = InferenceModel::new_mlp(&sizes, 4, 1, true, &mut Rng::new(21));
        assert_eq!(
            tuned.weight_alloc_ids().len(),
            2,
            "tuning must not fork the weight copies"
        );
        let x = Rng::new(22).vec_f32(2 * 18, -1.0, 1.0);
        let yp = plain.forward(2, &x);
        let yt = tuned.forward(2, &x);
        for i in 0..yp.len() {
            assert!((yp[i] - yt[i]).abs() < 1e-4, "[{}]: {} vs {}", i, yp[i], yt[i]);
        }
        // The untuned buckets are unaffected by the cache entry.
        let x4 = Rng::new(23).vec_f32(4 * 18, -1.0, 1.0);
        let y4p = plain.forward(4, &x4);
        let y4t = tuned.forward(4, &x4);
        for i in 0..y4p.len() {
            assert!((y4p[i] - y4t[i]).abs() < 1e-4, "b4 [{}]: {} vs {}", i, y4p[i], y4t[i]);
        }
    }
}
