//! Forward-only inference models with per-bucket execution plans over one
//! shared weight allocation per layer.
//!
//! Serving makes the mini-batch a runtime axis: the dynamic batcher may
//! hand a worker 1, 2, 4, … up to `max_batch` samples. Each bucket size
//! gets its own execution plan — the primitives' configs (and therefore
//! their BRGEMM descriptors and thread partitions) are built per bucket,
//! routed through the `tuned()` constructors so the autotune cache is
//! keyed per bucket shape. What the plans **share** is the packed
//! weights: [`FcSharedWeights`] / [`ConvSharedWeights`] are allocated
//! exactly once per layer and every plan executes against the same
//! [`Arc`]-backed buffers.
//!
//! The weight set itself is one immutable generation behind an
//! `RwLock<Arc<_>>`: [`InferenceModel::reload`] atomically swaps in the
//! parameters of a new [`ModelArtifact`] (re-packed against the canonical
//! feature blocking), while every in-flight batch keeps the `Arc` it
//! cloned at batch start and finishes on the weights it started with.
//! Weights come from either He init ([`InferenceModel::new_mlp`] /
//! [`InferenceModel::new_cnn`]) or a trained artifact
//! ([`InferenceModel::from_artifact`]); both paths build layer configs
//! through [`crate::coordinator::build`], the same module the training
//! drivers use, so trained weights lift into serving plans byte-compatibly
//! by construction.
//!
//! The feature blocking `(bc, bk)` is pinned across buckets (the packed
//! layout depends on it), so per-element accumulation order is identical
//! at every bucket size — a co-batched request's logits are bit-identical
//! to running it solo at batch 1, which is what makes pad-to-bucket
//! masking safe (and is asserted by the batcher tests).
//!
//! Sequence models add a second bucket axis: runtime length. Each plan's
//! stacked LSTM cells are configured at the arch's full capacity `T`, and
//! a batch of requests sharing a *length bucket* executes the same plan
//! as a prefix run ([`LstmPrimitive::forward_shared_t`] with `t_run` =
//! the length bucket) — no extra plans, no extra packed weights, one
//! tuned config per batch bucket covering every length. Each row's final
//! hidden state is gathered at the row's **own** true length, so a short
//! request co-batched under a longer bucket is bit-identical to running
//! it solo (zero time-padding past a row's length never feeds back into
//! the steps before it, and batch rows are computationally independent).
//!
//! The steady-state path allocates nothing per request: workers run
//! [`InferenceModel::forward_with`] against a per-worker [`ServeScratch`]
//! whose buffers grow to their high-water mark and are then reused
//! (asserted by the scratch test via [`ServeScratch::alloc_events`]).

use crate::coordinator::build;
use crate::coordinator::cnn::CnnSpec;
use crate::coordinator::rnn::RnnSpec;
use crate::modelio::{Arch, LayerKind, LayerParams, ModelArtifact};
use crate::primitives::conv::{ConvConfig, ConvPrimitive, ConvSharedWeights};
use crate::primitives::eltwise::Act;
use crate::primitives::fc::{FcConfig, FcPrimitive, FcSharedWeights};
use crate::primitives::lstm::{
    LstmConfig, LstmPrimitive, LstmSharedWeights, LstmWorkspace, GATES,
};
use crate::primitives::pool::AvgPool;
use crate::tensor::layout;
use crate::util::num::largest_divisor_le as pick;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// Which network a serving model executes.
#[derive(Debug, Clone)]
pub enum NetSpec {
    /// `sizes = [d_in, h1, ..., classes]`; hidden ReLU, linear head.
    Mlp { sizes: Vec<usize> },
    /// Conv stack + pool + FC head (the training driver's topology).
    Cnn(CnnSpec),
    /// Stacked LSTM cells + FC head on the top cell's final hidden
    /// state; a request is one flattened `[len][C]` sequence with
    /// `1 <= len <= spec.t` (runtime lengths ride the length-bucket
    /// ladder).
    Rnn(RnnSpec),
}

impl NetSpec {
    pub fn input_dim(&self) -> usize {
        match self {
            NetSpec::Mlp { sizes } => sizes[0],
            NetSpec::Cnn(spec) => spec.input_dim(),
            NetSpec::Rnn(spec) => spec.input_dim(),
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            NetSpec::Mlp { sizes } => *sizes.last().unwrap(),
            NetSpec::Cnn(spec) => spec.classes,
            NetSpec::Rnn(spec) => spec.classes,
        }
    }

    /// The artifact arch descriptor of this topology.
    pub fn to_arch(&self) -> Arch {
        match self {
            NetSpec::Mlp { sizes } => Arch::Mlp { sizes: sizes.clone() },
            NetSpec::Cnn(spec) => Arch::Cnn(spec.clone()),
            NetSpec::Rnn(spec) => Arch::Rnn(*spec),
        }
    }

    pub fn from_arch(arch: &Arch) -> NetSpec {
        match arch {
            Arch::Mlp { sizes } => NetSpec::Mlp { sizes: sizes.clone() },
            Arch::Cnn(spec) => NetSpec::Cnn(spec.clone()),
            Arch::Rnn(spec) => NetSpec::Rnn(*spec),
        }
    }
}

/// The batch buckets for a maximum batch: powers of two up to `max`, plus
/// `max` itself when it is not a power of two (so a full queue can always
/// be taken whole).
pub fn bucket_sizes(max_batch: usize) -> Vec<usize> {
    assert!(max_batch >= 1);
    let mut out = Vec::new();
    let mut b = 1;
    while b < max_batch {
        out.push(b);
        b *= 2;
    }
    out.push(max_batch);
    out
}

/// One bucket's executable pipeline (primitives only — weights live in
/// the model's current [`WeightSet`]).
enum PlanKind {
    Mlp { fcs: Vec<FcPrimitive> },
    Cnn { convs: Vec<ConvPrimitive>, pool: AvgPool, head: FcPrimitive },
    Rnn { cells: Vec<LstmPrimitive>, head: FcPrimitive },
}

struct Plan {
    batch: usize,
    kind: PlanKind,
}

/// One immutable generation of packed weights. [`InferenceModel::reload`]
/// replaces the whole set atomically; batches in flight keep the old
/// generation alive through their cloned [`Arc`].
struct WeightSet {
    /// MLP layer weights, or (for CNN/RNN) the single FC head entry.
    fc: Vec<FcSharedWeights>,
    /// CNN conv-stack weights (empty otherwise).
    conv: Vec<ConvSharedWeights>,
    /// Stacked RNN cell weights, bottom-up (empty otherwise).
    lstm: Vec<LstmSharedWeights>,
}

/// One layer's compute interval inside a forward pass, recorded only when
/// the span tracer is installed ([`crate::telemetry::trace::enabled`]).
/// The batcher turns these into per-layer trace spans nested under the
/// batch's compute span.
#[derive(Debug, Clone, Copy)]
pub struct LayerMark {
    /// Layer family: `"fc"`, `"conv"`, `"pool"`, `"lstm"`, or `"head"`.
    pub label: &'static str,
    /// Position within the plan (0-based, in execution order).
    pub index: u32,
    pub start: Instant,
    pub dur: Duration,
}

/// Per-worker reusable buffers for [`InferenceModel::forward_with`]. Each
/// buffer grows to the high-water mark across the buckets the worker has
/// executed and then stops allocating — the serving steady state performs
/// zero per-request allocation on the activation path.
#[derive(Default)]
pub struct ServeScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    pool_y: Vec<f32>,
    head_x: Vec<f32>,
    head_y: Vec<f32>,
    out: Vec<f32>,
    /// RNN plans' per-stacked-cell workspaces (gates/h/s), one per layer,
    /// sized at the config's full capacity `T` per bucket — prefix runs
    /// over any length bucket reuse the same buffers.
    lstm: Vec<LstmWorkspace>,
    /// Per-layer compute intervals of the most recent forward pass.
    /// Empty unless the span tracer is installed; the Vec's capacity
    /// stabilizes at the plan's layer count, so steady-state tracing
    /// stays allocation-free too.
    pub layer_marks: Vec<LayerMark>,
    grows: usize,
}

impl ServeScratch {
    pub fn new() -> ServeScratch {
        ServeScratch::default()
    }

    /// How many times any buffer had to (re)allocate. Stops increasing
    /// once every bucket the worker serves has been seen — the assertion
    /// handle for the no-per-request-allocation invariant.
    pub fn alloc_events(&self) -> usize {
        self.grows
    }
}

/// Resize `buf` to exactly `len`, counting a grow event iff the resize
/// had to allocate (capacity was insufficient).
fn ensure(buf: &mut Vec<f32>, len: usize, grows: &mut usize) {
    if buf.capacity() < len {
        *grows += 1;
        let cur = buf.len();
        buf.reserve_exact(len - cur);
    }
    buf.resize(len, 0.0);
}

/// Pack canonical layer params against the canonical configs — the one
/// routine behind fresh builds, artifact loads, and hot reloads. `params`
/// order is the artifact layer order: conv stack first, then LSTM cells,
/// then FC layers.
fn pack_weight_set(
    canon_fc: &[FcConfig],
    canon_conv: &[ConvConfig],
    canon_lstm: &[LstmConfig],
    params: &[LayerParams],
) -> Result<WeightSet> {
    if params.len() != canon_fc.len() + canon_conv.len() + canon_lstm.len() {
        bail!(
            "model has {} layers, artifact has {}",
            canon_fc.len() + canon_conv.len() + canon_lstm.len(),
            params.len()
        );
    }
    let conv = canon_conv
        .iter()
        .zip(&params[..canon_conv.len()])
        .enumerate()
        .map(|(i, (cfg, p))| {
            p.expect(
                &format!("serving layer {}", i),
                LayerKind::Conv,
                &[cfg.k, cfg.c, cfg.r, cfg.s],
            )?;
            Ok(ConvSharedWeights::pack(cfg, &p.w, &p.b))
        })
        .collect::<Result<Vec<_>>>()?;
    let lstm = canon_lstm
        .iter()
        .zip(&params[canon_conv.len()..canon_conv.len() + canon_lstm.len()])
        .enumerate()
        .map(|(i, (cfg, p))| {
            p.expect(
                &format!("serving layer {}", canon_conv.len() + i),
                LayerKind::Lstm,
                &[cfg.k, cfg.c],
            )?;
            let (w_gates, r_gates) = p.w.split_at(GATES * cfg.k * cfg.c);
            Ok(LstmSharedWeights::pack(cfg, w_gates, r_gates, &p.b))
        })
        .collect::<Result<Vec<_>>>()?;
    let fc = canon_fc
        .iter()
        .zip(&params[canon_conv.len() + canon_lstm.len()..])
        .enumerate()
        .map(|(i, (cfg, p))| {
            p.expect(
                &format!("serving layer {}", canon_conv.len() + canon_lstm.len() + i),
                LayerKind::Fc,
                &[cfg.k, cfg.c],
            )?;
            Ok(FcSharedWeights::pack(cfg, &p.w, &p.b))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(WeightSet { fc, conv, lstm })
}

/// A forward-only model: per-bucket plans over one shared weight copy per
/// layer. `Send + Sync` (all state is plain config + `Arc`/lock-guarded
/// buffers), so the worker pool shares it behind one `Arc`.
pub struct InferenceModel {
    spec: NetSpec,
    buckets: Vec<usize>,
    /// Runtime sequence-length buckets (powers of two up to the arch's
    /// `t`, plus `t` itself) for sequence models; empty otherwise. A
    /// batch of requests sharing a length bucket executes its batch
    /// bucket's plan as a prefix run at `t_run` = the length bucket.
    len_buckets: Vec<usize>,
    plans: Vec<Plan>,
    /// Canonical FC configs the packed layouts follow (all layers for
    /// MLP; just the head for CNN/RNN) — what a reloaded artifact
    /// re-packs against.
    canon_fc: Vec<FcConfig>,
    /// Canonical conv configs (empty otherwise).
    canon_conv: Vec<ConvConfig>,
    /// Canonical LSTM cell configs (empty otherwise).
    canon_lstm: Vec<LstmConfig>,
    /// The current weight generation, swapped whole on reload.
    weights: RwLock<Arc<WeightSet>>,
    reloads: AtomicU64,
}

impl InferenceModel {
    /// Build an MLP serving model with He-initialised weights. With
    /// `tuned`, each bucket's layer configs consult the autotune cache
    /// (the per-bucket shape is the cache key); the feature blocking is
    /// then pinned back to the shared packed layout, so a tuning hit can
    /// re-block the batch axis and kernel variants but never fork the
    /// weight copy.
    pub fn new_mlp(
        sizes: &[usize],
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        let canon = build::mlp_chain_configs(sizes, max_batch, nthreads, false);
        let params: Vec<LayerParams> = canon
            .iter()
            .map(|cfg| {
                let scale = (2.0 / cfg.c as f32).sqrt();
                LayerParams::fc(
                    cfg.k,
                    cfg.c,
                    rng.vec_f32(cfg.k * cfg.c, -scale, scale),
                    rng.vec_f32(cfg.k, -0.1, 0.1),
                )
            })
            .collect();
        InferenceModel::build_mlp(sizes, max_batch, nthreads, tuned, &params)
            .expect("freshly generated params always match their own configs")
    }

    /// Build a CNN serving model (conv stack + pool + FC head) with
    /// He-initialised weights; same sharing/tuning contract as
    /// [`Self::new_mlp`].
    pub fn new_cnn(
        spec: &CnnSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        let canon = build::conv_chain_configs(spec, max_batch, nthreads, false);
        let mut params: Vec<LayerParams> = canon
            .iter()
            .map(|cfg| {
                let scale = (2.0 / (cfg.c * cfg.r * cfg.s) as f32).sqrt();
                LayerParams::conv(
                    cfg.k,
                    cfg.c,
                    cfg.r,
                    cfg.s,
                    rng.vec_f32(cfg.weights_len(), -scale, scale),
                    rng.vec_f32(cfg.k, -0.1, 0.1),
                )
            })
            .collect();
        let last = *canon.last().unwrap();
        let pcfg = spec.pool_config(max_batch, &last).with_block(last.bk);
        let feat = last.k * pcfg.p() * pcfg.q();
        let hscale = (2.0 / feat as f32).sqrt();
        params.push(LayerParams::fc(
            spec.classes,
            feat,
            rng.vec_f32(spec.classes * feat, -hscale, hscale),
            rng.vec_f32(spec.classes, -0.1, 0.1),
        ));
        InferenceModel::build_cnn(spec, max_batch, nthreads, tuned, &params)
            .expect("freshly generated params always match their own configs")
    }

    /// Build an RNN serving model (stacked LSTM cells + FC head on the
    /// top cell's final hidden state) with random-init weights; same
    /// sharing/tuning contract as [`Self::new_mlp`].
    pub fn new_rnn(
        spec: &RnnSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        let k = spec.k;
        // Canonical gate-major cell params ([4][K][C_in] | [4][K][K]) per
        // stacked cell bottom-up, then the head — the artifact layer
        // layout (layer 0 reads the input, deeper cells read the hidden
        // sequence below, so their input width is k).
        let mut params = Vec::with_capacity(spec.layers + 1);
        for li in 0..spec.layers {
            let c_in = if li == 0 { spec.c } else { k };
            let wscale = (1.0 / c_in as f32).sqrt();
            let rscale = (1.0 / k as f32).sqrt();
            let mut w = rng.vec_f32(GATES * k * c_in, -wscale, wscale);
            w.extend(rng.vec_f32(GATES * k * k, -rscale, rscale));
            let mut b = vec![0.0f32; GATES * k];
            b[2 * k..3 * k].fill(1.0); // forget-gate bias, gate order i,g,f,o
            params.push(LayerParams::lstm(k, c_in, w, b));
        }
        let hscale = (2.0 / k as f32).sqrt();
        params.push(LayerParams::fc(
            spec.classes,
            k,
            rng.vec_f32(spec.classes * k, -hscale, hscale),
            rng.vec_f32(spec.classes, -0.1, 0.1),
        ));
        InferenceModel::build_rnn(spec, max_batch, nthreads, tuned, &params)
            .expect("freshly generated params always match their own configs")
    }

    /// Build from a [`NetSpec`] (the run-config dispatch point).
    pub fn from_spec(
        spec: &NetSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        rng: &mut Rng,
    ) -> InferenceModel {
        match spec {
            NetSpec::Mlp { sizes } => {
                InferenceModel::new_mlp(sizes, max_batch, nthreads, tuned, rng)
            }
            NetSpec::Cnn(c) => InferenceModel::new_cnn(c, max_batch, nthreads, tuned, rng),
            NetSpec::Rnn(r) => InferenceModel::new_rnn(r, max_batch, nthreads, tuned, rng),
        }
    }

    /// Build a serving model from a trained [`ModelArtifact`]: every
    /// bucket plan executes against the artifact's weights, re-packed
    /// once per layer into the canonical blocking (which need not match
    /// whatever blocking the model trained under — the artifact stores
    /// canonical unblocked parameters).
    pub fn from_artifact(
        art: &ModelArtifact,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
    ) -> Result<InferenceModel> {
        art.validate()?;
        match &art.arch {
            Arch::Mlp { sizes } => {
                InferenceModel::build_mlp(sizes, max_batch, nthreads, tuned, &art.layers)
            }
            Arch::Cnn(spec) => {
                InferenceModel::build_cnn(spec, max_batch, nthreads, tuned, &art.layers)
            }
            Arch::Rnn(spec) => {
                InferenceModel::build_rnn(spec, max_batch, nthreads, tuned, &art.layers)
            }
        }
    }

    fn build_mlp(
        sizes: &[usize],
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        params: &[LayerParams],
    ) -> Result<InferenceModel> {
        assert!(sizes.len() >= 2, "mlp needs at least input + output sizes");
        let buckets = bucket_sizes(max_batch);
        // Canonical feature blocking from the shared construction module
        // (chain invariant bc_i = bk_{i-1} holds by construction).
        let canon = build::mlp_chain_configs(sizes, max_batch, nthreads, false);
        // One packed weight allocation per layer, shared by every plan.
        let ws = pack_weight_set(&canon, &[], &[], params)?;
        let plans = buckets
            .iter()
            .map(|&b| {
                // One bn for the whole chain: blocked activations flow
                // between layers with no repack, so every layer of a
                // bucket's plan must agree on the batch block (the same
                // reconciliation MlpModel applies). With tuning, layer 0's
                // cached bn wins for the chain.
                let mut shared_bn = pick(b, 24);
                if tuned {
                    let cfg0 = FcConfig::new(b, canon[0].c, canon[0].k, canon[0].act)
                        .with_blocking(shared_bn, canon[0].bc, canon[0].bk)
                        .with_threads(nthreads);
                    shared_bn = crate::autotune::tuned_fc_config(cfg0).bn;
                }
                let fcs = canon
                    .iter()
                    .zip(&ws.fc)
                    .map(|(base, w)| {
                        let mut cfg = FcConfig::new(b, base.c, base.k, base.act)
                            .with_blocking(shared_bn, base.bc, base.bk)
                            .with_threads(nthreads);
                        if tuned {
                            // Per-bucket cache key; keep the tuned kernel
                            // variants, pin bn to the chain's shared value
                            // and the feature blocks to the shared packed
                            // layout.
                            let t = crate::autotune::tuned_fc_config(cfg);
                            cfg = t.with_blocking(shared_bn, base.bc, base.bk);
                        }
                        assert!(w.matches(&cfg), "bucket plan must match shared weights");
                        FcPrimitive::new(cfg)
                    })
                    .collect();
                Plan { batch: b, kind: PlanKind::Mlp { fcs } }
            })
            .collect();
        Ok(InferenceModel {
            spec: NetSpec::Mlp { sizes: sizes.to_vec() },
            buckets,
            len_buckets: Vec::new(),
            plans,
            canon_fc: canon,
            canon_conv: Vec::new(),
            canon_lstm: Vec::new(),
            weights: RwLock::new(Arc::new(ws)),
            reloads: AtomicU64::new(0),
        })
    }

    fn build_cnn(
        spec: &CnnSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        params: &[LayerParams],
    ) -> Result<InferenceModel> {
        assert!(!spec.convs.is_empty(), "need at least one conv layer");
        let buckets = bucket_sizes(max_batch);
        // Canonical conv configs with the chain invariant enforced, from
        // the same construction module as the training driver.
        let canon = build::conv_chain_configs(spec, max_batch, nthreads, false);
        let last = *canon.last().unwrap();
        let pcfg0 = spec.pool_config(max_batch, &last).with_block(last.bk);
        let feat = last.k * pcfg0.p() * pcfg0.q();
        let head_canon = build::head_fc_config(max_batch, feat, spec.classes, nthreads, false);
        let canon_fc = vec![head_canon];
        let ws = pack_weight_set(&canon_fc, &canon, &[], params)?;
        let plans = buckets
            .iter()
            .map(|&b| {
                let convs: Vec<ConvPrimitive> = spec
                    .conv_configs(b, nthreads)
                    .into_iter()
                    .zip(&canon)
                    .zip(&ws.conv)
                    .map(|((cfg, base), w)| {
                        let mut cfg = cfg;
                        if tuned {
                            cfg = crate::autotune::tuned_conv_config(cfg);
                        }
                        // Pin the feature blocks to the shared packed
                        // layout (keeps any tuned bq / flat / loop order).
                        if cfg.bc != base.bc || cfg.bk != base.bk {
                            cfg = cfg.with_blocking(base.bc, base.bk, cfg.bq);
                        }
                        assert!(w.matches(&cfg), "bucket plan must match shared weights");
                        ConvPrimitive::new(cfg)
                    })
                    .collect();
                let blast = convs.last().unwrap().cfg;
                let pool = AvgPool::new(
                    spec.pool_config(b, &blast).with_block(blast.bk).with_threads(nthreads),
                );
                let mut hcfg = FcConfig::new(b, feat, spec.classes, Act::Identity)
                    .with_blocking(pick(b, 24), head_canon.bc, head_canon.bk)
                    .with_threads(nthreads);
                if tuned {
                    let t = crate::autotune::tuned_fc_config(hcfg);
                    hcfg = t.with_blocking(t.bn, head_canon.bc, head_canon.bk);
                }
                assert!(ws.fc[0].matches(&hcfg));
                Plan {
                    batch: b,
                    kind: PlanKind::Cnn { convs, pool, head: FcPrimitive::new(hcfg) },
                }
            })
            .collect();
        Ok(InferenceModel {
            spec: NetSpec::Cnn(spec.clone()),
            buckets,
            len_buckets: Vec::new(),
            plans,
            canon_fc,
            canon_conv: canon,
            canon_lstm: Vec::new(),
            weights: RwLock::new(Arc::new(ws)),
            reloads: AtomicU64::new(0),
        })
    }

    fn build_rnn(
        spec: &RnnSpec,
        max_batch: usize,
        nthreads: usize,
        tuned: bool,
        params: &[LayerParams],
    ) -> Result<InferenceModel> {
        assert!(spec.classes >= 2, "rnn needs at least two classes");
        assert!(spec.c >= 1 && spec.k >= 1 && spec.t >= 1, "rnn c/k/t must be >= 1");
        assert!(spec.layers >= 1, "rnn needs at least one stacked cell");
        let buckets = bucket_sizes(max_batch);
        // Canonical per-cell + head configs from the shared construction
        // module: the feature blocking (bc, bk) depends only on the
        // layer's (c, k), so the packed weights are shareable across
        // every batch bucket and byte-compatible with the training
        // driver's packing. Cells are configured at the arch's full
        // capacity T; shorter length buckets run the same plan as a
        // prefix (`forward_shared_t`), so T never forks a plan either.
        let canon_cells = build::rnn_stack_configs(spec, max_batch, nthreads, false);
        let head_canon = build::head_fc_config(max_batch, spec.k, spec.classes, nthreads, false);
        let canon_fc = vec![head_canon];
        let ws = pack_weight_set(&canon_fc, &[], &canon_cells, params)?;
        let plans = buckets
            .iter()
            .map(|&b| {
                let cells: Vec<LstmPrimitive> = canon_cells
                    .iter()
                    .zip(&ws.lstm)
                    .map(|(base, w)| {
                        let mut ccfg = LstmConfig::new(b, base.c, base.k, base.t)
                            .with_blocking(pick(b, 24), base.bc, base.bk)
                            .with_threads(nthreads);
                        if tuned {
                            // Per-(bucket, layer) cache key (the layer's
                            // own input width participates); keep the
                            // tuned batch block, pin the feature blocks
                            // back to the shared packed layout.
                            let t = crate::autotune::tuned_lstm_config(ccfg);
                            ccfg = t.with_blocking(t.bn, base.bc, base.bk);
                        }
                        assert!(w.matches(&ccfg), "bucket plan must match shared weights");
                        LstmPrimitive::new(ccfg)
                    })
                    .collect();
                let mut hcfg = FcConfig::new(b, spec.k, spec.classes, Act::Identity)
                    .with_blocking(pick(b, 24), head_canon.bc, head_canon.bk)
                    .with_threads(nthreads);
                if tuned {
                    let t = crate::autotune::tuned_fc_config(hcfg);
                    hcfg = t.with_blocking(t.bn, head_canon.bc, head_canon.bk);
                }
                assert!(ws.fc[0].matches(&hcfg));
                Plan {
                    batch: b,
                    kind: PlanKind::Rnn { cells, head: FcPrimitive::new(hcfg) },
                }
            })
            .collect();
        Ok(InferenceModel {
            spec: NetSpec::Rnn(*spec),
            buckets,
            len_buckets: bucket_sizes(spec.t),
            plans,
            canon_fc,
            canon_conv: Vec::new(),
            canon_lstm: canon_cells,
            weights: RwLock::new(Arc::new(ws)),
            reloads: AtomicU64::new(0),
        })
    }

    /// Atomically swap in the weights of a new artifact (same arch
    /// required). In-flight batches finish on the generation they cloned
    /// at batch start; batches taken after this call run on the new
    /// weights. Bumps [`Self::reload_count`].
    pub fn reload(&self, art: &ModelArtifact) -> Result<()> {
        let want = self.spec.to_arch();
        if art.arch != want {
            bail!(
                "artifact arch ({}) does not match the serving model ({})",
                art.arch.describe(),
                want.describe()
            );
        }
        art.validate()?;
        let ws = pack_weight_set(&self.canon_fc, &self.canon_conv, &self.canon_lstm, &art.layers)?;
        *self.weights.write().unwrap() = Arc::new(ws);
        self.reloads.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// How many weight reloads have been applied.
    pub fn reload_count(&self) -> u64 {
        self.reloads.load(Ordering::SeqCst)
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn input_dim(&self) -> usize {
        self.spec.input_dim()
    }

    pub fn classes(&self) -> usize {
        self.spec.classes()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    pub fn max_batch(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits `k` requests (`1 <= k <= max_batch`).
    pub fn bucket_for(&self, k: usize) -> usize {
        assert!(k >= 1 && k <= self.max_batch(), "batch {} outside buckets", k);
        *self.buckets.iter().find(|&&b| b >= k).unwrap()
    }

    /// The runtime sequence-length buckets (empty for fixed-shape
    /// models).
    pub fn len_buckets(&self) -> &[usize] {
        &self.len_buckets
    }

    /// Per-step feature width for sequence models (`Some(c)` — a request
    /// is a flattened `[len][c]` sequence); `None` for fixed-shape
    /// models.
    pub fn seq_step_dim(&self) -> Option<usize> {
        match &self.spec {
            NetSpec::Rnn(spec) => Some(spec.c),
            _ => None,
        }
    }

    /// Maximum runtime sequence length (the arch's unroll capacity `t`)
    /// for sequence models; `None` otherwise.
    pub fn seq_max_len(&self) -> Option<usize> {
        match &self.spec {
            NetSpec::Rnn(spec) => Some(spec.t),
            _ => None,
        }
    }

    /// Smallest length bucket that fits a sequence of `len` steps
    /// (`1 <= len <= seq_max_len`). Panics on fixed-shape models.
    pub fn len_bucket_for(&self, len: usize) -> usize {
        assert!(!self.len_buckets.is_empty(), "not a sequence model");
        let cap = *self.len_buckets.last().unwrap();
        assert!(len >= 1 && len <= cap, "sequence length {} outside 1..={}", len, cap);
        *self.len_buckets.iter().find(|&&b| b >= len).unwrap()
    }

    /// Distinct packed-weight allocations backing the current weight
    /// generation — one per layer, *regardless of the number of batch
    /// buckets* (the acceptance invariant; plans hold no weight storage
    /// at all).
    pub fn weight_alloc_ids(&self) -> Vec<usize> {
        let ws = self.weights.read().unwrap().clone();
        let mut ids: Vec<usize> = ws
            .conv
            .iter()
            .map(|w| w.alloc_id())
            .chain(ws.lstm.iter().map(|w| w.alloc_id()))
            .chain(ws.fc.iter().map(|w| w.alloc_id()))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Number of weight-bearing layers (conv stack + LSTM cells + FC
    /// layers).
    pub fn layer_count(&self) -> usize {
        self.canon_conv.len() + self.canon_lstm.len() + self.canon_fc.len()
    }

    /// BRGEMM threads per forward plan (every primitive in a model is
    /// built with the same count; every model has at least the FC head).
    pub fn nthreads(&self) -> usize {
        self.canon_fc.first().map_or(1, |c| c.nthreads)
    }

    /// Time one acquisition of the weight-generation read lock. In the
    /// steady state this is nanoseconds (an uncontended `RwLock` read);
    /// during a hot reload's write-swap it measures how long the caller
    /// was stalled behind the swap — the SLO plane's `reload_stall`
    /// attribution signal.
    pub fn weight_pin_wait_secs(&self) -> f64 {
        let t0 = std::time::Instant::now();
        drop(self.weights.read().unwrap());
        t0.elapsed().as_secs_f64()
    }

    /// Forward `bucket` samples (plain `[bucket][input_dim]`, padded rows
    /// included) through the bucket's plan; returns plain
    /// `[bucket][classes]` logits. Allocating convenience wrapper over
    /// [`Self::forward_with`].
    pub fn forward(&self, bucket: usize, x: &[f32]) -> Vec<f32> {
        let mut scratch = ServeScratch::new();
        self.forward_with(bucket, x, &mut scratch).to_vec()
    }

    /// Forward through the bucket's plan using caller-owned scratch
    /// buffers; returns the plain `[bucket][classes]` logits as a slice
    /// into `scratch`. `&self` — safe to call concurrently from many
    /// workers, each with its own scratch. The weight generation is
    /// pinned once at entry, so a concurrent [`Self::reload`] never
    /// affects a batch in flight.
    pub fn forward_with<'s>(
        &self,
        bucket: usize,
        x: &[f32],
        scratch: &'s mut ServeScratch,
    ) -> &'s [f32] {
        assert_eq!(x.len(), bucket * self.input_dim(), "input shape mismatch");
        let tracing = crate::telemetry::trace::enabled();
        scratch.layer_marks.clear();
        let ws: Arc<WeightSet> = self.weights.read().unwrap().clone();
        let plan = self
            .plans
            .iter()
            .find(|p| p.batch == bucket)
            .unwrap_or_else(|| panic!("no plan for bucket {}", bucket));
        let classes = self.classes();
        match &plan.kind {
            PlanKind::Mlp { fcs } => {
                let cfg0 = fcs[0].cfg;
                ensure(&mut scratch.a, bucket * cfg0.c, &mut scratch.grows);
                layout::pack_act_2d_into(x, bucket, cfg0.c, cfg0.bn, cfg0.bc, &mut scratch.a);
                // Ping-pong between the two activation buffers.
                let mut cur_in_a = true;
                for (i, (fc, w)) in fcs.iter().zip(&ws.fc).enumerate() {
                    let t0 = tracing.then(Instant::now);
                    let ylen = bucket * fc.cfg.k;
                    if cur_in_a {
                        ensure(&mut scratch.b, ylen, &mut scratch.grows);
                        fc.forward_shared(&scratch.a, w, &mut scratch.b);
                    } else {
                        ensure(&mut scratch.a, ylen, &mut scratch.grows);
                        fc.forward_shared(&scratch.b, w, &mut scratch.a);
                    }
                    cur_in_a = !cur_in_a;
                    if let Some(t0) = t0 {
                        scratch.layer_marks.push(LayerMark {
                            label: "fc",
                            index: i as u32,
                            start: t0,
                            dur: t0.elapsed(),
                        });
                    }
                }
                let lcfg = fcs.last().unwrap().cfg;
                ensure(&mut scratch.out, bucket * classes, &mut scratch.grows);
                let src = if cur_in_a { &scratch.a } else { &scratch.b };
                layout::unpack_act_2d_into(
                    src,
                    bucket,
                    lcfg.k,
                    lcfg.bn,
                    lcfg.bk,
                    &mut scratch.out,
                );
            }
            PlanKind::Cnn { convs, pool, head } => {
                let cfg0 = convs[0].cfg;
                ensure(&mut scratch.a, cfg0.input_len(), &mut scratch.grows);
                layout::pack_conv_act_into(
                    x,
                    bucket,
                    cfg0.c,
                    cfg0.h,
                    cfg0.w,
                    cfg0.bc,
                    cfg0.pad,
                    cfg0.pad,
                    &mut scratch.a,
                );
                for (i, (prim, w)) in convs.iter().zip(&ws.conv).enumerate() {
                    let t0 = tracing.then(Instant::now);
                    ensure(&mut scratch.b, prim.cfg.output_len(), &mut scratch.grows);
                    prim.forward_shared(&scratch.a, w, &mut scratch.b);
                    if let Some(t0) = t0 {
                        scratch.layer_marks.push(LayerMark {
                            label: "conv",
                            index: i as u32,
                            start: t0,
                            dur: t0.elapsed(),
                        });
                    }
                    if let Some(next) = convs.get(i + 1) {
                        // Chain invariant: the output is the consumer's
                        // unpadded input; only the border re-pad remains.
                        let nc = next.cfg;
                        ensure(&mut scratch.a, nc.input_len(), &mut scratch.grows);
                        layout::repad_blocked_into(
                            &scratch.b,
                            bucket,
                            nc.cb_ct(),
                            nc.h,
                            nc.w,
                            nc.bc,
                            nc.pad,
                            nc.pad,
                            &mut scratch.a,
                        );
                    }
                }
                // The last conv's output is in `b`.
                let t0 = tracing.then(Instant::now);
                ensure(&mut scratch.pool_y, pool.cfg.output_len(), &mut scratch.grows);
                pool.forward(&scratch.b, &mut scratch.pool_y);
                if let Some(t0) = t0 {
                    scratch.layer_marks.push(LayerMark {
                        label: "pool",
                        index: convs.len() as u32,
                        start: t0,
                        dur: t0.elapsed(),
                    });
                }
                let hcfg = head.cfg;
                ensure(&mut scratch.head_x, bucket * hcfg.c, &mut scratch.grows);
                layout::pack_act_2d_into(
                    &scratch.pool_y,
                    bucket,
                    hcfg.c,
                    hcfg.bn,
                    hcfg.bc,
                    &mut scratch.head_x,
                );
                let t0 = tracing.then(Instant::now);
                ensure(&mut scratch.head_y, bucket * hcfg.k, &mut scratch.grows);
                head.forward_shared(&scratch.head_x, &ws.fc[0], &mut scratch.head_y);
                if let Some(t0) = t0 {
                    scratch.layer_marks.push(LayerMark {
                        label: "head",
                        index: convs.len() as u32 + 1,
                        start: t0,
                        dur: t0.elapsed(),
                    });
                }
                ensure(&mut scratch.out, bucket * classes, &mut scratch.grows);
                layout::unpack_act_2d_into(
                    &scratch.head_y,
                    bucket,
                    hcfg.k,
                    hcfg.bn,
                    hcfg.bk,
                    &mut scratch.out,
                );
            }
            PlanKind::Rnn { cells, head } => {
                let t = cells[0].cfg.t;
                Self::run_rnn(cells, head, &ws, bucket, classes, t, None, x, scratch);
            }
        }
        &scratch.out
    }

    /// Allocating convenience wrapper over [`Self::forward_seq_with`].
    pub fn forward_seq(
        &self,
        bucket: usize,
        len_bucket: usize,
        lens: &[usize],
        x: &[f32],
    ) -> Vec<f32> {
        let mut scratch = ServeScratch::new();
        self.forward_seq_with(bucket, len_bucket, lens, x, &mut scratch).to_vec()
    }

    /// Forward a co-batched group of variable-length sequence requests:
    /// `x` is `[bucket][len_bucket * c]` (each row a flattened
    /// `[len_bucket][c]` sequence, zero-padded in time past its true
    /// length and zero-padded rows at the tail past the real requests),
    /// `lens[i]` is row `i`'s true step count (`1 <= lens[i] <=
    /// len_bucket`; padded tail rows pass `len_bucket`). Executes the
    /// batch bucket's plan as a prefix run at `t_run = len_bucket` and
    /// gathers each row's final hidden state at the row's own length, so
    /// every row's logits are bit-identical to a solo batch-1 run of
    /// that request. Panics on fixed-shape models.
    pub fn forward_seq_with<'s>(
        &self,
        bucket: usize,
        len_bucket: usize,
        lens: &[usize],
        x: &[f32],
        scratch: &'s mut ServeScratch,
    ) -> &'s [f32] {
        let c = self.seq_step_dim().expect("forward_seq_with needs a sequence model");
        assert!(
            self.len_buckets.contains(&len_bucket),
            "length bucket {} not on the ladder {:?}",
            len_bucket,
            self.len_buckets
        );
        assert_eq!(lens.len(), bucket, "one true length per (possibly padded) row");
        for (i, &l) in lens.iter().enumerate() {
            assert!(l >= 1 && l <= len_bucket, "row {} length {} outside 1..={}", i, l, len_bucket);
        }
        assert_eq!(x.len(), bucket * len_bucket * c, "input shape mismatch");
        let ws: Arc<WeightSet> = self.weights.read().unwrap().clone();
        let plan = self
            .plans
            .iter()
            .find(|p| p.batch == bucket)
            .unwrap_or_else(|| panic!("no plan for bucket {}", bucket));
        let classes = self.classes();
        match &plan.kind {
            PlanKind::Rnn { cells, head } => {
                Self::run_rnn(cells, head, &ws, bucket, classes, len_bucket, Some(lens), x, scratch);
            }
            _ => unreachable!("sequence spec always builds Rnn plans"),
        }
        &scratch.out
    }

    /// The stacked variable-length RNN forward body shared by
    /// [`Self::forward_with`] (full-length, `lens = None`) and
    /// [`Self::forward_seq_with`]. `x` is `[bucket][t_run][c]` row-major;
    /// each cell runs a prefix of `t_run` steps over full-capacity
    /// workspaces, layer `i > 0` reading the hidden sequence of the layer
    /// below in place.
    #[allow(clippy::too_many_arguments)]
    fn run_rnn(
        cells: &[LstmPrimitive],
        head: &FcPrimitive,
        ws: &WeightSet,
        bucket: usize,
        classes: usize,
        t_run: usize,
        lens: Option<&[usize]>,
        x: &[f32],
        scratch: &mut ServeScratch,
    ) {
        let c = cells[0].cfg.c;
        let k = cells[0].cfg.k;
        let t_cap = cells[0].cfg.t;
        let nk = bucket * k;
        let tracing = crate::telemetry::trace::enabled();
        scratch.layer_marks.clear();
        // Rows are flattened [t_run][C] sequences; the cell wants
        // time-major [t_run][bucket][C].
        ensure(&mut scratch.a, t_run * bucket * c, &mut scratch.grows);
        for ni in 0..bucket {
            for ti in 0..t_run {
                let src = &x[(ni * t_run + ti) * c..(ni * t_run + ti + 1) * c];
                let dst = (ti * bucket + ni) * c;
                scratch.a[dst..dst + c].copy_from_slice(src);
            }
        }
        // One workspace per stacked cell, sized at full capacity T —
        // every length bucket shares the same high-water buffers (the
        // prefix run leaves entries past t_run untouched).
        if scratch.lstm.len() < cells.len() {
            scratch.grows += 1;
            scratch.lstm.resize_with(cells.len(), LstmWorkspace::default);
        }
        for li in 0..cells.len() {
            let t0 = tracing.then(Instant::now);
            let (below, rest) = scratch.lstm.split_at_mut(li);
            let ws_l = &mut rest[0];
            ensure(&mut ws_l.gates, GATES * t_cap * nk, &mut scratch.grows);
            ensure(&mut ws_l.h, (t_cap + 1) * nk, &mut scratch.grows);
            ensure(&mut ws_l.s, (t_cap + 1) * nk, &mut scratch.grows);
            // Layer 0 reads the transposed input; deeper layers read the
            // hidden sequence of the cell below ([T][N][K] starting at
            // step 1's slot — exactly the [T][N][C] the cell wants).
            let x_in: &[f32] =
                if li == 0 { &scratch.a } else { &below[li - 1].h[nk..] };
            cells[li].forward_shared_t(x_in, None, None, &ws.lstm[li], ws_l, t_run);
            if let Some(t0) = t0 {
                scratch.layer_marks.push(LayerMark {
                    label: "lstm",
                    index: li as u32,
                    start: t0,
                    dur: t0.elapsed(),
                });
            }
        }
        let top = scratch.lstm[cells.len() - 1].h.as_slice();
        let hcfg = head.cfg;
        ensure(&mut scratch.head_x, bucket * hcfg.c, &mut scratch.grows);
        match lens {
            None => {
                // Every row ran the full t_run steps: the final hidden
                // states are the contiguous step-(t_run-1) slot.
                layout::pack_act_2d_into(
                    &top[t_run * nk..(t_run + 1) * nk],
                    bucket,
                    hcfg.c,
                    hcfg.bn,
                    hcfg.bc,
                    &mut scratch.head_x,
                );
            }
            Some(lens) => {
                // Gather each row's final hidden state at the row's own
                // true length (h slot l = the state after l steps) — the
                // step that makes a short request co-batched under a
                // longer bucket bit-identical to its solo run.
                ensure(&mut scratch.b, nk, &mut scratch.grows);
                for (i, &l) in lens.iter().enumerate() {
                    let off = l * nk + i * k;
                    scratch.b[i * k..(i + 1) * k].copy_from_slice(&top[off..off + k]);
                }
                layout::pack_act_2d_into(
                    &scratch.b[..nk],
                    bucket,
                    hcfg.c,
                    hcfg.bn,
                    hcfg.bc,
                    &mut scratch.head_x,
                );
            }
        }
        let t0 = tracing.then(Instant::now);
        ensure(&mut scratch.head_y, bucket * hcfg.k, &mut scratch.grows);
        head.forward_shared(&scratch.head_x, &ws.fc[0], &mut scratch.head_y);
        if let Some(t0) = t0 {
            scratch.layer_marks.push(LayerMark {
                label: "head",
                index: cells.len() as u32,
                start: t0,
                dur: t0.elapsed(),
            });
        }
        ensure(&mut scratch.out, bucket * classes, &mut scratch.grows);
        layout::unpack_act_2d_into(
            &scratch.head_y,
            bucket,
            hcfg.k,
            hcfg.bn,
            hcfg.bk,
            &mut scratch.out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cnn::ConvSpec;
    use crate::coordinator::trainer::{MlpModel, Model};
    use crate::modelio::TrainMeta;

    fn tiny_cnn() -> CnnSpec {
        CnnSpec {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            convs: vec![
                ConvSpec { k: 3, r: 3, s: 3, stride: 1, pad: 1 },
                ConvSpec { k: 4, r: 1, s: 1, stride: 1, pad: 0 },
            ],
            pool_win: 0,
            pool_stride: 1,
            classes: 3,
        }
    }

    fn tiny_rnn() -> RnnSpec {
        RnnSpec { c: 6, k: 12, t: 4, classes: 3, layers: 1 }
    }

    fn stacked_rnn() -> RnnSpec {
        RnnSpec { c: 6, k: 12, t: 8, classes: 3, layers: 2 }
    }

    #[test]
    fn bucket_ladder_shapes() {
        assert_eq!(bucket_sizes(1), vec![1]);
        assert_eq!(bucket_sizes(8), vec![1, 2, 4, 8]);
        assert_eq!(bucket_sizes(6), vec![1, 2, 4, 6]);
        let m = InferenceModel::new_mlp(&[6, 8, 3], 6, 1, false, &mut Rng::new(1));
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(3), 4);
        assert_eq!(m.bucket_for(5), 6);
        assert_eq!(m.bucket_for(6), 6);
    }

    #[test]
    fn packed_weights_allocated_once_per_layer() {
        // The acceptance invariant: however many buckets exist, each
        // layer's packed weights are one allocation shared by every plan.
        let mlp = InferenceModel::new_mlp(&[12, 16, 8, 4], 16, 1, false, &mut Rng::new(2));
        assert_eq!(mlp.buckets().len(), 5, "1/2/4/8/16");
        assert_eq!(mlp.layer_count(), 3);
        assert_eq!(mlp.weight_alloc_ids().len(), 3, "3 layers -> 3 allocations, not 15");

        let cnn = InferenceModel::new_cnn(&tiny_cnn(), 8, 1, false, &mut Rng::new(3));
        assert_eq!(cnn.layer_count(), 3, "2 convs + head");
        assert_eq!(cnn.weight_alloc_ids().len(), 3, "3 layers -> 3 allocations, not 12");
    }

    #[test]
    fn co_batched_rows_bit_identical_to_solo_mlp() {
        let model = InferenceModel::new_mlp(&[10, 12, 5], 8, 1, false, &mut Rng::new(7));
        let mut rng = Rng::new(8);
        let dim = model.input_dim();
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        // 3 real rows padded into the 4-bucket.
        let mut x = vec![0.0f32; 4 * dim];
        for (i, s) in samples.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(s);
        }
        let batched = model.forward(4, &x);
        let classes = model.classes();
        for (i, s) in samples.iter().enumerate() {
            let solo = model.forward(1, s);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "row {} must be bit-identical to its solo batch-1 run",
                i
            );
        }
    }

    #[test]
    fn co_batched_rows_bit_identical_to_solo_cnn() {
        let model = InferenceModel::new_cnn(&tiny_cnn(), 4, 1, false, &mut Rng::new(11));
        let mut rng = Rng::new(12);
        let dim = model.input_dim();
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        let mut x = vec![0.0f32; 4 * dim];
        for (i, s) in samples.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(s);
        }
        let batched = model.forward(4, &x);
        let classes = model.classes();
        for (i, s) in samples.iter().enumerate() {
            let solo = model.forward(1, s);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "cnn row {} must be bit-identical to its solo batch-1 run",
                i
            );
        }
    }

    #[test]
    fn co_batched_rows_bit_identical_to_solo_rnn() {
        // Pad-to-bucket co-batched sequences must be bit-identical to a
        // solo batch-1 run — the acceptance invariant for sequence
        // requests (the cell's per-row accumulation order is independent
        // of the batch block).
        let model = InferenceModel::new_rnn(&tiny_rnn(), 8, 1, false, &mut Rng::new(17));
        let mut rng = Rng::new(18);
        let dim = model.input_dim();
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.vec_f32(dim, -1.0, 1.0)).collect();
        let mut x = vec![0.0f32; 4 * dim];
        for (i, s) in samples.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(s);
        }
        let batched = model.forward(4, &x);
        let classes = model.classes();
        for (i, s) in samples.iter().enumerate() {
            let solo = model.forward(1, s);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "rnn row {} must be bit-identical to its solo batch-1 run",
                i
            );
        }
    }

    #[test]
    fn rnn_packed_weights_allocated_once_per_layer() {
        let rnn = InferenceModel::new_rnn(&tiny_rnn(), 8, 1, false, &mut Rng::new(19));
        assert_eq!(rnn.buckets().len(), 4, "1/2/4/8");
        assert_eq!(rnn.layer_count(), 2, "cell + head");
        assert_eq!(rnn.weight_alloc_ids().len(), 2, "2 layers -> 2 allocations, not 8");
        // Stacked: one allocation per cell plus the head, still shared
        // across every (batch bucket x length bucket) combination.
        let stacked = InferenceModel::new_rnn(&stacked_rnn(), 8, 1, false, &mut Rng::new(20));
        assert_eq!(stacked.layer_count(), 3, "2 cells + head");
        assert_eq!(stacked.weight_alloc_ids().len(), 3);
    }

    #[test]
    fn len_bucket_ladder_shapes() {
        let model = InferenceModel::new_rnn(&stacked_rnn(), 4, 1, false, &mut Rng::new(23));
        assert_eq!(model.len_buckets(), &[1, 2, 4, 8], "pow-2 ladder up to t");
        assert_eq!(model.seq_step_dim(), Some(6));
        assert_eq!(model.seq_max_len(), Some(8));
        assert_eq!(model.len_bucket_for(1), 1);
        assert_eq!(model.len_bucket_for(3), 4);
        assert_eq!(model.len_bucket_for(5), 8);
        assert_eq!(model.len_bucket_for(8), 8);
        let mlp = InferenceModel::new_mlp(&[6, 8, 3], 4, 1, false, &mut Rng::new(24));
        assert!(mlp.len_buckets().is_empty(), "fixed-shape models have no length axis");
        assert_eq!(mlp.seq_step_dim(), None);
        assert_eq!(mlp.seq_max_len(), None);
    }

    #[test]
    fn variable_length_co_batched_rows_bit_identical_to_solo() {
        // The tentpole acceptance invariant: mixed-length requests
        // co-batched under one (len bucket x batch bucket) plan must be
        // bit-identical to running each request solo at batch 1 in its
        // own length bucket — short rows' zero time-padding and the
        // other rows in the batch must not perturb a single bit.
        let spec = stacked_rnn();
        let model = InferenceModel::new_rnn(&spec, 8, 1, false, &mut Rng::new(31));
        let c = spec.c;
        let lens = [3usize, 8, 5, 2];
        let lb = 8; // top length bucket holds them all
        let mut rng = Rng::new(32);
        let mut x = vec![0.0f32; 4 * lb * c];
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for (i, &l) in lens.iter().enumerate() {
            let data = rng.vec_f32(l * c, -1.0, 1.0);
            x[i * lb * c..i * lb * c + l * c].copy_from_slice(&data);
            rows.push(data);
        }
        let batched = model.forward_seq(4, lb, &lens, &x);
        let classes = spec.classes;
        for (i, &l) in lens.iter().enumerate() {
            let solo_lb = model.len_bucket_for(l);
            let mut solo_x = vec![0.0f32; solo_lb * c];
            solo_x[..l * c].copy_from_slice(&rows[i]);
            let solo = model.forward_seq(1, solo_lb, &[l], &solo_x);
            assert_eq!(
                &batched[i * classes..(i + 1) * classes],
                &solo[..],
                "row {} (len {}) must be bit-identical to its solo run at len bucket {}",
                i,
                l,
                solo_lb
            );
        }
        // A full-length row also agrees with the fixed-length entry point.
        let full = model.forward(1, &x[lb * c..2 * lb * c]);
        assert_eq!(&batched[classes..2 * classes], &full[..]);
    }

    #[test]
    fn stacked_rnn_from_artifact_serves_bit_identically() {
        use crate::coordinator::rnn::RnnModel;
        // Train a 2-deep stacked model, lift it through the binary
        // artifact format, serve it: full-length forwards and the
        // variable-length entry point at full length must both be
        // bit-identical to the trained model.
        let spec = stacked_rnn();
        let mut rng = Rng::new(41);
        let data = crate::coordinator::data::ClassifyData::synth_sequences(
            32,
            spec.t,
            spec.c,
            spec.classes,
            0.2,
            &mut rng,
        );
        let mut trained = RnnModel::new(&spec, 4, 1, &mut rng);
        for step in 0..5 {
            let (x, l) = data.batch(step, 4);
            trained.train_step(&x, &l, 0.1);
        }
        let art = ModelArtifact::new(
            Arch::Rnn(spec),
            TrainMeta::fresh(41),
            trained.export_weights(),
        );
        let art = ModelArtifact::decode(&art.encode()).unwrap();
        let served = InferenceModel::from_artifact(&art, 4, 1, false).unwrap();
        assert_eq!(served.layer_count(), 3, "2 cells + head");
        assert_eq!(served.weight_alloc_ids().len(), 3);
        let x = Rng::new(42).vec_f32(4 * spec.input_dim(), -1.0, 1.0);
        let want = trained.forward(&x);
        let got = served.forward(4, &x);
        assert_eq!(want, got, "served stacked logits must match the trained model");
        let lens = vec![spec.t; 4];
        let seq = served.forward_seq(4, spec.t, &lens, &x);
        assert_eq!(want, seq, "the variable-length path at full length is the same math");
    }

    #[test]
    fn seq_scratch_stops_allocating_across_len_buckets() {
        // Mixed-length steady state: once every (batch bucket x length
        // bucket) combination has been seen, further traffic of any
        // length mix performs zero allocations (the cell workspaces are
        // sized at full capacity T, so length buckets share them).
        let spec = stacked_rnn();
        let model = InferenceModel::new_rnn(&spec, 4, 1, false, &mut Rng::new(51));
        let c = spec.c;
        let mut rng = Rng::new(52);
        let mut scratch = ServeScratch::new();
        let buckets: Vec<usize> = model.buckets().to_vec();
        let len_buckets: Vec<usize> = model.len_buckets().to_vec();
        for &b in &buckets {
            for &lb in &len_buckets {
                let lens = vec![lb; b];
                let x = rng.vec_f32(b * lb * c, -1.0, 1.0);
                model.forward_seq_with(b, lb, &lens, &x, &mut scratch);
            }
        }
        let warm = scratch.alloc_events();
        assert!(warm > 0, "warm-up must have sized the buffers");
        for round in 0..10 {
            for &b in &buckets {
                for &lb in &len_buckets {
                    // Vary the true lengths within the bucket too.
                    let lens: Vec<usize> = (0..b).map(|i| 1 + (i % lb)).collect();
                    let x = rng.vec_f32(b * lb * c, -1.0, 1.0);
                    model.forward_seq_with(b, lb, &lens, &x, &mut scratch);
                }
            }
            assert_eq!(
                scratch.alloc_events(),
                warm,
                "steady-state round {} must not allocate",
                round
            );
        }
    }

    #[test]
    fn from_artifact_serves_trained_rnn_bit_identically() {
        use crate::coordinator::rnn::RnnModel;
        // Train the sequence classifier, lift it through the binary
        // artifact format, serve it: every bucket's forward must be
        // bit-identical to the trained model's forward on the same rows.
        let spec = tiny_rnn();
        let mut rng = Rng::new(91);
        let data = crate::coordinator::data::ClassifyData::synth_sequences(
            64,
            spec.t,
            spec.c,
            spec.classes,
            0.2,
            &mut rng,
        );
        let mut trained = RnnModel::new(&spec, 4, 1, &mut rng);
        for step in 0..10 {
            let (x, l) = data.batch(step, 4);
            trained.train_step(&x, &l, 0.1);
        }
        let art = ModelArtifact::new(
            Arch::Rnn(spec),
            crate::modelio::TrainMeta::fresh(91),
            trained.export_weights(),
        );
        let art = ModelArtifact::decode(&art.encode()).unwrap();
        let served = InferenceModel::from_artifact(&art, 4, 1, false).unwrap();
        let x = Rng::new(92).vec_f32(4 * spec.input_dim(), -1.0, 1.0);
        let want = trained.forward(&x);
        let got = served.forward(4, &x);
        assert_eq!(want, got, "served RNN logits must be bit-identical to the trained model");
        // And per-row at bucket 1.
        let dim = spec.input_dim();
        for i in 0..3 {
            let solo = served.forward(1, &x[i * dim..(i + 1) * dim]);
            assert_eq!(&want[i * spec.classes..(i + 1) * spec.classes], &solo[..], "row {}", i);
        }
        // Reload with a different arch is a clear error.
        let other = RnnSpec { k: 8, ..spec };
        let donor = RnnModel::new(&other, 4, 1, &mut Rng::new(1));
        let bad = ModelArtifact::new(
            Arch::Rnn(other),
            crate::modelio::TrainMeta::fresh(1),
            donor.export_weights(),
        );
        assert!(served.reload(&bad).is_err(), "reload must reject a different arch");
    }

    #[test]
    fn tuned_bucket_plans_share_weights_and_match_untuned_math() {
        use crate::autotune::{cache, Candidate, TuneEntry, TuningCache};
        // Seed the cache for the bucket-2 layer-0 shape only, with a
        // candidate whose batch and feature blocks disagree with the
        // defaults: the plan must adopt the tuned bn for the *whole chain*
        // (blocked activations flow between layers with no repack) while
        // pinning bc/bk back to the shared packing. Layer 1 has Cb > 1
        // (130 features, bc 26), so a bn mismatch between the layers
        // would scramble the layout and fail the math check below.
        let sizes = [18usize, 130, 5];
        let cfg_b2 = FcConfig::new(2, 18, 130, Act::Relu);
        let cand = Candidate {
            bn: 1,
            bc: 9,
            bk: 13,
            bq: 1,
            flat_bq: 0,
            order: None,
            fwd_strided: true,
            upd_transpose: false,
        };
        TuningCache::global()
            .lock()
            .unwrap()
            .put(&cache::fc_key(&cfg_b2), TuneEntry { cand, gflops: 1.0, model_gflops: 1.0 });
        let plain = InferenceModel::new_mlp(&sizes, 4, 1, false, &mut Rng::new(21));
        let tuned = InferenceModel::new_mlp(&sizes, 4, 1, true, &mut Rng::new(21));
        assert_eq!(
            tuned.weight_alloc_ids().len(),
            2,
            "tuning must not fork the weight copies"
        );
        let x = Rng::new(22).vec_f32(2 * 18, -1.0, 1.0);
        let yp = plain.forward(2, &x);
        let yt = tuned.forward(2, &x);
        for i in 0..yp.len() {
            assert!((yp[i] - yt[i]).abs() < 1e-4, "[{}]: {} vs {}", i, yp[i], yt[i]);
        }
        // The untuned buckets are unaffected by the cache entry.
        let x4 = Rng::new(23).vec_f32(4 * 18, -1.0, 1.0);
        let y4p = plain.forward(4, &x4);
        let y4t = tuned.forward(4, &x4);
        for i in 0..y4p.len() {
            assert!((y4p[i] - y4t[i]).abs() < 1e-4, "b4 [{}]: {} vs {}", i, y4p[i], y4t[i]);
        }
    }

    #[test]
    fn scratch_stops_allocating_once_buckets_are_warm() {
        // The no-per-request-allocation invariant: after one pass over
        // every bucket a worker serves, the scratch high-water marks are
        // set and further forwards perform zero allocations.
        let mut rng = Rng::new(61);
        for model in [
            InferenceModel::new_mlp(&[10, 24, 4], 8, 1, false, &mut rng),
            InferenceModel::new_cnn(&tiny_cnn(), 8, 1, false, &mut rng),
            InferenceModel::new_rnn(&tiny_rnn(), 8, 1, false, &mut rng),
        ] {
            let dim = model.input_dim();
            let mut scratch = ServeScratch::new();
            let buckets: Vec<usize> = model.buckets().to_vec();
            // Warm-up: largest bucket first would be enough, but visit all.
            for &b in &buckets {
                let x = rng.vec_f32(b * dim, -1.0, 1.0);
                model.forward_with(b, &x, &mut scratch);
            }
            let warm = scratch.alloc_events();
            assert!(warm > 0, "warm-up must have sized the buffers");
            for round in 0..20 {
                for &b in &buckets {
                    let x = rng.vec_f32(b * dim, -1.0, 1.0);
                    model.forward_with(b, &x, &mut scratch);
                }
                assert_eq!(
                    scratch.alloc_events(),
                    warm,
                    "steady-state round {} must not allocate",
                    round
                );
            }
        }
    }

    #[test]
    fn forward_with_matches_forward() {
        let model = InferenceModel::new_cnn(&tiny_cnn(), 4, 1, false, &mut Rng::new(13));
        let mut scratch = ServeScratch::new();
        let mut rng = Rng::new(14);
        for &b in model.buckets() {
            let x = rng.vec_f32(b * model.input_dim(), -1.0, 1.0);
            let fresh = model.forward(b, &x);
            let reused = model.forward_with(b, &x, &mut scratch).to_vec();
            assert_eq!(fresh, reused, "bucket {}: scratch reuse must not change the math", b);
        }
    }

    #[test]
    fn from_artifact_serves_trained_weights_bit_identically() {
        // Train an MLP, export it through the artifact pipeline, serve it:
        // every bucket's forward must be bit-identical to the trained
        // model's forward on the same rows (FC accumulation order is
        // invariant under batch re-blocking).
        let sizes = [12usize, 32, 4];
        let mut rng = Rng::new(71);
        let data =
            crate::coordinator::data::ClassifyData::synth(128, 12, 4, 0.2, &mut rng);
        let mut trained = MlpModel::new(&sizes, 8, 1, &mut rng);
        for step in 0..20 {
            let (x, l) = data.batch(step, 8);
            trained.train_step(&x, &l, 0.1);
        }
        let art = ModelArtifact::new(
            Arch::Mlp { sizes: sizes.to_vec() },
            TrainMeta::fresh(71),
            trained.export_weights(),
        );
        // Round-trip through the *binary format* too, not just the structs.
        let art = ModelArtifact::decode(&art.encode()).unwrap();
        let served = InferenceModel::from_artifact(&art, 8, 1, false).unwrap();
        assert_eq!(served.weight_alloc_ids().len(), 2, "one allocation per layer");
        let x8 = Rng::new(72).vec_f32(8 * 12, -1.0, 1.0);
        let want = trained.forward(&x8);
        let got = served.forward(8, &x8);
        assert_eq!(want, got, "served logits must be bit-identical to the trained model");
        // And per-row at bucket 1.
        for i in 0..3 {
            let solo = served.forward(1, &x8[i * 12..(i + 1) * 12]);
            assert_eq!(&want[i * 4..(i + 1) * 4], &solo[..], "row {}", i);
        }
        // Arch mismatch is a clear error.
        let bad = ModelArtifact::new(
            Arch::Mlp { sizes: vec![12, 32, 5] },
            TrainMeta::fresh(0),
            MlpModel::new(&[12, 32, 5], 4, 1, &mut Rng::new(1)).export_weights(),
        );
        assert!(served.reload(&bad).is_err(), "reload must reject a different arch");
    }

    #[test]
    fn from_artifact_serves_trained_cnn_bit_identically() {
        let spec = tiny_cnn();
        let mut rng = Rng::new(81);
        let data = crate::coordinator::data::ClassifyData::synth(
            64,
            spec.input_dim(),
            spec.classes,
            0.2,
            &mut rng,
        );
        let mut trained = crate::coordinator::cnn::CnnModel::new(&spec, 4, 1, &mut rng);
        for step in 0..5 {
            let (x, l) = data.batch(step, 4);
            trained.train_step(&x, &l, 0.05);
        }
        let art = ModelArtifact::new(
            Arch::Cnn(spec.clone()),
            TrainMeta::fresh(81),
            trained.export_weights(),
        );
        let art = ModelArtifact::decode(&art.encode()).unwrap();
        let served = InferenceModel::from_artifact(&art, 4, 1, false).unwrap();
        let x = Rng::new(82).vec_f32(4 * spec.input_dim(), -1.0, 1.0);
        let want = trained.forward(&x);
        let got = served.forward(4, &x);
        assert_eq!(want, got, "served CNN logits must be bit-identical to the trained model");
    }

    #[test]
    fn reload_swaps_weights_atomically_and_counts() {
        let sizes = [6usize, 10, 3];
        let model = InferenceModel::new_mlp(&sizes, 4, 1, false, &mut Rng::new(91));
        assert_eq!(model.reload_count(), 0);
        let before_ids = model.weight_alloc_ids();
        let x = Rng::new(92).vec_f32(6, -1.0, 1.0);
        let y_old = model.forward(1, &x);
        // A different trained model, lifted to an artifact.
        let mut other = MlpModel::new(&sizes, 4, 1, &mut Rng::new(93));
        let art = ModelArtifact::new(
            Arch::Mlp { sizes: sizes.to_vec() },
            TrainMeta::fresh(93),
            other.export_weights(),
        );
        model.reload(&art).unwrap();
        assert_eq!(model.reload_count(), 1);
        assert_ne!(model.weight_alloc_ids(), before_ids, "new generation, new allocations");
        let y_new = model.forward(1, &x);
        assert_ne!(y_old, y_new, "different weights, different logits");
        // `other` has batch 4; compare row 0 of a zero-padded batch (rows
        // are independent in an MLP forward).
        let mut x4 = x.clone();
        x4.extend(vec![0.0; 3 * 6]);
        let want4 = other.forward(&x4);
        assert_eq!(&want4[..3], &y_new[..], "post-reload logits come from the new artifact");
    }
}
