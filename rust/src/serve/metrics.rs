//! Serving metrics: per-request latency percentiles, throughput, queue
//! depth, and the batch-fill histogram.
//!
//! The batcher records one entry per executed batch ([`ServeStats::record_batch`]);
//! the final [`ServeReport`] is what the `serve` CLI prints and the
//! `serve_load` bench emits as a JSON row. Latencies land in fixed-size
//! log-bucketed histograms ([`LogHistogram`]) — run-wide, per batch
//! bucket, and per length bucket — so a serving run's metric memory is
//! O(1) in request count and a long-lived server can stream stats
//! forever. Percentiles read from the histogram are accurate to within
//! one bucket's relative width (≈8%); mean and max stay exact (tracked
//! alongside the buckets). Queue depth uses the [`Online`] accumulator.
//!
//! When a latency SLO is configured, [`ServeStats`] additionally owns an
//! [`SloStats`] accumulator (per-request met/violated classification with
//! cause attribution, burn rate, error budget — see [`super::slo`]), and
//! the whole registry can be rendered in **Prometheus text exposition
//! format** ([`ServeStats::prometheus_into`]) for the `admin metrics`
//! command — counters, Welford gauges, the log histograms as cumulative
//! `_bucket{le=...}` rows — with no dependencies beyond `std`.

use crate::serve::slo::{SloOutcome, SloSpec, SloStats, SloSummary};
use crate::util::json::{obj, Json};
use crate::util::stats::Online;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Histogram range: values below land in a dedicated underflow bucket,
/// values at/above (and NaNs) in an overflow bucket.
pub const HIST_MIN_SECS: f64 = 1e-6;
pub const HIST_MAX_SECS: f64 = 100.0;
/// Interior geometric buckets tiling `[HIST_MIN_SECS, HIST_MAX_SECS)`:
/// growth `(MAX/MIN)^(1/240) = 1e8^(1/240) ≈ 1.08`, i.e. ≤ ~8% relative
/// error for any percentile read.
pub const HIST_BUCKETS: usize = 240;
const TOTAL_BUCKETS: usize = HIST_BUCKETS + 2;

fn ln_growth() -> f64 {
    (HIST_MAX_SECS / HIST_MIN_SECS).ln() / HIST_BUCKETS as f64
}

/// A fixed-size log-bucketed latency histogram. Recording is O(1) and
/// allocation-free after construction; memory is `TOTAL_BUCKETS`
/// counters regardless of how many samples land. Mean and max are exact
/// (a sum and a max ride alongside the buckets); percentiles return the
/// geometric midpoint of the covering bucket, clamped into the observed
/// `[min, max]` so `p99 <= max` always holds.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    /// Sum/min/max of the finite samples (NaNs count toward `total`
    /// and the overflow bucket but are excluded from the moments, so a
    /// single clock hiccup cannot poison the whole report).
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; TOTAL_BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn index(v: f64) -> usize {
        if v.is_nan() || v >= HIST_MAX_SECS {
            TOTAL_BUCKETS - 1
        } else if v < HIST_MIN_SECS {
            0
        } else {
            // Interior bucket i covers [MIN·g^(i-1), MIN·g^i).
            let i = ((v / HIST_MIN_SECS).ln() / ln_growth()) as usize + 1;
            i.min(HIST_BUCKETS)
        }
    }

    /// Geometric midpoint of bucket `i` — the value a percentile read
    /// reports for samples that landed there.
    fn representative(i: usize) -> f64 {
        if i == 0 {
            HIST_MIN_SECS * 0.5
        } else if i == TOTAL_BUCKETS - 1 {
            HIST_MAX_SECS
        } else {
            HIST_MIN_SECS * (ln_growth() * (i as f64 - 0.5)).exp()
        }
    }

    pub fn record(&mut self, v: f64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        if !v.is_nan() {
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Exact mean of the finite samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Exact max of the finite samples (0 when empty).
    pub fn max_secs(&self) -> f64 {
        if self.max.is_finite() {
            self.max
        } else {
            0.0
        }
    }

    /// Nearest-rank percentile from the buckets, within one bucket's
    /// relative error of the exact value; clamped into the observed
    /// `[min, max]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                let rep = Self::representative(i);
                // min > max only when every sample was NaN.
                return if self.min <= self.max { rep.clamp(self.min, self.max) } else { rep };
            }
        }
        self.max_secs()
    }

    /// Storage footprint in counter slots — constant by construction;
    /// the O(1)-memory test asserts it never moves.
    pub fn storage_buckets(&self) -> usize {
        self.counts.len()
    }

    /// Exact sum of the finite samples (the Prometheus `_sum` series).
    pub fn sum_secs(&self) -> f64 {
        self.sum
    }

    /// Cumulative `(upper_bound_secs, count <= bound)` rows for Prometheus
    /// exposition, downsampled to every `PROM_BUCKET_STRIDE`-th interior
    /// boundary plus the mandatory `+Inf` row (which carries `total`,
    /// NaNs included). Downsampling only widens each reported quantile's
    /// bucket, it never breaks the cumulative invariant.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        const PROM_BUCKET_STRIDE: usize = 10;
        let mut rows = Vec::with_capacity(HIST_BUCKETS / PROM_BUCKET_STRIDE + 1);
        // The underflow bucket (< HIST_MIN_SECS) is inside every bound.
        let mut cum = self.counts[0];
        for i in 1..=HIST_BUCKETS {
            cum += self.counts[i];
            if i % PROM_BUCKET_STRIDE == 0 {
                rows.push((HIST_MIN_SECS * (ln_growth() * i as f64).exp(), cum));
            }
        }
        rows.push((f64::INFINITY, self.total));
        rows
    }
}

/// Per-bucket accounting: how many batches ran at this bucket size, how
/// many real (non-padded) requests they carried, and the stage split —
/// time requests spent queued vs the batch's forward-compute time.
#[derive(Debug, Clone)]
pub struct BucketStat {
    pub batches: usize,
    pub requests: usize,
    /// Enqueue→dequeue seconds of the real requests in this bucket.
    pub queue_wait: Online,
    /// Forward-compute seconds per batch executed at this bucket size.
    pub compute: Online,
    /// End-to-end latency of the real requests in this bucket.
    pub latency: LogHistogram,
}

impl Default for BucketStat {
    fn default() -> BucketStat {
        // Explicit so the Online accumulators start with the ±∞ min/max
        // sentinels of `Online::new`, not the derived zeros.
        BucketStat {
            batches: 0,
            requests: 0,
            queue_wait: Online::new(),
            compute: Online::new(),
            latency: LogHistogram::new(),
        }
    }
}

/// Per-length-bucket accounting for sequence models: how many batches a
/// runtime length bucket dispatched, the real requests they carried, and
/// their forward-compute time (a batch never mixes length buckets, so
/// the split is exact).
#[derive(Debug, Clone)]
pub struct LenBucketStat {
    pub batches: usize,
    pub requests: usize,
    pub compute: Online,
    /// End-to-end latency of the real requests in this length bucket.
    pub latency: LogHistogram,
}

impl Default for LenBucketStat {
    fn default() -> LenBucketStat {
        LenBucketStat {
            batches: 0,
            requests: 0,
            compute: Online::new(),
            latency: LogHistogram::new(),
        }
    }
}

/// Accumulated by the worker pool during a serving run.
#[derive(Debug)]
pub struct ServeStats {
    latency: LogHistogram,
    queue_depth: Option<Online>,
    buckets: BTreeMap<usize, BucketStat>,
    /// Sequence-length split (empty for fixed-shape models, which record
    /// the `0` sentinel and are skipped).
    len_buckets: BTreeMap<usize, LenBucketStat>,
    /// Run-wide stage accumulators (the per-bucket splits, merged).
    queue_wait: Online,
    compute: Online,
    /// SLO accounting, present only when a latency objective is
    /// configured ([`ServeStats::with_slo`]).
    slo: Option<SloStats>,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        ServeStats::new()
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            latency: LogHistogram::new(),
            queue_depth: None,
            buckets: BTreeMap::new(),
            len_buckets: BTreeMap::new(),
            queue_wait: Online::new(),
            compute: Online::new(),
            slo: None,
        }
    }

    /// Stats with SLO accounting attached — the batcher constructs this
    /// when `ServeOpts.slo` is set.
    pub fn with_slo(spec: SloSpec) -> ServeStats {
        ServeStats { slo: Some(SloStats::new(spec)), ..ServeStats::new() }
    }

    pub fn slo(&self) -> Option<&SloStats> {
        self.slo.as_ref()
    }

    /// Account the SLO outcomes of one executed batch's real requests
    /// (call right after [`record_batch`](Self::record_batch), under the
    /// same lock). No-op when no SLO is configured.
    pub fn record_slo(&mut self, bucket: usize, len_bucket: usize, outcomes: &[SloOutcome]) {
        if let Some(slo) = self.slo.as_mut() {
            for &o in outcomes {
                slo.record(bucket, len_bucket, o);
            }
        }
    }

    /// One executed batch: `bucket` is the padded size, `len_bucket` the
    /// runtime sequence-length bucket the batch dispatched under (`0` for
    /// fixed-shape models — not tracked), `fill` the real request count
    /// (`fill <= bucket`), `depth_after` the queue backlog right after
    /// the batch was taken, `latencies` the enqueue→response seconds of
    /// the `fill` real requests, `queue_waits` their enqueue→dequeue
    /// seconds (same order), and `compute_secs` the batch's
    /// forward-compute time.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &mut self,
        bucket: usize,
        len_bucket: usize,
        fill: usize,
        depth_after: usize,
        latencies: &[f64],
        queue_waits: &[f64],
        compute_secs: f64,
    ) {
        assert!(fill <= bucket && fill == latencies.len());
        assert_eq!(queue_waits.len(), fill, "one queue-wait sample per real request");
        if len_bucket > 0 {
            let l = self.len_buckets.entry(len_bucket).or_default();
            l.batches += 1;
            l.requests += fill;
            l.compute.push(compute_secs);
            for &lat in latencies {
                l.latency.record(lat);
            }
        }
        let e = self.buckets.entry(bucket).or_default();
        e.batches += 1;
        e.requests += fill;
        for &w in queue_waits {
            e.queue_wait.push(w);
            self.queue_wait.push(w);
        }
        e.compute.push(compute_secs);
        self.compute.push(compute_secs);
        self.queue_depth.get_or_insert_with(Online::new).push(depth_after as f64);
        for &lat in latencies {
            e.latency.record(lat);
            self.latency.record(lat);
        }
    }

    pub fn requests(&self) -> usize {
        self.latency.total() as usize
    }

    /// Total latency-counter slots across every histogram this run
    /// allocated — grows with the number of *buckets served* (bounded by
    /// the ladder), never with the number of requests.
    pub fn latency_storage_buckets(&self) -> usize {
        self.latency.storage_buckets()
            + self.buckets.values().map(|b| b.latency.storage_buckets()).sum::<usize>()
            + self.len_buckets.values().map(|b| b.latency.storage_buckets()).sum::<usize>()
    }

    /// Summarise into a report; `wall_secs` is the whole run's wall time
    /// (open-loop: arrival pacing included, which is what a served client
    /// experiences); `reloads` is the number of hot weight swaps applied
    /// during the run.
    pub fn report(&self, wall_secs: f64, reloads: u64) -> ServeReport {
        let n = self.requests();
        let pct = |q: f64| self.latency.percentile(q) * 1e3;
        let (qd_mean, qd_max) = match &self.queue_depth {
            Some(o) => (o.mean(), o.max),
            None => (0.0, 0.0),
        };
        let stage_ms = |o: &Online| if o.n == 0 { (0.0, 0.0) } else { (o.mean() * 1e3, o.max * 1e3) };
        let (qw_mean, qw_max) = stage_ms(&self.queue_wait);
        let (cp_mean, cp_max) = stage_ms(&self.compute);
        let bucket_mean = |o: &Online| if o.n == 0 { 0.0 } else { o.mean() * 1e3 };
        ServeReport {
            requests: n,
            reloads,
            wall_secs,
            uptime_secs: wall_secs,
            slo: self.slo.as_ref().map(|s| s.summary()),
            info: None,
            resource: crate::telemetry::resource::snapshot(),
            throughput_rps: if wall_secs > 0.0 { n as f64 / wall_secs } else { 0.0 },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            mean_ms: self.latency.mean() * 1e3,
            max_ms: self.latency.max_secs() * 1e3,
            queue_depth_mean: qd_mean,
            queue_depth_max: qd_max,
            queue_wait_mean_ms: qw_mean,
            queue_wait_max_ms: qw_max,
            compute_mean_ms: cp_mean,
            compute_max_ms: cp_max,
            batch_fill: self
                .buckets
                .iter()
                .map(|(&b, s)| (b, s.batches, s.requests as f64 / (s.batches * b) as f64))
                .collect(),
            bucket_stages: self
                .buckets
                .iter()
                .map(|(&b, s)| (b, bucket_mean(&s.queue_wait), bucket_mean(&s.compute)))
                .collect(),
            bucket_p99: self
                .buckets
                .iter()
                .map(|(&b, s)| (b, s.latency.percentile(0.99) * 1e3))
                .collect(),
            len_buckets: self
                .len_buckets
                .iter()
                .map(|(&lb, s)| (lb, s.batches, s.requests, bucket_mean(&s.compute)))
                .collect(),
            len_bucket_p99: self
                .len_buckets
                .iter()
                .map(|(&lb, s)| (lb, s.latency.percentile(0.99) * 1e3))
                .collect(),
        }
    }
}

/// Static server identity: what is loaded and how it is provisioned.
/// Constant for the life of the server (a hot reload swaps weights, not
/// architecture), so it is attached to reports rather than accumulated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerInfo {
    /// Architecture tag of the loaded model (e.g. `mlp 64-128-10`).
    pub arch: String,
    /// Serving worker threads in the batcher pool.
    pub workers: usize,
    /// BRGEMM threads per forward plan.
    pub threads: usize,
    pub max_batch: usize,
    /// Padded batch-size ladder the plans were built for.
    pub buckets: Vec<usize>,
    /// Sequence length-bucket ladder (empty for fixed-shape models).
    pub len_buckets: Vec<usize>,
}

impl ServerInfo {
    pub fn to_json(&self) -> Json {
        let sizes = |v: &[usize]| Json::Arr(v.iter().map(|&b| b.into()).collect());
        obj([
            ("arch", self.arch.as_str().into()),
            ("workers", self.workers.into()),
            ("threads", self.threads.into()),
            ("max_batch", self.max_batch.into()),
            ("buckets", sizes(&self.buckets)),
            ("len_buckets", sizes(&self.len_buckets)),
        ])
    }

    pub fn render(&self) -> String {
        let fmt_ladder = |v: &[usize]| {
            v.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",")
        };
        let mut s = format!(
            "server: {} — {} workers x {} threads, max batch {}, buckets [{}]",
            self.arch,
            self.workers,
            self.threads,
            self.max_batch,
            fmt_ladder(&self.buckets)
        );
        if !self.len_buckets.is_empty() {
            s.push_str(&format!(", len buckets [{}]", fmt_ladder(&self.len_buckets)));
        }
        s.push('\n');
        s
    }
}

/// The summary a serving run reports: throughput + latency percentiles +
/// batching behaviour.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    /// Hot weight reloads applied during the run (artifact swaps).
    pub reloads: u64,
    pub wall_secs: f64,
    /// How long the server has been up when this report was taken. For a
    /// final report this equals `wall_secs`; for a live `admin stats`
    /// snapshot it is the server's age.
    pub uptime_secs: f64,
    /// SLO attainment summary, when a latency objective is configured.
    pub slo: Option<SloSummary>,
    /// Static server identity (model arch, pool sizes, bucket ladders) —
    /// attached by the batcher's admin/report paths so an operator can
    /// tell from `stats` what is actually loaded.
    pub info: Option<ServerInfo>,
    /// Process resource accounting (RSS, faults, CPU, allocations) taken
    /// at report time. Present only when the resource plane is installed
    /// ([`crate::telemetry::resource::install`]); absence means "plane
    /// off", same contract as `slo`/`info`.
    pub resource: Option<crate::telemetry::resource::ResourceSnapshot>,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Queue backlog sampled at every dequeue (mean / max).
    pub queue_depth_mean: f64,
    pub queue_depth_max: f64,
    /// Stage split of the end-to-end latency: time a request spent queued
    /// before the batcher dequeued it (ms)...
    pub queue_wait_mean_ms: f64,
    pub queue_wait_max_ms: f64,
    /// ...vs the forward-compute time of the batch that carried it (ms).
    pub compute_mean_ms: f64,
    pub compute_max_ms: f64,
    /// Per bucket size: (bucket, batches executed, mean fill fraction).
    pub batch_fill: Vec<(usize, usize, f64)>,
    /// Per bucket size: (bucket, mean queue-wait ms, mean compute ms).
    pub bucket_stages: Vec<(usize, f64, f64)>,
    /// Per bucket size: (bucket, p99 end-to-end latency ms) from the
    /// per-bucket histogram. Parallels `batch_fill`.
    pub bucket_p99: Vec<(usize, f64)>,
    /// Per runtime sequence-length bucket: (len bucket, batches, real
    /// requests, mean compute ms). Empty for fixed-shape models.
    pub len_buckets: Vec<(usize, usize, usize, f64)>,
    /// Per runtime sequence-length bucket: (len bucket, p99 latency ms).
    /// Parallels `len_buckets`.
    pub len_bucket_p99: Vec<(usize, f64)>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        if let Some(info) = &self.info {
            s.push_str(&info.render());
        }
        s.push_str(&format!(
            "served {} requests in {:.2} s — {:.1} req/s\n",
            self.requests, self.wall_secs, self.throughput_rps
        ));
        s.push_str(&format!(
            "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  mean {:.3}  max {:.3}\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms, self.max_ms
        ));
        s.push_str(&format!(
            "queue depth at dequeue: mean {:.2}  max {:.0}\n",
            self.queue_depth_mean, self.queue_depth_max
        ));
        s.push_str(&format!(
            "stage split ms: queue-wait mean {:.3} max {:.3}  compute mean {:.3} max {:.3}\n",
            self.queue_wait_mean_ms, self.queue_wait_max_ms, self.compute_mean_ms, self.compute_max_ms
        ));
        if self.reloads > 0 {
            s.push_str(&format!("hot weight reloads: {}\n", self.reloads));
        }
        if let Some(r) = &self.resource {
            s.push_str(&r.render());
        }
        if let Some(slo) = &self.slo {
            slo.render_into(&mut s);
        }
        s.push_str("batch-fill histogram (bucket: batches, mean fill, stage split, p99):\n");
        for (i, (bucket, batches, fill)) in self.batch_fill.iter().enumerate() {
            s.push_str(&format!(
                "  b{:<4} {:>6} batches  {:>5.1}% full",
                bucket,
                batches,
                100.0 * fill
            ));
            // bucket_stages parallels batch_fill (both walk the same
            // ordered bucket map), but guard anyway.
            if let Some((_, qw, cp)) = self.bucket_stages.get(i) {
                s.push_str(&format!("  wait {:.3} ms  compute {:.3} ms", qw, cp));
            }
            if let Some((_, p99)) = self.bucket_p99.get(i) {
                s.push_str(&format!("  p99 {:.3} ms", p99));
            }
            s.push('\n');
        }
        if !self.len_buckets.is_empty() {
            s.push_str("length-bucket split (len bucket: batches, requests, compute):\n");
            for (i, (lb, batches, requests, cp)) in self.len_buckets.iter().enumerate() {
                s.push_str(&format!(
                    "  t{:<4} {:>6} batches  {:>6} requests  compute {:.3} ms",
                    lb, batches, requests, cp
                ));
                if let Some((_, p99)) = self.len_bucket_p99.get(i) {
                    s.push_str(&format!("  p99 {:.3} ms", p99));
                }
                s.push('\n');
            }
        }
        s
    }

    /// One JSON row, shaped like the fig benches' output (consumed by
    /// EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> = self
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &(b, n, f))| {
                let (qw, cp) = self
                    .bucket_stages
                    .get(i)
                    .map(|&(_, qw, cp)| (qw, cp))
                    .unwrap_or((0.0, 0.0));
                let p99 = self.bucket_p99.get(i).map(|&(_, p)| p).unwrap_or(0.0);
                obj([
                    ("bucket", (b as f64).into()),
                    ("batches", (n as f64).into()),
                    ("mean_fill", f.into()),
                    ("queue_wait_ms", qw.into()),
                    ("compute_ms", cp.into()),
                    ("p99_ms", p99.into()),
                ])
            })
            .collect();
        let mut row = obj([
            ("requests", (self.requests as f64).into()),
            ("reloads", (self.reloads as f64).into()),
            ("wall_s", self.wall_secs.into()),
            ("uptime_secs", self.uptime_secs.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_ms", self.mean_ms.into()),
            ("max_ms", self.max_ms.into()),
            ("queue_depth_mean", self.queue_depth_mean.into()),
            ("queue_depth_max", self.queue_depth_max.into()),
            (
                "queue_wait",
                obj([
                    ("mean_ms", self.queue_wait_mean_ms.into()),
                    ("max_ms", self.queue_wait_max_ms.into()),
                ]),
            ),
            (
                "compute",
                obj([
                    ("mean_ms", self.compute_mean_ms.into()),
                    ("max_ms", self.compute_max_ms.into()),
                ]),
            ),
            ("batch_fill", Json::Arr(hist)),
            (
                "len_buckets",
                Json::Arr(
                    self.len_buckets
                        .iter()
                        .enumerate()
                        .map(|(i, &(lb, batches, requests, cp))| {
                            let p99 =
                                self.len_bucket_p99.get(i).map(|&(_, p)| p).unwrap_or(0.0);
                            obj([
                                ("len_bucket", (lb as f64).into()),
                                ("batches", (batches as f64).into()),
                                ("requests", (requests as f64).into()),
                                ("compute_ms", cp.into()),
                                ("p99_ms", p99.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        // Optional blocks join only when configured — their absence (not
        // a null) is what "SLO off" looks like downstream.
        if let Json::Obj(fields) = &mut row {
            if let Some(slo) = &self.slo {
                fields.insert("slo".to_string(), slo.to_json());
            }
            if let Some(info) = &self.info {
                fields.insert("server".to_string(), info.to_json());
            }
            if let Some(r) = &self.resource {
                fields.insert("resource".to_string(), r.to_json());
            }
        }
        row
    }
}

// ---- Prometheus text exposition (no deps beyond std) ----

/// Escape a Prometheus label value (backslash, double quote, newline).
pub fn prom_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format a sample value; Prometheus spells infinity `+Inf`/`-Inf`.
fn prom_num(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{}", v)
    }
}

fn prom_header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {} {}", name, help);
    let _ = writeln!(out, "# TYPE {} {}", name, kind);
}

fn prom_sample(out: &mut String, name: &str, labels: &str, v: f64) {
    let _ = writeln!(out, "{}{} {}", name, labels, prom_num(v));
}

impl ServeStats {
    /// Render the serve registry in Prometheus text exposition format:
    /// one `# HELP`/`# TYPE` header per family, monotone counts as
    /// `_total` counters, Welford accumulators as mean/max gauges, and
    /// the run-wide latency histogram as cumulative `_bucket{le="..."}`
    /// rows (downsampled from the 240 native buckets). `queue_depth` is
    /// the instantaneous backlog — the one gauge the CI smoke greps for.
    pub fn prometheus_into(
        &self,
        out: &mut String,
        wall_secs: f64,
        reloads: u64,
        queue_depth: usize,
        info: Option<&ServerInfo>,
    ) {
        prom_header(out, "brgemm_serve_uptime_seconds", "gauge", "Server age in seconds.");
        prom_sample(out, "brgemm_serve_uptime_seconds", "", wall_secs);
        prom_header(out, "brgemm_serve_requests_total", "counter", "Requests answered.");
        prom_sample(out, "brgemm_serve_requests_total", "", self.requests() as f64);
        prom_header(out, "brgemm_serve_reloads_total", "counter", "Hot weight reloads applied.");
        prom_sample(out, "brgemm_serve_reloads_total", "", reloads as f64);
        prom_header(
            out,
            "brgemm_serve_queue_depth",
            "gauge",
            "Requests queued right now (instantaneous backlog).",
        );
        prom_sample(out, "brgemm_serve_queue_depth", "", queue_depth as f64);

        let stage = |out: &mut String, name: &str, help: &str, o: &Online| {
            prom_header(out, name, "gauge", help);
            let (mean, max) = if o.n == 0 { (0.0, 0.0) } else { (o.mean(), o.max) };
            prom_sample(out, name, "{stat=\"mean\"}", mean);
            prom_sample(out, name, "{stat=\"max\"}", max);
        };
        stage(
            out,
            "brgemm_serve_queue_wait_seconds",
            "Enqueue-to-dequeue wait of answered requests.",
            &self.queue_wait,
        );
        stage(
            out,
            "brgemm_serve_compute_seconds",
            "Forward-compute time per executed batch.",
            &self.compute,
        );

        prom_header(
            out,
            "brgemm_serve_latency_seconds",
            "histogram",
            "End-to-end request latency (log-bucketed, downsampled).",
        );
        for (le, count) in self.latency.cumulative_buckets() {
            prom_sample(
                out,
                "brgemm_serve_latency_seconds_bucket",
                &format!("{{le=\"{}\"}}", prom_num(le)),
                count as f64,
            );
        }
        prom_sample(out, "brgemm_serve_latency_seconds_sum", "", self.latency.sum_secs());
        prom_sample(out, "brgemm_serve_latency_seconds_count", "", self.latency.total() as f64);

        prom_header(
            out,
            "brgemm_serve_bucket_requests_total",
            "counter",
            "Real requests served, by padded batch bucket.",
        );
        for (&b, s) in &self.buckets {
            prom_sample(
                out,
                "brgemm_serve_bucket_requests_total",
                &format!("{{bucket=\"{}\"}}", b),
                s.requests as f64,
            );
        }
        prom_header(
            out,
            "brgemm_serve_bucket_batches_total",
            "counter",
            "Batches executed, by padded batch bucket.",
        );
        for (&b, s) in &self.buckets {
            prom_sample(
                out,
                "brgemm_serve_bucket_batches_total",
                &format!("{{bucket=\"{}\"}}", b),
                s.batches as f64,
            );
        }
        if !self.len_buckets.is_empty() {
            prom_header(
                out,
                "brgemm_serve_len_bucket_requests_total",
                "counter",
                "Real requests served, by sequence length bucket.",
            );
            for (&lb, s) in &self.len_buckets {
                prom_sample(
                    out,
                    "brgemm_serve_len_bucket_requests_total",
                    &format!("{{len_bucket=\"{}\"}}", lb),
                    s.requests as f64,
                );
            }
        }

        if let Some(slo) = &self.slo {
            let s = slo.summary();
            prom_header(
                out,
                "brgemm_slo_attainment",
                "gauge",
                "Fraction of requests that met their deadline.",
            );
            prom_sample(out, "brgemm_slo_attainment", "", s.attainment);
            prom_header(
                out,
                "brgemm_slo_error_budget_remaining",
                "gauge",
                "Unspent fraction of the run's violation allowance (negative = objective blown).",
            );
            prom_sample(out, "brgemm_slo_error_budget_remaining", "", s.error_budget_remaining);
            prom_header(
                out,
                "brgemm_slo_burn_rate",
                "gauge",
                "Windowed violation rate over the budget rate (1.0 = sustainable pace).",
            );
            prom_sample(out, "brgemm_slo_burn_rate", "{window=\"short\"}", s.burn_rate_short);
            prom_sample(out, "brgemm_slo_burn_rate", "{window=\"long\"}", s.burn_rate_long);
            prom_header(
                out,
                "brgemm_slo_violations_total",
                "counter",
                "Deadline violations, attributed to their dominant stage.",
            );
            prom_sample(
                out,
                "brgemm_slo_violations_total",
                "{cause=\"queue_wait\"}",
                s.viol_queue_wait as f64,
            );
            prom_sample(
                out,
                "brgemm_slo_violations_total",
                "{cause=\"compute\"}",
                s.viol_compute as f64,
            );
            prom_sample(
                out,
                "brgemm_slo_violations_total",
                "{cause=\"reload_stall\"}",
                s.viol_reload as f64,
            );
        }

        if let Some(info) = info {
            prom_header(
                out,
                "brgemm_serve_info",
                "gauge",
                "Static server identity (constant 1; the identity is in the labels).",
            );
            prom_sample(
                out,
                "brgemm_serve_info",
                &format!(
                    "{{arch=\"{}\",workers=\"{}\",threads=\"{}\",max_batch=\"{}\"}}",
                    prom_label(&info.arch),
                    info.workers,
                    info.threads,
                    info.max_batch
                ),
                1.0,
            );
        }
    }
}

/// Append the health plane's families to a Prometheus rendering.
pub fn prometheus_health_into(out: &mut String, snap: &crate::telemetry::health::HealthSnapshot) {
    prom_header(
        out,
        "brgemm_health_state",
        "gauge",
        "Derived health state: 0=starting, 1=ready, 2=degraded, 3=draining.",
    );
    prom_sample(out, "brgemm_health_state", "", snap.state.code() as f64);
    prom_header(
        out,
        "brgemm_health_heartbeats_total",
        "counter",
        "Per-worker heartbeats (serve: per batch/wake; train: per step).",
    );
    for g in &snap.groups {
        for (i, &beats) in g.beats.iter().enumerate() {
            prom_sample(
                out,
                "brgemm_health_heartbeats_total",
                &format!("{{group=\"{}\",worker=\"{}\"}}", prom_label(&g.name), i),
                beats as f64,
            );
        }
    }
    prom_header(out, "brgemm_health_reload_failures_total", "counter", "Failed hot reloads.");
    prom_sample(out, "brgemm_health_reload_failures_total", "", snap.reload_failures as f64);
}

/// Append the BRGEMM profiler's per-primitive families (when installed).
pub fn prometheus_profiler_into(out: &mut String, prof: &crate::telemetry::Profiler) {
    use crate::telemetry::Pass;
    let slots = prof.slots();
    if slots.is_empty() {
        return;
    }
    struct Family {
        name: &'static str,
        help: &'static str,
        read: fn(&crate::telemetry::PassSnapshot) -> f64,
    }
    let families = [
        Family {
            name: "brgemm_prim_calls_total",
            help: "Primitive pass executions.",
            read: |s| s.calls as f64,
        },
        Family {
            name: "brgemm_prim_brgemm_calls_total",
            help: "BRGEMM kernel invocations.",
            read: |s| s.brgemm_calls as f64,
        },
        Family {
            name: "brgemm_prim_seconds_total",
            help: "Wall seconds spent in the primitive pass.",
            read: |s| s.secs,
        },
    ];
    for fam in &families {
        prom_header(out, fam.name, "counter", fam.help);
        for slot in &slots {
            for pass in [Pass::Fwd, Pass::Bwd, Pass::Upd] {
                let s = slot.pass_snapshot(pass);
                if s.calls == 0 {
                    continue;
                }
                prom_sample(
                    out,
                    fam.name,
                    &format!(
                        "{{kind=\"{}\",label=\"{}\",pass=\"{}\"}}",
                        slot.kind(),
                        prom_label(slot.label()),
                        pass.name()
                    ),
                    (fam.read)(&s),
                );
            }
        }
    }
}

/// Append the resource plane's families (when installed): process RSS,
/// page faults, CPU accounting and allocator counts, all prefixed
/// `brgemm_resource_`.
pub fn prometheus_resource_into(
    out: &mut String,
    r: &crate::telemetry::resource::ResourceSnapshot,
) {
    prom_header(out, "brgemm_resource_rss_mb", "gauge", "Resident set size (VmRSS), MiB.");
    prom_sample(out, "brgemm_resource_rss_mb", "", r.rss_mb);
    prom_header(
        out,
        "brgemm_resource_rss_peak_mb",
        "gauge",
        "Peak resident set size (VmHWM), MiB.",
    );
    prom_sample(out, "brgemm_resource_rss_peak_mb", "", r.rss_peak_mb);
    prom_header(
        out,
        "brgemm_resource_page_faults_total",
        "counter",
        "Process page faults since start, by severity.",
    );
    prom_sample(out, "brgemm_resource_page_faults_total", "{kind=\"minor\"}", r.minor_faults as f64);
    prom_sample(out, "brgemm_resource_page_faults_total", "{kind=\"major\"}", r.major_faults as f64);
    prom_header(
        out,
        "brgemm_resource_cpu_seconds_total",
        "counter",
        "Process CPU time since start, by mode.",
    );
    prom_sample(out, "brgemm_resource_cpu_seconds_total", "{mode=\"user\"}", r.cpu_utime_s);
    prom_sample(out, "brgemm_resource_cpu_seconds_total", "{mode=\"system\"}", r.cpu_stime_s);
    prom_header(
        out,
        "brgemm_resource_cpu_utilization",
        "gauge",
        "CPU seconds per wall second since the plane was installed (cores-worth of CPU).",
    );
    prom_sample(out, "brgemm_resource_cpu_utilization", "", r.cpu_util);
    prom_header(
        out,
        "brgemm_resource_ctx_switches_total",
        "counter",
        "Context switches since start, by kind.",
    );
    prom_sample(
        out,
        "brgemm_resource_ctx_switches_total",
        "{kind=\"voluntary\"}",
        r.ctx_voluntary as f64,
    );
    prom_sample(
        out,
        "brgemm_resource_ctx_switches_total",
        "{kind=\"involuntary\"}",
        r.ctx_involuntary as f64,
    );
    prom_header(
        out,
        "brgemm_resource_allocations_total",
        "counter",
        "Heap allocations counted while the plane was installed.",
    );
    prom_sample(out, "brgemm_resource_allocations_total", "", r.alloc_count as f64);
    prom_header(
        out,
        "brgemm_resource_allocated_bytes_total",
        "counter",
        "Heap bytes requested while the plane was installed.",
    );
    prom_sample(out, "brgemm_resource_allocated_bytes_total", "", r.alloc_bytes as f64);
    prom_header(
        out,
        "brgemm_resource_frees_total",
        "counter",
        "Heap frees counted while the plane was installed.",
    );
    prom_sample(out, "brgemm_resource_frees_total", "", r.free_count as f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;

    /// Relative error helper for histogram-vs-exact comparisons: one
    /// bucket's relative width is ≈8%, so 9% is the contract bound.
    fn rel_close(got: f64, want: f64) -> bool {
        (got - want).abs() <= 0.09 * want.abs().max(1e-12)
    }

    #[test]
    fn percentiles_and_histogram() {
        let mut st = ServeStats::new();
        // Two b4 batches (fills 4 and 2) and one b1 batch.
        st.record_batch(4, 0, 4, 3, &[0.010, 0.020, 0.030, 0.040], &[0.001, 0.002, 0.003, 0.004], 0.006);
        st.record_batch(4, 0, 2, 1, &[0.050, 0.060], &[0.005, 0.006], 0.044);
        st.record_batch(1, 0, 1, 0, &[0.070], &[0.010], 0.060);
        assert_eq!(st.requests(), 7);
        let r = st.report(1.0, 2);
        assert_eq!(r.requests, 7);
        assert_eq!(r.reloads, 2, "reload count flows into the report");
        assert!((r.throughput_rps - 7.0).abs() < 1e-12);
        // Percentiles come from the log histogram now: within one
        // bucket's relative error of the exact values.
        assert!(rel_close(r.p50_ms, 40.0), "p50 {}", r.p50_ms);
        assert!((r.max_ms - 70.0).abs() < 1e-9, "max stays exact: {}", r.max_ms);
        assert!(rel_close(r.mean_ms, 280.0 / 7.0), "mean stays exact: {}", r.mean_ms);
        assert!(r.p50_ms <= r.p95_ms && r.p95_ms <= r.p99_ms && r.p99_ms <= r.max_ms);
        // Histogram: b1 with 1 batch 100% full; b4 with 2 batches, fill
        // (4+2)/(2*4) = 75%.
        assert_eq!(r.batch_fill.len(), 2);
        assert_eq!(r.batch_fill[0].0, 1);
        assert!((r.batch_fill[0].2 - 1.0).abs() < 1e-12);
        assert_eq!(r.batch_fill[1], (4, 2, 0.75));
        // Per-bucket p99 parallels the fill histogram: b1 saw only the
        // 70 ms request, b4 tops out at 60 ms.
        assert_eq!(r.bucket_p99.len(), 2);
        assert!(rel_close(r.bucket_p99[0].1, 70.0), "{}", r.bucket_p99[0].1);
        assert!(rel_close(r.bucket_p99[1].1, 60.0), "{}", r.bucket_p99[1].1);
        // Queue depth mean over samples 3,1,0.
        assert!((r.queue_depth_mean - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.queue_depth_max, 3.0);
        // JSON row carries the headline numbers and the stage split.
        let j = r.to_json().to_string_compact();
        assert!(j.contains("\"throughput_rps\"") && j.contains("\"p99_ms\""), "{}", j);
        assert!(j.contains("\"queue_wait\"") && j.contains("\"compute\""), "{}", j);
    }

    #[test]
    fn histogram_percentiles_track_exact_within_bucket_error() {
        // 500 log-uniform latencies across 1 ms .. 1 s — three decades,
        // the range a serving run actually spans. The histogram's
        // percentile must track the exact (sorted-sample) percentile to
        // within one bucket's relative width at every probed quantile.
        let samples: Vec<f64> =
            (0..500).map(|i| 0.001 * 1000.0f64.powf(i as f64 / 499.0)).collect();
        let mut hist = LogHistogram::new();
        for &s in &samples {
            hist.record(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(f64::total_cmp);
        for q in [0.10, 0.50, 0.90, 0.95, 0.99] {
            let exact = percentile(&sorted, q);
            let got = hist.percentile(q);
            assert!(
                rel_close(got, exact),
                "q={}: histogram {} vs exact {}",
                q,
                got,
                exact
            );
        }
        assert_eq!(hist.total(), 500);
        assert!((hist.max_secs() - 1.0).abs() < 1e-12, "max exact");
        let exact_mean = samples.iter().sum::<f64>() / 500.0;
        assert!((hist.mean() - exact_mean).abs() < 1e-12, "mean exact");
    }

    #[test]
    fn histogram_edges_underflow_overflow() {
        let mut h = LogHistogram::new();
        h.record(0.0); // below range → underflow bucket
        h.record(1e9); // above range → overflow bucket
        h.record(0.010);
        assert_eq!(h.total(), 3);
        // Percentile output is clamped into the observed [min, max].
        assert!(h.percentile(0.0) >= 0.0);
        assert!(h.percentile(1.0) <= 1e9);
        assert_eq!(h.max_secs(), 1e9);
        // An empty histogram reports zeros, not NaN.
        let e = LogHistogram::new();
        assert_eq!(e.percentile(0.5), 0.0);
        assert_eq!(e.mean(), 0.0);
        assert_eq!(e.max_secs(), 0.0);
    }

    #[test]
    fn latency_storage_is_constant_in_request_count() {
        // The whole point of the histogram swap: metric memory must not
        // grow with served requests. Record 100 then 10_000 more
        // requests into the same bucket shape and assert the counter
        // storage is bit-for-bit the same size.
        let mut st = ServeStats::new();
        let lat = [0.005; 4];
        let qw = [0.001; 4];
        for _ in 0..25 {
            st.record_batch(4, 8, 4, 0, &lat, &qw, 0.003);
        }
        let small = st.latency_storage_buckets();
        assert_eq!(st.requests(), 100);
        for _ in 0..2500 {
            st.record_batch(4, 8, 4, 0, &lat, &qw, 0.003);
        }
        assert_eq!(st.requests(), 10_100);
        assert_eq!(
            st.latency_storage_buckets(),
            small,
            "latency storage grew with request count"
        );
    }

    #[test]
    fn queue_wait_compute_split_arithmetic() {
        let mut st = ServeStats::new();
        st.record_batch(4, 0, 4, 3, &[0.010, 0.020, 0.030, 0.040], &[0.001, 0.002, 0.003, 0.004], 0.006);
        st.record_batch(4, 0, 2, 1, &[0.050, 0.060], &[0.005, 0.006], 0.044);
        st.record_batch(1, 0, 1, 0, &[0.070], &[0.010], 0.060);
        let r = st.report(1.0, 0);
        // Run-wide queue wait over 7 samples: (1+2+3+4+5+6+10)/7 ms.
        assert!((r.queue_wait_mean_ms - 31.0 / 7.0).abs() < 1e-9, "{}", r.queue_wait_mean_ms);
        assert!((r.queue_wait_max_ms - 10.0).abs() < 1e-9);
        // Compute per batch: 6, 44, 60 ms → mean 110/3.
        assert!((r.compute_mean_ms - 110.0 / 3.0).abs() < 1e-9, "{}", r.compute_mean_ms);
        assert!((r.compute_max_ms - 60.0).abs() < 1e-9);
        // Per-bucket splits parallel the fill histogram ordering (b1, b4).
        assert_eq!(r.bucket_stages.len(), 2);
        assert_eq!(r.bucket_stages[0].0, 1);
        assert!((r.bucket_stages[0].1 - 10.0).abs() < 1e-9);
        assert!((r.bucket_stages[0].2 - 60.0).abs() < 1e-9);
        assert_eq!(r.bucket_stages[1].0, 4);
        assert!((r.bucket_stages[1].1 - 21.0 / 6.0).abs() < 1e-9, "{}", r.bucket_stages[1].1);
        assert!((r.bucket_stages[1].2 - 25.0).abs() < 1e-9);
        // And the render mentions the split.
        assert!(r.render().contains("stage split"), "{}", r.render());
    }

    #[test]
    fn nan_latency_sample_does_not_panic() {
        let mut st = ServeStats::new();
        // One corrupt (NaN) latency among three good ones: it must count
        // toward the request total (overflow bucket) without poisoning
        // the finite stats.
        st.record_batch(4, 0, 4, 0, &[0.010, 0.020, f64::NAN, 0.030], &[0.001; 4], 0.005);
        let r = st.report(1.0, 0);
        assert_eq!(r.requests, 4);
        assert!(r.p50_ms.is_finite(), "{}", r.p50_ms);
        assert!((r.max_ms - 30.0).abs() < 1e-9, "max ignores the NaN: {}", r.max_ms);
    }

    #[test]
    fn len_bucket_split_tracks_sequence_batches() {
        let mut st = ServeStats::new();
        // Two length-8 batches and one length-2 batch; a fixed-shape
        // batch (sentinel 0) must not pollute the split.
        st.record_batch(4, 8, 4, 0, &[0.01; 4], &[0.001; 4], 0.008);
        st.record_batch(2, 8, 2, 0, &[0.01; 2], &[0.001; 2], 0.004);
        st.record_batch(4, 2, 3, 0, &[0.01; 3], &[0.001; 3], 0.002);
        st.record_batch(1, 0, 1, 0, &[0.01], &[0.001], 0.001);
        let r = st.report(1.0, 0);
        assert_eq!(r.len_buckets.len(), 2, "two length buckets, sentinel skipped");
        let (lb, batches, requests, cp) = r.len_buckets[0];
        assert_eq!((lb, batches, requests), (2, 1, 3));
        assert!((cp - 2.0).abs() < 1e-9, "{}", cp);
        let (lb, batches, requests, cp) = r.len_buckets[1];
        assert_eq!((lb, batches, requests), (8, 2, 6));
        assert!((cp - 6.0).abs() < 1e-9, "{}", cp);
        // Per-length-bucket p99 parallels the split (all requests here
        // were 10 ms).
        assert_eq!(r.len_bucket_p99.len(), 2);
        assert!(rel_close(r.len_bucket_p99[0].1, 10.0), "{}", r.len_bucket_p99[0].1);
        // The JSON row carries per-entry "len_bucket" keys (the CI smoke
        // greps for them) and the render mentions the split.
        let j = r.to_json().to_string_compact();
        assert_eq!(j.matches("\"len_bucket\"").count(), 2, "{}", j);
        // One run-wide p99_ms plus one per batch bucket and length
        // bucket row.
        assert_eq!(
            j.matches("\"p99_ms\"").count(),
            1 + r.batch_fill.len() + r.len_buckets.len(),
            "{}",
            j
        );
        assert!(r.render().contains("length-bucket split"), "{}", r.render());
        // Fixed-shape-only runs keep the split empty.
        let mut fixed = ServeStats::new();
        fixed.record_batch(2, 0, 2, 0, &[0.01; 2], &[0.001; 2], 0.001);
        assert!(fixed.report(1.0, 0).len_buckets.is_empty());
    }

    #[test]
    fn empty_run_reports_zeros() {
        let r = ServeStats::new().report(0.5, 0);
        assert_eq!(r.requests, 0);
        assert_eq!(r.reloads, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.queue_depth_max, 0.0);
        assert!(r.batch_fill.is_empty());
        assert!(r.slo.is_none() && r.info.is_none());
        assert!((r.uptime_secs - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_end_at_inf() {
        let mut h = LogHistogram::new();
        h.record(0.0); // underflow
        for i in 0..200 {
            h.record(0.0005 * (i + 1) as f64); // spread over the range
        }
        h.record(f64::NAN); // overflow bucket, counts toward total
        let rows = h.cumulative_buckets();
        let mut prev_le = 0.0;
        let mut prev_count = 0;
        for &(le, count) in &rows {
            assert!(le > prev_le, "bounds strictly increase");
            assert!(count >= prev_count, "counts are cumulative");
            prev_le = le;
            prev_count = count;
        }
        let &(last_le, last_count) = rows.last().unwrap();
        assert_eq!(last_le, f64::INFINITY, "mandatory +Inf bucket");
        assert_eq!(last_count, h.total(), "+Inf carries everything, NaNs included");
        // Downsampled: far fewer rows than native buckets, but plural.
        assert!(rows.len() > 5 && rows.len() < HIST_BUCKETS, "{}", rows.len());
        // The exact sum rides along for the _sum series.
        assert!((h.sum_secs() - (0..200).map(|i| 0.0005 * (i + 1) as f64).sum::<f64>()).abs() < 1e-12);
    }

    #[test]
    fn slo_outcomes_flow_into_report_render_and_json() {
        use crate::serve::slo::{SloCause, SloOutcome};
        let mut st = ServeStats::with_slo(SloSpec { latency_ms: 25.0, objective: 0.9 });
        st.record_batch(4, 0, 4, 0, &[0.010, 0.020, 0.030, 0.040], &[0.001; 4], 0.005);
        st.record_slo(
            4,
            0,
            &[
                SloOutcome { met: true, cause: None },
                SloOutcome { met: true, cause: None },
                SloOutcome { met: false, cause: Some(SloCause::QueueWait) },
                SloOutcome { met: false, cause: Some(SloCause::Compute) },
            ],
        );
        let r = st.report(1.0, 0);
        let slo = r.slo.as_ref().expect("slo summary present");
        assert_eq!((slo.total, slo.met), (4, 2));
        assert_eq!((slo.viol_queue_wait, slo.viol_compute), (1, 1));
        assert!(r.render().contains("slo:"), "{}", r.render());
        let j = r.to_json().to_string_compact();
        assert!(j.contains("\"slo_attainment\":0.5"), "{}", j);
        assert!(j.contains("\"viol_queue_wait\":1"), "{}", j);
        // Without SLO config, record_slo is a no-op and the key is absent.
        let mut plain = ServeStats::new();
        plain.record_batch(4, 0, 1, 0, &[0.01], &[0.001], 0.005);
        plain.record_slo(4, 0, &[SloOutcome { met: true, cause: None }]);
        let pj = plain.report(1.0, 0).to_json().to_string_compact();
        assert!(!pj.contains("\"slo\""), "{}", pj);
    }

    #[test]
    fn server_info_lands_in_render_and_json() {
        let mut r = ServeStats::new().report(1.0, 0);
        r.info = Some(ServerInfo {
            arch: "mlp 64-128-10".into(),
            workers: 2,
            threads: 1,
            max_batch: 8,
            buckets: vec![1, 2, 4, 8],
            len_buckets: vec![],
        });
        assert!(r.render().contains("server: mlp 64-128-10"), "{}", r.render());
        let j = r.to_json();
        let server = j.get("server").expect("server block");
        assert_eq!(server.get("workers").and_then(|w| w.as_f64()), Some(2.0));
        assert_eq!(server.get("arch").and_then(|a| a.as_str()), Some("mlp 64-128-10"));
    }

    #[test]
    fn prometheus_rendering_is_wellformed_exposition_text() {
        use crate::serve::slo::{SloCause, SloOutcome};
        let mut st = ServeStats::with_slo(SloSpec::default());
        st.record_batch(4, 8, 2, 3, &[0.010, 0.020], &[0.001; 2], 0.005);
        st.record_slo(
            4,
            8,
            &[
                SloOutcome { met: true, cause: None },
                SloOutcome { met: false, cause: Some(SloCause::Compute) },
            ],
        );
        let info = ServerInfo {
            arch: "rnn \"quoted\" 2x32".into(),
            workers: 2,
            threads: 1,
            max_batch: 8,
            buckets: vec![1, 2, 4, 8],
            len_buckets: vec![4, 8],
        };
        let mut out = String::new();
        st.prometheus_into(&mut out, 12.5, 1, 3, Some(&info));
        // Every family has a TYPE header; every sample line is
        // `name{labels} value` with a parseable float value.
        let mut type_lines = 0;
        for line in out.lines() {
            assert!(!line.is_empty(), "no blank lines inside exposition");
            if line.starts_with("# TYPE ") || line.starts_with("# HELP ") {
                if line.starts_with("# TYPE ") {
                    type_lines += 1;
                }
                continue;
            }
            let value = line.rsplit(' ').next().unwrap();
            assert!(
                value == "+Inf" || value.parse::<f64>().is_ok(),
                "unparseable sample value in {:?}",
                line
            );
        }
        assert!(type_lines >= 8, "one TYPE per family, got {}", type_lines);
        assert!(out.contains("brgemm_serve_queue_depth 3"), "{}", out);
        assert!(out.contains("brgemm_slo_attainment 0.5"), "{}", out);
        assert!(out.contains("le=\"+Inf\""), "{}", out);
        assert!(out.contains("brgemm_serve_len_bucket_requests_total{len_bucket=\"8\"} 2"));
        // Label escaping: the quoted arch survives as \" inside the label.
        assert!(out.contains("arch=\"rnn \\\"quoted\\\" 2x32\""), "{}", out);
        // Cumulative invariant on the histogram rows.
        let mut prev = 0.0;
        for line in out.lines().filter(|l| l.starts_with("brgemm_serve_latency_seconds_bucket")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "histogram rows must be cumulative: {}", line);
            prev = v;
        }
        assert_eq!(prev, 2.0, "+Inf row carries the count");
    }

    #[test]
    fn prometheus_health_and_profiler_families_render() {
        use crate::telemetry::health::{Health, HealthThresholds};
        let h = Health::new(HealthThresholds::default());
        let g = h.register("serve", 2);
        g.beat(0);
        g.beat(0);
        g.beat(1);
        let mut out = String::new();
        prometheus_health_into(&mut out, &h.evaluate());
        assert!(out.contains("# TYPE brgemm_health_state gauge"), "{}", out);
        assert!(out.contains("brgemm_health_state 1"), "ready encodes as 1: {}", out);
        assert!(
            out.contains("brgemm_health_heartbeats_total{group=\"serve\",worker=\"0\"} 2"),
            "{}",
            out
        );
    }

    #[test]
    fn prometheus_resource_families_render() {
        let snap = crate::telemetry::resource::ResourceSnapshot {
            rss_mb: 12.5,
            rss_peak_mb: 20.0,
            minor_faults: 1000,
            major_faults: 2,
            cpu_utime_s: 1.25,
            cpu_stime_s: 0.5,
            cpu_util: 0.9,
            ctx_voluntary: 40,
            ctx_involuntary: 3,
            alloc_count: 500,
            alloc_bytes: 1 << 20,
            free_count: 480,
            samples: 7,
        };
        let mut out = String::new();
        prometheus_resource_into(&mut out, &snap);
        assert!(out.contains("# TYPE brgemm_resource_rss_mb gauge"), "{}", out);
        assert!(out.contains("brgemm_resource_rss_peak_mb 20"), "{}", out);
        assert!(out.contains("brgemm_resource_page_faults_total{kind=\"minor\"} 1000"), "{}", out);
        assert!(out.contains("brgemm_resource_cpu_seconds_total{mode=\"user\"} 1.25"), "{}", out);
        assert!(out.contains("brgemm_resource_ctx_switches_total{kind=\"involuntary\"} 3"), "{}", out);
        assert!(out.contains("brgemm_resource_allocations_total 500"), "{}", out);
        // Every sample line is `name[{labels}] value` with a parseable value.
        for line in out.lines().filter(|l| !l.starts_with('#')) {
            let v = line.rsplit(' ').next().unwrap();
            assert!(v.parse::<f64>().is_ok(), "unparseable sample in {:?}", line);
        }
    }

    #[test]
    fn report_json_carries_resource_block_when_plane_installed() {
        let _g = crate::telemetry::test_lock();
        crate::telemetry::resource::install();
        let st = ServeStats::new();
        let r = st.report(1.0, 0);
        crate::telemetry::resource::uninstall();
        let snap = r.resource.as_ref().expect("plane installed → block present");
        assert!(snap.rss_peak_mb >= 0.0);
        let j = r.to_json().to_string_compact();
        assert!(j.contains("\"resource\"") && j.contains("\"rss_peak_mb\""), "{}", j);
        // Plane off → block absent (not null).
        let r2 = st.report(1.0, 0);
        assert!(r2.resource.is_none());
        assert!(!r2.to_json().to_string_compact().contains("\"resource\""));
    }
}
