//! Serving metrics: per-request latency percentiles, throughput, queue
//! depth, and the batch-fill histogram.
//!
//! The batcher records one entry per executed batch ([`ServeStats::record_batch`]);
//! the final [`ServeReport`] is what the `serve` CLI prints and the
//! `serve_load` bench emits as a JSON row. Latencies are kept as raw
//! samples (a serving run is at most a few hundred thousand requests);
//! queue depth uses the [`Online`] accumulator.

use crate::util::json::{obj, Json};
use crate::util::stats::{percentile, Online};
use std::collections::BTreeMap;

/// Per-bucket accounting: how many batches ran at this bucket size, how
/// many real (non-padded) requests they carried, and the stage split —
/// time requests spent queued vs the batch's forward-compute time.
#[derive(Debug, Clone)]
pub struct BucketStat {
    pub batches: usize,
    pub requests: usize,
    /// Enqueue→dequeue seconds of the real requests in this bucket.
    pub queue_wait: Online,
    /// Forward-compute seconds per batch executed at this bucket size.
    pub compute: Online,
}

impl Default for BucketStat {
    fn default() -> BucketStat {
        // Explicit so the Online accumulators start with the ±∞ min/max
        // sentinels of `Online::new`, not the derived zeros.
        BucketStat {
            batches: 0,
            requests: 0,
            queue_wait: Online::new(),
            compute: Online::new(),
        }
    }
}

/// Per-length-bucket accounting for sequence models: how many batches a
/// runtime length bucket dispatched, the real requests they carried, and
/// their forward-compute time (a batch never mixes length buckets, so
/// the split is exact).
#[derive(Debug, Clone)]
pub struct LenBucketStat {
    pub batches: usize,
    pub requests: usize,
    pub compute: Online,
}

impl Default for LenBucketStat {
    fn default() -> LenBucketStat {
        LenBucketStat { batches: 0, requests: 0, compute: Online::new() }
    }
}

/// Accumulated by the worker pool during a serving run.
#[derive(Debug)]
pub struct ServeStats {
    latencies: Vec<f64>,
    queue_depth: Option<Online>,
    buckets: BTreeMap<usize, BucketStat>,
    /// Sequence-length split (empty for fixed-shape models, which record
    /// the `0` sentinel and are skipped).
    len_buckets: BTreeMap<usize, LenBucketStat>,
    /// Run-wide stage accumulators (the per-bucket splits, merged).
    queue_wait: Online,
    compute: Online,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        ServeStats::new()
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            latencies: Vec::new(),
            queue_depth: None,
            buckets: BTreeMap::new(),
            len_buckets: BTreeMap::new(),
            queue_wait: Online::new(),
            compute: Online::new(),
        }
    }

    /// One executed batch: `bucket` is the padded size, `len_bucket` the
    /// runtime sequence-length bucket the batch dispatched under (`0` for
    /// fixed-shape models — not tracked), `fill` the real request count
    /// (`fill <= bucket`), `depth_after` the queue backlog right after
    /// the batch was taken, `latencies` the enqueue→response seconds of
    /// the `fill` real requests, `queue_waits` their enqueue→dequeue
    /// seconds (same order), and `compute_secs` the batch's
    /// forward-compute time.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &mut self,
        bucket: usize,
        len_bucket: usize,
        fill: usize,
        depth_after: usize,
        latencies: &[f64],
        queue_waits: &[f64],
        compute_secs: f64,
    ) {
        assert!(fill <= bucket && fill == latencies.len());
        assert_eq!(queue_waits.len(), fill, "one queue-wait sample per real request");
        if len_bucket > 0 {
            let l = self.len_buckets.entry(len_bucket).or_default();
            l.batches += 1;
            l.requests += fill;
            l.compute.push(compute_secs);
        }
        let e = self.buckets.entry(bucket).or_default();
        e.batches += 1;
        e.requests += fill;
        for &w in queue_waits {
            e.queue_wait.push(w);
            self.queue_wait.push(w);
        }
        e.compute.push(compute_secs);
        self.compute.push(compute_secs);
        self.queue_depth.get_or_insert_with(Online::new).push(depth_after as f64);
        self.latencies.extend_from_slice(latencies);
    }

    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    /// Summarise into a report; `wall_secs` is the whole run's wall time
    /// (open-loop: arrival pacing included, which is what a served client
    /// experiences); `reloads` is the number of hot weight swaps applied
    /// during the run.
    pub fn report(&self, wall_secs: f64, reloads: u64) -> ServeReport {
        let n = self.latencies.len();
        let mut sorted = self.latencies.clone();
        // total_cmp, not partial_cmp().unwrap(): a single NaN sample (a
        // clock hiccup) must not panic the report; NaNs sort to the end.
        sorted.sort_by(f64::total_cmp);
        let pct = |q: f64| if n == 0 { 0.0 } else { percentile(&sorted, q) * 1e3 };
        let (qd_mean, qd_max) = match &self.queue_depth {
            Some(o) => (o.mean(), o.max),
            None => (0.0, 0.0),
        };
        let stage_ms = |o: &Online| if o.n == 0 { (0.0, 0.0) } else { (o.mean() * 1e3, o.max * 1e3) };
        let (qw_mean, qw_max) = stage_ms(&self.queue_wait);
        let (cp_mean, cp_max) = stage_ms(&self.compute);
        let bucket_mean = |o: &Online| if o.n == 0 { 0.0 } else { o.mean() * 1e3 };
        ServeReport {
            requests: n,
            reloads,
            wall_secs,
            throughput_rps: if wall_secs > 0.0 { n as f64 / wall_secs } else { 0.0 },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            mean_ms: if n == 0 {
                0.0
            } else {
                self.latencies.iter().sum::<f64>() / n as f64 * 1e3
            },
            max_ms: sorted.last().copied().unwrap_or(0.0) * 1e3,
            queue_depth_mean: qd_mean,
            queue_depth_max: qd_max,
            queue_wait_mean_ms: qw_mean,
            queue_wait_max_ms: qw_max,
            compute_mean_ms: cp_mean,
            compute_max_ms: cp_max,
            batch_fill: self
                .buckets
                .iter()
                .map(|(&b, s)| (b, s.batches, s.requests as f64 / (s.batches * b) as f64))
                .collect(),
            bucket_stages: self
                .buckets
                .iter()
                .map(|(&b, s)| (b, bucket_mean(&s.queue_wait), bucket_mean(&s.compute)))
                .collect(),
            len_buckets: self
                .len_buckets
                .iter()
                .map(|(&lb, s)| (lb, s.batches, s.requests, bucket_mean(&s.compute)))
                .collect(),
        }
    }
}

/// The summary a serving run reports: throughput + latency percentiles +
/// batching behaviour.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    /// Hot weight reloads applied during the run (artifact swaps).
    pub reloads: u64,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Queue backlog sampled at every dequeue (mean / max).
    pub queue_depth_mean: f64,
    pub queue_depth_max: f64,
    /// Stage split of the end-to-end latency: time a request spent queued
    /// before the batcher dequeued it (ms)...
    pub queue_wait_mean_ms: f64,
    pub queue_wait_max_ms: f64,
    /// ...vs the forward-compute time of the batch that carried it (ms).
    pub compute_mean_ms: f64,
    pub compute_max_ms: f64,
    /// Per bucket size: (bucket, batches executed, mean fill fraction).
    pub batch_fill: Vec<(usize, usize, f64)>,
    /// Per bucket size: (bucket, mean queue-wait ms, mean compute ms).
    pub bucket_stages: Vec<(usize, f64, f64)>,
    /// Per runtime sequence-length bucket: (len bucket, batches, real
    /// requests, mean compute ms). Empty for fixed-shape models.
    pub len_buckets: Vec<(usize, usize, usize, f64)>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "served {} requests in {:.2} s — {:.1} req/s\n",
            self.requests, self.wall_secs, self.throughput_rps
        ));
        s.push_str(&format!(
            "latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  mean {:.3}  max {:.3}\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms, self.max_ms
        ));
        s.push_str(&format!(
            "queue depth at dequeue: mean {:.2}  max {:.0}\n",
            self.queue_depth_mean, self.queue_depth_max
        ));
        s.push_str(&format!(
            "stage split ms: queue-wait mean {:.3} max {:.3}  compute mean {:.3} max {:.3}\n",
            self.queue_wait_mean_ms, self.queue_wait_max_ms, self.compute_mean_ms, self.compute_max_ms
        ));
        if self.reloads > 0 {
            s.push_str(&format!("hot weight reloads: {}\n", self.reloads));
        }
        s.push_str("batch-fill histogram (bucket: batches, mean fill, stage split):\n");
        for (i, (bucket, batches, fill)) in self.batch_fill.iter().enumerate() {
            s.push_str(&format!(
                "  b{:<4} {:>6} batches  {:>5.1}% full",
                bucket,
                batches,
                100.0 * fill
            ));
            // bucket_stages parallels batch_fill (both walk the same
            // ordered bucket map), but guard anyway.
            if let Some((_, qw, cp)) = self.bucket_stages.get(i) {
                s.push_str(&format!("  wait {:.3} ms  compute {:.3} ms", qw, cp));
            }
            s.push('\n');
        }
        if !self.len_buckets.is_empty() {
            s.push_str("length-bucket split (len bucket: batches, requests, compute):\n");
            for (lb, batches, requests, cp) in &self.len_buckets {
                s.push_str(&format!(
                    "  t{:<4} {:>6} batches  {:>6} requests  compute {:.3} ms\n",
                    lb, batches, requests, cp
                ));
            }
        }
        s
    }

    /// One JSON row, shaped like the fig benches' output (consumed by
    /// EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> = self
            .batch_fill
            .iter()
            .enumerate()
            .map(|(i, &(b, n, f))| {
                let (qw, cp) = self
                    .bucket_stages
                    .get(i)
                    .map(|&(_, qw, cp)| (qw, cp))
                    .unwrap_or((0.0, 0.0));
                obj([
                    ("bucket", (b as f64).into()),
                    ("batches", (n as f64).into()),
                    ("mean_fill", f.into()),
                    ("queue_wait_ms", qw.into()),
                    ("compute_ms", cp.into()),
                ])
            })
            .collect();
        obj([
            ("requests", (self.requests as f64).into()),
            ("reloads", (self.reloads as f64).into()),
            ("wall_s", self.wall_secs.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_ms", self.mean_ms.into()),
            ("max_ms", self.max_ms.into()),
            ("queue_depth_mean", self.queue_depth_mean.into()),
            ("queue_depth_max", self.queue_depth_max.into()),
            (
                "queue_wait",
                obj([
                    ("mean_ms", self.queue_wait_mean_ms.into()),
                    ("max_ms", self.queue_wait_max_ms.into()),
                ]),
            ),
            (
                "compute",
                obj([
                    ("mean_ms", self.compute_mean_ms.into()),
                    ("max_ms", self.compute_max_ms.into()),
                ]),
            ),
            ("batch_fill", Json::Arr(hist)),
            (
                "len_buckets",
                Json::Arr(
                    self.len_buckets
                        .iter()
                        .map(|&(lb, batches, requests, cp)| {
                            obj([
                                ("len_bucket", (lb as f64).into()),
                                ("batches", (batches as f64).into()),
                                ("requests", (requests as f64).into()),
                                ("compute_ms", cp.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_histogram() {
        let mut st = ServeStats::new();
        // Two b4 batches (fills 4 and 2) and one b1 batch.
        st.record_batch(4, 0, 4, 3, &[0.010, 0.020, 0.030, 0.040], &[0.001, 0.002, 0.003, 0.004], 0.006);
        st.record_batch(4, 0, 2, 1, &[0.050, 0.060], &[0.005, 0.006], 0.044);
        st.record_batch(1, 0, 1, 0, &[0.070], &[0.010], 0.060);
        assert_eq!(st.requests(), 7);
        let r = st.report(1.0, 2);
        assert_eq!(r.requests, 7);
        assert_eq!(r.reloads, 2, "reload count flows into the report");
        assert!((r.throughput_rps - 7.0).abs() < 1e-12);
        assert!((r.p50_ms - 40.0).abs() < 1e-9, "p50 {}", r.p50_ms);
        assert!((r.max_ms - 70.0).abs() < 1e-9);
        assert!(r.p95_ms <= r.p99_ms && r.p99_ms <= r.max_ms);
        // Histogram: b1 with 1 batch 100% full; b4 with 2 batches, fill
        // (4+2)/(2*4) = 75%.
        assert_eq!(r.batch_fill.len(), 2);
        assert_eq!(r.batch_fill[0].0, 1);
        assert!((r.batch_fill[0].2 - 1.0).abs() < 1e-12);
        assert_eq!(r.batch_fill[1], (4, 2, 0.75));
        // Queue depth mean over samples 3,1,0.
        assert!((r.queue_depth_mean - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.queue_depth_max, 3.0);
        // JSON row carries the headline numbers and the stage split.
        let j = r.to_json().to_string_compact();
        assert!(j.contains("\"throughput_rps\"") && j.contains("\"p99_ms\""), "{}", j);
        assert!(j.contains("\"queue_wait\"") && j.contains("\"compute\""), "{}", j);
    }

    #[test]
    fn queue_wait_compute_split_arithmetic() {
        let mut st = ServeStats::new();
        st.record_batch(4, 0, 4, 3, &[0.010, 0.020, 0.030, 0.040], &[0.001, 0.002, 0.003, 0.004], 0.006);
        st.record_batch(4, 0, 2, 1, &[0.050, 0.060], &[0.005, 0.006], 0.044);
        st.record_batch(1, 0, 1, 0, &[0.070], &[0.010], 0.060);
        let r = st.report(1.0, 0);
        // Run-wide queue wait over 7 samples: (1+2+3+4+5+6+10)/7 ms.
        assert!((r.queue_wait_mean_ms - 31.0 / 7.0).abs() < 1e-9, "{}", r.queue_wait_mean_ms);
        assert!((r.queue_wait_max_ms - 10.0).abs() < 1e-9);
        // Compute per batch: 6, 44, 60 ms → mean 110/3.
        assert!((r.compute_mean_ms - 110.0 / 3.0).abs() < 1e-9, "{}", r.compute_mean_ms);
        assert!((r.compute_max_ms - 60.0).abs() < 1e-9);
        // Per-bucket splits parallel the fill histogram ordering (b1, b4).
        assert_eq!(r.bucket_stages.len(), 2);
        assert_eq!(r.bucket_stages[0].0, 1);
        assert!((r.bucket_stages[0].1 - 10.0).abs() < 1e-9);
        assert!((r.bucket_stages[0].2 - 60.0).abs() < 1e-9);
        assert_eq!(r.bucket_stages[1].0, 4);
        assert!((r.bucket_stages[1].1 - 21.0 / 6.0).abs() < 1e-9, "{}", r.bucket_stages[1].1);
        assert!((r.bucket_stages[1].2 - 25.0).abs() < 1e-9);
        // And the render mentions the split.
        assert!(r.render().contains("stage split"), "{}", r.render());
    }

    #[test]
    fn nan_latency_sample_does_not_panic() {
        let mut st = ServeStats::new();
        // One corrupt (NaN) latency among three good ones: the old
        // partial_cmp().unwrap() sort comparator panicked here.
        st.record_batch(4, 0, 4, 0, &[0.010, 0.020, f64::NAN, 0.030], &[0.001; 4], 0.005);
        let r = st.report(1.0, 0);
        assert_eq!(r.requests, 4);
        // NaN sorts last under total_cmp, so the median stays finite.
        assert!(r.p50_ms.is_finite(), "{}", r.p50_ms);
    }

    #[test]
    fn len_bucket_split_tracks_sequence_batches() {
        let mut st = ServeStats::new();
        // Two length-8 batches and one length-2 batch; a fixed-shape
        // batch (sentinel 0) must not pollute the split.
        st.record_batch(4, 8, 4, 0, &[0.01; 4], &[0.001; 4], 0.008);
        st.record_batch(2, 8, 2, 0, &[0.01; 2], &[0.001; 2], 0.004);
        st.record_batch(4, 2, 3, 0, &[0.01; 3], &[0.001; 3], 0.002);
        st.record_batch(1, 0, 1, 0, &[0.01], &[0.001], 0.001);
        let r = st.report(1.0, 0);
        assert_eq!(r.len_buckets.len(), 2, "two length buckets, sentinel skipped");
        let (lb, batches, requests, cp) = r.len_buckets[0];
        assert_eq!((lb, batches, requests), (2, 1, 3));
        assert!((cp - 2.0).abs() < 1e-9, "{}", cp);
        let (lb, batches, requests, cp) = r.len_buckets[1];
        assert_eq!((lb, batches, requests), (8, 2, 6));
        assert!((cp - 6.0).abs() < 1e-9, "{}", cp);
        // The JSON row carries per-entry "len_bucket" keys (the CI smoke
        // greps for them) and the render mentions the split.
        let j = r.to_json().to_string_compact();
        assert_eq!(j.matches("\"len_bucket\"").count(), 2, "{}", j);
        assert!(r.render().contains("length-bucket split"), "{}", r.render());
        // Fixed-shape-only runs keep the split empty.
        let mut fixed = ServeStats::new();
        fixed.record_batch(2, 0, 2, 0, &[0.01; 2], &[0.001; 2], 0.001);
        assert!(fixed.report(1.0, 0).len_buckets.is_empty());
    }

    #[test]
    fn empty_run_reports_zeros() {
        let r = ServeStats::new().report(0.5, 0);
        assert_eq!(r.requests, 0);
        assert_eq!(r.reloads, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.p99_ms, 0.0);
        assert_eq!(r.queue_depth_max, 0.0);
        assert!(r.batch_fill.is_empty());
    }
}
