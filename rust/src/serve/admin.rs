//! Admin plane: a Unix-domain-socket control endpoint over a running
//! server (`serve --admin-sock <path>`).
//!
//! The ROADMAP's push-style answer to poll-only `--watch-model`: instead
//! of a thread watching an artifact file's mtime, an operator (or CI)
//! connects to the socket and *tells* the server what to do. The protocol
//! is deliberately tiny — one JSON object per line in, one JSON object
//! per line out:
//!
//! ```text
//! {"cmd":"stats"}                  → {"ok":true,"stats":{...ServeReport...}}
//! {"cmd":"trace"}                  → {"ok":true,"trace":{"traceEvents":[...]}}
//! {"cmd":"reload","path":"m.json"} → {"ok":true,"reloads":N}
//! {"cmd":"drain"}                  → {"ok":true,"stats":{...final report...}}
//! {"cmd":"health"}                 → {"ok":true,"health":{"state":"ready",...}}
//! {"cmd":"metrics"}                → {"ok":true,"metrics":"<Prometheus text>"}
//! anything else                    → {"ok":false,"error":"..."}
//! ```
//!
//! `stats` snapshots the live [`ServeReport`]; `trace` drains the span
//! tracer's rings into a Chrome trace-event document (error when no
//! tracer is installed); `reload` loads a [`ModelArtifact`] from a path
//! visible to the *server* process and hot-swaps it atomically (in-flight
//! batches finish on the generation they pinned — same contract as
//! `Server::reload`); `drain` stops intake, waits until every accepted
//! request is answered, and returns the final report; `health` evaluates
//! the installed health monitor ([`crate::telemetry::health`]) — the
//! same derivation the watchdog logs; `metrics` renders everything in
//! Prometheus text exposition format (one JSON-escaped string — a
//! scraper splits it back on `\n`).
//!
//! Each connection gets its own serving thread: a blocking `drain` on
//! one connection must not wedge a concurrent `health` poll — that is
//! precisely the window where an operator wants liveness answered. The
//! accept and read loops poll with a short sleep so
//! [`AdminServer::stop`] (and `Drop`) can always reclaim every thread
//! and unlink the socket file.

use crate::modelio::ModelArtifact;
use crate::serve::batcher::AdminHandle;
use crate::serve::metrics::ServeReport;
use crate::telemetry::health;
use crate::telemetry::trace;
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the accept/read loops sleep between stop-flag checks.
const POLL: Duration = Duration::from_millis(20);

/// A running admin endpoint; unlinks its socket file and joins its
/// thread on [`AdminServer::stop`] or drop.
pub struct AdminServer {
    path: PathBuf,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Bind `path` and start serving commands against `handle`. A stale
    /// socket file at `path` (e.g. from a killed process) is replaced.
    pub fn start(path: impl AsRef<Path>, handle: AdminHandle) -> Result<AdminServer> {
        let path = path.as_ref().to_path_buf();
        if path.exists() {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing stale admin socket {}", path.display()))?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding admin socket {}", path.display()))?;
        listener
            .set_nonblocking(true)
            .context("setting admin socket non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // One thread per connection: a blocking drain on
                        // one client must not wedge another's health
                        // poll. Errors on one connection (client hung up
                        // mid-line) must not take the admin plane down.
                        let handle = handle.clone();
                        let stop = Arc::clone(&stop2);
                        conns.push(std::thread::spawn(move || {
                            let _ = serve_conn(stream, &handle, &stop);
                        }));
                        conns.retain(|c| !c.is_finished());
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
                    Err(_) => break,
                }
            }
            // Connection threads see the same stop flag on their next
            // read timeout, so this join is bounded by POLL (plus any
            // still-blocking drain, which stop deliberately waits out).
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(AdminServer { path, stop, thread: Some(thread) })
    }

    /// The socket path this endpoint is bound to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stop accepting, join the serving thread, unlink the socket file.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One connection: read newline-delimited commands until EOF (or server
/// stop), answering each with one JSON line.
fn serve_conn(stream: UnixStream, handle: &AdminHandle, stop: &AtomicBool) -> std::io::Result<()> {
    stream.set_read_timeout(Some(POLL))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                if !line.ends_with('\n') {
                    // EOF mid-line: still answer what we got.
                }
                let reply = handle_command(line.trim(), handle);
                writer.write_all(reply.to_string_compact().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                line.clear();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

fn err_reply(msg: impl Into<String>) -> Json {
    obj([("ok", false.into()), ("error", Json::Str(msg.into()))])
}

fn stats_reply(report: &ServeReport) -> Json {
    obj([("ok", true.into()), ("stats", report.to_json())])
}

/// Execute one protocol line. Pure request→reply; never panics on
/// malformed input (the admin plane must survive a fat-fingered client).
pub fn handle_command(line: &str, handle: &AdminHandle) -> Json {
    if line.is_empty() {
        return err_reply("empty command");
    }
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_reply(format!("bad json: {}", e)),
    };
    let cmd = match req.get("cmd").and_then(|c| c.as_str()) {
        Some(c) => c,
        None => return err_reply("missing \"cmd\""),
    };
    match cmd {
        "stats" => stats_reply(&handle.stats()),
        "trace" => match trace::current() {
            Some(t) => obj([("ok", true.into()), ("trace", t.drain().to_chrome())]),
            None => err_reply("no tracer installed (serve --trace-out enables it)"),
        },
        "reload" => {
            let path = match req.get("path").and_then(|p| p.as_str()) {
                Some(p) => p,
                None => return err_reply("reload needs a \"path\""),
            };
            let artifact = match ModelArtifact::load(path) {
                Ok(a) => a,
                Err(e) => return reload_failure(format!("loading {}: {}", path, e)),
            };
            match handle.reload(&artifact) {
                Ok(()) => obj([
                    ("ok", true.into()),
                    ("reloads", (handle.reload_count() as usize).into()),
                ]),
                Err(e) => reload_failure(format!("reload rejected: {}", e)),
            }
        }
        "drain" => stats_reply(&handle.drain()),
        "health" => match health::current() {
            Some(h) => obj([("ok", true.into()), ("health", h.evaluate().to_json())]),
            None => err_reply("no health monitor installed (serve --admin-sock enables it)"),
        },
        "metrics" => obj([("ok", true.into()), ("metrics", Json::Str(handle.prometheus()))]),
        other => err_reply(format!("unknown cmd {:?}", other)),
    }
}

/// A failed reload is both an error reply *and* a health signal: the
/// monitor keeps the server Degraded for its failure window so a
/// watching operator sees that an artifact push went wrong.
fn reload_failure(msg: String) -> Json {
    if let Some(h) = health::current() {
        h.reload_failed();
    }
    err_reply(msg)
}

/// One-shot client: connect to `sock`, send `line`, return the reply
/// line. What `admin --sock <path> <cmd>` (and ci.sh) drive.
pub fn send_command(sock: impl AsRef<Path>, line: &str) -> Result<String> {
    let sock = sock.as_ref();
    let mut stream = UnixStream::connect(sock)
        .with_context(|| format!("connecting to admin socket {}", sock.display()))?;
    stream.write_all(line.as_bytes()).context("sending admin command")?;
    stream.write_all(b"\n").context("sending admin command")?;
    stream.flush().context("sending admin command")?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).context("reading admin reply")?;
    Ok(reply.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::Model;
    use crate::modelio::{Arch, TrainMeta};
    use crate::serve::batcher::{ServeOpts, Server};
    use crate::serve::model::InferenceModel;
    use crate::util::rng::Rng;
    use std::sync::atomic::{AtomicU64, Ordering as AOrd};

    static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

    /// A unique, short socket path (sun_path is ~108 bytes — stay short).
    fn sock_path(tag: &str) -> PathBuf {
        let n = SOCK_SEQ.fetch_add(1, AOrd::Relaxed);
        std::env::temp_dir().join(format!("adm-{}-{}-{}.sock", std::process::id(), tag, n))
    }

    fn mlp_server() -> (Server, std::sync::mpsc::Receiver<crate::serve::batcher::Response>) {
        let model = InferenceModel::new_mlp(&[10, 12, 4], 4, 1, false, &mut Rng::new(5));
        Server::start(model, ServeOpts { max_batch: 4, workers: 2, ..ServeOpts::default() })
    }

    #[test]
    fn stats_round_trip_over_a_real_socket() {
        let (server, rx) = mlp_server();
        let admin = AdminServer::start(sock_path("stats"), server.admin_handle()).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        // The worker pool is asynchronous: poll until all 10 are served.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let served = loop {
            let reply = send_command(admin.path(), "{\"cmd\":\"stats\"}").unwrap();
            let v = Json::parse(&reply).expect("stats reply is valid JSON");
            assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
            let n = v
                .get("stats")
                .and_then(|s| s.get("requests"))
                .and_then(|r| r.as_f64())
                .unwrap();
            if n >= 10.0 || std::time::Instant::now() > deadline {
                break n;
            }
            std::thread::sleep(Duration::from_millis(10));
        };
        assert_eq!(served, 10.0, "live stats see every served request");
        admin.stop();
        let report = server.shutdown();
        assert_eq!(report.requests, 10);
        assert_eq!(rx.iter().count(), 10);
    }

    #[test]
    fn reload_via_socket_bumps_the_visible_count() {
        let (server, rx) = mlp_server();
        let admin = AdminServer::start(sock_path("reload"), server.admin_handle()).unwrap();
        // Donor artifact on disk, as the protocol requires.
        let donor = crate::coordinator::trainer::MlpModel::new(
            &[10usize, 12, 4],
            4,
            1,
            &mut Rng::new(99),
        );
        let art = ModelArtifact::new(
            Arch::Mlp { sizes: vec![10, 12, 4] },
            TrainMeta::fresh(99),
            donor.export_weights(),
        );
        let art_path = std::env::temp_dir()
            .join(format!("adm-art-{}.json", std::process::id()));
        art.save(&art_path).unwrap();

        let cmd = format!("{{\"cmd\":\"reload\",\"path\":\"{}\"}}", art_path.display());
        let reply = Json::parse(&send_command(admin.path(), &cmd).unwrap()).unwrap();
        assert_eq!(reply.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(reply.get("reloads").and_then(|r| r.as_f64()), Some(1.0));
        // The count is visible in a subsequent stats reply — the CI
        // round-trip contract.
        let stats = Json::parse(&send_command(admin.path(), "{\"cmd\":\"stats\"}").unwrap()).unwrap();
        assert_eq!(
            stats.get("stats").and_then(|s| s.get("reloads")).and_then(|r| r.as_f64()),
            Some(1.0)
        );
        std::fs::remove_file(&art_path).ok();
        admin.stop();
        drop(server.shutdown());
        drop(rx);
    }

    #[test]
    fn drain_answers_everything_and_bad_commands_do_not_kill_the_plane() {
        let (server, rx) = mlp_server();
        let admin = AdminServer::start(sock_path("drain"), server.admin_handle()).unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..25 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        // Garbage first: the plane answers with ok:false and keeps going.
        let bad = Json::parse(&send_command(admin.path(), "not json").unwrap()).unwrap();
        assert_eq!(bad.get("ok").and_then(|b| b.as_bool()), Some(false));
        let bad2 = Json::parse(&send_command(admin.path(), "{\"cmd\":\"nope\"}").unwrap()).unwrap();
        assert_eq!(bad2.get("ok").and_then(|b| b.as_bool()), Some(false));
        // Drain: blocks until all 25 are answered, then reports.
        let reply = Json::parse(&send_command(admin.path(), "{\"cmd\":\"drain\"}").unwrap()).unwrap();
        assert_eq!(reply.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert_eq!(
            reply.get("stats").and_then(|s| s.get("requests")).and_then(|r| r.as_f64()),
            Some(25.0)
        );
        admin.stop();
        let report = server.shutdown();
        assert_eq!(report.requests, 25);
        assert_eq!(rx.iter().count(), 25, "drain loses no responses");
    }

    #[test]
    fn health_and_metrics_commands_round_trip() {
        let _g = crate::telemetry::test_lock();
        use crate::serve::slo::SloSpec;
        use crate::telemetry::health::HealthThresholds;
        health::uninstall();
        let model = InferenceModel::new_mlp(&[10, 12, 4], 4, 1, false, &mut Rng::new(5));
        // No monitor installed yet: `health` is an error, `metrics` still
        // renders the serve families.
        let (server, rx) = Server::start(
            model,
            ServeOpts {
                max_batch: 4,
                workers: 2,
                slo: Some(SloSpec::default()),
                health: true,
                ..ServeOpts::default()
            },
        );
        let admin = AdminServer::start(sock_path("health"), server.admin_handle()).unwrap();
        let off = Json::parse(&send_command(admin.path(), "{\"cmd\":\"health\"}").unwrap()).unwrap();
        assert_eq!(off.get("ok").and_then(|b| b.as_bool()), Some(false));
        let mut rng = Rng::new(17);
        for _ in 0..8 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        let m = Json::parse(&send_command(admin.path(), "{\"cmd\":\"metrics\"}").unwrap()).unwrap();
        assert_eq!(m.get("ok").and_then(|b| b.as_bool()), Some(true));
        let text = m.get("metrics").and_then(|t| t.as_str()).unwrap().to_string();
        assert!(text.contains("# TYPE brgemm_serve_queue_depth gauge"), "{}", text);
        assert!(text.contains("brgemm_slo_attainment"), "{}", text);
        // With a monitor installed the reply carries the derived state
        // (this server registered no heartbeats into it — it started
        // before the install — so the monitor reports Starting).
        health::install(HealthThresholds::default());
        let on = Json::parse(&send_command(admin.path(), "{\"cmd\":\"health\"}").unwrap()).unwrap();
        assert_eq!(on.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(on.get("health").and_then(|h| h.get("state")).and_then(|s| s.as_str()).is_some());
        // A failed reload feeds the monitor: state degrades with a
        // reload-failure reason.
        let bad = send_command(admin.path(), "{\"cmd\":\"reload\",\"path\":\"/no/such.json\"}")
            .unwrap();
        assert_eq!(Json::parse(&bad).unwrap().get("ok").and_then(|b| b.as_bool()), Some(false));
        let snap = crate::telemetry::health::current().unwrap().evaluate();
        assert_eq!(snap.reload_failures, 1);
        health::uninstall();
        admin.stop();
        drop(server.shutdown());
        drop(rx);
    }

    #[test]
    fn concurrent_stats_survive_a_racing_drain_and_reload() {
        // Satellite contract: `stats` hammering the socket while another
        // client drains (and a third reloads) must never wedge, corrupt
        // a reply line, or drop a response.
        let (server, rx) = mlp_server();
        let admin = AdminServer::start(sock_path("conc"), server.admin_handle()).unwrap();
        let mut rng = Rng::new(13);
        for _ in 0..300 {
            server.submit(rng.vec_f32(10, -1.0, 1.0));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let pollers: Vec<_> = (0..3)
            .map(|_| {
                let path = admin.path().to_path_buf();
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut replies = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let reply = send_command(&path, "{\"cmd\":\"stats\"}").unwrap();
                        let v = Json::parse(&reply).expect("reply stays one valid JSON line");
                        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
                        replies += 1;
                    }
                    replies
                })
            })
            .collect();
        // A reload races the pollers mid-drain window.
        let donor = crate::coordinator::trainer::MlpModel::new(
            &[10usize, 12, 4],
            4,
            1,
            &mut Rng::new(99),
        );
        let art = ModelArtifact::new(
            Arch::Mlp { sizes: vec![10, 12, 4] },
            TrainMeta::fresh(99),
            donor.export_weights(),
        );
        let art_path = std::env::temp_dir().join(format!("adm-conc-{}.json", std::process::id()));
        art.save(&art_path).unwrap();
        let cmd = format!("{{\"cmd\":\"reload\",\"path\":\"{}\"}}", art_path.display());
        let reload_reply = Json::parse(&send_command(admin.path(), &cmd).unwrap()).unwrap();
        assert_eq!(reload_reply.get("ok").and_then(|b| b.as_bool()), Some(true));
        // Drain on this connection while the pollers keep asking: with a
        // thread per connection the polls answer throughout the drain.
        let drained =
            Json::parse(&send_command(admin.path(), "{\"cmd\":\"drain\"}").unwrap()).unwrap();
        assert_eq!(
            drained.get("stats").and_then(|s| s.get("requests")).and_then(|r| r.as_f64()),
            Some(300.0)
        );
        stop.store(true, Ordering::Relaxed);
        for p in pollers {
            let n = p.join().expect("stats poller never wedges or panics");
            assert!(n > 0, "poller answered at least once during the race");
        }
        std::fs::remove_file(&art_path).ok();
        admin.stop();
        let report = server.shutdown();
        assert_eq!(report.requests, 300);
        assert_eq!(rx.iter().count(), 300, "no response dropped across the race");
    }

    #[test]
    fn trace_command_requires_an_installed_tracer() {
        let _g = crate::telemetry::test_lock();
        trace::uninstall();
        let (server, rx) = mlp_server();
        let admin = AdminServer::start(sock_path("trace"), server.admin_handle()).unwrap();
        let off = Json::parse(&send_command(admin.path(), "{\"cmd\":\"trace\"}").unwrap()).unwrap();
        assert_eq!(off.get("ok").and_then(|b| b.as_bool()), Some(false));
        trace::install(1, 64);
        let on = Json::parse(&send_command(admin.path(), "{\"cmd\":\"trace\"}").unwrap()).unwrap();
        assert_eq!(on.get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(on.get("trace").and_then(|t| t.get("traceEvents")).is_some());
        trace::uninstall();
        admin.stop();
        drop(server.shutdown());
        drop(rx);
    }
}
